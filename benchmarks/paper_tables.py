"""One benchmark per paper table/figure (DESIGN.md §10 index).

Each function prints ``name,us_per_call,derived`` CSV rows and returns a
dict for EXPERIMENTS.md.  All results come from REAL small-model training in
the event-driven async simulator (virtual wall-clock from the heterogeneous
LinkTimeModel) — the same protocol the paper measures, at laptop scale.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import policy
from repro.core.nettime import LinkTimeModel, Topology, homogeneous_times
from repro.data.partition import non_iid_partition, size_skewed_partition, uniform_partition
from repro.data.synthetic import train_eval_split
from repro.train.simulator import SimConfig, simulate

ALGOS = ("netmax", "adpsgd", "allreduce", "prague")


def _setup(M=8, n=4000, seed=0, margin=0.5):
    # margin 0.5: classes overlap so accuracy saturates ~85-95% (paper-like),
    # not 100% — accuracy-parity tables need headroom to differ.
    topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    x, y, ex, ey = train_eval_split(n, 1000, 32, 10, seed=seed, margin=margin)
    parts = uniform_partition(len(y), M, seed=seed)
    return topo, x, y, parts, ex, ey


def _sim(algo, topo, x, y, parts, ex, ey, *, hetero=True, events=4000, M=8, **kw):
    link = LinkTimeModel(
        topo,
        jitter=0.02,
        seed=5,
        slow_interval=120.0 if hetero else 1e18,
        slowdown_range=(2.0, 100.0) if hetero else (1.0, 1.0),
    )
    if not hetero:
        link.base_times = {k: 0.02 for k in link.base_times}
    cfg = SimConfig(algorithm=algo, n_workers=M, total_events=events, lr=0.01,
                    monitor_period=10.0, seed=0, **kw)
    return simulate(cfg, link, x, y, parts, ex, ey, record_every=100)


def bench_epoch_time(hetero=True):
    """Fig. 5 (hetero) / Fig. 6 (homog): per-epoch compute vs comm cost."""
    topo, x, y, parts, ex, ey = _setup()
    rows = {}
    for algo in ALGOS:
        t0 = time.time()
        res = _sim(algo, topo, x, y, parts, ex, ey, hetero=hetero, events=2000)
        events_per_epoch = len(y) / 64  # batch 64
        epochs = res.events[-1] / events_per_epoch
        epoch_t = res.times[-1] / max(epochs, 1e-9)
        comm_frac = res.comm_time / max(res.comm_time + res.compute_time, 1e-9)
        rows[algo] = dict(
            epoch_time_s=epoch_t,
            comm_fraction=comm_frac,
            us_per_call=(time.time() - t0) * 1e6,
        )
        print(f"epoch_time[{'het' if hetero else 'hom'}]/{algo},"
              f"{rows[algo]['us_per_call']:.0f},{epoch_t:.3f}s_comm{comm_frac:.2f}")
    return rows


def bench_ablation_fig7():
    """Fig. 7: serial/parallel execution x uniform/adaptive probabilities.

    Reported as time-to-target-loss: under the Eq.-10 equalization the
    adaptive policy may trade raw epoch time for convergence rate, so the
    meaningful Fig.-7 metric here is time to reach the common loss target
    (the paper's protocols differ mainly through their epoch times; ours
    expose the k*t_bar product directly)."""
    topo, x, y, parts, ex, ey = _setup()
    settings = {
        "serial+uniform": dict(serial_compute=True, uniform_policy=True),
        "parallel+uniform": dict(serial_compute=False, uniform_policy=True),
        "serial+adaptive": dict(serial_compute=True, uniform_policy=False),
        "parallel+adaptive": dict(serial_compute=False, uniform_policy=False),
    }
    runs = {}
    for name, kw in settings.items():
        t0 = time.time()
        runs[name] = (_sim("netmax", topo, x, y, parts, ex, ey, events=3000, **kw),
                      (time.time() - t0) * 1e6)
    target = max(r.losses[-1] for r, _ in runs.values()) * 1.2
    rows = {}
    for name, (res, us) in runs.items():
        events_per_epoch = len(y) / 64
        epoch_t = res.times[-1] / (res.events[-1] / events_per_epoch)
        ttl = res.time_to_loss(target)
        rows[name] = dict(epoch_time_s=epoch_t, time_to_loss=ttl, us_per_call=us)
        print(f"ablation_fig7/{name},{us:.0f},ttl={ttl:.2f}s_epoch={epoch_t:.3f}s")
    return rows


def bench_convergence(events=5000):
    """Fig. 8 + headline speedups: time-to-target-loss, hetero network."""
    topo, x, y, parts, ex, ey = _setup()
    res = {a: _sim(a, topo, x, y, parts, ex, ey, events=events) for a in ALGOS}
    target = max(r.losses[-1] for r in res.values()) * 1.1
    t_nm = res["netmax"].time_to_loss(target)
    rows = {}
    for a in ALGOS:
        t = res[a].time_to_loss(target)
        rows[a] = dict(
            time_to_loss=t,
            speedup_of_netmax=t / t_nm if np.isfinite(t) else float("inf"),
            final_loss=res[a].losses[-1],
            curve=(res[a].times, res[a].losses),
        )
        print(f"convergence/{a},{t*1e6:.0f},netmax_speedup={rows[a]['speedup_of_netmax']:.2f}x")
    return rows


def bench_convergence_homogeneous(events=4000):
    """Fig. 9: homogeneous network — NetMax ~ AD-PSGD."""
    topo, x, y, parts, ex, ey = _setup()
    res = {a: _sim(a, topo, x, y, parts, ex, ey, hetero=False, events=events)
           for a in ("netmax", "adpsgd")}
    target = max(r.losses[-1] for r in res.values()) * 1.1
    rows = {a: dict(time_to_loss=r.time_to_loss(target)) for a, r in res.items()}
    ratio = rows["netmax"]["time_to_loss"] / max(rows["adpsgd"]["time_to_loss"], 1e-9)
    print(f"convergence_hom/netmax_vs_adpsgd,{ratio*1e6:.0f},ratio={ratio:.2f}")
    rows["ratio"] = ratio
    return rows


def bench_scalability(events=3000):
    """Fig. 10/11: speedup vs #workers (baseline: allreduce @ 4 workers)."""
    rows = {}
    base_time = None
    for M in (4, 8, 16):
        topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
        x, y, ex, ey = train_eval_split(4000, 1000, 32, 10, seed=0)
        parts = uniform_partition(len(y), M, seed=0)
        for algo in ALGOS:
            res = _sim(algo, topo, x, y, parts, ex, ey, events=events, M=M)
            target = 0.55
            t = res.time_to_loss(target)
            if base_time is None and algo == "allreduce" and M == 4:
                base_time = t
            rows[(algo, M)] = t
    out = {}
    for (algo, M), t in rows.items():
        sp = base_time / t if np.isfinite(t) and t > 0 else 0.0
        out[f"{algo}_{M}"] = sp
        print(f"scalability/{algo}_M{M},0,speedup={sp:.2f}x")
    return out


def bench_accuracy_tables(events=4000):
    """Tables II/III: accuracy parity across approaches."""
    topo, x, y, parts, ex, ey = _setup()
    rows = {}
    for hetero in (True, False):
        for a in ALGOS:
            res = _sim(a, topo, x, y, parts, ex, ey, hetero=hetero, events=events)
            key = f"{'het' if hetero else 'hom'}_{a}"
            rows[key] = res.final_accuracy()
            print(f"accuracy/{key},0,{rows[key]:.4f}")
    return rows


def bench_noniid(events=4000):
    """§V-F / Fig. 18: non-IID label-skew partitioning."""
    M = 8
    topo, x, y, _, ex, ey = _setup(M)
    lost = [[i % 10, (i + 1) % 10, (i + 2) % 10] for i in range(M)]
    parts = non_iid_partition(y, M, lost)
    rows = {}
    for a in ALGOS:
        res = _sim(a, topo, x, y, parts, ex, ey, events=events)
        rows[a] = dict(final_loss=res.losses[-1], acc=res.final_accuracy(),
                       time=res.times[-1])
        print(f"noniid/{a},0,loss={res.losses[-1]:.3f}_acc={res.final_accuracy():.3f}")
    return rows


def bench_nonuniform_sizes(events=3000):
    """§V-F: size-skewed shards <2,1,2,1> on half the workers."""
    M = 8
    topo, x, y, _, ex, ey = _setup(M)
    parts = size_skewed_partition(len(y), M, [1, 1, 1, 1, 2, 1, 2, 1], seed=0)
    res = _sim("netmax", topo, x, y, parts, ex, ey, events=events)
    print(f"nonuniform/netmax,0,loss={res.losses[-1]:.3f}")
    return dict(final_loss=res.losses[-1], acc=res.final_accuracy())


def bench_ps_baseline(events=4000):
    """Fig. 14: parameter-server baselines (sync + async)."""
    topo, x, y, parts, ex, ey = _setup()
    rows = {}
    for a in ("netmax", "ps-sync", "ps-async", "allreduce"):
        res = _sim(a, topo, x, y, parts, ex, ey, events=events)
        target = 0.55
        rows[a] = dict(time_to_loss=res.time_to_loss(target), loss=res.losses[-1])
        print(f"ps_baseline/{a},0,ttl={rows[a]['time_to_loss']:.1f}s")
    return rows


def bench_monitor_extension(events=4000):
    """Fig. 15: AD-PSGD retrofitted with the Network Monitor."""
    topo, x, y, parts, ex, ey = _setup()
    rows = {}
    for a in ("adpsgd", "adpsgd+mon", "netmax"):
        res = _sim(a, topo, x, y, parts, ex, ey, events=events)
        target = 0.55
        rows[a] = dict(time_to_loss=res.time_to_loss(target), loss=res.losses[-1])
        print(f"monitor_ext/{a},0,ttl={rows[a]['time_to_loss']:.1f}s")
    return rows


def bench_policy_generation():
    """Alg. 3 runtime + quality vs M (Monitor control-plane cost)."""
    rows = {}
    for M in (4, 8, 16, 32):
        T = homogeneous_times(M, 0.02)
        T[0, 1] = T[1, 0] = 0.4
        t0 = time.time()
        res = policy.generate_policy_matrix(0.1, K=8, R=8, T=T)
        dt = (time.time() - t0) * 1e6
        rows[M] = dict(us=dt, lambda2=res.lambda2, Tconv=res.T_convergence)
        print(f"policy_gen/M{M},{dt:.0f},lam2={res.lambda2:.4f}")
    return rows
