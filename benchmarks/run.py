"""Benchmark harness: one function per paper table/figure + roofline driver.

Default run = the paper-reproduction suite (simulator-based, real small-model
training) + kernel microbenches + policy-generation cost.  Dry-run/roofline
cells are produced by ``python -m repro.launch.dryrun --all`` (hours of XLA
compiles) and read back here from artifacts/ when present.

Prints ``name,us_per_call,derived`` CSV per the harness contract.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))


def bench_kernels():
    """Microbench the three Pallas kernels (interpret) vs their oracles."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.gossip_mix import gossip_mix
    from repro.kernels.rwkv_scan import rwkv_scan

    rows = {}
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    r = jax.random.normal(ks[0], (1, 128, 2, 32)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 128, 2, 32)) + 2.0)
    u = jax.random.normal(ks[4], (2, 32)) * 0.1
    x = jax.random.normal(ks[0], (65536,))
    cases = (
        ("flash_attention_ref", lambda: ref.reference_attention(q, k, v)),
        ("flash_attention_interp", lambda: flash_attention(q, k, v, interpret=True)),
        ("rwkv_ref", lambda: ref.reference_rwkv(r, r, r, w, u)),
        ("rwkv_interp", lambda: rwkv_scan(r, r, r, w, u, chunk=32, interpret=True)),
        ("gossip_mix_ref", lambda: ref.reference_gossip_mix(x, x, x, 0.3)),
        ("gossip_mix_interp", lambda: gossip_mix(x, x, x, jnp.float32(0.3), interpret=True)),
    )
    for name, fn in cases:
        jax.block_until_ready(fn())  # warm/compile
        t0 = time.time()
        jax.block_until_ready(fn())
        rows[name] = (time.time() - t0) * 1e6
        print(f"{name},{rows[name]:.0f},interpret-mode-correctness-path")
    return rows


def bench_algorithms(events=1200):
    """One row per *registered* communication strategy (repro.algos).

    The algorithm list is enumerated from the registry, not hardcoded: any
    newly ``@register``'d strategy is benchmarked automatically.  Reports
    host us per simulated event plus the virtual-time/comm split.
    """
    from repro.algos import list_algorithms
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.train.simulator import SimConfig, simulate

    M = 8
    topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    x, y, ex, ey = train_eval_split(3000, 800, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    rows = {}
    for name in list_algorithms():
        link = LinkTimeModel(topo, jitter=0.02, seed=5, slow_interval=120.0)
        cfg = SimConfig(algorithm=name, n_workers=M, total_events=events,
                        lr=0.05, monitor_period=20.0, seed=0)
        t0 = time.time()
        res = simulate(cfg, link, x, y, parts, ex, ey, record_every=events)
        us_per_event = (time.time() - t0) * 1e6 / events
        rows[name] = dict(
            us_per_event=us_per_event,
            virtual_time_s=res.times[-1],
            comm_time_s=res.comm_time,
            final_loss=res.losses[-1],
            policy_updates=res.policy_updates,
        )
        print(f"algo/{name},{us_per_event:.0f},"
              f"vt={res.times[-1]:.1f}s_comm={res.comm_time:.1f}s_"
              f"loss={res.losses[-1]:.3f}")
    return rows


def bench_fleet_rows(sizes=(128, 1024, 4096)):
    """Fleet-scale rows for the simulator suite (ISSUE 7 tentpole).

    Batched engine only (the reference loop is the small-M ground truth,
    not a fleet tool), monitor-less adpsgd — the regime where host-side
    engine cost, not policy math, is the scaling story.  A from-t=0
    ClusterOutage plus a handful of degraded links keep the sparse
    per-segment link state (core/nettime) on the hot path of every draw.

    Events scale as ``max(4000, 3 * M)`` so each row measures steady-state
    per-event cost rather than one-time setup (stacked-replica init, CDF
    cache fills) — per-event cost is the metric the regression gate pins:
    ``cost_ratio_vs_base = us_per_event(base) / us_per_event(M)`` is a
    higher-is-better ratio row in scripts/check_bench.py, and the ISSUE 7
    acceptance wants it >= 0.5 at M=1024 (cost within 2x of M=128).

    Peak host memory comes from a separate tracemalloc run (tracemalloc
    hooks every allocation, so the timed run stays clean); link-state
    bytes compare ``LinkTimeModel.link_state_nbytes()`` against the dense
    equivalent (per-segment (M, M) dead bool + degrade float64).
    """
    import time as _time
    import tracemalloc

    import jax

    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.scenarios.timeline import ClusterOutage, LinkDegrade, Timeline
    from repro.train.simulator import SimConfig, simulate

    x, y, ex, ey = train_eval_split(4000, 800, 32, 10, seed=0)
    rows = {}
    base_us = None
    for M in sizes:
        # Drop compiled programs from earlier suites/sizes: at M=4096 the
        # accumulated executables and their buffers otherwise inflate the
        # timed run ~2x (memory pressure), making the row depend on what
        # ran before it.  The warm-up below rebuilds this size's programs.
        jax.clear_caches()
        topo = Topology.multi_cluster(M)
        parts = uniform_partition(len(y), M, seed=0)
        events = max(4000, 3 * M)
        timeline = Timeline(
            [ClusterOutage(topo.n_clusters - 1, 0.0, float("inf"))]
            + [LinkDegrade(0, m, 0.0, float("inf"), 10.0)
               for m in range(1, 4)]
        )

        def once():
            link = LinkTimeModel(topo, jitter=0.02, seed=5,
                                 scenario=timeline, dead_link_timeout=5.0)
            cfg = SimConfig(algorithm="adpsgd", n_workers=M,
                            total_events=events, lr=0.05, batch_size=16,
                            seed=0, engine="batched")
            t0 = _time.time()
            res = simulate(cfg, link, x, y, parts, ex, ey,
                           record_every=events)
            return res, link, _time.time() - t0

        once()  # warm-up: compile the cohort buckets for this M
        res, link, dt = once()
        tracemalloc.start()
        once()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()

        us = dt * 1e6 / events
        if base_us is None:
            base_us = us
        seg_count = len(link.compiled_scenario.segments)
        sparse_nbytes = link.link_state_nbytes()
        dense_nbytes = seg_count * M * M * 9  # dead bool + degrade f64
        rows[f"M={M}"] = dict(
            events=events,
            wall_s=round(dt, 4),
            us_per_event=round(us, 2),
            cost_ratio_vs_base=round(base_us / us, 4),
            host_peak_mb=round(peak / 1e6, 2),
            link_state_bytes=sparse_nbytes,
            link_state_dense_equiv_bytes=dense_nbytes,
            link_state_savings=round(dense_nbytes / max(1, sparse_nbytes), 1),
            dispatches=res.dispatches,
            failed_pulls=len(res.failed_pulls),
            final_loss=round(res.losses[-1], 4),
        )
        print(f"simengine/fleet/M={M},{us:.0f},"
              f"ratio={rows[f'M={M}']['cost_ratio_vs_base']}_"
              f"peak={rows[f'M={M}']['host_peak_mb']}MB_"
              f"links={sparse_nbytes}B_vs_{dense_nbytes}B")
    return {
        "engine": "batched",
        "algorithm": "adpsgd",
        "base_size": f"M={sizes[0]}",
        "events_rule": "max(4000, 3*M)",
        "results": rows,
    }


def bench_simulator_engines(sizes=(8, 32, 64, 128), events=2000,
                            out_path=None, fleet_sizes=(128, 1024, 4096),
                            algos=("netmax", "ps-async", "ps-sync",
                                   "allreduce", "prague")):
    """Reference vs batched engine throughput on the multi-cluster WAN
    topology (paper §V wide-area setting) for one representative of each
    strategy family plus the full PS/collective baselines; writes
    BENCH_simulator.json.

    Each engine gets one full warm-up run (XLA compiles excluded — both
    engines keep per-process jit caches) before the timed run.  ISSUE 3
    acceptance: >= 4x batched-vs-reference for the PS/collective families
    at M=64, and >= 2x dispatch-count reduction from chain fusion
    (``dispatch_reduction`` = logical cohorts / actual device dispatches).
    """
    import time as _time

    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.train.simulator import SimConfig, simulate

    x, y, ex, ey = train_eval_split(4000, 800, 32, 10, seed=0)
    results = {}
    for algo in algos:
        results[algo] = {}
        for M in sizes:
            topo = Topology.multi_cluster(M)
            parts = uniform_partition(len(y), M, seed=0)

            def timed(engine):
                def once():
                    link = LinkTimeModel(topo, jitter=0.02, seed=5)
                    # Small per-worker batch = the regime the paper's async
                    # gossip targets (and where engine overhead, not GEMM
                    # time, dominates — the thing this suite compares).
                    cfg = SimConfig(algorithm=algo, n_workers=M,
                                    total_events=events, lr=0.05,
                                    batch_size=16, monitor_period=20.0,
                                    seed=0, engine=engine)
                    t0 = _time.time()
                    res = simulate(cfg, link, x, y, parts, ex, ey,
                                   record_every=events)
                    return res, _time.time() - t0

                once()  # warm-up: compile every cohort bucket / event step
                res, dt = once()
                return dict(
                    wall_s=round(dt, 4),
                    events_per_s=round(events / dt, 1),
                    cohorts=res.cohorts,
                    dispatches=res.dispatches,
                    virtual_time_s=round(res.times[-1], 2),
                    final_loss=round(res.losses[-1], 4),
                )

            row = {e: timed(e) for e in ("reference", "batched")}
            row["speedup"] = round(
                row["reference"]["wall_s"] / row["batched"]["wall_s"], 2
            )
            bat = row["batched"]
            row["dispatch_reduction"] = round(
                bat["cohorts"] / max(1, bat["dispatches"]), 2
            )
            results[algo][f"M={M}"] = row
            print(f"simengine/{algo}/M={M},"
                  f"{bat['wall_s'] * 1e6 / events:.0f},"
                  f"speedup={row['speedup']}x_"
                  f"fuse={row['dispatch_reduction']}x_"
                  f"cohorts={bat['cohorts']}_"
                  f"dispatches={bat['dispatches']}_"
                  f"ref_evps={row['reference']['events_per_s']:.0f}_"
                  f"bat_evps={bat['events_per_s']:.0f}")

    out = {
        "suite": "simulator-engines",
        "algorithms": list(algos),
        "topology": "multi_cluster(workers_per_host=4, hosts_per_pod=2, "
                    "pods_per_cluster=2)",
        "total_events": events,
        "batch_size": 16,
        "results": results,
    }
    if fleet_sizes:
        out["fleet"] = bench_fleet_rows(tuple(fleet_sizes))
    path = Path(out_path) if out_path else ROOT / "BENCH_simulator.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return results


def bench_policy_solver(sizes=(16, 32, 64, 128), K=8, R=8, dense_cap=32,
                        out_path=None):
    """Algorithm-3 policy-generation cost across the LP solver stack
    (ISSUE 4 acceptance): revised simplex with warm-started (rho, t_bar)
    sweeps vs cold restarts vs the dense two-phase oracle, on full graphs
    and multi-cluster (sparse-connectivity) masks; writes BENCH_policy.json.

    The dense oracle builds an O(M^2) x O(M^2) tableau, so it is only run
    up to ``dense_cap`` workers — beyond that its cell records the reason
    instead of a number (at M=128 a full-graph tableau alone is ~6 GB and
    the pre-PR behaviour was an iteration-cap blowup into the uniform
    AD-PSGD fallback).
    """
    import time as _time

    import numpy as np

    from repro.core import policy
    from repro.core.nettime import Topology
    from repro.solver.lp import lp_method

    def hetero_T(M, seed=0):
        rng = np.random.default_rng(seed)
        T = rng.uniform(0.01, 0.05, size=(M, M))
        T = (T + T.T) / 2
        i, m = rng.choice(M, size=2, replace=False)
        T[i, m] = T[m, i] = T[i, m] * 10.0
        np.fill_diagonal(T, 0.0)
        return T

    def multi_cluster_instance(M, seed=0):
        """Tiered times from Topology.multi_cluster; connectivity = full
        mesh inside a cluster + gateway links (host-0 workers) across —
        the sparse regime where the live-edge variable set shrinks."""
        topo = Topology.multi_cluster(M)
        tier_t = {"intra_host": 0.005, "intra_pod": 0.02,
                  "inter_pod": 0.05, "inter_cluster": 0.4}
        rng = np.random.default_rng(seed)
        jit = rng.uniform(0.9, 1.1, size=(M, M))
        jit = (jit + jit.T) / 2
        T = np.zeros((M, M))
        d = np.zeros((M, M))
        cluster_size = max(1, M // max(1, topo.n_clusters))
        for i in range(M):
            for m in range(M):
                if i == m:
                    continue
                T[i, m] = tier_t[topo.tier(i, m)] * jit[i, m]
                same = topo.cluster_of(i) == topo.cluster_of(m)
                gateway = (i % cluster_size == 0) and (m % cluster_size == 0)
                if same or gateway:
                    d[i, m] = 1.0
        return T, d

    results = {}
    for topo_name in ("full", "multi_cluster"):
        results[topo_name] = {}
        for M in sizes:
            if topo_name == "full":
                T, d = hetero_T(M), None
            else:
                T, d = multi_cluster_instance(M)

            def timed(**kw):
                t0 = _time.time()
                res = policy.generate_policy_matrix(0.1, K=K, R=R, T=T, d=d, **kw)
                return res, _time.time() - t0

            warm_res, warm_s = timed()
            cold_res, cold_s = timed(warm_start=False)
            used_fallback = warm_res.n_lp_feasible == 0 and not any(
                np.isfinite(g[3]) for g in warm_res.grid
            )
            row = dict(
                warm_s=round(warm_s, 4),
                cold_s=round(cold_s, 4),
                pivots_warm=warm_res.n_pivots,
                pivots_cold=cold_res.n_pivots,
                warm_hit_rate=round(
                    warm_res.n_warm_used / max(1, warm_res.n_solves), 3
                ),
                lp_solves=warm_res.n_solves,
                lp_feasible=sum(1 for g in warm_res.grid if np.isfinite(g[3])),
                lp_grid=len(warm_res.grid),
                uniform_fallback=bool(used_fallback),
                T_convergence=round(float(warm_res.T_convergence), 4),
            )
            if M <= dense_cap:
                with lp_method("dense"):
                    dense_res, dense_s = timed()
                row["dense_s"] = round(dense_s, 4)
                row["speedup_vs_dense"] = round(dense_s / warm_s, 1)
                row["same_grid_point_as_dense"] = bool(
                    warm_res.rho == dense_res.rho
                    and warm_res.t_bar == dense_res.t_bar
                )
            else:
                row["dense_s"] = None
                row["dense_skipped"] = (
                    f"dense tableau is O(M^4) memory/time at M={M} "
                    f"(> dense_cap={dense_cap}); pre-PR this path hit the "
                    "iteration cap and fell back to the uniform policy"
                )
            results[topo_name][f"M={M}"] = row
            msg = (f"policy/{topo_name}/M={M},{warm_s * 1e6:.0f},"
                   f"warm={warm_s:.3f}s_cold={cold_s:.3f}s_"
                   f"pivots={row['pivots_warm']}v{row['pivots_cold']}_"
                   f"hit={row['warm_hit_rate']}")
            if row.get("dense_s") is not None:
                msg += f"_dense={row['dense_s']:.3f}s_x{row['speedup_vs_dense']}"
            print(msg)

    out = {
        "suite": "policy-solver",
        "K": K,
        "R": R,
        "sizes": list(sizes),
        "solver": "revised simplex (implicit bounds, warm-started dual "
                  "restarts) vs dense two-phase oracle",
        "results": results,
    }
    path = Path(out_path) if out_path else ROOT / "BENCH_policy.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return results


def bench_scenarios(M=32, small=False, out_path=None,
                    algos=("netmax", "adpsgd", "allreduce")):
    """Cluster-outage scenario sweep (ISSUE 5 acceptance): a whole cluster
    drops off the WAN mid-run; NetMax's Monitor must re-route (dead-cluster
    selection probability -> 0 within one refresh) while the non-adaptive
    baselines (AD-PSGD, Allreduce-SGD) stall on timeouts.  Writes
    BENCH_scenarios.json with per-algorithm time-to-recover and pre/during/
    post-outage throughput, plus a reference-vs-batched parity spot check
    on the same timeline.

    ``small`` is the CI smoke shape (fewer workers/events, same structure).
    """
    import time as _time

    import numpy as np

    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.scenarios import presets
    from repro.train.simulator import SimConfig, simulate

    if small:
        # Half-size clusters so M=16 still spans two WAN-separated clusters.
        M = min(M, 16)
        topo = Topology.multi_cluster(M, workers_per_host=4, hosts_per_pod=1,
                                      pods_per_cluster=2)
    else:
        topo = Topology.multi_cluster(M)
    assert topo.n_clusters >= 2, "outage scenario needs a WAN tier"
    cluster = np.array([topo.cluster_of(i) for i in range(M)])
    # Links the outage kills: WAN links touching the dead cluster (NOT all
    # cross-cluster links — at 3+ clusters a re-routed policy rightly keeps
    # mass on the healthy cluster pairs).
    dead_cluster = topo.n_clusters - 1
    touch = cluster == dead_cluster
    cross = (touch[:, None] | touch[None, :]) & (cluster[:, None] != cluster[None, :])
    t0, t1 = (5.0, 20.0) if small else (10.0, 60.0)
    timeout = 2.0 if small else 5.0
    monitor_period = 3.0 if small else 8.0
    horizon = t1 + (t1 - t0)  # post-outage window mirrors the outage
    timeline = presets.cluster_outage(topo.n_clusters - 1, t0, t1)

    x, y, ex, ey = train_eval_split(4000, 800, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)

    def run(algo, events, engine="auto", seed=0):
        link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=timeline,
                             dead_link_timeout=timeout)
        cfg = SimConfig(algorithm=algo, n_workers=M, total_events=events,
                        lr=0.05, batch_size=16, monitor_period=monitor_period,
                        seed=seed, engine=engine)
        wall0 = _time.time()
        res = simulate(cfg, link, x, y, parts, ex, ey,
                       record_every=max(50, events // 100))
        return res, _time.time() - wall0

    def rate(res, a, b):
        """Events per virtual second over [a, b] (interpolated records)."""
        b = min(b, res.times[-1])
        if b <= a:
            return None
        ea, eb = np.interp([a, b], res.times, res.events)
        return round(float((eb - ea) / (b - a)), 1)

    results = {}
    for algo in algos:
        # Adaptive event budget: grow until the virtual clock passes the
        # post-outage window (stalling baselines cover it in few events).
        events = 2000 if small else 4000
        while True:
            res, wall = run(algo, events)
            if res.times[-1] >= horizon or events >= (64000 if small else 256000):
                break
            events *= 2
        row = dict(
            events=events,
            wall_s=round(wall, 2),
            virtual_time_s=round(res.times[-1], 2),
            failed_pulls=len(res.failed_pulls),
            last_failure_t=round(res.failed_pulls[-1][0], 3)
            if res.failed_pulls else None,
            throughput_pre=rate(res, 0.0, t0),
            throughput_outage=rate(res, t0, t1),
            throughput_post=rate(res, t1, horizon),
            policy_refreshes=res.policy_updates,
            final_loss=round(res.losses[-1], 4),
        )
        # Monitor adaptivity: the first refresh at/after the outage whose
        # policy carries zero dead-cluster selection mass.
        reroute_t = None
        refreshes_to_reroute = 0
        for tq, _rho, P in res.policy_log:
            if tq >= t0:
                refreshes_to_reroute += 1
                if float(P[cross].sum()) <= 1e-12:
                    reroute_t = tq
                    break
        if res.policy_log:
            row["time_to_reroute_s"] = (
                round(reroute_t - t0, 3) if reroute_t is not None else None
            )
            row["refreshes_to_reroute"] = (
                refreshes_to_reroute if reroute_t is not None else None
            )
            row["dead_cluster_prob_after_reroute"] = (
                0.0 if reroute_t is not None else None
            )
            # Time-to-recover: the last timeout any worker pays during the
            # outage — after it, the policy routes fully around the dead
            # cluster (probation probes excluded by capping at reroute_t).
            stalls = [tf for tf, _, _ in res.failed_pulls
                      if tf <= (reroute_t or t1)]
            row["time_to_recover_s"] = (
                round(max(stalls) + timeout - t0, 3) if stalls else 0.0
            )
        results[algo] = row
        print(f"scenario/{algo}/M={M},{wall * 1e6 / events:.0f},"
              f"fails={row['failed_pulls']}_pre={row['throughput_pre']}_"
              f"out={row['throughput_outage']}_post={row['throughput_post']}_"
              f"reroute={row.get('time_to_reroute_s')}")

    # Parity spot check: the same timeline, both engines, exact host-side
    # equality (the full per-algorithm sweep lives in tests/test_engines.py).
    pM, pev = (8, 600) if small else (16, 1200)
    ref, _ = _bench_parity_run(pM, pev, timeout)
    bat, _ = _bench_parity_run(pM, pev, timeout, engine="batched")
    parity = dict(
        M=pM, events=pev,
        times_equal=bool(ref.times == bat.times),
        comm_equal=bool(ref.comm_time == bat.comm_time),
        failed_pulls_equal=bool(ref.failed_pulls == bat.failed_pulls),
        policies_equal=bool(
            len(ref.policy_log) == len(bat.policy_log)
            and all(a[0] == b[0] and a[1] == b[1] and np.array_equal(a[2], b[2])
                    for a, b in zip(ref.policy_log, bat.policy_log))
        ),
    )
    print(f"scenario/parity,0,{parity}")

    out = {
        "suite": "scenarios",
        "topology": f"multi_cluster(M={M})",
        "outage": {"cluster": int(topo.n_clusters - 1), "start": t0, "end": t1},
        "dead_link_timeout_s": timeout,
        "monitor_period_s": monitor_period,
        "small": bool(small),
        "results": results,
        "engine_parity": parity,
    }
    path = Path(out_path) if out_path else ROOT / "BENCH_scenarios.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return results


def _bench_parity_run(M, events, timeout, engine="reference"):
    import time as _time

    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.scenarios import presets
    from repro.train.simulator import SimConfig, simulate

    topo = Topology.multi_cluster(M, workers_per_host=2, hosts_per_pod=1,
                                  pods_per_cluster=2)  # clusters of 4
    timeline = presets.cluster_outage(1, 1.0, 4.0).add(
        *presets.worker_blip(M - 1, 2.0, 5.0).events
    )
    x, y, ex, ey = train_eval_split(1600, 400, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=timeline,
                         dead_link_timeout=timeout)
    cfg = SimConfig(algorithm="netmax", n_workers=M, total_events=events,
                    lr=0.05, monitor_period=2.0, seed=0, engine=engine)
    t0 = _time.time()
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=events // 4)
    return res, _time.time() - t0


def bench_trace(M=8, small=False, out_path=None,
                algos=("netmax", "adpsgd", "allreduce", "ps-async",
                       "netmax-topk")):
    """Trace round-trip suite (ISSUE 6 acceptance): simulate -> export ->
    ingest -> calibrate -> replay per algorithm, then what-if queries over
    the replayed baseline.  Writes BENCH_trace.json with per-algorithm
    replay wall-clock ratios and calibration residuals, plus the headline
    orderings — netmax < adpsgd < allreduce time-to-loss on the replayed
    runs, and the what-if sanity checks (a 4x WAN upgrade helps, switching
    adpsgd -> netmax helps more).

    ``small`` is the CI smoke shape: same topology, algorithms, and metric
    keys (so scripts/check_bench.py finds full overlap with the committed
    baseline), just fewer events.
    """
    import tempfile
    import time as _time

    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.train.simulator import SimConfig, simulate
    from repro.trace import (
        SwitchAlgorithm,
        UpgradeLink,
        WhatIf,
        calibrate,
        from_sim_result,
        load_trace,
        read_jsonl,
        replay_model,
        write_jsonl,
    )

    # The paper-tables hetero shape (benchmarks/paper_tables.py _sim):
    # single cluster, two pods, the roaming 2x-100x slow link.  That is
    # the published configuration where the headline ordering holds —
    # netmax < adpsgd < allreduce time-to-loss — and replay is exact for
    # all three strategies (sync rounds tap their per-link draws into
    # the trace).
    topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    events = 800 if small else 3000
    x, y, ex, ey = train_eval_split(4000, 800, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)

    def run(algo, link, ev=events):
        cfg = SimConfig(algorithm=algo, n_workers=M, total_events=ev,
                        lr=0.01, monitor_period=10.0, seed=0, trace=True)
        res = simulate(cfg, link, x, y, parts, ex, ey,
                       record_every=max(25, ev // 20))
        return res, cfg

    results, replays = {}, {}
    cal_adpsgd = trace_adpsgd = cfg_adpsgd = None
    for algo in algos:
        link = LinkTimeModel(topo, jitter=0.02, seed=5,
                             slowdown_range=(2.0, 100.0),
                             slow_interval=120.0)
        wall0 = _time.time()
        res, cfg = run(algo, link)
        # Round-trip through the on-disk format — the ratio below measures
        # the full export -> ingest -> calibrate -> replay chain.
        with tempfile.TemporaryDirectory() as td:
            p = Path(td) / "t.jsonl"
            write_jsonl(from_sim_result(res, cfg=cfg, link_model=link), p)
            trace = read_jsonl(p)
        cal = calibrate(trace)
        rep, _ = run(algo, replay_model(trace, cal))
        wall = _time.time() - wall0
        ratio = rep.times[-1] / res.times[-1]
        results[algo] = dict(
            events=events,
            wall_s=round(wall, 2),
            virtual_time_s=round(res.times[-1], 3),
            replay_wall_clock_ratio=round(ratio, 6),
            replay_accuracy=round(min(ratio, 1.0 / ratio), 6),
            replay_exact=bool(rep.trace_events == res.trace_events),
            calibration_residual=round(cal.residual, 6),
            calibration_accuracy=round(1.0 - cal.residual, 6),
            final_loss=round(rep.losses[-1], 4),
        )
        replays[algo] = rep
        if algo == "adpsgd":
            cal_adpsgd, trace_adpsgd, cfg_adpsgd = cal, trace, cfg
        print(f"trace/{algo}/M={M},{wall * 1e6 / events:.0f},"
              f"ratio={ratio:.4f}_exact={results[algo]['replay_exact']}_"
              f"resid={cal.residual:.4f}")

    # Headline ordering at a loss bar every replayed run reaches (the
    # paper-tables target: 1.1x the weakest final loss).  The ordering is
    # the paper's gossip-vs-collective story, so it stays pinned to the
    # original three algorithms — the ps-async / netmax-topk rows above
    # exist for their exact-replay ratios (ISSUE 7), not the ordering.
    core = tuple(a for a in ("netmax", "adpsgd", "allreduce") if a in algos)
    target = max(replays[a].losses[-1] for a in core) * 1.1
    ttl = {a: replays[a].time_to_loss(target) for a in core}
    summary = dict(
        target_loss=round(target, 4),
        time_to_loss_s={a: round(t, 3) for a, t in ttl.items()},
        netmax_speedup_vs_adpsgd=round(ttl["adpsgd"] / ttl["netmax"], 4),
        adpsgd_speedup_vs_allreduce=round(
            ttl["allreduce"] / ttl["adpsgd"], 4),
        ordering_ok=bool(ttl["netmax"] < ttl["adpsgd"] < ttl["allreduce"]),
    )

    # What-if sanity over the replayed adpsgd baseline: upgrading the
    # slowest-tier (inter-pod) link helps; switching the strategy helps
    # more.  The ordering target (deep in the run) is the meaningful bar:
    # the default 25%-depth target is crossed before netmax's first
    # Monitor refresh, where its uniform warm-up is event-for-event
    # identical to adpsgd.
    session = WhatIf(trace_adpsgd, cal_adpsgd, cfg_adpsgd,
                     (x, y, parts, ex, ey), target_loss=target,
                     record_every=max(25, events // 20))
    up = session.query(UpgradeLink(0, M // 2, speedup=4.0))
    sw = session.query(SwitchAlgorithm("netmax"))
    summary["whatif_upgrade_speedup"] = round(up.wall_clock_speedup, 4)
    summary["whatif_switch_ttl_speedup"] = round(sw.time_to_loss_speedup, 4)
    print(f"trace/whatif/M={M},0,up={up.wall_clock_speedup:.3f}_"
          f"switch={sw.time_to_loss_speedup:.3f}")

    # Calibration quality on the committed fixture (scenario + slow links +
    # timeouts: the adversarial shape, pinned portable across hardware).
    fix = calibrate(load_trace(ROOT / "tests" / "fixtures"
                               / "trace_hetero_M8.jsonl"))
    summary["fixture_calibration_accuracy"] = round(1.0 - fix.residual, 6)
    print(f"trace/fixture,0,resid={fix.residual:.4f}")
    print(f"trace/ordering,0,{summary['time_to_loss_s']}_"
          f"ok={summary['ordering_ok']}")

    out = {
        "suite": "trace",
        "topology": f"multi_cluster(M={M})",
        "events": events,
        "small": bool(small),
        "results": results,
        "summary": summary,
    }
    path = Path(out_path) if out_path else ROOT / "BENCH_trace.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return {"results": results, "summary": summary}


def bench_serve(sizes=(128, 256), serve_M=32, n_requests=600, K=8, R=8,
                small=False, out_path=None):
    """Policy-serving hot path (PR 8 tentpole): pricing/warm-sweep pivot
    economics at M >= 128, the M=256 full-graph wall target, PolicyServer
    latency under a jittered request stream, and the batched-sweep
    dispatch.  Writes BENCH_serve.json.

    Gated (hardware-portable ratios, scripts/check_bench.py):
      * ``pivot_reduction_vs_dantzig`` — pivots of the pre-PR-shaped
        baseline (Dantzig full pricing, cold restarts, via
        ``lp_pricing("dantzig")``) over the serving stack's warm auto
        sweep.  Deterministic; the ISSUE floor is >= 2x at M >= 128.
      * ``no_uniform_fallback`` — 1.0 iff the sweep solved real LPs (the
        pre-PR M=256 behaviour was an iteration-cap blowup into the
        uniform AD-PSGD policy).
      * ``cache_hit_rate`` / ``p99_is_hit`` — the served stream must be
        dominated by cache hits, including at the p99 latency.
      * ``same_grid_point_batched`` — the lockstep stacked sweep picks the
        identical (rho, t_bar) as the serial path.
      * ``same_grid_point_jax`` — the jitted device sweep (PR 10) picks
        the identical grid point as the numpy lockstep path.
      * ``all_answered`` — 1.0 iff every RPC request through the sharded
        service + admission stack got an answer (sheds count; errors and
        hangs do not).
    Wall-clock seconds — including requests/s and shed rate through the
    RPC front-end at 1 vs 4 shards, and the jax compile/warm sweep walls
    — are reported ungated (runner-dependent).

    ``small`` is the CI smoke shape: M=128 only, a smaller served graph,
    same metric keys so check_bench finds overlap with the committed
    baseline.
    """
    import time as _time

    import numpy as np

    from repro.core import policy
    from repro.serve import PolicyServer
    from repro.solver.lp import lp_pricing

    if small:
        sizes = tuple(s for s in sizes if s <= 128) or (128,)
        serve_M = min(serve_M, 16)
        n_requests = min(n_requests, 200)

    def hetero_T(M, seed=0):
        rng = np.random.default_rng(seed)
        T = rng.uniform(0.01, 0.05, size=(M, M))
        T = (T + T.T) / 2
        i, m = rng.choice(M, size=2, replace=False)
        T[i, m] = T[m, i] = T[i, m] * 10.0
        np.fill_diagonal(T, 0.0)
        return T

    # -- pricing: warm auto sweep vs the Dantzig-cold baseline ------------
    pricing_rows = {}
    for M in sizes:
        T = hetero_T(M)
        t0 = _time.time()
        warm1 = policy.generate_policy_matrix(0.1, K=K, R=R, T=T)
        first_s = _time.time() - t0
        t0 = _time.time()
        warm2 = policy.generate_policy_matrix(0.1, K=K, R=R, T=T,
                                              warm=warm1.basis)
        refresh_s = _time.time() - t0
        with lp_pricing("dantzig"):
            t0 = _time.time()
            cold = policy.generate_policy_matrix(0.1, K=K, R=R, T=T,
                                                 warm_start=False)
            dantzig_s = _time.time() - t0
        fallback = warm1.n_lp_feasible == 0 and not any(
            np.isfinite(g[3]) for g in warm1.grid
        )
        row = dict(
            warm_first_s=round(first_s, 4),
            warm_refresh_s=round(refresh_s, 4),
            dantzig_cold_s=round(dantzig_s, 4),
            pivots_warm=warm1.n_pivots,
            pivots_refresh=warm2.n_pivots,
            pivots_dantzig_cold=cold.n_pivots,
            pivot_reduction_vs_dantzig=round(
                cold.n_pivots / max(1, warm1.n_pivots), 2
            ),
            wall_reduction_vs_dantzig=round(dantzig_s / first_s, 2),
            warm_hit_rate=round(warm1.n_warm_used / max(1, warm1.n_solves), 3),
            no_uniform_fallback=0.0 if fallback else 1.0,
            same_grid_point_as_cold=bool(
                warm1.rho == cold.rho and warm1.t_bar == cold.t_bar
            ),
            T_convergence=round(float(warm1.T_convergence), 4),
        )
        pricing_rows[f"M={M}"] = row
        print(f"serve/pricing/M={M},{first_s * 1e6:.0f},"
              f"warm={first_s:.2f}s_refresh={refresh_s:.2f}s_"
              f"dantzig_cold={dantzig_s:.2f}s_"
              f"piv_red={row['pivot_reduction_vs_dantzig']}x_"
              f"wall_red={row['wall_reduction_vs_dantzig']}x_"
              f"fallback={fallback}")

    # -- served stream ----------------------------------------------------
    # Access pattern: the Monitor publishes an EMA snapshot per epoch; a
    # fleet of tenants (what-if probes, simulator replicas) then requests
    # policies for that snapshot, each holding a copy that differs by
    # fp-recompute noise (~1e-9 — absorbed by quantization, so the copies
    # share one cache line despite differing bytes).  Epoch-to-epoch EMA
    # drift (~1e-4) produces a genuinely new instance and one warm solve.
    # Warm-up (priming the bases + one edge-churn invalidation cycle) is
    # excluded from the latency percentiles, as serving benches do.
    rng = np.random.default_rng(7)
    bases = [hetero_T(serve_M, seed=s) for s in range(4)]
    srv = PolicyServer(alpha=0.1, K=K, R=R, quant=0.05)
    srv.request_many([(B, None) for B in bases])  # prime: 4 cold solves
    solve_ms = list(srv.stats.latencies_ms)  # priming = pure solve latency
    # Edge churn during warm-up: the PR-5 invalidation rule on the served
    # path (drops base 0's line + warm basis; the re-request re-solves).
    d = np.ones((serve_M, serve_M)) - np.eye(serve_M)
    d[0, 1] = d[1, 0] = 0.0
    srv.request(bases[0], d=d, tenant="churn")
    srv.request(bases[0], tenant="churn")
    warm_n = len(srv.stats.latencies_ms)
    solves_before = srv.stats.n_solves
    epochs = 5
    per_epoch = max(1, n_requests // epochs)
    for e in range(epochs):
        B = bases[int(rng.integers(len(bases)))]
        snapshot = B + rng.uniform(-1e-4, 1e-4, B.shape)  # EMA drift
        for _ in range(per_epoch):
            noise = rng.uniform(-1e-9, 1e-9, B.shape)  # fp-recompute noise
            srv.request(snapshot + noise, tenant="stream")
    lat = np.asarray(srv.stats.latencies_ms[warm_n:])
    n_measured = len(lat)
    misses = srv.stats.n_solves - solves_before
    p50 = float(np.percentile(lat, 50))
    p99 = float(np.percentile(lat, 99))
    serving = dict(
        M=serve_M, quant=0.05, epochs=epochs, requests=n_measured,
        cache_hit_rate=round(1.0 - misses / n_measured, 4),
        n_solves=misses,
        n_invalidations=srv.stats.n_invalidations,
        p50_ms=round(p50, 4),
        p99_ms=round(p99, 4),
        min_solve_ms=round(min(solve_ms), 3),
        p99_is_hit=1.0 if p99 < min(solve_ms) else 0.0,
    )
    print(f"serve/stream/M={serve_M},{p50 * 1e3:.1f},"
          f"hit={serving['cache_hit_rate']}_p50={serving['p50_ms']}ms_"
          f"p99={serving['p99_ms']}ms_solves={serving['n_solves']}_"
          f"inval={serving['n_invalidations']}")

    # -- batched lockstep sweep vs serial cold at the served size ---------
    Tb = bases[0]
    t0 = _time.time()
    serial_cold = policy.generate_policy_matrix(0.1, K=K, R=R, T=Tb,
                                                warm_start=False)
    serial_s = _time.time() - t0
    t0 = _time.time()
    batched = policy.generate_policy_matrix_batched(0.1, K=K, R=R, T=Tb)
    batched_s = _time.time() - t0
    batch_row = dict(
        M=serve_M,
        serial_cold_s=round(serial_s, 4),
        batched_s=round(batched_s, 4),
        batched_speedup_vs_serial_cold=round(serial_s / batched_s, 2),
        same_grid_point_batched=1.0 if (
            batched.rho == serial_cold.rho
            and batched.t_bar == serial_cold.t_bar
        ) else 0.0,
        lp_instances=batched.n_solves,
    )
    print(f"serve/batched/M={serve_M},{batched_s * 1e6:.0f},"
          f"serial_cold={serial_s:.3f}s_batched={batched_s:.3f}s_"
          f"same_pt={bool(batch_row['same_grid_point_batched'])}")

    # -- jax lockstep sweep vs numpy at the served size (PR 10) -----------
    # Two calls: the first pays jit compilation (reported separately —
    # compile cost amortizes across a serving process's lifetime), the
    # second is the steady-state device sweep.  Gated: the grid-point
    # agreement flag (deterministic); wall clocks reported ungated.
    try:
        import jax  # noqa: F401  (availability probe)

        t0 = _time.time()
        jax_cold = policy.generate_policy_matrix_batched(
            0.1, K=K, R=R, T=Tb, backend="jax"
        )
        jax_compile_s = _time.time() - t0
        t0 = _time.time()
        jax_warm = policy.generate_policy_matrix_batched(
            0.1, K=K, R=R, T=Tb, backend="jax"
        )
        jax_warm_s = _time.time() - t0
        jax_row = dict(
            M=serve_M,
            numpy_s=round(batched_s, 4),
            jax_compile_s=round(jax_compile_s, 4),
            jax_warm_s=round(jax_warm_s, 4),
            jax_warm_speedup_vs_numpy=round(batched_s / jax_warm_s, 2),
            same_grid_point_jax=1.0 if (
                jax_warm.rho == batched.rho
                and jax_warm.t_bar == batched.t_bar
                and jax_cold.rho == batched.rho
            ) else 0.0,
        )
        print(f"serve/jax/M={serve_M},{jax_warm_s * 1e6:.0f},"
              f"compile={jax_compile_s:.1f}s_warm={jax_warm_s:.3f}s_"
              f"numpy={batched_s:.3f}s_"
              f"same_pt={bool(jax_row['same_grid_point_jax'])}")
    except ImportError:
        jax_row = dict(M=serve_M, skipped="jax unavailable")
        print(f"serve/jax/M={serve_M},0,skipped_jax_unavailable")

    # -- RPC service: requests/s + shed rate at 1 vs 4 shards (PR 10) ----
    # Real sockets, real threads: N client threads drive a sharded
    # PolicyService (admission in front) with a mix of edge sets so
    # traffic actually spreads.  requests/s and shed rate are reported
    # ungated (wall-clock-derived); the all-answered flag is gated —
    # the service contract is that overload sheds, it never errors or
    # hangs.
    import threading as _threading

    from repro.serve import (
        AdmissionController,
        PolicyClient,
        PolicyService,
        ShardRouter,
    )

    svc_M = min(serve_M, 16)
    n_svc_requests = 120 if small else 240
    n_clients = 4

    def ring_d(M, chord):
        dd = np.zeros((M, M))
        for i in range(M):
            dd[i, (i + 1) % M] = dd[(i + 1) % M, i] = 1.0
        i, j = chord
        dd[i, j] = dd[j, i] = 1.0
        return dd

    edge_sets = [None] + [
        ring_d(svc_M, (0, 2 + k)) for k in range(7)
    ]
    service_rows = {}
    for n_shards in (1, 4):
        router = ShardRouter.build(
            n_shards, 0.1, K=K, R=R, quant=0.05
        )
        adm = AdmissionController(router, max_queue=64, workers=4)
        svc = PolicyService(adm).start()
        answered = [0] * n_clients
        per_client = n_svc_requests // n_clients

        def drive(k, answered=answered, svc=svc):
            with PolicyClient(svc.address) as cli:
                for i in range(per_client):
                    j = (k * per_client + i) % len(edge_sets)
                    # Tenant sticks to one edge set: a per-client tenant
                    # would trip the PR-5 invalidation rule on every
                    # rotation and measure cache thrash, not sharding.
                    res = cli.request(
                        hetero_T(svc_M, seed=j), d=edge_sets[j],
                        tenant=f"c{k}-e{j}", deadline_ms=30_000.0,
                    )
                    if res is not None:
                        answered[k] += 1

        t0 = _time.time()
        threads = [
            _threading.Thread(target=drive, args=(k,))
            for k in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = _time.time() - t0
        svc.stop()
        adm.close()
        n_answered = sum(answered)
        st = router.stats()
        row = dict(
            M=svc_M,
            n_shards=n_shards,
            clients=n_clients,
            requests=n_svc_requests,
            wall_s=round(wall, 4),
            requests_per_s=round(n_answered / wall, 1),
            shed_rate=round(
                adm.stats.n_shed / max(1, adm.stats.n_submitted), 4
            ),
            all_answered=1.0 if n_answered == n_svc_requests else 0.0,
            cache_hit_rate=round(st["hit_rate"], 4),
            p50_ms=round(st["p50_ms"], 4),
            p99_ms=round(st["p99_ms"], 4),
        )
        service_rows[f"shards={n_shards}"] = row
        print(f"serve/service/shards={n_shards},{wall * 1e6:.0f},"
              f"rps={row['requests_per_s']}_shed={row['shed_rate']}_"
              f"hit={row['cache_hit_rate']}_"
              f"all_answered={bool(row['all_answered'])}")

    out = {
        "suite": "serve",
        "K": K,
        "R": R,
        "sizes": list(sizes),
        "small": bool(small),
        "baseline": "lp_pricing('dantzig') + warm_start=False "
                    "(pre-PR solver shape)",
        "pricing": pricing_rows,
        "serving": serving,
        "batched": batch_row,
        "jax": jax_row,
        "service": service_rows,
    }
    path = Path(out_path) if out_path else ROOT / "BENCH_serve.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


def bench_storms(small=False, out_path=None):
    """Failure-storm robustness suite (PR 9): cascading hazard storms,
    Monitor failover, and degraded-mode policy serving.  Writes
    BENCH_storms.json.

    Three sections, all sized as CI smokes already (M=12 sims, M=16 served
    graphs) — ``small`` is accepted for CLI symmetry with the other gated
    suites but changes nothing, so the smoke and the committed baseline
    compute *identical* virtual-time metrics (every gated number below is
    seeded and wall-clock-free, hence bit-stable across hardware):

    * ``throughput`` — netmax (home-pinned Monitor + failover) vs adpsgd
      events per virtual second through the same self-exciting storm
      timeline.  Gated ratio: ``netmax_vs_adpsgd_evps``.
    * ``failover`` — the PR acceptance scenario: a permanent outage kills
      the Monitor's home cluster.  Without failover the far side hammers
      the dead cluster to the end of the run (``pinned_never_reroutes``);
      with failover a standby is elected and dead-cluster pulls stop
      (``reroutes_with_failover``, ``dead_pull_rate_reduction`` = the
      far side's post-outage dead-cluster pulls per virtual second,
      pinned over failover).  Total failed pulls is deliberately NOT the
      comparator: the failover run's orphaned home-cluster workers —
      unreachable behind the WAN cut, correctly degraded to their last
      published rows — keep timing out on cross-cluster pulls for the
      whole (longer) run, which is the expected degraded mode, not a
      regression.
    * ``serving`` — PolicyServer under injected solver faults
      (scenarios.chaos): a 35%-fault stream with deadline+retry+stale
      (``all_served``), then a total solver blackout where the circuit
      breaker trips and every request still gets the uniform fallback
      (``served_under_blackout``, ``breaker_tripped``), then fault clearing
      where a probe closes the breaker (``breaker_recovered``).  p50/p99
      latencies are reported ungated (wall-clock).
    """
    import time as _time

    import numpy as np

    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.scenarios import ChaosInjector, presets, storm
    from repro.serve import PolicyServer
    from repro.train.simulator import SimConfig, simulate

    del small  # suite is already smoke-sized; kept for CLI symmetry
    M = 12
    topo = Topology(n_workers=M, workers_per_host=2, hosts_per_pod=2,
                    pods_per_cluster=1)  # 3 clusters of 4
    cluster = np.array([topo.cluster_of(i) for i in range(M)])
    x, y, ex, ey = train_eval_split(3000, 600, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)

    def run(algo, timeline, events, *, timeout, failover=False, seed=3):
        link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=timeline,
                             dead_link_timeout=timeout)
        kw = {}
        if algo == "netmax":
            kw = dict(monitor_period=1.0, monitor_home_cluster=0,
                      monitor_failover=failover)
        cfg = SimConfig(algorithm=algo, n_workers=M, total_events=events,
                        lr=0.05, seed=seed, engine="batched", **kw)
        t0 = _time.time()
        res = simulate(cfg, link, x, y, parts, ex, ey,
                       record_every=max(50, events // 20))
        return res, link, _time.time() - t0

    # -- storm throughput: netmax+failover vs adpsgd ----------------------
    # One self-exciting storm (trigger strike on the Monitor's home
    # cluster at t=0.8, excitation cascades across correlated domains);
    # both algorithms ride the identical compiled timeline.
    tl = storm(topo, seed=7, horizon=40.0, intensity=2.0,
               trigger_cluster=0, trigger_time=0.8)
    events = 2000
    throughput = {"storm_events": len(tl.events)}
    evps = {}
    for algo in ("netmax", "adpsgd"):
        res, link, wall = run(algo, tl, events, timeout=0.5,
                              failover=(algo == "netmax"))
        evps[algo] = events / res.times[-1]
        throughput[algo] = dict(
            events=events,
            wall_s=round(wall, 3),
            virtual_time_s=round(res.times[-1], 3),
            events_per_vsec=round(evps[algo], 2),
            failed_pulls=len(res.failed_pulls),
            failovers=len(res.leader_log),
            skipped_refreshes=res.skipped_refreshes,
            segments=len(link.compiled_scenario.segments),
            final_loss=round(res.losses[-1], 4),
        )
        print(f"storms/throughput/{algo},{wall * 1e6 / events:.0f},"
              f"evps={throughput[algo]['events_per_vsec']}_"
              f"fails={throughput[algo]['failed_pulls']}_"
              f"failovers={throughput[algo]['failovers']}")
    throughput["netmax_vs_adpsgd_evps"] = round(
        evps["netmax"] / evps["adpsgd"], 4
    )

    # -- failover: refreshes-to-reroute with/without standby Monitors -----
    period, timeout, t0 = 0.5, 0.4, 1.0
    outage = presets.cluster_outage(0, t0, 1e9)
    runs = {}
    for failover in (False, True):
        link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=outage,
                             dead_link_timeout=timeout)
        cfg = SimConfig(algorithm="netmax", n_workers=M, total_events=1200,
                        monitor_period=period, monitor_home_cluster=0,
                        monitor_failover=failover, seed=3, engine="batched")
        runs[failover] = simulate(cfg, link, x, y, parts, ex, ey,
                                  record_every=600)
    pinned, elected = runs[False], runs[True]

    def into_dead(res):
        return [t for t, i, m in res.failed_pulls
                if cluster[i] != 0 and cluster[m] == 0]

    # First post-outage refresh whose published policy carries zero mass
    # into the dead cluster (same rule as bench_scenarios).
    touch = cluster == 0
    cross = (touch[:, None] | touch[None, :]) & (
        cluster[:, None] != cluster[None, :]
    )

    def refreshes_to_reroute(res):
        n = 0
        for tq, _rho, P in res.policy_log:
            if tq >= t0:
                n += 1
                if float(P[cross].sum()) <= 1e-12:
                    return n
        return None

    t_elect = elected.leader_log[0][0] if elected.leader_log else None
    late_pinned = into_dead(pinned)
    dead_elected = into_dead(elected)
    late_elected = [t for t in dead_elected
                    if t_elect is not None
                    and t > t_elect + 2 * period + timeout]

    def dead_rate(res):
        span = res.times[-1] - t0
        return len(into_dead(res)) / span if span > 0 else 0.0

    failover_row = dict(
        outage_start=t0,
        monitor_period=period,
        pinned=dict(
            failed_pulls=len(pinned.failed_pulls),
            dead_cluster_pulls=len(late_pinned),
            last_dead_pull_t=round(max(late_pinned), 3)
            if late_pinned else None,
            virtual_time_s=round(pinned.times[-1], 3),
            refreshes_to_reroute=refreshes_to_reroute(pinned),
            skipped_refreshes=pinned.skipped_refreshes,
        ),
        failover=dict(
            failed_pulls=len(elected.failed_pulls),
            dead_cluster_pulls=len(dead_elected),
            virtual_time_s=round(elected.times[-1], 3),
            failovers=len(elected.leader_log),
            elected_cluster=elected.leader_log[0][1]
            if elected.leader_log else None,
            election_t=round(t_elect, 3) if t_elect is not None else None,
            refreshes_to_reroute=refreshes_to_reroute(elected),
            dead_pulls_after_handoff=len(late_elected),
        ),
        # Gated flags/ratios (virtual-time deterministic):
        pinned_never_reroutes=1.0 if (
            not pinned.leader_log
            and late_pinned
            and max(late_pinned) > 0.75 * pinned.times[-1]
        ) else 0.0,
        reroutes_with_failover=1.0 if (
            elected.leader_log and not late_elected
        ) else 0.0,
        dead_pull_rate_reduction=round(
            dead_rate(pinned) / max(dead_rate(elected), 1e-9), 3
        ),
    )
    print(f"storms/failover,0,"
          f"pinned_fails={failover_row['pinned']['failed_pulls']}_"
          f"failover_fails={failover_row['failover']['failed_pulls']}_"
          f"elect_t={failover_row['failover']['election_t']}_"
          f"reroute_refreshes={failover_row['failover']['refreshes_to_reroute']}_"
          f"dead_rate_red={failover_row['dead_pull_rate_reduction']}x")

    # -- degraded-mode serving under injected solver faults ---------------
    def hetero_T(Mw, seed=0):
        rng = np.random.default_rng(seed)
        T = rng.uniform(0.01, 0.05, size=(Mw, Mw))
        T = (T + T.T) / 2
        np.fill_diagonal(T, 0.0)
        return T

    serve_M = 16
    bases = [hetero_T(serve_M, seed=s) for s in range(3)]
    rng = np.random.default_rng(11)

    # Phase 1: 35% per-attempt fault rate; bounded retry + stale-while-
    # revalidate keep every request answered with a real policy object.
    chaos = ChaosInjector(seed=3, solver_fail_rate=0.35)
    srv = PolicyServer(alpha=0.1, K=6, R=6, quant=0.05, deadline_ms=2000.0,
                       max_retries=2, backoff_ms=1.0, breaker_threshold=3,
                       breaker_probe_every=4, chaos=chaos)
    served = 0
    n_requests = 0
    t0w = _time.time()
    for epoch in range(6):
        B = bases[int(rng.integers(len(bases)))]
        snapshot = B + rng.uniform(-1e-4, 1e-4, B.shape)  # EMA drift: miss
        for _ in range(30):
            noise = rng.uniform(-1e-9, 1e-9, B.shape)  # absorbed by quant
            n_requests += 1
            if srv.request(snapshot + noise, tenant="stream") is not None:
                served += 1
    stream_wall = _time.time() - t0w
    st = srv.stats.snapshot()
    serving = dict(
        M=serve_M,
        requests=n_requests,
        chaos_fail_rate=0.35,
        all_served=1.0 if served == n_requests else 0.0,
        p50_ms=round(srv.stats.latency_ms(0.50), 4),
        p99_ms=round(srv.stats.latency_ms(0.99), 4),
        n_solves=st["n_solves"],
        n_retries=st["n_retries"],
        n_solve_errors=st["n_solve_errors"],
        n_stale_served=st["n_stale_served"],
        n_uniform_fallbacks=st["n_uniform_fallbacks"],
        n_deadline_misses=st["n_deadline_misses"],
        injected_faults=chaos.n_solver_faults,
    )
    print(f"storms/serving/faulty,{serving['p50_ms'] * 1e3:.1f},"
          f"served={served}/{n_requests}_p99={serving['p99_ms']}ms_"
          f"retries={serving['n_retries']}_stale={serving['n_stale_served']}_"
          f"uniform={serving['n_uniform_fallbacks']}")

    # Phase 2: total solver blackout -> breaker trips, every request still
    # answered by the uniform fallback; then the fault clears and a
    # breaker probe restores fresh solves.
    blackout = ChaosInjector(seed=4, solver_fail_rate=1.0)
    srv2 = PolicyServer(alpha=0.1, K=6, R=6, quant=0.05, deadline_ms=2000.0,
                        max_retries=1, backoff_ms=1.0, breaker_threshold=2,
                        breaker_probe_every=3, chaos=blackout)
    dark_served = 0
    n_dark = 12
    for k in range(n_dark):
        snap = bases[0] + rng.uniform(-1e-4, 1e-4, bases[0].shape)
        res = srv2.request(snap, tenant="dark")
        if res is not None and not res.ok:  # uniform fallback marker
            dark_served += 1
    tripped = srv2.stats.n_breaker_trips
    blackout.solver_fail_rate = 0.0  # fault clears
    recovered = None
    for k in range(2 * srv2.breaker_probe_every):
        snap = bases[0] + rng.uniform(-1e-4, 1e-4, bases[0].shape)
        res = srv2.request(snap, tenant="dark")
        if res is not None and res.ok:  # a probe closed the breaker
            recovered = k + 1
            break
    st2 = srv2.stats.snapshot()
    serving["blackout"] = dict(
        requests=n_dark,
        served_under_blackout=1.0 if dark_served == n_dark else 0.0,
        breaker_tripped=1.0 if tripped >= 1 else 0.0,
        breaker_probes=st2["n_breaker_probes"],
        breaker_recovered=1.0
        if st2["n_breaker_recoveries"] >= 1 and recovered is not None
        else 0.0,
        requests_to_recover=recovered,
    )
    print(f"storms/serving/blackout,0,"
          f"served={dark_served}/{n_dark}_trips={tripped}_"
          f"probes={st2['n_breaker_probes']}_"
          f"recovered_after={recovered}_reqs")

    out = {
        "suite": "storms",
        "topology": "3 clusters x 4 workers (M=12)",
        "storm": {"seed": 7, "horizon_s": 40.0, "intensity": 2.0,
                  "trigger_cluster": 0, "trigger_time": 0.8},
        "throughput": throughput,
        "failover": failover_row,
        "serving": serving,
        "stream_wall_s": round(stream_wall, 3),
    }
    path = Path(out_path) if out_path else ROOT / "BENCH_storms.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


def bench_roofline_summary():
    """Summarize dry-run artifacts (if present) into roofline terms."""
    from repro.analysis.roofline import from_record
    from repro.configs.base import SHAPES

    path = ROOT / "artifacts" / "dryrun" / "records.jsonl"
    if not path.exists():
        print("roofline/none,0,run `python -m repro.launch.dryrun --all --out "
              "artifacts/dryrun/records.jsonl` first")
        return {}
    rows = {}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if not rec.get("ok"):
                continue
            r = from_record(rec, SHAPES[rec["shape"]])
            key = f"{rec['mesh']}/{rec['arch']}/{rec['shape']}"
            rows[key] = dict(
                compute_s=r.compute_s, memory_s=r.memory_s,
                collective_s=r.collective_s, dominant=r.dominant,
                useful_ratio=r.useful_ratio, fraction=r.roofline_fraction,
            )
            print(f"roofline/{key},0,"
                  f"c={r.compute_s:.2e}s_m={r.memory_s:.2e}s_x={r.collective_s:.2e}s_"
                  f"dom={r.dominant}_frac={r.roofline_fraction:.3f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "paper", "kernels", "roofline", "quick",
                             "algos", "simulator", "policy", "scenarios",
                             "trace", "serve", "storms"])
    ap.add_argument("--events", type=int, default=4000)
    ap.add_argument("--policy-sizes", type=int, nargs="+", default=None,
                    help="worker counts for --suite policy "
                         "(default 16 32 64 128; CI smoke passes 16 32)")
    ap.add_argument("--sim-sizes", type=int, nargs="+", default=None,
                    help="worker counts for --suite simulator "
                         "(default 8 32 64 128; CI smoke passes 8 32)")
    ap.add_argument("--fleet-sizes", type=int, nargs="+", default=None,
                    help="fleet-scale worker counts for the simulator "
                         "suite's batched-only rows (default 128 1024 4096; "
                         "pass 0 to skip; CI smoke passes 128 1024)")
    ap.add_argument("--small", action="store_true",
                    help="CI smoke shape for --suite scenarios/trace/serve "
                         "(fewer workers/events, same structure)")
    ap.add_argument("--out-dir", default=None,
                    help="write BENCH_*.json here instead of the repo root "
                         "(CI writes fresh numbers to artifacts/ so "
                         "scripts/check_bench.py can diff them against the "
                         "committed baselines)")
    args = ap.parse_args()

    out_dir = Path(args.out_dir) if args.out_dir else None
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)

    def bench_path(name):
        return (out_dir / name) if out_dir else None

    from benchmarks import paper_tables as pt

    out = {}
    if args.suite in ("all", "kernels", "quick"):
        out["kernels"] = bench_kernels()
    if args.suite in ("all", "quick", "algos"):
        out["algorithms"] = bench_algorithms(
            events=min(args.events, 1200) if args.suite == "quick" else args.events
        )
    if args.suite in ("all", "simulator"):
        sizes = tuple(args.sim_sizes) if args.sim_sizes else (8, 32, 64, 128)
        if args.fleet_sizes is None:
            fleet = (128, 1024, 4096)
        else:
            fleet = tuple(s for s in args.fleet_sizes if s > 0)
        out["simulator_engines"] = bench_simulator_engines(
            sizes=sizes, fleet_sizes=fleet,
            out_path=bench_path("BENCH_simulator.json")
        )
    if args.suite in ("all", "policy"):
        sizes = tuple(args.policy_sizes) if args.policy_sizes else (16, 32, 64, 128)
        out["policy_solver"] = bench_policy_solver(
            sizes=sizes, out_path=bench_path("BENCH_policy.json")
        )
    if args.suite in ("all", "scenarios"):
        out["scenarios"] = bench_scenarios(
            small=args.small, out_path=bench_path("BENCH_scenarios.json")
        )
    if args.suite in ("all", "trace"):
        out["trace"] = bench_trace(
            small=args.small, out_path=bench_path("BENCH_trace.json")
        )
    if args.suite in ("all", "serve"):
        out["serve"] = bench_serve(
            small=args.small, out_path=bench_path("BENCH_serve.json")
        )
    if args.suite in ("all", "storms"):
        out["storms"] = bench_storms(
            small=args.small, out_path=bench_path("BENCH_storms.json")
        )
    if args.suite in ("all", "paper"):
        out["policy_generation"] = pt.bench_policy_generation()
        out["epoch_time_hetero"] = pt.bench_epoch_time(hetero=True)
        out["epoch_time_homog"] = pt.bench_epoch_time(hetero=False)
        out["ablation_fig7"] = pt.bench_ablation_fig7()
        out["convergence"] = pt.bench_convergence(events=args.events)
        out["convergence_hom"] = pt.bench_convergence_homogeneous(events=args.events)
        out["scalability"] = pt.bench_scalability()
        out["accuracy"] = pt.bench_accuracy_tables(events=args.events)
        out["noniid"] = pt.bench_noniid(events=args.events)
        out["nonuniform"] = pt.bench_nonuniform_sizes()
        out["ps_baseline"] = pt.bench_ps_baseline(events=args.events)
        out["monitor_ext"] = pt.bench_monitor_extension(events=args.events)
    if args.suite in ("all", "roofline", "quick"):
        out["roofline"] = bench_roofline_summary()

    art = ROOT / "artifacts"
    art.mkdir(exist_ok=True)
    with open(art / "bench_results.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"\nwrote artifacts/bench_results.json ({len(out)} suites)")


if __name__ == "__main__":
    main()
