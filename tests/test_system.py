"""End-to-end system tests: the full NetMax stack wired together.

These exercise the same composition the examples/drivers use: Monitor +
policy + consensus trainer + checkpointing, and validate the dry-run
artifacts when present.
"""

import json
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_end_to_end_netmax_lm_with_monitor(tmp_path):
    """Train a tiny LM under NetMax-DP with a live Network Monitor and
    checkpointing; verify loss decreases, the policy adapts, and restart
    resumes exactly."""
    from repro.configs.base import get_arch
    from repro.core import consensus
    from repro.core.monitor import IterationTimeEMA, NetworkMonitor
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.synthetic import TokenStream
    from repro.optim import sgd
    from repro.train import checkpoint as ckpt
    from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step

    M = 4
    cfg = replace(get_arch("qwen1.5-0.5b").reduced(), vocab_size=512)
    opt = sgd(momentum=0.9)
    lr = 0.05
    step = jax.jit(make_train_step(cfg, opt, M, TrainStepConfig(gossip_mode="gather")))
    stream = TokenStream(cfg.vocab_size, 32, 4, seed=0)
    topo = Topology(M, workers_per_host=2, hosts_per_pod=1)
    link = LinkTimeModel(topo, jitter=0.0, seed=0)
    monitor = NetworkMonitor(M, alpha=lr, K=5, R=5)
    emas = [IterationTimeEMA(M, beta=0.5) for _ in range(M)]
    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / (M - 1), 0.0)
    rho = 0.5 / (2 * lr * (M - 1))
    rng = np.random.default_rng(0)
    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))

    losses = []
    policies = 0
    for r in range(30):
        batch = {k: jnp.stack([jnp.asarray(stream.batch(w, r)[k]) for w in range(M)])
                 for k in ("tokens", "labels")}
        nb, wts = consensus.sample_round(rng, P, lr, rho, d)
        gi = {"neighbors": jnp.asarray(nb), "weights": jnp.asarray(wts),
              "lr": jnp.float32(lr)}
        params, opt_state, m = step(params, opt_state, batch, gi)
        losses.append(float(m["loss"]))
        for i in range(M):
            emas[i].update(int(nb[i]), link.iteration_time(i, int(nb[i])))
        if (r + 1) % 10 == 0:
            monitor.collect({i: emas[i].snapshot() for i in range(M)})
            pol = monitor.step()
            if np.isfinite(pol.T_convergence):
                P, rho = pol.P, pol.rho
                policies += 1
        if r == 19:
            ckpt.save(tmp_path, r + 1, params, opt_state, data_cursor={"round": r + 1})

    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) <= losses[0] * 1.02
    assert policies >= 2
    assert monitor.policy.lambda2 < 1.0

    # restart from round 20 reproduces the checkpointed state
    p2, o2 = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    p2, o2, man, _ = ckpt.restore(tmp_path, p2, o2)
    assert man["data_cursor"]["round"] == 20


def test_dryrun_artifacts_cover_assigned_cells():
    """If the sweep has run, every (arch x shape x mesh) cell must be
    ok or an explicitly documented skip (the multi-pod dry-run deliverable)."""
    path = ROOT / "artifacts" / "dryrun" / "records.jsonl"
    if not path.exists():
        pytest.skip("dry-run sweep not executed in this environment")
    from repro.configs.base import SHAPES, all_archs

    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["mesh"], r["arch"], r["shape"])] = r
    archs = sorted(a for a in all_archs() if a != "netmax_paper")
    meshes = {m for (m, _, _) in recs}
    assert "16x16" in meshes
    for mesh in meshes:
        for a in archs:
            for s in SHAPES:
                key = (mesh, a, s)
                if key not in recs:
                    continue  # partial sweep
                r = recs[key]
                assert r["ok"] or r.get("skipped"), f"{key}: {r.get('error', '')[:100]}"
                if r.get("skipped"):
                    assert not all_archs()[a].supports(SHAPES[s])


def test_dryrun_gossip_collectives_present():
    """Multi-worker train cells must show the gossip collective-permute in
    their lowered collective schedule."""
    path = ROOT / "artifacts" / "dryrun" / "records.jsonl"
    if not path.exists():
        pytest.skip("dry-run sweep not executed")
    found = 0
    for line in open(path):
        r = json.loads(line)
        if r.get("ok") and r["shape"] == "train_4k" and r.get("M", 1) > 1:
            assert "collective-permute" in r["collective_bytes_per_device"], r["arch"]
            found += 1
    assert found >= 5
