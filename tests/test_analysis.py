"""Tests for the HLO cost model + roofline pipeline."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import HloCostModel, _parse_op_line, _shape_elems_bytes
from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, from_record
from repro.configs.base import SHAPES


def test_shape_parse():
    e, b = _shape_elems_bytes("bf16[256,4096]{1,0}")
    assert e == 256 * 4096 and b == 2 * e
    e, b = _shape_elems_bytes("(f32[2,3]{1,0}, s32[])")
    assert e == 7 and b == 4 * 7
    e, b = _shape_elems_bytes("pred[]")
    assert e == 1 and b == 1


def test_parse_op_line_with_index_comments():
    line = ('  %while.289 = (s32[], f32[1,16]{1,0}, /*index=2*/pred[4]{0}) '
            'while(%tuple), condition=%cond, body=%body, '
            'backend_config={"known_trip_count":{"n":"4"}}')
    name, tstr, opcode, rest = _parse_op_line(line)
    assert name == "while.289"
    assert opcode == "while"
    assert "known_trip_count" in rest
    assert "pred[4]" in tstr


def test_scan_flops_multiplied_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    rep = HloCostModel(c.as_text()).entry_cost()
    expect = 8 * 2 * 128 * 256 * 256
    assert rep.flops == pytest.approx(expect, rel=0.05)
    assert rep.unknown_trip_loops == 0


def test_nested_scan_flops():
    def inner(c2, z):
        return c2 + jnp.tanh(c2 @ z), None

    def outer(x, ws):
        def ob(c2, w):
            return jax.lax.scan(inner, c2, jnp.stack([w] * 4))[0], None
        return jax.lax.scan(ob, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    rep = HloCostModel(c.as_text()).entry_cost()
    expect = 8 * 4 * 2 * 64 * 128 * 128
    assert rep.flops == pytest.approx(expect, rel=0.05)


def test_matches_cost_analysis_on_unrolled():
    """On a loop-free module, our flops ~ XLA's cost_analysis."""
    def f(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(64, 128), (128, 256), (256, 32)]]
    c = jax.jit(f).lower(*args).compile()
    rep = HloCostModel(c.as_text()).entry_cost()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert rep.flops == pytest.approx(xla, rel=0.1)


def test_roofline_terms_and_dominance():
    rec = dict(
        ok=True, arch="a", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops_per_device=1e12, hlo_bytes_per_device=1e11,
        collective_bytes_per_device={"all-reduce": 1e10},
        active_params=1e9,
    )
    r = from_record(rec, SHAPES["train_4k"])
    assert r.compute_s == pytest.approx(1e12 / PEAK_FLOPS)
    assert r.memory_s == pytest.approx(1e11 / HBM_BW)
    assert r.collective_s == pytest.approx(1e10 / LINK_BW)
    # 5.08ms compute vs 0.12s memory vs 0.2s collective -> collective wins
    assert r.dominant == "collective"
    assert 0 < r.roofline_fraction <= 1.5
    assert r.model_flops == pytest.approx(6 * 1e9 * 4096 * 256)


def test_roofline_decode_tokens():
    rec = dict(
        ok=True, arch="a", shape="decode_32k", mesh="16x16", chips=256,
        hlo_flops_per_device=1e9, hlo_bytes_per_device=1e9,
        collective_bytes_per_device={}, active_params=1e9,
    )
    r = from_record(rec, SHAPES["decode_32k"])
    # decode: 2*N*batch (one token per sequence)
    assert r.model_flops == pytest.approx(2 * 1e9 * 128)


def test_collective_bytes_collected():
    """A psum across devices shows up as all-reduce bytes (subprocess-free:
    single-device psum lowers away, so test the parser on a synthetic HLO)."""
    hlo = """
HloModule test, entry_computation_layout={()->f32[4]{0}}

ENTRY %main.1 () -> f32[4] {
  %c = f32[4]{0} constant({1,2,3,4})
  ROOT %ar = f32[4]{0} all-reduce(%c), replica_groups={}, to_apply=%add
}
"""
    rep = HloCostModel(hlo).entry_cost()
    assert rep.collective_bytes.get("all-reduce") == 16.0
    assert rep.collective_count.get("all-reduce") == 1
