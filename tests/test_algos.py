"""Tests for the pluggable Algorithm registry (repro.algos).

Pins the unified-API contract: registry round-trips, event-driven vs
stacked-SPMD mixing parity for every gossip-family strategy, the
TrainStepConfig deprecation shim, and the Monitor-period single source
of truth.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algos import Algorithm, get_algorithm, list_algorithms, register
from repro.train.simulator import SimConfig

EXPECTED = {
    "netmax", "adpsgd", "adpsgd+mon", "allreduce", "prague",
    "ps-sync", "ps-async", "netmax-topk",
}


# --------------------------------------------------------------------------
# Registry smoke
# --------------------------------------------------------------------------


def test_all_legacy_names_plus_topk_registered():
    assert EXPECTED <= set(list_algorithms())


def test_get_algorithm_round_trips():
    for name in list_algorithms():
        algo = get_algorithm(name)
        assert isinstance(algo, Algorithm)
        assert algo.name == name
        assert get_algorithm(algo.name).name == name


def test_unknown_name_raises_with_listing():
    with pytest.raises(KeyError, match="netmax"):
        get_algorithm("definitely-not-registered")


def test_register_decorator_adds_new_strategy():
    @register("_test-only")
    class TestOnly(Algorithm):
        pass

    try:
        assert "_test-only" in list_algorithms()
        assert get_algorithm("_test-only").name == "_test-only"
    finally:
        from repro.algos import base

        del base._REGISTRY["_test-only"]


# --------------------------------------------------------------------------
# Event-driven vs stacked parity (the API's core promise)
# --------------------------------------------------------------------------


def _tiny_tree(rng, M):
    return {
        "w": jnp.asarray(rng.normal(size=(M, 6, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(M, 4)).astype(np.float32)),
    }


def _gossip_algos():
    return [n for n in list_algorithms() if get_algorithm(n).family == "gossip"]


@pytest.mark.parametrize("name", ["netmax", "adpsgd", "adpsgd+mon", "netmax-topk"])
def test_gossip_parity_event_vs_stacked(name):
    """Given the same neighbor draw and mixing weight, the per-replica mix
    (event simulator path) and the stacked round (SPMD path) must produce
    identical replica states."""
    algo = get_algorithm(name)
    M, alpha = 4, 0.1
    rng = np.random.default_rng(0)
    params = _tiny_tree(rng, M)
    grads = _tiny_tree(rng, M)
    neighbors = np.array([1, 2, 0, 3], dtype=np.int32)  # worker 3 self-selects
    weights = np.array([0.3, 0.5, 0.25, 0.0], dtype=np.float32)

    stacked = algo.stacked_round(
        params, grads, jnp.asarray(neighbors), jnp.asarray(weights), alpha
    )

    # Event-driven path: per-replica trees, pre-round pulls, same draws.
    replicas = [
        jax.tree_util.tree_map(lambda l: l[i], params) for i in range(M)
    ]
    gtrees = [jax.tree_util.tree_map(lambda l: l[i], grads) for i in range(M)]
    pre_round = list(replicas)
    for i in range(M):
        x_half = jax.tree_util.tree_map(
            lambda x, g: x - alpha * g, replicas[i], gtrees[i]
        )
        m = int(neighbors[i])
        if m != i and weights[i] > 0:
            replicas[i] = algo.mix(x_half, pre_round[m], float(weights[i]))
        else:
            replicas[i] = x_half

    for i in range(M):
        for k in ("w", "b"):
            np.testing.assert_allclose(
                np.asarray(replicas[i][k]),
                np.asarray(stacked[k][i]),
                rtol=1e-5, atol=1e-6,
                err_msg=f"{name}: worker {i} leaf {k}",
            )


def test_parity_covers_every_registered_gossip_algorithm():
    """The parametrized parity test above must not silently miss a newly
    registered gossip strategy."""
    assert set(_gossip_algos()) == {"netmax", "adpsgd", "adpsgd+mon", "netmax-topk"}


def test_identity_delta_matches_legacy_consensus_stacked_round():
    """Base stacked_round == consensus.stacked_round for identity transforms."""
    from repro.core import consensus

    algo = get_algorithm("adpsgd")
    M, alpha = 4, 0.05
    rng = np.random.default_rng(1)
    params = _tiny_tree(rng, M)
    grads = _tiny_tree(rng, M)
    nb = jnp.asarray(np.array([2, 0, 3, 1], dtype=np.int32))
    w = jnp.asarray(np.array([0.5, 0.5, 0.0, 0.2], dtype=np.float32))
    a = algo.stacked_round(params, grads, nb, w, alpha)
    b = consensus.stacked_round(params, grads, nb, w, alpha)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-6)


def test_topk_delta_transform_sparsifies():
    algo = get_algorithm("netmax-topk", ratio=0.1)
    delta = jnp.asarray(np.random.default_rng(2).normal(size=(10, 10)).astype(np.float32))
    out = algo.delta_transform(delta)
    assert int((out != 0).sum()) == 10  # 10% of 100 entries kept
    kept = np.abs(np.asarray(out))[np.asarray(out) != 0].min()
    dropped = np.abs(np.asarray(delta))[np.asarray(out) == 0].max()
    assert kept >= dropped  # largest-magnitude entries survive
    assert algo.wire_ratio() == pytest.approx(0.2)


# --------------------------------------------------------------------------
# Simulator integration of the new strategy
# --------------------------------------------------------------------------


def test_netmax_topk_learns_and_spends_less_comm_time():
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.train.simulator import simulate

    M = 8
    topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    x, y, ex, ey = train_eval_split(1500, 400, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)

    def run(algo):
        link = LinkTimeModel(topo, jitter=0.02, seed=5, slow_interval=120.0)
        cfg = SimConfig(algorithm=algo, n_workers=M, total_events=700, lr=0.05,
                        monitor_period=20.0, seed=0)
        return simulate(cfg, link, x, y, parts, ex, ey, record_every=350)

    sparse = run("netmax-topk")
    dense = run("netmax")
    assert sparse.losses[-1] < sparse.losses[0] * 0.9
    assert np.isfinite(sparse.losses[-1])
    assert sparse.comm_time < dense.comm_time  # sparsified pulls are cheaper


# --------------------------------------------------------------------------
# Monitor period: single source of truth
# --------------------------------------------------------------------------


def test_monitor_period_flows_from_config():
    algo = get_algorithm("netmax")
    cfg = SimConfig(monitor_period=7.5)
    mon = algo.make_monitor(cfg, 4)
    assert mon.schedule_period == pytest.approx(7.5)


def test_monitor_period_defaults_to_monitor_own_default():
    algo = get_algorithm("netmax")
    cfg = SimConfig()  # monitor_period=None -> Monitor's paper default
    mon = algo.make_monitor(cfg, 4)
    assert mon.schedule_period == pytest.approx(120.0)


# --------------------------------------------------------------------------
# Trainer shim
# --------------------------------------------------------------------------


def test_resolve_algorithm_shim_maps_legacy_flags():
    from repro.train.trainer import TrainStepConfig, resolve_algorithm

    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no warning for the modern path
        assert resolve_algorithm("prague", TrainStepConfig()).name == "prague"
        assert resolve_algorithm(get_algorithm("adpsgd"), TrainStepConfig()).name == "adpsgd"

    with pytest.deprecated_call():
        assert resolve_algorithm(None, TrainStepConfig(allreduce=True)).name == "allreduce"
    with pytest.deprecated_call():
        algo = resolve_algorithm(None, TrainStepConfig(prague_groups=2))
    assert algo.name == "prague" and algo.trainer_groups == 2
    assert resolve_algorithm(None, TrainStepConfig()).name == "netmax"


def test_make_train_step_accepts_algorithm_by_name():
    from dataclasses import replace

    from repro.configs.base import get_arch
    from repro.optim import sgd
    from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step

    cfg = replace(get_arch("tinyllama-1.1b").reduced(), vocab_size=64,
                  n_layers=1, d_model=32)
    M, lr = 4, 0.05
    opt = sgd(momentum=0.9)
    step = jax.jit(make_train_step(cfg, opt, M, "allreduce"))
    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, size=(M, 2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, size=(M, 2, 16)), jnp.int32),
    }
    gi = {"neighbors": jnp.zeros((M,), jnp.int32),
          "weights": jnp.zeros((M,), jnp.float32), "lr": jnp.float32(lr)}
    params, opt_state, m = step(params, opt_state, batch, gi)
    # Allreduce keeps replicas identical.
    for l in jax.tree_util.tree_leaves(params):
        lf = np.asarray(l, np.float32)
        np.testing.assert_allclose(lf, np.broadcast_to(lf[:1], lf.shape), atol=1e-5)
    assert np.isfinite(float(m["loss"]))
