"""Fleet-scale engine tests (ISSUE 7 tentpole; DESIGN.md §16).

Three layers:

* **Sparse-vs-dense bit-identity** at M <= 64: the O(M) ``Segment`` link
  state must answer every directed query exactly like the (M, M) dense
  views it replaced (property-fuzzed via tests/_hypothesis_stub.py), and
  the dict form of ``link_scale`` must be bit-identical to the legacy
  dense-array form.
* **O(M) memory pins**: compiled link state stays far below the dense
  footprint and grows linearly in M; the @slow M=1024 smoke pins host
  peak memory for a whole batched run.
* **Fleet execution**: the federated-cohorts preset and the
  device-sharded path (subprocess, forced 8-device host mesh) reproduce
  the dense batched engine exactly.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core.nettime import LinkTimeModel, Topology
from repro.data.partition import uniform_partition
from repro.data.synthetic import train_eval_split
from repro.scenarios import presets
from repro.scenarios.timeline import (
    ClusterOutage,
    LinkDegrade,
    Timeline,
    WorkerLeave,
    WorkerRejoin,
)
from repro.train.simulator import SimConfig, simulate

ROOT = Path(__file__).resolve().parents[1]


def fleet_topo(M):
    return Topology.multi_cluster(M)


def rich_timeline(topo, seed=0, horizon=10.0):
    """Outages (all three directions), degrades, and churn in one timeline.

    Windows and degrade links are chosen overlap-free per failure domain
    (compile() now rejects same-domain overlap): the directed out/in cuts
    may share a window (different directed domains), the symmetric cut
    gets its own, and degrade links are distinct unordered pairs.
    """
    M = topo.n_workers
    rng = np.random.default_rng(seed)
    ev = [
        ClusterOutage(0, 1.0, 4.0, direction="out"),
        ClusterOutage(topo.n_clusters - 1, 2.0, 6.0, direction="in"),
        ClusterOutage(min(1, topo.n_clusters - 1), 6.5, 8.0),
    ]
    iu, ju = np.triu_indices(M, 1)
    for k in rng.choice(len(iu), size=4, replace=False):
        t0 = float(rng.uniform(0, horizon / 2))
        ev.append(LinkDegrade(int(iu[k]), int(ju[k]), t0, t0 + 2.0,
                              float(rng.uniform(2, 50))))
    w = int(rng.integers(1, M))
    ev += [WorkerLeave(w, 1.5), WorkerRejoin(w, 7.0)]
    return Timeline(ev)


# --------------------------------------------------------------------------
# Sparse-vs-dense bit-identity (satellite 4)
# --------------------------------------------------------------------------


def _check_segment_identity(seg):
    M = len(seg.dead_out)
    dense_dead = seg.dead
    dense_deg = seg.degrade
    for i in range(M):
        for m in range(M):
            if i == m:
                assert not dense_dead[i, m]
                continue
            assert seg.link_dead(i, m) == bool(dense_dead[i, m])
            assert seg.degrade_factor(i, m) == dense_deg[i, m]


def test_segment_sparse_queries_match_dense_views():
    topo = fleet_topo(32)
    scn = rich_timeline(topo).compile(topo)
    assert len(scn.segments) > 4
    for seg in scn.segments:
        _check_segment_identity(seg)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_segment_identity_fuzzed(seed):
    topo = fleet_topo(16)
    scn = rich_timeline(topo, seed=seed).compile(topo)
    for seg in scn.segments:
        _check_segment_identity(seg)


def test_matrix_matches_per_element_queries():
    """matrix() (vectorized, sparse-state-fed) == brute-force expected
    times from the same model state, mid-outage and mid-degrade."""
    topo = fleet_topo(16)
    link = LinkTimeModel(topo, jitter=0.0, slowdown_range=(1.0, 1.0),
                         seed=3, scenario=rich_timeline(topo),
                         dead_link_timeout=5.0)
    for now in (0.0, 2.5, 4.5, 8.0):
        T = link.matrix(now)
        seg = link.current_segment
        M = topo.n_workers
        for i in range(M):
            for m in range(M):
                if i == m:
                    assert T[i, m] == 0.0
                elif seg.link_dead(i, m):
                    assert T[i, m] == max(link.compute_time, 5.0)
                else:
                    exp = link.base_times[topo.tier(i, m)]
                    exp *= seg.degrade_factor(i, m)
                    assert T[i, m] == max(link.compute_time, exp)


def test_link_scale_dict_bit_identical_to_dense():
    """The sparse {(i, m): f} link_scale form must reproduce the legacy
    dense-array form bit-for-bit (same seed => same jitter stream)."""
    topo = fleet_topo(16)
    M = topo.n_workers
    dense = np.ones((M, M))
    entries = {(0, 9): 3.5, (9, 0): 0.25, (3, 12): 17.0}
    for (i, m), f in entries.items():
        dense[i, m] = f
    a = LinkTimeModel(topo, jitter=0.05, seed=11, link_scale=dense)
    b = LinkTimeModel(topo, jitter=0.05, seed=11, link_scale=dict(entries))
    rng = np.random.default_rng(0)
    for q in range(200):
        i = int(rng.integers(M))
        m = int(rng.integers(M - 1))
        m = m if m < i else m + 1
        now = q * 0.05
        assert a.network_time(i, m, now) == b.network_time(i, m, now)
    assert np.array_equal(a.matrix(12.0), b.matrix(12.0))


# --------------------------------------------------------------------------
# O(M) link-state memory (satellite 4: the fleet memory pins)
# --------------------------------------------------------------------------


def test_link_state_memory_is_o_m():
    sizes = (256, 1024)
    nbytes = {}
    for M in sizes:
        topo = fleet_topo(M)
        tl = Timeline(
            [ClusterOutage(0, 1.0, 4.0)]
            + [LinkDegrade(0, m, 0.0, 5.0, 10.0) for m in range(1, 4)]
        )
        link = LinkTimeModel(topo, seed=0, scenario=tl)
        n_seg = len(link.compiled_scenario.segments)
        dense_equiv = n_seg * M * M * 9  # per-segment dead bool + degrade f64
        assert link.link_state_nbytes() * 20 < dense_equiv, (
            f"link state {link.link_state_nbytes()}B is not far below "
            f"dense {dense_equiv}B"
        )
        nbytes[M] = link.compiled_scenario.nbytes
    # Linear growth of the compiled segments: 4x the workers => ~4x the
    # bytes, never ~16x.  (The model's total link_state_nbytes also holds
    # the per-cluster-pair WAN AR(1) state — O(n_clusters^2), which is
    # M^2/256 under multi_cluster and already covered by the dense-floor
    # assertion above.)
    assert nbytes[1024] < 6 * nbytes[256]


@pytest.mark.slow
def test_fleet_smoke_m1024():
    """Whole batched run at M=1024 under an active outage: completes,
    learns, and stays O(M) in link state with a pinned host-peak budget."""
    import tracemalloc

    M, events = 1024, 1500
    topo = fleet_topo(M)
    x, y, ex, ey = train_eval_split(4000, 800, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    tl = Timeline([ClusterOutage(topo.n_clusters - 1, 0.0, float("inf"))])
    link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=tl,
                         dead_link_timeout=5.0)
    cfg = SimConfig(algorithm="adpsgd", n_workers=M, total_events=events,
                    lr=0.05, batch_size=16, seed=0, engine="batched")
    tracemalloc.start()
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=events)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert res.events[-1] == events
    assert np.isfinite(res.losses[-1])
    assert len(res.failed_pulls) > 0  # the outage was live
    # O(M) link state: far below one dense (M, M) float64 mask.
    assert link.link_state_nbytes() * 20 < M * M * 8
    # Host peak pins the no-dense-in-M regression: an accidental (M, M)
    # float64 matrix per *worker pair* structure (the pre-PR EMA default
    # alone was M * M * 8B = 8.4 MB x overhead) would blow through this.
    assert peak < 300 * 1024 * 1024, f"host peak {peak / 1e6:.0f} MB"


# --------------------------------------------------------------------------
# Federated-cohorts preset (tentpole: fleet participation pattern)
# --------------------------------------------------------------------------


def test_federated_cohorts_deterministic_and_bounded():
    topo = fleet_topo(64)
    a = presets.federated_cohorts(topo, seed=4, horizon=20.0, rounds=5,
                                  cohort_size=8, carryover=2)
    b = presets.federated_cohorts(topo, seed=4, horizon=20.0, rounds=5,
                                  cohort_size=8, carryover=2)
    assert [repr(e) for e in a.events] == [repr(e) for e in b.events]
    scn = a.compile(topo)
    # Active cohort is exactly cohort_size inside every round window.
    for r in range(5):
        mid = (r + 0.5) * 4.0
        assert scn.active_workers(mid).sum() == 8
    # Carryover threads consensus: every rejoin has a live reseed source
    # (compile would raise otherwise), and the timeline stays O(rounds).
    assert len(a.events) < 64 + 5 * 2 * 8


def test_federated_cohorts_validation():
    topo = fleet_topo(16)
    with pytest.raises(ValueError, match="cohort_size"):
        presets.federated_cohorts(topo, 0, 10.0, 2, cohort_size=17)
    with pytest.raises(ValueError, match="carryover"):
        presets.federated_cohorts(topo, 0, 10.0, 2, cohort_size=4,
                                  carryover=5)
    with pytest.raises(ValueError, match="fresh"):
        presets.federated_cohorts(topo, 0, 10.0, 2, cohort_size=12,
                                  carryover=1)
    with pytest.raises(ValueError, match="horizon"):
        presets.federated_cohorts(topo, 0, float("inf"), 2, cohort_size=4)


def test_federated_cohorts_engine_parity():
    """Reference vs batched on the churning-cohort timeline (the sparse
    link state + leave/rejoin path): exact host-side parity."""
    M, events = 16, 400
    topo = fleet_topo(M)
    tl = presets.federated_cohorts(topo, seed=2, horizon=3.0, rounds=3,
                                   cohort_size=6, carryover=2)
    x, y, ex, ey = train_eval_split(1600, 400, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)

    def run(engine):
        link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=tl,
                             dead_link_timeout=2.0)
        cfg = SimConfig(algorithm="adpsgd", n_workers=M, total_events=events,
                        lr=0.05, batch_size=16, seed=0, engine=engine,
                        trace=True)
        return simulate(cfg, link, x, y, parts, ex, ey, record_every=100)

    ref, bat = run("reference"), run("batched")
    assert ref.times == bat.times
    assert ref.trace_events == bat.trace_events
    assert ref.failed_pulls == bat.failed_pulls
    assert ref.comm_time == bat.comm_time
    np.testing.assert_allclose(ref.losses, bat.losses, atol=5e-4)


# --------------------------------------------------------------------------
# Device-sharded execution path (tentpole: mesh-split stacked replicas)
# --------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import numpy as np
from repro.core.nettime import LinkTimeModel, Topology
from repro.data.partition import uniform_partition
from repro.data.synthetic import train_eval_split
from repro.train.simulator import SimConfig, simulate

M, events = 8, 300
topo = Topology.multi_cluster(M, workers_per_host=2, hosts_per_pod=1,
                              pods_per_cluster=2)
x, y, ex, ey = train_eval_split(1600, 400, 32, 10, seed=0)
parts = uniform_partition(len(y), M, seed=0)

def run(shard):
    link = LinkTimeModel(topo, jitter=0.02, seed=5)
    cfg = SimConfig(algorithm="adpsgd", n_workers=M, total_events=events,
                    lr=0.05, batch_size=16, seed=0, engine="batched",
                    shard_workers=shard, trace=True)
    return simulate(cfg, link, x, y, parts, ex, ey, record_every=100)

dense, sharded = run(False), run(True)
assert dense.times == sharded.times
assert dense.trace_events == sharded.trace_events
assert dense.dispatches != sharded.dispatches  # genuinely different path
np.testing.assert_allclose(dense.losses, sharded.losses, atol=5e-4)
import jax
assert len(jax.devices()) == 8  # the mesh really had 8 devices
print("SHARDED-PARITY-OK", dense.losses[-1])
"""


@pytest.mark.slow
def test_sharded_engine_parity_subprocess():
    """shard_workers=True on a forced 8-device host mesh reproduces the
    dense batched engine (subprocess: XLA device count is fixed at first
    jax import, so the mesh shape needs a fresh interpreter)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-PARITY-OK" in proc.stdout


def test_shard_workers_rejects_unsupported_shapes():
    M = 6
    topo = Topology(n_workers=M, workers_per_host=3, hosts_per_pod=2)
    x, y, ex, ey = train_eval_split(800, 200, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    link = LinkTimeModel(topo, seed=5)
    cfg = SimConfig(algorithm="ps-async", n_workers=M, total_events=50,
                    lr=0.05, seed=0, engine="batched", shard_workers=True)
    with pytest.raises(ValueError, match="gossip"):
        simulate(cfg, link, x, y, parts, ex, ey, record_every=50)


@pytest.mark.slow
def test_fleet_storm_smoke_m1024():
    """Fleet-sized cascading storm (PR 9): the federated-cohorts churn
    pattern composed with a storm timeline (worker_blips=False — the
    cohort preset owns worker churn) at M=1024.  Pins that the EventHeap's
    lazy invalidation and the O(M) link state survive a storm's boundary
    density: the run completes, learns, and stays inside the same host
    peak budget as the quiet fleet smoke."""
    import tracemalloc

    from repro.scenarios import storm

    M, events = 1024, 1500
    topo = fleet_topo(M)
    x, y, ex, ey = train_eval_split(4000, 800, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    cohorts = presets.federated_cohorts(topo, seed=1, horizon=40.0, rounds=4,
                                        cohort_size=256, carryover=8)
    blast = storm(topo, seed=9, horizon=40.0, intensity=5.0,
                  trigger_cluster=0, trigger_time=1.0, worker_blips=False)
    tl = Timeline(list(cohorts.events) + list(blast.events))
    link = LinkTimeModel(topo, jitter=0.02, seed=5, scenario=tl,
                         dead_link_timeout=5.0)
    n_seg = len(link.compiled_scenario.segments)
    assert n_seg > 10  # the storm produced real boundary density
    cfg = SimConfig(algorithm="adpsgd", n_workers=M, total_events=events,
                    lr=0.05, batch_size=16, seed=0, engine="batched")
    tracemalloc.start()
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=events)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert res.events[-1] == events
    assert np.isfinite(res.losses[-1])
    assert res.failed_pulls  # the storm actually bit the active cohort
    # O(M) per segment: a storm's boundary density multiplies segments,
    # not the per-segment footprint — the compiled state must stay far
    # below one dense (M, M) mask *per segment*.
    assert link.link_state_nbytes() * 20 < n_seg * M * M * 9
    # Same host-peak budget as the quiet M=1024 smoke: a storm must not
    # change the memory class of the run.
    assert peak < 300 * 1024 * 1024, f"host peak {peak / 1e6:.0f} MB"
