"""SPMD tests in a subprocess (8 host devices) — keeps the main test
process at 1 device per the harness contract.

Covers: gossip lowering equivalence (gather == masked_psum == ppermute for
permutation draws), sharded NetMax train step == single-device reference,
and collective presence in the lowered HLO.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import gossip
    from repro.launch.mesh import make_debug_mesh

    out = {}
    mesh = make_debug_mesh(n_workers=4, tp=2)
    M = 4
    rng = np.random.default_rng(0)
    tree = {
        "w": jnp.asarray(rng.normal(size=(M, 16, 8)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(M, 8)).astype(np.float32)),
    }
    sh = NamedSharding(mesh, P("data", None))
    tree = jax.tree_util.tree_map(lambda x: jax.device_put(x, NamedSharding(mesh, P(("data",), *([None] * (x.ndim - 1))))), tree)
    perm = (1, 2, 3, 0)
    neighbors = jnp.asarray(np.array(perm), dtype=jnp.int32)

    g1 = jax.jit(lambda t: gossip.pull_gather(t, neighbors))(tree)
    g2 = jax.jit(lambda t: gossip.pull_masked_psum(t, neighbors, M))(tree)
    g3 = jax.jit(lambda t: gossip.pull_ppermute(t, perm, mesh, ("data",)))(tree)
    out["gather_vs_psum"] = float(
        max(jnp.abs(a - b).max() for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
    )
    out["gather_vs_ppermute"] = float(
        max(jnp.abs(a - b).max() for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g3)))
    )

    # collective opcodes present in lowered HLO
    txt = jax.jit(lambda t: gossip.pull_ppermute(t, perm, mesh, ("data",))).lower(tree).compile().as_text()
    out["ppermute_in_hlo"] = "collective-permute" in txt

    # sharded NetMax step == single-device step
    from dataclasses import replace
    from repro.configs.base import get_arch
    from repro.optim import sgd
    from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step

    cfg = replace(get_arch("qwen1.5-0.5b").reduced(), vocab_size=128)
    opt = sgd(momentum=0.9)
    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    rngb = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rngb.integers(0, 128, size=(M, 2, 32)), jnp.int32),
        "labels": jnp.asarray(rngb.integers(0, 128, size=(M, 2, 32)), jnp.int32),
    }
    gossip_in = {
        "neighbors": neighbors,
        "weights": jnp.asarray([0.3, 0.0, 0.5, 0.25], jnp.float32),
        "lr": jnp.float32(0.05),
    }
    step = make_train_step(cfg, opt, M, TrainStepConfig(gossip_mode="gather"))
    p_ref, _, m_ref = jax.jit(step)(params, opt_state, batch, gossip_in)

    def shard(t, spec_fn):
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, spec_fn(x))), t
        )
    lead = lambda x: P(("data",), *([None] * (x.ndim - 1)))
    params_s = shard(params, lead)
    opt_s = shard(opt_state, lead)
    batch_s = shard(batch, lead)
    p_sh, _, m_sh = jax.jit(step)(params_s, opt_s, batch_s, gossip_in)
    out["sharded_vs_ref"] = float(
        max(
            jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()
            for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_sh))
        )
    )
    out["loss_match"] = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
    print("RESULT " + json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def spmd_results():
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env and k != "XLA_FLAGS"})
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=600, env=env, cwd=str(ROOT),
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_gossip_lowerings_equivalent(spmd_results):
    assert spmd_results["gather_vs_psum"] < 1e-5
    assert spmd_results["gather_vs_ppermute"] < 1e-6


def test_ppermute_lowers_to_collective_permute(spmd_results):
    assert spmd_results["ppermute_in_hlo"] is True


def test_sharded_train_step_matches_reference(spmd_results):
    assert spmd_results["sharded_vs_ref"] < 5e-3
    assert spmd_results["loss_match"] < 1e-4
