"""Differential + property tests for the bounded-variable revised simplex.

The dense two-phase tableau solver (``repro.solver.dense``) is the oracle,
the same way the reference event loop anchors the batched engine:

  * revised vs dense on randomized Eq.-14 policy instances (M = 4..32;
    dense, sparse, degenerate-homogeneous, and infeasible) and on raw
    random LPs — statuses match, objectives match, solutions feasible;
  * warm-started re-solves reach the same optimum as cold starts across
    both warm-start axes (t_bar grid: only b changes; rho steps: only the
    Eq.-11 bound floors change) in strictly fewer pivots;
  * the full Algorithm-3 stack picks the *same grid point* (rho, t_bar)
    through either backend on the tests/test_policy.py fixtures.
"""

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import policy
from repro.core.policy import WarmStartCarry, _solve_policy_lp, _t_bar_interval
from repro.solver.dense import solve_lp_dense
from repro.solver.lp import lp_method, solve_lp
from repro.solver.result import BasisState
from repro.solver.revised import solve_lp_revised


def hetero_times(M, seed, slow_factor=10.0):
    rng = np.random.default_rng(seed)
    T = rng.uniform(0.01, 0.05, size=(M, M))
    T = (T + T.T) / 2
    i, m = rng.choice(M, size=2, replace=False)
    T[i, m] = T[m, i] = T[i, m] * slow_factor
    np.fill_diagonal(T, 0.0)
    return T


def sparse_mask(M, seed, density=0.6):
    rng = np.random.default_rng(seed)
    d = (rng.uniform(size=(M, M)) < density).astype(float)
    d = np.maximum(d, d.T)
    np.fill_diagonal(d, 0.0)
    for i in range(M):
        if d[i].sum() == 0:
            j = (i + 1) % M
            d[i, j] = d[j, i] = 1.0
    return d


def eq14_instance(M, seed, kind):
    """(T, d, rho, t_bar) spanning the shapes Algorithm 3 actually emits."""
    alpha = 0.1
    if kind == "dense":
        T = hetero_times(M, seed)
        d = np.ones((M, M)) - np.eye(M)
    elif kind == "sparse":
        T = hetero_times(M, seed)
        d = sparse_mask(M, seed)
    elif kind == "degenerate":  # homogeneous times: massively dual-degenerate
        T = np.full((M, M), 0.02)
        np.fill_diagonal(T, 0.0)
        d = np.ones((M, M)) - np.eye(M)
    else:  # "infeasible": rho so large the floors overflow the row budget
        T = hetero_times(M, seed)
        d = np.ones((M, M)) - np.eye(M)
        return T, d, 10.0 / alpha, 0.02
    rng = np.random.default_rng(seed + 99)
    rho = float(rng.uniform(0.05, 0.8))
    L, U = _t_bar_interval(T, d, alpha, rho)
    if not np.isfinite(U) or U <= L:
        return None
    t_bar = L + (U - L) * float(rng.uniform(0.2, 0.9))
    return T, d, rho, t_bar


# --------------------------------------------------------------------------
# Differential: revised vs dense oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "sparse", "degenerate", "infeasible"])
@pytest.mark.parametrize("M", [4, 8, 16, 32])
def test_revised_matches_dense_on_eq14(M, kind):
    inst = eq14_instance(M, seed=M * 7 + len(kind), kind=kind)
    if inst is None:
        pytest.skip("empty t_bar interval for this draw")
    T, d, rho, t_bar = inst
    if M == 32 and kind != "sparse":
        # dense-oracle tableau is O(M^2) x O(M^2): keep the slowest cell out
        # of tier-1 (sparse at M=32 stays small enough).
        pytest.skip("dense oracle too slow at M=32 full graph")
    with lp_method("dense"):
        P_d = _solve_policy_lp(T, d, 0.1, rho, t_bar)
    P_r = _solve_policy_lp(T, d, 0.1, rho, t_bar)
    assert (P_d is None) == (P_r is None)
    if P_d is None:
        return
    # Same optimum (objective = total self-selection); the argmin vertex may
    # legitimately differ under degeneracy, the value may not.
    assert np.trace(P_r) == pytest.approx(np.trace(P_d), abs=1e-6)
    # Revised solution satisfies Eq. (10)/(13)/(11) and the box.
    M_ = T.shape[0]
    assert np.allclose(P_r.sum(axis=1), 1.0, atol=1e-6)
    t_rows = (T * P_r * d).sum(axis=1)
    assert np.allclose(t_rows, M_ * t_bar, atol=1e-6)
    edge = (d != 0) & ~np.eye(M_, dtype=bool)
    floors = 0.1 * rho * (d + d.T)[edge]
    assert np.all(P_r[edge] >= floors - 1e-8)
    assert np.all(P_r >= -1e-9) and np.all(P_r <= 1.0 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_revised_matches_dense_on_random_lps(seed):
    rng = np.random.default_rng(seed)
    n, m = int(rng.integers(4, 12)), int(rng.integers(1, 5))
    A = rng.normal(size=(m, n))
    c = rng.normal(size=n)
    if seed % 3 == 0:
        x0 = rng.uniform(0.1, 0.9, size=n)
        b = A @ x0
        lb, ub = np.zeros(n), np.ones(n)
    elif seed % 3 == 1:
        x0 = rng.uniform(0.1, 2.0, size=n)
        b = A @ x0
        lb, ub = np.zeros(n), np.full(n, np.inf)
    else:  # arbitrary b: frequently infeasible
        lb = rng.uniform(-1, 0.5, size=n)
        ub = lb + rng.uniform(0.1, 2.0, size=n)
        b = rng.normal(size=m)
    res_d = solve_lp_dense(c, A, b, lb, ub)
    res_r = solve_lp_revised(c, A, b, lb, ub)
    assert res_d.status == res_r.status
    if res_d.ok:
        assert res_r.fun == pytest.approx(res_d.fun, rel=1e-6, abs=1e-7)
        assert np.allclose(A @ res_r.x, b, atol=1e-6)
        assert np.all(res_r.x >= lb - 1e-7)
        assert np.all(res_r.x <= ub + 1e-7)


def test_unbounded_detected():
    # min -x0, x0 - x1 == 0, x >= 0 unbounded above.
    r = solve_lp_revised(
        np.array([-1.0, 0.0]), np.array([[1.0, -1.0]]), np.array([0.0])
    )
    assert r.status == "unbounded"


def test_infeasible_box():
    r = solve_lp_revised(
        np.array([1.0]), np.array([[1.0]]), np.array([5.0]),
        lb=np.array([0.0]), ub=np.array([1.0]),
    )
    assert r.status == "infeasible"


def test_bound_flip_path():
    # Optimum needs x1 nonbasic AT its upper bound: exercises the implicit-
    # bound flip the dense oracle needs a slack row for.
    r = solve_lp_revised(
        np.array([-1.0, -2.0]),
        np.array([[1.0, 1.0]]),
        np.array([1.0]),
        ub=np.array([0.6, 0.6]),
    )
    assert r.ok
    assert r.fun == pytest.approx(-1.6)
    assert r.x == pytest.approx([0.4, 0.6])


# --------------------------------------------------------------------------
# Warm-start protocol
# --------------------------------------------------------------------------


def test_warm_start_equals_cold_start_across_t_bar_grid():
    """Across the inner grid only b changes: warm restarts must hit the same
    optimum as cold solves, in (far) fewer pivots overall."""
    M = 12
    T = hetero_times(M, 5)
    d = np.ones((M, M)) - np.eye(M)
    alpha, rho = 0.1, 0.1
    L, U = _t_bar_interval(T, d, alpha, rho)
    assert np.isfinite(U) and U > L
    carry = WarmStartCarry()
    cold_pivots = 0
    n_compared = 0
    for r in range(1, 9):
        t_bar = L + (U - L) * r / 8
        cold_carry = WarmStartCarry()
        P_cold = _solve_policy_lp(T, d, alpha, rho, t_bar, carry=cold_carry)
        P_warm = _solve_policy_lp(T, d, alpha, rho, t_bar, carry=carry)
        assert (P_cold is None) == (P_warm is None)
        if P_cold is None:
            continue
        assert np.trace(P_warm) == pytest.approx(np.trace(P_cold), abs=1e-7)
        n_compared += 1
        cold_pivots += cold_carry.n_pivots
    assert n_compared >= 2
    assert carry.n_warm_used >= n_compared - 1
    assert carry.n_pivots < cold_pivots  # warm sweeps beat cold sweeps


def test_warm_start_equals_cold_start_across_rho_steps():
    """Across rho steps only the Eq.-11 floors move (dual feasibility is
    preserved): one shared carry across the whole (rho, t_bar) sweep must
    reproduce every cold optimum."""
    M = 10
    T = hetero_times(M, 11)
    d = sparse_mask(M, 11, density=0.7)
    alpha = 0.1
    carry = WarmStartCarry()
    n_compared = 0
    for rho in (0.05, 0.1, 0.15, 0.2):
        L, U = _t_bar_interval(T, d, alpha, rho)
        if not np.isfinite(U) or U <= L:
            continue
        for frac in (0.3, 0.7):
            t_bar = L + (U - L) * frac
            P_cold = _solve_policy_lp(T, d, alpha, rho, t_bar)
            P_warm = _solve_policy_lp(T, d, alpha, rho, t_bar, carry=carry)
            assert (P_cold is None) == (P_warm is None)
            if P_cold is not None:
                assert np.trace(P_warm) == pytest.approx(
                    np.trace(P_cold), abs=1e-7
                )
                n_compared += 1
    assert n_compared >= 3
    assert carry.n_warm_used >= 1


def test_stale_basis_is_validated_not_trusted():
    """A wrong-shape or corrupted basis must be rejected (cold fallback),
    never crash or corrupt the solve."""
    n, m = 8, 3
    rng = np.random.default_rng(0)
    A = rng.normal(size=(m, n))
    b = A @ rng.uniform(0.2, 0.8, size=n)
    c = rng.normal(size=n)
    lb, ub = np.zeros(n), np.ones(n)
    ref = solve_lp_revised(c, A, b, lb, ub)
    assert ref.ok
    stale_shapes = [
        BasisState(key=(m + 1, n), basis=np.arange(m + 1), vstat=np.zeros(n, np.int8)),
        BasisState(key=(m, n), basis=np.array([0, 0, 1]), vstat=np.zeros(n, np.int8)),
        BasisState(key=(m, n), basis=np.array([0, 1, n + 5]), vstat=np.zeros(n, np.int8)),
    ]
    for stale in stale_shapes:
        r = solve_lp_revised(c, A, b, lb, ub, warm=stale)
        assert r.ok and not r.warm_used
        assert r.fun == pytest.approx(ref.fun, abs=1e-8)
    # A *valid but unrelated* basis from a same-shaped different instance is
    # accepted or rejected, but either way the optimum is exact.
    A2 = rng.normal(size=(m, n))
    b2 = A2 @ rng.uniform(0.2, 0.8, size=n)
    other = solve_lp_revised(c, A2, b2, lb, ub)
    assert other.ok and other.basis is not None
    r = solve_lp_revised(c, A, b, lb, ub, warm=other.basis)
    assert r.ok
    assert r.fun == pytest.approx(ref.fun, abs=1e-7)


def test_warm_start_with_infinite_lower_bounds_never_crashes():
    """Regression: dual-feasibility forcing must not flip an AT_UB variable
    to an infinite lower bound (that injected -inf into the restart and
    crashed instead of cold-starting)."""
    rng = np.random.default_rng(3)
    n, m = 8, 3
    lb = np.where(rng.uniform(size=n) < 0.5, -np.inf, 0.0)
    ub = np.full(n, 2.0)
    for trial in range(12):
        A = rng.normal(size=(m, n))
        b = A @ rng.uniform(0.1, 0.9, size=n)
        c = rng.normal(size=n)
        r1 = solve_lp_revised(c, A, b, lb, ub)
        if not r1.ok or r1.basis is None:
            continue
        c2 = rng.normal(size=n)  # new costs: forces status flips
        b2 = b * rng.uniform(0.9, 1.1, size=m)
        cold = solve_lp_revised(c2, A, b2, lb, ub)
        warm = solve_lp_revised(c2, A, b2, lb, ub, warm=r1.basis)
        assert cold.status == warm.status
        if cold.ok:
            assert warm.fun == pytest.approx(cold.fun, rel=1e-6, abs=1e-7)


def test_eq14_precheck_verdict_matches_lp():
    """The _eq14_time_bounds skip must agree with the LP's own verdict —
    checked directly (pre-check bypassed), including points well outside
    the Appendix-A interval, so a wrong skip cannot hide behind the two
    backends sharing the same pre-check."""
    from repro.core.policy import _eq14_time_bounds

    n_skippable = 0
    for seed in range(8):
        for M in (4, 6, 8):
            T = hetero_times(M, seed)
            d = sparse_mask(M, seed) if seed % 2 else np.ones((M, M)) - np.eye(M)
            rng = np.random.default_rng(seed + 7)
            rho = float(rng.uniform(0.05, 0.6))
            L, U = _t_bar_interval(T, d, 0.1, rho)
            if not np.isfinite(U) or U <= L:
                continue
            lo, hi = _eq14_time_bounds(T, d, 0.1, rho)
            for frac in (-0.5, 0.05, 0.3, 0.6, 0.95, 1.5):
                t_bar = L + (U - L) * frac
                if t_bar <= 0:
                    continue
                target = M * t_bar
                tol = 1e-6 * max(1.0, abs(target))
                skip = target < lo - tol or target > hi + tol
                P = _solve_policy_lp(T, d, 0.1, rho, t_bar)
                if skip:
                    n_skippable += 1
                    assert P is None  # a skip must never drop a feasible point
    assert n_skippable >= 5  # the pre-check actually fired


def test_monitor_threads_basis_across_refreshes():
    from repro.core.monitor import NetworkMonitor

    M = 6
    rng = np.random.default_rng(2)
    base = hetero_times(M, 2)
    mon = NetworkMonitor(n_workers=M, alpha=0.1, K=4, R=4)
    mon.collect({i: base[i] for i in range(M)})
    mon.step()
    assert mon._basis is not None
    first_pivots = mon.history[-1]["n_pivots"]
    # Second refresh with slightly drifted times: warm restarts kick in.
    drift = base * rng.uniform(0.95, 1.05, size=(M, M))
    np.fill_diagonal(drift, 0.0)
    mon.collect({i: drift[i] for i in range(M)})
    res = mon.step()
    assert res.ok
    assert res.n_warm_used > 0
    assert mon.history[-1]["n_pivots"] < first_pivots


# --------------------------------------------------------------------------
# Full-stack exact pin: Algorithm 3 picks the same grid point either way
# --------------------------------------------------------------------------


def _slowlink8():
    M = 8
    T = np.full((M, M), 0.04)
    for i in range(M):
        for m in range(M):
            if (i < 4) == (m < 4):
                T[i, m] = 0.01
    np.fill_diagonal(T, 0.0)
    T[0, 4] = T[4, 0] = 0.4
    return T


def _deadlink6():
    M = 6
    T = np.full((M, M), 0.02)
    np.fill_diagonal(T, 0.0)
    T[1, 3] = T[3, 1] = np.inf
    return T


def _pin_fixtures():
    """tests/test_policy.py fixtures on which the grid-point pin is exact."""
    out = [(f"hetero{M}s{seed}", hetero_times(M, seed), None)
           for seed, M in ((0, 4), (7, 8), (1, 12))]
    out.append(("deadlink6", _deadlink6(), None))
    out.append(("sparse16", hetero_times(16, 4), sparse_mask(16, 4, 0.4)))
    return out


def _run_both(T, d):
    rev = policy.generate_policy_matrix(0.1, K=6, R=6, T=T, d=d)
    with lp_method("dense"):
        den = policy.generate_policy_matrix(0.1, K=6, R=6, T=T, d=d)
    # Both backends must mark the same grid points feasible.
    feas_r = [(g[0], g[1]) for g in rev.grid if np.isfinite(g[3])]
    feas_d = [(g[0], g[1]) for g in den.grid if np.isfinite(g[3])]
    assert feas_r == feas_d
    return rev, den


@pytest.mark.parametrize(
    "name,T,d", _pin_fixtures(), ids=[f[0] for f in _pin_fixtures()]
)
def test_generate_policy_matrix_same_grid_point_as_dense(name, T, d):
    rev, den = _run_both(T, d)
    # Exact pin: identical grid point selected (rho and t_bar are exact
    # grid arithmetic, not solver output, so equality is bitwise).
    assert rev.rho == den.rho
    assert rev.t_bar == den.t_bar
    # The LP objective (total self-selection mass) is the solver-level
    # invariant and is pinned tightly; lambda2/T_convergence are vertex
    # functionals and may differ under degenerate alternate optima.
    assert np.trace(rev.P) == pytest.approx(np.trace(den.P), abs=1e-6)


@pytest.mark.parametrize(
    "name,T,d",
    [("hetero6s3", hetero_times(6, 3), None), ("slowlink8", _slowlink8(), None)],
    ids=["hetero6s3", "slowlink8"],
)
def test_generate_policy_matrix_near_tie_fixtures(name, T, d):
    """On heavily degenerate fixtures the two backends sit on different
    optimal vertices, whose lambda2 can flip near-tied grid points.  The
    guarantee that survives: the revised choice is a *near-tie* — scored by
    the dense path's own grid, it is within 5% of the dense optimum — and
    every per-point LP objective matches."""
    rev, den = _run_both(T, d)
    dense_scores = {(g[0], g[1]): g[3] for g in den.grid}
    assert (rev.rho, rev.t_bar) in dense_scores
    assert dense_scores[(rev.rho, rev.t_bar)] <= 1.05 * den.T_convergence
    assert rev.T_convergence <= 1.05 * den.T_convergence


def test_facade_method_switch_and_default():
    from repro.solver import lp

    assert lp.default_method() == "revised"
    with lp_method("dense"):
        assert lp.default_method() == "dense"
        r = solve_lp(
            np.array([1.0, 1.0]), np.array([[1.0, 1.0]]), np.array([1.0])
        )
        assert r.ok and r.basis is None  # dense backend: no basis token
    assert lp.default_method() == "revised"
    r = solve_lp(np.array([1.0, 1.0]), np.array([[1.0, 1.0]]), np.array([1.0]))
    assert r.ok and r.basis is not None
    with pytest.raises(ValueError):
        solve_lp(
            np.array([1.0]), np.array([[1.0]]), np.array([1.0]),
            method="interior-point",
        )


# --------------------------------------------------------------------------
# Pricing rules, engines, sparse-LU drift (PR 8)
# --------------------------------------------------------------------------


def _eq14_lp(M, seed, kind, alpha=0.1):
    """Raw (c, A, b, lb, ub) arrays for an Eq.-14 draw, or None.

    For feasible kinds t_bar is picked inside the *exact* feasible range
    (the Appendix-A interval is necessary, not sufficient), halving rho
    until that range opens, so the optimum-matching assertions actually
    exercise optima."""
    if kind == "infeasible":
        inst_pt = eq14_instance(M, seed, kind)
        if inst_pt is None:
            return None
        T, d, rho, t_bar = inst_pt
    else:
        T = (
            np.full((M, M), 0.02) - 0.02 * np.eye(M)
            if kind == "degenerate"
            else hetero_times(M, seed)
        )
        d = (
            sparse_mask(M, seed)
            if kind == "sparse"
            else np.ones((M, M)) - np.eye(M)
        )
        rho = float(np.random.default_rng(seed + 99).uniform(0.05, 0.8))
        for _ in range(8):
            lo, hi = policy._eq14_time_bounds(T, d, alpha, rho)
            if np.isfinite(hi) and hi > lo:
                break
            rho /= 2.0
        else:
            return None
        t_bar = (lo + 0.6 * (hi - lo)) / M
    sk = policy._build_eq14(T, d)
    lb = np.zeros(sk.n)
    lb[sk.pos] = alpha * rho * sk.dsym + policy._FLOOR_MARGIN
    b = np.zeros(2 * sk.M)
    b[: sk.M] = sk.M * t_bar
    b[sk.M :] = 1.0
    A = sk.A.toarray() if hasattr(sk.A, "toarray") else sk.A
    return sk.c, A, b, lb, sk.ub


@pytest.mark.parametrize("pricing", ["dantzig", "partial", "devex"])
@pytest.mark.parametrize("kind", ["dense", "sparse", "degenerate", "infeasible"])
def test_pricing_rules_match_dense_oracle(pricing, kind):
    """Every pricing rule reaches the dense oracle's optimum (or verdict)
    on randomized Eq.-14 instances — the rotation in partial pricing and
    the reference-framework scores in Devex change the pivot *path*, never
    the optimum."""
    n_opt = 0
    for M, seed in ((4, 1), (8, 2), (16, 3), (16, 9)):
        lp5 = _eq14_lp(M, seed, kind)
        if lp5 is None:
            continue
        c, A, b, lb, ub = lp5
        ref = solve_lp_dense(c, A, b, lb=lb, ub=ub)
        for engine in ("dense", "lu"):
            r = solve_lp_revised(
                c, A, b, lb=lb, ub=ub, pricing=pricing, engine=engine
            )
            assert r.status == ref.status, (M, seed, engine)
            if ref.ok:
                assert r.fun == pytest.approx(ref.fun, rel=1e-6, abs=1e-7)
                assert np.allclose(A @ r.x, b, atol=1e-6)
                assert np.all(r.x >= lb - 1e-7) and np.all(r.x <= ub + 1e-7)
        if ref.ok:
            n_opt += 1
    if kind != "infeasible":
        assert n_opt >= 2  # the sweep exercised real optima


@pytest.mark.parametrize("M", [32, 64])
def test_pricing_rules_agree_at_scale(M):
    """At M = 32/64 (past the dense oracle's reach) all pricing rules and
    both engines agree with the revised-Dantzig reference, including when
    A arrives as a scipy CSC matrix."""
    sp = pytest.importorskip("scipy.sparse")
    lp5 = _eq14_lp(M, seed=5, kind="sparse")
    if lp5 is None:
        pytest.skip("empty t_bar interval for this draw")
    c, A, b, lb, ub = lp5
    ref = solve_lp_revised(c, A, b, lb=lb, ub=ub, pricing="dantzig")
    assert ref.ok
    A_sp = sp.csc_matrix(A)
    for pricing in ("partial", "devex", "auto"):
        for A_in in (A, A_sp):
            r = solve_lp_revised(c, A_in, b, lb=lb, ub=ub, pricing=pricing)
            assert r.ok
            assert r.fun == pytest.approx(ref.fun, rel=1e-6, abs=1e-7)
            assert np.allclose(A @ r.x, b, atol=1e-6)


def test_lu_engine_matches_dense_engine_on_random_lps():
    pytest.importorskip("scipy.sparse.linalg")
    rng = np.random.default_rng(17)
    n_ok = 0
    for trial in range(20):
        n, m = int(rng.integers(4, 12)), int(rng.integers(2, 6))
        A = rng.normal(size=(m, n))
        c = rng.normal(size=n)
        if trial % 2:
            b = A @ rng.uniform(0.1, 0.9, size=n)
            lb, ub = np.zeros(n), np.ones(n)
        else:
            b = rng.normal(size=m)
            lb, ub = np.zeros(n), np.full(n, np.inf)
        r_d = solve_lp_revised(c, A, b, lb=lb, ub=ub, engine="dense")
        r_l = solve_lp_revised(c, A, b, lb=lb, ub=ub, engine="lu")
        assert r_d.status == r_l.status
        if r_d.ok:
            n_ok += 1
            assert r_l.fun == pytest.approx(r_d.fun, rel=1e-6, abs=1e-7)
    assert n_ok >= 5


def test_sparse_lu_drift_bounded():
    """The eta file accumulates pivots between refactorizations; the primal
    solution it produces must still satisfy the constraints to tight
    tolerance (drift is reset by periodic refactorization, never allowed
    to reach the answer)."""
    pytest.importorskip("scipy.sparse.linalg")
    for M, seed in ((48, 3), (64, 8)):
        lp5 = _eq14_lp(M, seed, "dense")
        if lp5 is None:
            continue
        c, A, b, lb, ub = lp5
        r = solve_lp_revised(c, A, b, lb=lb, ub=ub, engine="lu", pricing="devex")
        assert r.ok
        assert r.pivots > 64  # long enough for at least one refactor cycle
        resid = np.abs(A @ r.x - b).max()
        assert resid <= 1e-7 * max(1.0, np.abs(b).max())
        assert np.all(r.x >= lb - 1e-8) and np.all(r.x <= ub + 1e-8)


def test_lu_warm_restart_matches_cold():
    """Warm restarts run through the LU engine too (the Monitor at M>=48
    lives on this path): same optimum, strictly fewer pivots."""
    pytest.importorskip("scipy.sparse.linalg")
    lp5 = _eq14_lp(48, 3, "dense")
    assert lp5 is not None
    c, A, b, lb, ub = lp5
    r1 = solve_lp_revised(c, A, b, lb=lb, ub=ub, engine="lu")
    assert r1.ok and r1.basis is not None
    b2 = b.copy()
    b2[:48] *= 1.02  # drift the Eq.-10 budget, keep Eq.-13 rows
    cold = solve_lp_revised(c, A, b2, lb=lb, ub=ub, engine="lu")
    warm = solve_lp_revised(c, A, b2, lb=lb, ub=ub, engine="lu", warm=r1.basis)
    assert cold.status == warm.status
    if cold.ok:
        assert warm.warm_used
        assert warm.fun == pytest.approx(cold.fun, rel=1e-7, abs=1e-9)
        assert warm.pivots < cold.pivots


def test_lp_pricing_context_manager():
    from repro.solver import lp

    assert lp.default_pricing() == "auto"
    with lp.lp_pricing("dantzig"):
        assert lp.default_pricing() == "dantzig"
        r = solve_lp(
            np.array([1.0, 1.0]), np.array([[1.0, 1.0]]), np.array([1.0])
        )
        assert r.ok
    assert lp.default_pricing() == "auto"
    with pytest.raises(ValueError):
        lp.lp_pricing("steepest-descent").__enter__()


# --------------------------------------------------------------------------
# Lockstep batched solver (PR 8)
# --------------------------------------------------------------------------


def test_solve_lp_batch_matches_serial_random():
    from repro.solver.batch import solve_lp_batch

    rng = np.random.default_rng(23)
    n, m, S = 10, 4, 12
    A = rng.normal(size=(m, n))
    c = rng.normal(size=n)
    b_stack = np.stack(
        [A @ rng.uniform(0.1, 0.9, size=n) for _ in range(S - 2)]
        + [rng.normal(size=m), rng.normal(size=m)]  # likely infeasible tail
    )
    lb = np.zeros((S, n))
    lb[3] = 0.05  # per-instance floors
    ub = np.ones((S, n))
    batch = solve_lp_batch(c, A, b_stack, lb_stack=lb, ub_stack=ub)
    assert len(batch) == S
    for s in range(S):
        ref = solve_lp_revised(c, A, b_stack[s], lb=lb[s], ub=ub[s])
        if batch[s].status == "iteration_limit":
            continue  # numerical breakdown escape hatch: never wrong, just out
        assert batch[s].status == ref.status, s
        if ref.ok:
            assert batch[s].fun == pytest.approx(ref.fun, rel=1e-6, abs=1e-7)
            assert np.allclose(A @ batch[s].x, b_stack[s], atol=1e-6)


def test_solve_lp_batch_eq14_t_bar_stack():
    """The batched sweep's actual shape: one Eq.-14 skeleton, a stack of
    t_bar right-hand sides."""
    from repro.solver.batch import solve_lp_batch

    T = hetero_times(10, 4)
    d = np.ones((10, 10)) - np.eye(10)
    alpha, rho = 0.1, 0.1
    sk = policy._build_eq14(T, d)
    L, U = _t_bar_interval(T, d, alpha, rho)
    assert np.isfinite(U) and U > L
    t_bars = [L + (U - L) * f for f in (0.2, 0.4, 0.6, 0.8)]
    lb = np.zeros(sk.n)
    lb[sk.pos] = alpha * rho * sk.dsym + policy._FLOOR_MARGIN
    b_stack = np.zeros((len(t_bars), 2 * sk.M))
    for s, tb in enumerate(t_bars):
        b_stack[s, : sk.M] = sk.M * tb
        b_stack[s, sk.M :] = 1.0
    A = sk.A.toarray() if hasattr(sk.A, "toarray") else sk.A
    batch = solve_lp_batch(sk.c, A, b_stack, lb_stack=lb, ub_stack=sk.ub)
    for s, tb in enumerate(t_bars):
        ref = solve_lp_revised(sk.c, A, b_stack[s], lb=lb, ub=sk.ub)
        assert batch[s].status == ref.status
        if ref.ok:
            assert batch[s].fun == pytest.approx(ref.fun, rel=1e-6, abs=1e-7)


# --------------------------------------------------------------------------
# jax lockstep batched solver (PR 10)
# --------------------------------------------------------------------------


def test_solve_lp_batch_jax_lockstep_with_numpy():
    """The jitted device sweep must walk the numpy lockstep path exactly:
    same statuses, same pivot counts (the simplex trajectory is identical,
    pivot for pivot), objectives equal to float64 round-off.  The S=12
    stack also exercises the power-of-two padding (pads to 16)."""
    pytest.importorskip("jax")
    from repro.solver.batch import solve_lp_batch
    from repro.solver.batch_jax import solve_lp_batch_jax

    rng = np.random.default_rng(23)
    n, m, S = 10, 4, 12
    A = rng.normal(size=(m, n))
    c = rng.normal(size=n)
    b_stack = np.stack(
        [A @ rng.uniform(0.1, 0.9, size=n) for _ in range(S - 2)]
        + [rng.normal(size=m), rng.normal(size=m)]
    )
    lb = np.zeros((S, n))
    lb[3] = 0.05
    ub = np.ones((S, n))
    ref = solve_lp_batch(c, A, b_stack, lb_stack=lb, ub_stack=ub)
    dev = solve_lp_batch_jax(c, A, b_stack, lb_stack=lb, ub_stack=ub)
    assert len(dev) == S
    for s in range(S):
        assert dev[s].status == ref[s].status, s
        assert dev[s].pivots == ref[s].pivots, s
        if ref[s].ok:
            assert dev[s].fun == pytest.approx(ref[s].fun, rel=1e-9, abs=1e-9)
            assert np.allclose(dev[s].x, ref[s].x, atol=1e-8)


@pytest.mark.slow
def test_batched_backend_jax_same_grid_point():
    """Acceptance pin: ``generate_policy_matrix_batched(backend="jax")``
    lands on the same (rho, t_bar) grid point as the numpy lockstep sweep
    across a randomized Eq.-14 suite (dense and sparse connectivity)."""
    pytest.importorskip("jax")

    cases = [(8, 0, False), (10, 1, False), (12, 2, True), (9, 3, True)]
    for M, seed, sparse in cases:
        T = hetero_times(M, seed)
        d = None
        if sparse:
            d = np.ones((M, M)) - np.eye(M)
            rng = np.random.default_rng(100 + seed)
            i, j = rng.integers(0, M, 2)
            while i == j:
                i, j = rng.integers(0, M, 2)
            d[i, j] = d[j, i] = 0.0
        pn = policy.generate_policy_matrix_batched(0.9, 6, 6, T, d=d)
        pj = policy.generate_policy_matrix_batched(
            0.9, 6, 6, T, d=d, backend="jax"
        )
        assert pj.rho == pn.rho, (M, seed)
        assert pj.t_bar == pn.t_bar, (M, seed)
        assert pj.ok == pn.ok
        assert np.allclose(pj.P, pn.P, atol=1e-12)


def test_batched_backend_rejects_unknown():
    T = hetero_times(6, 0)
    with pytest.raises(ValueError, match="backend"):
        policy.generate_policy_matrix_batched(0.9, 4, 4, T, backend="torch")
