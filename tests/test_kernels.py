"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes / dtypes / GQA ratios / causality per the deliverable spec.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gossip_mix import gossip_mix
from repro.kernels.rwkv_scan import rwkv_scan


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- flash attn

ATTN_CASES = [
    # (B, S, Sk, H, Hk, hd, causal, dtype)
    (1, 128, 128, 4, 4, 64, True, jnp.float32),
    (2, 256, 256, 8, 2, 64, True, jnp.float32),   # GQA G=4
    (1, 128, 128, 4, 1, 32, True, jnp.float32),   # MQA
    (2, 128, 256, 4, 4, 64, False, jnp.float32),  # cross-attn shapes
    (1, 256, 256, 2, 2, 128, True, jnp.bfloat16),
    (1, 512, 512, 4, 2, 64, True, jnp.float32),   # multiple q/kv blocks
]


@pytest.mark.parametrize("B,S,Sk,H,Hk,hd,causal,dtype", ATTN_CASES)
def test_flash_attention_matches_reference(B, S, Sk, H, Hk, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, Sk, Hk, hd), dtype)
    v = _rand(ks[2], (B, Sk, Hk, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.reference_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_block_shape_sweep():
    B, S, H, Hk, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, Hk, hd), jnp.float32)
    v = _rand(ks[2], (B, S, Hk, hd), jnp.float32)
    want = ref.reference_attention(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_flash_attention_property_random(seed):
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 3))
    S = int(rng.choice([128, 256]))
    Hk = int(rng.choice([1, 2]))
    G = int(rng.choice([1, 2, 4]))
    hd = int(rng.choice([32, 64]))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (B, S, Hk * G, hd), jnp.float32)
    k = _rand(ks[1], (B, S, Hk, hd), jnp.float32)
    v = _rand(ks[2], (B, S, Hk, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_flash_attention_rows_are_convex_combinations():
    """Property: each output row lies in the convex hull of V rows (softmax
    weights sum to 1) — catches normalization bugs."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (1, 128, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 128, 2, 32), jnp.float32)
    v = jnp.ones((1, 128, 2, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 1.0, atol=1e-5)


# ---------------------------------------------------------------- rwkv scan

RWKV_CASES = [
    # (B, S, H, N, chunk, dtype)
    (1, 64, 2, 16, 16, jnp.float32),
    (2, 128, 4, 32, 32, jnp.float32),
    (1, 128, 2, 64, 64, jnp.float32),
    (1, 256, 2, 16, 64, jnp.float32),  # multiple chunks
    (1, 128, 2, 32, 32, jnp.bfloat16),
]


def _rwkv_inputs(seed, B, S, H, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = _rand(ks[0], (B, S, H, N), dtype) * 0.5
    k = _rand(ks[1], (B, S, H, N), dtype) * 0.5
    v = _rand(ks[2], (B, S, H, N), dtype)
    # decays in (0.7, 1.0) like trained RWKV models
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N)) + 2.0).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, N)) * 0.1).astype(jnp.float32)
    return r, k, v, w.astype(dtype), u


@pytest.mark.parametrize("B,S,H,N,chunk,dtype", RWKV_CASES)
def test_rwkv_scan_matches_reference(B, S, H, N, chunk, dtype):
    r, k, v, w, u = _rwkv_inputs(0, B, S, H, N, dtype)
    got = rwkv_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    want = ref.reference_rwkv(r, k, v, w, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_rwkv_chunk_invariance():
    """Output must not depend on the chunk size (state handoff correct)."""
    r, k, v, w, u = _rwkv_inputs(3, 1, 128, 2, 16, jnp.float32)
    outs = [
        np.asarray(rwkv_scan(r, k, v, w, u, chunk=c, interpret=True))
        for c in (16, 32, 64, 128)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_rwkv_property_random(seed):
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 3))
    S = int(rng.choice([64, 128]))
    H = int(rng.choice([1, 2]))
    N = int(rng.choice([16, 32]))
    r, k, v, w, u = _rwkv_inputs(seed, B, S, H, N, jnp.float32)
    got = rwkv_scan(r, k, v, w, u, chunk=32, interpret=True)
    want = ref.reference_rwkv(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_rwkv_extreme_decay_clamped_semantics():
    """Decays stronger than the kernel's f32-safety clamp (e^-(75/chunk) per
    step) are clamped; the kernel must match the reference run with the SAME
    clamp — and stay finite where the unclamped factored form would overflow."""
    B, S, H, N = 1, 32, 1, 16
    chunk = 16
    r, k, v, _, u = _rwkv_inputs(4, B, S, H, N, jnp.float32)
    w0 = jnp.full((B, S, H, N), 1e-30, jnp.float32)
    got = rwkv_scan(r, k, v, w0, u, chunk=chunk, interpret=True)
    assert np.all(np.isfinite(np.asarray(got)))
    from repro.kernels.rwkv_scan import _SUB
    w_clamped = jnp.exp(jnp.clip(jnp.log(w0), -75.0 / min(_SUB, chunk), 0.0))
    want = ref.reference_rwkv(r, k, v, w_clamped, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------- gossip mix

MIX_CASES = [
    ((1024,), jnp.float32, 0.25),
    ((127, 33), jnp.float32, 0.8),       # non-divisible -> padding path
    ((8, 64, 32), jnp.bfloat16, 0.5),
    ((70000,), jnp.float32, 0.0),        # multi-block, w=0 edge
    ((256,), jnp.float32, 1.0),          # w=1 edge
]


@pytest.mark.parametrize("shape,dtype,w", MIX_CASES)
def test_gossip_mix_matches_reference(shape, dtype, w):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = _rand(ks[0], shape, dtype)
    u = _rand(ks[1], shape, dtype) * 0.01
    p = _rand(ks[2], shape, dtype)
    got = gossip_mix(x, u, p, jnp.float32(w), interpret=True, block=4096)
    want = ref.reference_gossip_mix(x, u, p, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


MIX_ROWS_CASES = [
    ((4, 1024), jnp.float32),
    ((3, 127, 33), jnp.float32),   # non-divisible trailing -> padding path
    ((8, 64, 32), jnp.bfloat16),
    ((1, 70000), jnp.float32),     # multi-block row
]


@pytest.mark.parametrize("shape,dtype", MIX_ROWS_CASES)
def test_gossip_mix_rows_matches_reference(shape, dtype):
    from repro.kernels.gossip_mix import gossip_mix_rows

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = _rand(ks[0], shape, dtype)
    u = _rand(ks[1], shape, dtype) * 0.01
    p = _rand(ks[2], shape, dtype)
    w = jnp.asarray(np.linspace(0.0, 1.0, shape[0]), jnp.float32)
    got = gossip_mix_rows(x, u, p, w, interpret=True, block=4096)
    want = ref.reference_gossip_mix_rows(x, u, p, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_gossip_mix_rows_agrees_with_per_row_scalar_kernel():
    """The rows kernel is exactly R stacked scalar-kernel calls."""
    from repro.kernels.gossip_mix import gossip_mix_rows

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    R, n = 5, 777
    x = _rand(ks[0], (R, n), jnp.float32)
    u = _rand(ks[1], (R, n), jnp.float32)
    p = _rand(ks[2], (R, n), jnp.float32)
    w = jnp.asarray([0.0, 0.25, 0.5, 0.9, 1.0], jnp.float32)
    got = gossip_mix_rows(x, u, p, w, interpret=True, block=512)
    for r in range(R):
        want = gossip_mix(x[r], u[r], p[r], w[r], interpret=True, block=512)
        np.testing.assert_allclose(
            np.asarray(got[r]), np.asarray(want), atol=1e-6, rtol=1e-6
        )


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_gossip_mix_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 5000))
    w = float(rng.uniform(0, 1))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(ks[0], (n,), jnp.float32)
    u = _rand(ks[1], (n,), jnp.float32)
    p = _rand(ks[2], (n,), jnp.float32)
    got = gossip_mix(x, u, p, jnp.float32(w), interpret=True, block=1024)
    want = (1 - w) * (x + u) + w * p
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)
