"""Integration tests: event-driven async simulator reproduces the paper's
qualitative claims on small real models."""

import numpy as np
import pytest

from repro.core.nettime import LinkTimeModel, Topology
from repro.data.partition import non_iid_partition, uniform_partition
from repro.data.synthetic import train_eval_split
from repro.train.simulator import SimConfig, simulate


@pytest.fixture(scope="module")
def setup():
    M = 8
    topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    x, y, ex, ey = train_eval_split(3000, 800, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    return M, topo, x, y, parts, ex, ey


def _run(algo, setup, events=1500, **kw):
    M, topo, x, y, parts, ex, ey = setup
    link = LinkTimeModel(topo, jitter=0.02, seed=5, slow_interval=120.0)
    cfg = SimConfig(algorithm=algo, n_workers=M, total_events=events, lr=0.05,
                    monitor_period=20.0, seed=0, **kw)
    return simulate(cfg, link, x, y, parts, ex, ey, record_every=250)


def test_all_algorithms_learn(setup):
    for algo in ("netmax", "adpsgd", "allreduce", "prague", "ps-sync", "ps-async"):
        res = _run(algo, setup, events=900)
        assert res.losses[-1] < res.losses[0] * 0.7, f"{algo} did not learn"
        assert np.isfinite(res.losses[-1])


@pytest.mark.slow
def test_netmax_faster_than_adpsgd_hetero(setup):
    """Paper §V-D: NetMax beats AD-PSGD in time-to-loss on hetero networks."""
    nm = _run("netmax", setup, events=2000)
    ad = _run("adpsgd", setup, events=2000)
    target = max(nm.losses[-1], ad.losses[-1]) * 1.15
    t_nm, t_ad = nm.time_to_loss(target), ad.time_to_loss(target)
    assert t_nm < t_ad, f"netmax {t_nm:.1f}s vs adpsgd {t_ad:.1f}s"


def test_monitor_actually_updates_policy(setup):
    M, topo, x, y, parts, ex, ey = setup
    from repro.core.nettime import LinkTimeModel
    from repro.train.simulator import SimConfig, simulate
    link = LinkTimeModel(topo, jitter=0.02, seed=5, slow_interval=120.0)
    cfg = SimConfig(algorithm="netmax", n_workers=M, total_events=2500, lr=0.05,
                    monitor_period=3.0, seed=0)
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=500)
    assert res.policy_updates >= 2


@pytest.mark.slow
def test_accuracy_parity(setup):
    """Paper Table II: all approaches reach comparable accuracy."""
    accs = {a: _run(a, setup, events=1600).final_accuracy()
            for a in ("netmax", "adpsgd", "allreduce")}
    assert max(accs.values()) - min(accs.values()) < 0.12, accs
    assert accs["netmax"] > 0.5


@pytest.mark.slow
def test_non_iid_still_converges(setup):
    """Paper §V-F: non-IID partitions — NetMax still converges."""
    M, topo, x, y, _, ex, ey = setup
    lost = [[i % 10, (i + 1) % 10] for i in range(M)]
    parts = non_iid_partition(y, M, lost)
    link = LinkTimeModel(topo, jitter=0.02, seed=5)
    cfg = SimConfig(algorithm="netmax", n_workers=M, total_events=1600, lr=0.05,
                    monitor_period=20.0, seed=0)
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=400)
    assert res.losses[-1] < res.losses[0] * 0.7
