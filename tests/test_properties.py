"""Property-based invariants for the timing model and policy guards.

Uses tests/_hypothesis_stub.py: with hypothesis installed these fuzz the
invariants; without it they collect and skip (the tier-1 contract).  A few
non-random spot checks ride along so the invariants keep *some* coverage
either way.
"""

import numpy as np
import pytest
from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st

from repro.algos.base import guard_policy_rows
from repro.core.nettime import TIERS, LinkTimeModel, Topology

# --------------------------------------------------------------------------
# LinkTimeModel invariants
# --------------------------------------------------------------------------


def test_default_tier_times_are_ordered():
    """Base times non-decreasing from intra_host out to inter_cluster WAN."""
    bt = LinkTimeModel(Topology(8)).base_times
    assert list(bt) == list(TIERS)
    vals = [bt[t] for t in TIERS]
    assert vals == sorted(vals)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 24),   # workers
    st.integers(1, 4),    # workers_per_host
    st.integers(1, 3),    # hosts_per_pod
    st.integers(1, 2),    # pods_per_cluster
    st.integers(0, 10_000),
)
def test_iteration_time_at_least_compute_time(M, wph, hpp, ppc, seed):
    """t_{i,m} = max(C_i, N_{i,m}) >= C_i for every pair, time, and draw."""
    rng = np.random.default_rng(seed)
    topo = Topology(M, workers_per_host=wph, hosts_per_pod=hpp,
                    pods_per_cluster=ppc)
    model = LinkTimeModel(topo, jitter=float(rng.uniform(0, 0.2)), seed=seed)
    for _ in range(20):
        i, m = rng.integers(M), rng.integers(M)
        if i == m:
            continue
        now = float(rng.uniform(0, 1000))
        assert model.iteration_time(int(i), int(m), now=now) >= model.compute_time


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 32), st.integers(0, 10_000))
def test_tier_ordering_monotone_in_placement_distance(M, seed):
    """Nearer placement never costs more than farther placement (no jitter,
    no slow link): the tier hierarchy is monotone."""
    topo = Topology(M, workers_per_host=2, hosts_per_pod=2, pods_per_cluster=2)
    model = LinkTimeModel(topo, jitter=0.0, slowdown_range=(1.0, 1.0), seed=seed)
    rank = {t: k for k, t in enumerate(TIERS)}
    rng = np.random.default_rng(seed)
    pairs = [(int(a), int(b)) for a, b in rng.integers(0, M, (30, 2)) if a != b]
    for (i, m), (j, n) in zip(pairs, pairs[1:]):
        ti, tj = topo.tier(i, m), topo.tier(j, n)
        ni, nj = model.network_time(i, m), model.network_time(j, n)
        if rank[ti] <= rank[tj]:
            assert ni <= nj
        else:
            assert ni >= nj


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(0, 10_000))
def test_slowdown_factor_bounded_by_range(M, seed):
    """The dynamic slow link inflates by a factor within slowdown_range."""
    lo, hi = 2.0, 100.0
    topo = Topology(M)
    model = LinkTimeModel(topo, jitter=0.0, slowdown_range=(lo, hi), seed=seed)
    for now in (0.0, 400.0, 1200.0):
        model.advance_to(now)
        assert lo <= model._slow_factor <= hi
        # And observably: every link costs base * factor with factor in
        # {1} ∪ [lo, hi].
        for i in range(M):
            for m in range(M):
                if i == m:
                    continue
                ratio = model.network_time(i, m, now=now) / \
                    model.base_times[topo.tier(i, m)]
                assert ratio == pytest.approx(1.0) or lo <= ratio <= hi * 1.0001


def test_slow_link_redraw_changes_edge_over_time():
    """Paper §V setup: the slowed link moves every slow_interval seconds."""
    model = LinkTimeModel(Topology(8), jitter=0.0, slow_interval=10.0, seed=3)
    model.advance_to(0.0)
    edges = set()
    for k in range(20):
        model.advance_to(10.0 * k + 1.0)
        edges.add(model._slow_edge)
    assert len(edges) > 1


# --------------------------------------------------------------------------
# WAN scenario options (correlated jitter + per-direction asymmetry):
# default-off keeps every historical trace pinned; enabled they are
# seedable, directional, and temporally correlated — on WAN links only.
# --------------------------------------------------------------------------


def _wan_topo(M=32):
    return Topology.multi_cluster(M, workers_per_host=4, hosts_per_pod=1,
                                  pods_per_cluster=2)


def test_wan_options_default_off_is_bit_identical():
    """Default-off must not change any draw: no extra rng consumed, no
    factor applied.  Pinned against values frozen *before* the WAN options
    existed (a same-config A/B comparison could not catch a regression
    that perturbs both models in lockstep)."""
    topo = _wan_topo()
    frozen = [  # LinkTimeModel(topo, seed=7).network_time(0, 31, now=13k),
        # recorded pre-wan-options; numpy Generator draws are
        # platform-stable, so exact equality is the contract.
        0.473465577094706,
        0.45909470234202004,
        0.4692110018667875,
        0.4567808686163859,
        0.48144561899128135,
    ]
    a = LinkTimeModel(topo, seed=7)
    b = LinkTimeModel(topo, seed=7, wan_jitter=0.0, wan_asymmetry=0.0)
    for k, expect in enumerate(frozen):
        now = 13.0 * k
        assert a.network_time(0, 31, now=now) == expect
        assert b.network_time(0, 31, now=now) == expect


def test_wan_stream_isolated_from_base_draws():
    """Enabling WAN options must not perturb the base jitter / slow-link
    sequence (they draw from a dedicated stream)."""
    topo = _wan_topo()
    plain = LinkTimeModel(topo, seed=3)
    wan = LinkTimeModel(topo, seed=3, wan_jitter=0.25, wan_asymmetry=0.4)
    for k in range(20):
        now = 40.0 * k
        plain.advance_to(now)
        wan.advance_to(now)
        assert plain._slow_edge == wan._slow_edge
        assert plain._slow_factor == wan._slow_factor
        # intra-cluster links are untouched by the WAN factors entirely
        assert plain.network_time(0, 1, now=now) == wan.network_time(0, 1, now=now)


def test_wan_asymmetry_directional_deterministic_and_mean_preserving():
    topo = _wan_topo()
    kw = dict(jitter=0.0, slowdown_range=(1.0, 1.0), wan_asymmetry=0.5)
    a = LinkTimeModel(topo, seed=7, **kw)
    b = LinkTimeModel(topo, seed=7, **kw)
    up, down = a.network_time(0, 31), a.network_time(31, 0)
    assert up != down  # per-direction bandwidth skew
    assert up == b.network_time(0, 31)  # seedable
    base = a.base_times["inter_cluster"]
    # antisymmetric in log space: up * down == base^2
    assert up * down == pytest.approx(base * base, rel=1e-12)
    # wan_seed overrides the derived stream
    c = LinkTimeModel(topo, seed=7, wan_seed=99, **kw)
    assert c.network_time(0, 31) != up


def test_wan_jitter_correlated_and_seedable():
    topo = _wan_topo()
    kw = dict(jitter=0.0, slowdown_range=(1.0, 1.0), wan_jitter=0.3,
              wan_jitter_corr=0.9, wan_jitter_interval=60.0)
    a = LinkTimeModel(topo, seed=7, **kw)
    b = LinkTimeModel(topo, seed=7, **kw)
    sa = [a.network_time(0, 31, now=60.0 * k) for k in range(60)]
    sb = [b.network_time(0, 31, now=60.0 * k) for k in range(60)]
    assert sa == sb  # seedable / deterministic
    assert len(set(sa)) > 1  # actually moves
    x = np.log(np.array(sa))
    lag1 = float(np.corrcoef(x[:-1], x[1:])[0, 1])
    assert lag1 > 0.3  # AR(1) with corr=0.9: strong temporal correlation
    # both directions share the congestion state (it models the shared link)
    assert a.network_time(0, 31, now=3600.0) == a.network_time(31, 0, now=3600.0)
    # iteration_time still respects the compute floor with WAN factors on
    assert a.iteration_time(0, 31, now=3660.0) >= a.compute_time


# --------------------------------------------------------------------------
# guard_policy_rows: every row stays a usable sampling distribution
# --------------------------------------------------------------------------


def _random_masked_policy(rng, M):
    d = (rng.uniform(size=(M, M)) < 0.6).astype(float)
    np.fill_diagonal(d, 0.0)
    # ensure every row has at least one edge (a disconnected worker has no
    # valid distribution under any guard)
    for i in range(M):
        if d[i].sum() == 0:
            j = (i + 1) % M
            d[i, j] = 1.0
    P = rng.uniform(size=(M, M)) * d
    dead = rng.uniform(size=M) < 0.3
    P[dead] = 0.0  # rows the Monitor zeroed out entirely
    return P, d


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 20), st.integers(0, 10_000))
def test_guard_policy_rows_row_stochastic(M, seed):
    rng = np.random.default_rng(seed)
    P, d = _random_masked_policy(rng, M)
    G = guard_policy_rows(P, d)
    assert (G >= 0).all()
    assert (G.sum(axis=1) > 0).all()  # every row samplable
    bad = P.sum(axis=1) <= 0
    # repaired rows carry uniform 1/(M-1) mass on exactly the d-edges
    expect = np.where(d[bad] > 0, 1.0 / max(M - 1, 1), 0.0)
    np.testing.assert_allclose(G[bad], expect)
    # healthy rows pass through untouched
    np.testing.assert_array_equal(G[~bad], P[~bad])


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 20), st.integers(0, 10_000))
def test_guard_policy_rows_stochastic_on_full_graph(M, seed):
    """On a fully-connected mask, repaired rows are exact distributions
    (sum to 1) — the row-stochasticity the Monitor relies on."""
    rng = np.random.default_rng(seed)
    d = np.ones((M, M)) - np.eye(M)
    P = rng.uniform(size=(M, M)) * d
    P[rng.uniform(size=M) < 0.5] = 0.0
    G = guard_policy_rows(P, d)
    bad = P.sum(axis=1) <= 0
    np.testing.assert_allclose(G[bad].sum(axis=1), 1.0)
    assert (G.sum(axis=1) > 0).all()


def test_guard_policy_rows_spot_check():
    d = np.ones((3, 3)) - np.eye(3)
    P = np.array([[0.0, 0.7, 0.3], [0.0, 0.0, 0.0], [0.5, 0.5, 0.0]])
    G = guard_policy_rows(P, d)
    np.testing.assert_allclose(G[1], [0.5, 0.0, 0.5])
    np.testing.assert_array_equal(G[0], P[0])
    np.testing.assert_array_equal(G[2], P[2])


# --------------------------------------------------------------------------
# Trace-calibration invariants (repro.trace.calibrate; DESIGN.md §15)
# --------------------------------------------------------------------------


def _synthetic_trace(topo, compute, jitter, seed, per_link=6):
    """Pull records drawn from a known tiered model: duration =
    max(C, base[tier] * lognormal jitter), every directed pair covered."""
    from repro.trace.schema import Trace, TraceRecord

    base = LinkTimeModel(topo).base_times
    rng = np.random.default_rng(seed)
    recs, t = [], 0.0
    for i in range(topo.n_workers):
        for m in range(topo.n_workers):
            if i == m:
                continue
            for _ in range(per_link):
                n = base[topo.tier(i, m)] * float(
                    np.exp(rng.normal(0.0, jitter))
                )
                recs.append(TraceRecord(t, max(compute, n), i, m, "pull"))
                t += 0.01
    return Trace(records=recs)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(4, 16),   # workers
    st.integers(1, 4),    # workers_per_host
    st.integers(1, 3),    # hosts_per_pod
    st.integers(0, 10_000),
)
def test_calibrate_invariants_on_synthetic_traces(M, wph, hpp, seed):
    """Whatever the placement and noise level, the fit obeys its contract:
    tier bases ordered along TIERS, jitter in [0, 1], link_scale strictly
    positive and 1.0 off the WAN tier, residual finite and non-negative."""
    from repro.trace.calibrate import calibrate

    rng = np.random.default_rng(seed)
    topo = Topology(M, workers_per_host=wph, hosts_per_pod=hpp,
                    pods_per_cluster=2)
    jitter = float(rng.uniform(0.0, 0.3))
    trace = _synthetic_trace(topo, compute=0.012, jitter=jitter, seed=seed)
    cal = calibrate(trace, topology=topo)
    vals = [cal.base_times[t] for t in TIERS]
    assert vals == sorted(vals)
    assert 0.0 <= cal.jitter <= 1.0
    assert (cal.link_scale > 0).all()
    for i in range(M):
        for m in range(M):
            if i != m and topo.tier(i, m) != "inter_cluster":
                assert cal.link_scale[i, m] == 1.0
    assert np.isfinite(cal.residual) and cal.residual >= 0.0
    assert cal.n_pulls == len(trace.records)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_calibrate_recovers_noise_free_tiers_exactly(seed):
    """With zero jitter every uncensored tier base is the recorded
    duration itself — the fit must return it exactly, and censored tiers
    must pin at the compute floor (the max() hides the true base)."""
    from repro.trace.calibrate import calibrate

    # all four tiers present: 8 hosts, 4 pods, 2 clusters
    topo = Topology(16, workers_per_host=2, hosts_per_pod=2,
                    pods_per_cluster=2)
    trace = _synthetic_trace(topo, compute=0.012, jitter=0.0, seed=seed,
                             per_link=3)
    cal = calibrate(trace, topology=topo)
    true = LinkTimeModel(topo).base_times
    assert cal.jitter == 0.0
    assert cal.residual == 0.0
    for tier in ("intra_pod", "inter_pod", "inter_cluster"):
        assert cal.base_times[tier] == pytest.approx(true[tier], rel=1e-9)
    # intra_host's true 0.010 base hides under the 0.012 compute floor
    assert "intra_host" in cal.censored_tiers
    assert cal.base_times["intra_host"] == pytest.approx(0.012)
    # ...which leaves every iteration_time query identical anyway
    assert cal.model.iteration_time(0, 1, now=0.0) == pytest.approx(0.012)


def test_calibrate_censored_trace_spot_check():
    """All-censored trace (every duration == compute): bases pin at the
    floor, jitter is zero, and nothing divides by zero."""
    from repro.trace.calibrate import calibrate
    from repro.trace.schema import Trace, TraceRecord

    topo = Topology(4, workers_per_host=1, hosts_per_pod=1,
                    pods_per_cluster=2)
    recs = [TraceRecord(0.01 * k, 0.5, i, m, "pull")
            for k, (i, m) in enumerate((i, m) for i in range(4)
                                       for m in range(4) if i != m)]
    cal = calibrate(Trace(records=recs), topology=topo)
    assert cal.compute_time == pytest.approx(0.5)  # min-duration fallback
    vals = [cal.base_times[t] for t in TIERS]
    assert vals == sorted(vals)
    assert cal.jitter == 0.0
    assert (cal.link_scale > 0).all()


def test_stub_mode_visible():
    """Sanity: record whether this environment runs the fuzzed versions."""
    assert HAVE_HYPOTHESIS in (True, False)
