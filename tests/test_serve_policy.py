"""PolicyServer: cache semantics, quantization, invalidation, batching.

The server's contract is *coherence*: every response is exactly what a
direct ``generate_policy_matrix`` call on the quantized instance would
return — caching, coalescing, and warm bases are invisible except in the
counters.  These tests pin that contract plus the PR-5 invalidation rule
(edge-set change drops cache lines and the warm basis).
"""

import threading

import numpy as np
import pytest

from repro.core.policy import generate_policy_matrix
from repro.serve import PolicyServer


def make_T(M, seed, lo=0.5, hi=3.0):
    rng = np.random.default_rng(seed)
    T = rng.uniform(lo, hi, (M, M))
    T = (T + T.T) / 2
    np.fill_diagonal(T, 0.0)
    return T


# --------------------------------------------------------------------------
# Cache hit / miss / coherence
# --------------------------------------------------------------------------


def test_exact_repeat_is_a_hit():
    srv = PolicyServer(alpha=0.05)
    T = make_T(10, 0)
    r1 = srv.request(T)
    r2 = srv.request(T.copy())
    assert r2 is r1
    assert srv.stats.n_solves == 1 and srv.stats.n_hits == 1
    assert srv.stats.hit_rate == pytest.approx(0.5)


def test_hit_result_equals_direct_solve():
    """Coherence: the served result is bit-equal to solving the quantized
    instance directly."""
    srv = PolicyServer(alpha=0.05, quant=0.05)
    T = make_T(12, 1)
    served = srv.request(T)
    Tn, dn = srv._normalize(T, None)
    direct = generate_policy_matrix(0.05, 5, 6, srv._quantize(Tn), d=dn)
    assert np.array_equal(served.P, direct.P)
    assert served.rho == direct.rho and served.t_bar == direct.t_bar
    assert served.T_convergence == direct.T_convergence


def test_near_identical_link_state_shares_a_cache_line():
    """EMA jitter well inside the quantum must not fragment the cache."""
    srv = PolicyServer(alpha=0.05, quant=0.05)
    rng = np.random.default_rng(2)
    T = make_T(10, 2)
    r1 = srv.request(T)
    for _ in range(5):
        jitter = rng.uniform(-1e-5, 1e-5, T.shape)
        assert srv.request(T + jitter) is r1
    assert srv.stats.n_solves == 1 and srv.stats.n_hits == 5


def test_distinct_link_states_miss():
    srv = PolicyServer(alpha=0.05, quant=0.05)
    r1 = srv.request(make_T(10, 3))
    r2 = srv.request(make_T(10, 4))
    assert r2 is not r1
    assert srv.stats.n_solves == 2 and srv.stats.n_hits == 0


def test_irrelevant_entries_do_not_fragment_the_cache():
    """T's diagonal and dead-link entries never enter Eq. 14 — changing
    them must still hit."""
    srv = PolicyServer(alpha=0.05)
    M = 8
    T = make_T(M, 5)
    d = np.ones((M, M)) - np.eye(M)
    d[0, 1] = d[1, 0] = 0.0
    r1 = srv.request(T, d=d)
    T2 = T.copy()
    np.fill_diagonal(T2, 99.0)   # diagonal is irrelevant
    T2[0, 1] = T2[1, 0] = 77.0   # d==0 edge is irrelevant
    assert srv.request(T2, d=d) is r1
    # inf on a live link means "dead" and produces a *different* edge set.
    T3 = T.copy()
    T3[2, 3] = T3[3, 2] = np.inf
    r3 = srv.request(T3, d=d)
    assert r3 is not r1


def test_quantization_boundary_splits_the_cell():
    """Values that quantize to different grid points are different keys —
    straddling a cell boundary misses (correctness beats hit rate)."""
    srv = PolicyServer(alpha=0.05, quant=0.05)
    M = 8
    T = np.full((M, M), 1.9)  # dominant max pins the scale bucket...
    np.fill_diagonal(T, 0.0)
    # ...at 2**ceil(log2(1.9)) = 2 -> quantum 0.1, cell boundary at 1.05.
    Ta = T.copy()
    Tb = T.copy()
    Ta[0, 1] = Ta[1, 0] = 1.02  # rounds to 1.0
    Tb[0, 1] = Tb[1, 0] = 1.08  # rounds to 1.1
    ra = srv.request(Ta)
    rb = srv.request(Tb)
    assert rb is not ra and srv.stats.n_solves == 2
    # ...while two values inside the same cell share a line.
    Tc = T.copy()
    Tc[0, 1] = Tc[1, 0] = 1.04  # also rounds to 1.0
    assert srv.request(Tc) is ra


def test_quant_zero_disables_snapping():
    srv = PolicyServer(alpha=0.05, quant=0.0)
    T = make_T(8, 6)
    r1 = srv.request(T)
    assert srv.request(T + 1e-9) is not r1
    assert srv.stats.n_solves == 2


def test_lru_eviction():
    srv = PolicyServer(alpha=0.05, cache_size=2)
    Ts = [make_T(8, 10 + k) for k in range(3)]
    r0 = srv.request(Ts[0])
    srv.request(Ts[1])
    srv.request(Ts[2])  # evicts Ts[0]'s line
    assert srv.stats.n_evictions == 1 and srv.cache_len() == 2
    assert srv.request(Ts[0]) is not r0  # re-solved
    assert srv.stats.n_solves == 4


# --------------------------------------------------------------------------
# PR-5 invalidation rule + warm-basis reuse
# --------------------------------------------------------------------------


def test_edge_set_change_drops_cache_and_warm_basis():
    srv = PolicyServer(alpha=0.05)
    M = 10
    T = make_T(M, 7)
    srv.request(T, tenant="w")
    assert srv.cache_len() == 1 and len(srv._warm) == 1
    d2 = np.ones((M, M)) - np.eye(M)
    d2[0, 1] = d2[1, 0] = 0.0
    srv.request(T, d=d2, tenant="w")  # tenant's edge set changed
    assert srv.stats.n_invalidations == 1
    # Full-graph line + warm basis are gone; a repeat re-solves.
    n = srv.stats.n_solves
    srv.request(T, tenant="other")
    assert srv.stats.n_solves == n + 1


def test_explicit_invalidate():
    srv = PolicyServer(alpha=0.05)
    M = 10
    T = make_T(M, 8)
    srv.request(T)
    d = np.ones((M, M)) - np.eye(M)
    srv.invalidate(d)
    assert srv.cache_len() == 0 and not srv._warm
    srv.request(T)
    assert srv.stats.n_solves == 2


def test_same_conn_key_reuses_warm_basis():
    """Misses under an unchanged edge set restart from the previous optimal
    basis — visible as warm-start hits inside the sweep counters."""
    srv = PolicyServer(alpha=0.05, quant=0.05)
    T = make_T(12, 9)
    r1 = srv.request(T)
    assert r1.basis is not None
    r2 = srv.request(T * 1.3)  # same edges, different quantized key
    assert srv.stats.n_solves == 2
    assert r2.n_warm_used > 0


# --------------------------------------------------------------------------
# Concurrency + micro-batching
# --------------------------------------------------------------------------


def test_concurrent_identical_requests_coalesce():
    srv = PolicyServer(alpha=0.05)
    T = make_T(10, 11)
    out = [None] * 6
    def work(i):
        out[i] = srv.request(T)
    threads = [threading.Thread(target=work, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert srv.stats.n_solves == 1
    assert srv.stats.n_hits + srv.stats.n_coalesced == 5
    assert all(r is out[0] for r in out)


def test_concurrent_distinct_requests_all_resolve():
    srv = PolicyServer(alpha=0.05)
    Ts = [make_T(8, 20 + k) for k in range(4)]
    out = {}
    def work(k):
        out[k] = srv.request(Ts[k])
    threads = [threading.Thread(target=work, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert srv.stats.n_solves == 4
    for k in range(4):
        assert out[k].ok
        assert srv.request(Ts[k]) is out[k]  # each populated its line


def test_request_many_dedups_compatible_instances():
    srv = PolicyServer(alpha=0.05, quant=0.05)
    T = make_T(10, 12)
    Tj = T + 1e-6          # same quantized key
    T_other = make_T(10, 13)
    out = srv.request_many([(T, None), (Tj, None), (T_other, None), (T, None)])
    assert len(out) == 4
    assert out[0] is out[1] is out[3]
    assert out[2] is not out[0]
    assert srv.stats.n_solves == 2
    assert srv.stats.n_requests == 4


def test_batched_sweep_mode_matches_serial_mode():
    T = make_T(12, 14)
    serial = PolicyServer(alpha=0.05, sweep="serial").request(T)
    batched = PolicyServer(alpha=0.05, sweep="batched").request(T)
    assert batched.ok and serial.ok
    # Both sweeps pick the identical grid point; P agrees to solver tol.
    assert (batched.rho, batched.t_bar) == (serial.rho, serial.t_bar)
    assert batched.T_convergence == pytest.approx(
        serial.T_convergence, rel=1e-6
    )
    assert np.allclose(batched.P, serial.P, atol=1e-6)


def test_stats_snapshot_shape():
    srv = PolicyServer(alpha=0.05)
    T = make_T(8, 15)
    srv.request(T)
    srv.request(T)
    snap = srv.stats.snapshot()
    assert snap["n_requests"] == 2 and snap["n_solves"] == 1
    assert snap["p99_ms"] >= snap["p50_ms"] >= 0.0
    assert 0.0 <= snap["hit_rate"] <= 1.0


def test_invalid_sweep_mode_rejected():
    with pytest.raises(ValueError):
        PolicyServer(alpha=0.05, sweep="vectorized")


# --------------------------------------------------------------------------
# Degraded-mode serving (PR 9): retry -> stale -> uniform, circuit breaker
# --------------------------------------------------------------------------


def _conn(M):
    return np.ones((M, M)) - np.eye(M)


def test_uniform_fallback_when_solver_always_fails():
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(alpha=0.05, max_retries=2,
                       chaos=ChaosInjector(seed=1, solver_fail_rate=1.0))
    T = make_T(8, 40)
    res = srv.request(T)
    # Never an exception: the uniform fallback is served, marked degraded.
    assert not res.ok
    assert np.allclose(res.P.sum(axis=1), 1.0)
    assert (np.diag(res.P) == 0).all()
    assert res.rho > 0
    assert srv.stats.n_uniform_fallbacks == 1
    assert srv.stats.n_solve_errors == 3  # first attempt + 2 retries
    assert srv.stats.n_retries == 2
    # Degraded results are never cached: the same request misses again.
    srv.request(T)
    assert srv.stats.n_hits == 0


def test_stale_while_revalidate_serves_last_good():
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(alpha=0.05, max_retries=0,
                       chaos=ChaosInjector(seed=2, solver_fail_rate=0.0))
    good = srv.request(make_T(8, 41))
    assert good.ok
    srv.chaos.solver_fail_rate = 1.0
    # Different quantized T, same connectivity: the failed solve serves
    # the last good result for that edge set instead of degrading further.
    stale = srv.request(make_T(8, 41) + 5.0)
    assert stale is good
    assert srv.stats.n_stale_served == 1
    assert srv.stats.n_uniform_fallbacks == 0


def test_invalidation_drops_stale_fallback():
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(alpha=0.05, max_retries=0,
                       chaos=ChaosInjector(seed=3))
    srv.request(make_T(8, 42))
    srv.invalidate(_conn(8))  # edge-set rule: last-good layout is stale
    srv.chaos.solver_fail_rate = 1.0
    res = srv.request(make_T(8, 42) + 5.0)
    assert not res.ok  # uniform, not the dropped stale result
    assert srv.stats.n_uniform_fallbacks == 1


def test_breaker_trips_probes_and_recovers():
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(alpha=0.05, max_retries=0, breaker_threshold=2,
                       breaker_probe_every=3,
                       chaos=ChaosInjector(seed=4, solver_fail_rate=1.0))
    for k in range(2):
        srv.request(make_T(8, 50 + k))
    assert srv.breaker_open
    assert srv.stats.n_breaker_trips == 1
    solves_when_tripped = srv.stats.n_solve_errors
    # While open, misses short-circuit: no solver attempts except probes
    # (every 3rd short-circuited miss).
    for k in range(4):
        srv.request(make_T(8, 60 + k))
    assert srv.stats.n_breaker_probes == 1
    assert srv.stats.n_solve_errors == solves_when_tripped + 1
    # Heal the solver: the next probe closes the breaker.
    srv.chaos.solver_fail_rate = 0.0
    served = [srv.request(make_T(8, 70 + k)) for k in range(6)]
    assert not srv.breaker_open
    assert srv.stats.n_breaker_recoveries == 1
    assert any(r.ok for r in served)
    # Fully recovered: fresh solves flow again.
    assert srv.request(make_T(8, 99)).ok


def test_deadline_bounds_the_retry_tail():
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(
        alpha=0.05, max_retries=5, deadline_ms=10.0,
        chaos=ChaosInjector(seed=5, solver_fail_rate=1.0,
                            solver_delay_rate=1.0, solver_delay_ms=50.0),
    )
    res = srv.request(make_T(8, 43))
    assert not res.ok  # degraded, not an exception
    assert srv.stats.n_deadline_misses == 1
    # The 50ms injected delay blew the 10ms deadline on attempt one: the
    # other 5 retries were never burned.
    assert srv.stats.n_solve_errors == 1
    assert srv.stats.n_retries == 0


def test_retry_recovers_from_transient_faults():
    from repro.scenarios import ChaosInjector

    # seed=5 stream: the first attempt fails, the retry re-rolls and
    # succeeds (deterministic for the fixed seed).
    srv = PolicyServer(alpha=0.05, max_retries=5,
                       chaos=ChaosInjector(seed=5, solver_fail_rate=0.5))
    res = srv.request(make_T(8, 44))
    assert res.ok
    assert srv.stats.n_retries >= 1
    assert srv.stats.n_solves == 1


def test_chaos_rate_validation():
    from repro.scenarios import ChaosInjector

    with pytest.raises(ValueError, match="solver_fail_rate"):
        ChaosInjector(solver_fail_rate=1.5)
    with pytest.raises(ValueError, match="report_drop_rate"):
        ChaosInjector(report_drop_rate=-0.1)


def test_server_degraded_knob_validation():
    with pytest.raises(ValueError, match="deadline_ms"):
        PolicyServer(alpha=0.05, deadline_ms=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        PolicyServer(alpha=0.05, max_retries=-1)
    with pytest.raises(ValueError, match="breaker"):
        PolicyServer(alpha=0.05, breaker_threshold=0)


# --------------------------------------------------------------------------
# Concurrency audit (PR 9 satellite): stats race fixed, invalidation vs
# in-flight solves, coalescing under interleaved invalidations
# --------------------------------------------------------------------------


def test_invalidate_during_solve_is_not_cached():
    """An invalidation that lands while a solve is in flight must win: the
    solve started from the pre-invalidation edge set, so its result is
    never inserted (epoch check) — the next request re-solves."""
    srv = PolicyServer(alpha=0.05)
    T = make_T(8, 45)
    real_solve = srv._solve
    started, release = threading.Event(), threading.Event()

    def slow_solve(Tq, d, ck):
        started.set()
        release.wait()
        return real_solve(Tq, d, ck)

    srv._solve = slow_solve
    out = {}
    th = threading.Thread(target=lambda: out.setdefault("r", srv.request(T)))
    th.start()
    started.wait()
    srv.invalidate(_conn(8))  # races the in-flight solve
    release.set()
    th.join()
    srv._solve = real_solve
    assert out["r"].ok  # the racing caller still got its fresh result
    assert srv.cache_len() == 0  # ... but it was not cached
    srv.request(T)
    assert srv.stats.n_hits == 0 and srv.stats.n_solves == 2
    assert srv.request(T).ok and srv.stats.n_hits == 1  # now cached


def test_coalescing_with_interleaved_invalidations():
    """6 requester threads on one key interleaved with invalidator threads:
    every request is answered (no exception, no deadlock), counters add
    up, and the cache never serves a result across an invalidation epoch."""
    srv = PolicyServer(alpha=0.05)
    T = make_T(10, 46)
    d = _conn(10)
    rounds, n_req = 6, 6
    results = []
    res_lock = threading.Lock()
    for _ in range(rounds):
        def work(_k):
            r = srv.request(T, d)
            with res_lock:
                results.append(r)

        def chaos_invalidate():
            srv.invalidate(d)

        threads = [threading.Thread(target=work, args=(k,))
                   for k in range(n_req)]
        threads += [threading.Thread(target=chaos_invalidate)
                    for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(results) == rounds * n_req
    for r in results:
        assert r is not None and r.ok
        assert np.allclose(r.P.sum(axis=1), 1.0)
    s = srv.stats
    assert s.n_requests == rounds * n_req
    # Every request was answered by exactly one path.
    assert (s.n_hits + s.n_coalesced + s.n_solves + s.n_degraded
            >= s.n_requests)
    assert s.n_invalidations == rounds * 2
    assert len(s.latencies_ms) == s.n_requests


def test_degraded_results_never_enter_last_good():
    """A stale-served result must be the last *fresh* solve, never a
    previously degraded answer (no degraded-feedback loop)."""
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(alpha=0.05, max_retries=0,
                       chaos=ChaosInjector(seed=7))
    good = srv.request(make_T(8, 47))
    srv.chaos.solver_fail_rate = 1.0
    first = srv.request(make_T(8, 47) + 3.0)   # stale <- good
    second = srv.request(make_T(8, 47) + 6.0)  # stale <- still good
    assert first is good and second is good
    assert srv.stats.n_stale_served == 2


# --------------------------------------------------------------------------
# Rung metadata (PR 10: consumed by the RPC front-end and the E2E pin)
# --------------------------------------------------------------------------


def test_request_meta_rungs_clean_path():
    srv = PolicyServer(alpha=0.05)
    T = make_T(8, 60)
    r1, m1 = srv.request_meta(T)
    r2, m2 = srv.request_meta(T)
    assert m1["rung"] == "fresh" and m2["rung"] == "hit"
    assert r2 is r1
    assert m1["ms"] >= 0.0 and m2["ms"] >= 0.0


def test_request_meta_rungs_degraded_path():
    from repro.scenarios import ChaosInjector

    srv = PolicyServer(alpha=0.05, max_retries=0,
                       chaos=ChaosInjector(seed=3))
    good, m0 = srv.request_meta(make_T(8, 61))
    assert m0["rung"] == "fresh"
    srv.chaos.solver_fail_rate = 1.0
    stale, m1 = srv.request_meta(make_T(8, 61) + 2.0)
    assert m1["rung"] == "stale" and stale is good
    fresh_d = np.ones((8, 8)) - np.eye(8)
    fresh_d[0, 5] = fresh_d[5, 0] = 0.0  # new edge set: no stale to serve
    uni, m2 = srv.request_meta(make_T(8, 62), d=fresh_d)
    assert m2["rung"] == "uniform" and not uni.ok


def test_request_meta_coalesced_rung():
    srv = PolicyServer(alpha=0.05)
    T = make_T(10, 63)
    rungs = []
    lock = threading.Lock()

    def work():
        _, meta = srv.request_meta(T)
        with lock:
            rungs.append(meta["rung"])

    threads = [threading.Thread(target=work) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert rungs.count("fresh") == 1
    assert set(rungs) <= {"fresh", "coalesced", "hit"}


def test_normalize_instance_shared_helper():
    """Module-level normalize_instance is what both the server's cache
    key and the shard router's route hash see: inf links die, the
    diagonal drops, and off-edge T entries zero out."""
    from repro.serve.policy import normalize_instance

    T = make_T(6, 64)
    T[1, 4] = T[4, 1] = np.inf
    Tn, dn = normalize_instance(T, None)
    assert dn[1, 4] == 0.0 and dn[4, 1] == 0.0
    assert Tn[1, 4] == 0.0 and np.all(np.diag(dn) == 0.0)


# --------------------------------------------------------------------------
# Chaos queue channel (PR 10: admission-control seam)
# --------------------------------------------------------------------------


def test_chaos_queue_channel_seeded_and_counted():
    from repro.scenarios import ChaosInjector

    a = ChaosInjector(seed=11, queue_delay_rate=0.5, queue_delay_ms=25.0)
    b = ChaosInjector(seed=11, queue_delay_rate=0.5, queue_delay_ms=25.0)
    seq_a = [a.injected_queue_delay_ms() for _ in range(50)]
    seq_b = [b.injected_queue_delay_ms() for _ in range(50)]
    assert seq_a == seq_b  # seeded: identical schedules
    assert set(seq_a) == {0.0, 25.0}
    assert a.n_queue_delays == sum(x > 0 for x in seq_a)
    with pytest.raises(ValueError, match="queue_delay_rate"):
        ChaosInjector(queue_delay_rate=1.5)


def test_chaos_queue_stream_does_not_perturb_existing_channels():
    """The queue stream is spawned child #4; children are deterministic
    by index, so the solver channel's fault schedule is identical to what
    a 4-stream (pre-PR-10) injector drew for the same seed."""
    import numpy as _np
    from repro.scenarios import ChaosInjector

    inj = ChaosInjector(seed=9, solver_fail_rate=0.3)
    legacy = _np.random.default_rng(_np.random.SeedSequence(9).spawn(4)[0])
    faults = []
    for _ in range(40):
        try:
            inj.maybe_fail_solver()
            faults.append(False)
        except Exception:
            faults.append(True)
    expect = [bool(legacy.uniform() < 0.3) for _ in range(40)]
    assert faults == expect
