"""Scenario-subsystem tests (DESIGN.md §14).

Covers: timeline compilation into the piecewise link-state machine, the
LinkTimeModel integration (timeouts, degradation, default-off bit
identity), Monitor dead-link detection with failure-domain escalation and
probation, the warm-start basis invalidation rule (ISSUE 5 satellite), the
elastic reseed helpers, and the fully-partitioned-cluster property.
"""

import numpy as np
import pytest

from repro.core import monitor as monitor_mod
from repro.core.monitor import NetworkMonitor
from repro.core.nettime import LinkTimeModel, Topology, homogeneous_times
from repro.scenarios import (
    ClusterOutage,
    LinkDegrade,
    ScenarioCursor,
    Timeline,
    WorkerLeave,
    WorkerRejoin,
    presets,
)


def two_cluster_topo(M=8):
    """Workers 0..M/2-1 in cluster 0, the rest in cluster 1."""
    return Topology(M, workers_per_host=2, hosts_per_pod=2, pods_per_cluster=1)


def cross_mask(topo):
    cl = np.array([topo.cluster_of(i) for i in range(topo.n_workers)])
    return cl[:, None] != cl[None, :]


# --------------------------------------------------------------------------
# Timeline compilation
# --------------------------------------------------------------------------


def test_compile_boundaries_and_outage_masks():
    topo = two_cluster_topo()
    tl = Timeline([ClusterOutage(1, 1.0, 3.0), LinkDegrade(0, 2, 2.0, 4.0, 8.0)])
    comp = tl.compile(topo)
    assert comp.boundaries == (1.0, 2.0, 3.0, 4.0)

    def seg(t):
        return comp.segments[comp.segment_index(t)]

    assert not seg(0.5).dead.any()  # nothing active before the outage
    cross = cross_mask(topo)
    mid = seg(1.5)
    assert mid.dead[cross].all()  # every WAN link of cluster 1, both ways
    assert not mid.dead[~cross].any()  # intra links keep working
    assert not seg(3.5).dead.any()  # outage over
    # Degradation window [2, 4): symmetric by default.
    assert seg(2.5).degrade[0, 2] == 8.0 and seg(2.5).degrade[2, 0] == 8.0
    assert seg(1.5).degrade[0, 2] == 1.0 and seg(4.5).degrade[0, 2] == 1.0


def test_compile_churn_intervals():
    topo = two_cluster_topo()
    comp = Timeline([WorkerLeave(3, 1.0), WorkerRejoin(3, 2.0)]).compile(topo)

    def seg(t):
        return comp.segments[comp.segment_index(t)]

    assert seg(1.5).dead[3, :].sum() == topo.n_workers - 1
    assert seg(1.5).dead[:, 3].sum() == topo.n_workers - 1
    assert not seg(0.5).dead.any() and not seg(2.5).dead.any()
    assert list(comp.active_workers(1.5)) == [i != 3 for i in range(8)]
    assert comp.active_workers(2.5).all()


def test_compile_validation():
    topo = two_cluster_topo()
    with pytest.raises(ValueError, match="out of range"):
        Timeline([ClusterOutage(7, 0.0, 1.0)]).compile(topo)
    with pytest.raises(ValueError, match="factor"):
        Timeline([LinkDegrade(0, 1, 0.0, 1.0, -2.0)]).compile(topo)
    with pytest.raises(ValueError, match="start < end"):
        Timeline([ClusterOutage(0, 2.0, 1.0)]).compile(topo)
    with pytest.raises(ValueError, match="rejoins without"):
        Timeline([WorkerRejoin(0, 1.0)]).compile(topo)
    with pytest.raises(ValueError, match="leaves twice"):
        Timeline([WorkerLeave(0, 1.0), WorkerLeave(0, 2.0)]).compile(topo)
    with pytest.raises(ValueError, match="zero active"):
        Timeline([WorkerLeave(w, 1.0) for w in range(8)]).compile(topo)


def test_compile_validation_uses_runtime_action_order():
    """Equal-time leaves fire before rejoins at runtime; validation and
    churn pairing must see the same order (regression: a rejoin+re-leave
    at the same instant used to validate as rejoin-first, then compile as
    leave-first, silently dropping the departure interval)."""
    topo = two_cluster_topo()
    with pytest.raises(ValueError, match="leaves twice"):
        Timeline(
            [WorkerLeave(0, 1.0), WorkerRejoin(0, 2.0), WorkerLeave(0, 2.0)]
        ).compile(topo)


def test_compile_rejects_rejoin_without_reseed_source():
    """A rejoin whose automatic reseed source set is empty (everyone else
    departed) must be a compile error, not a mid-simulation crash."""
    topo = Topology(2, workers_per_host=1, hosts_per_pod=1)
    with pytest.raises(ValueError, match="no live worker to reseed"):
        Timeline(
            [WorkerLeave(0, 1.0), WorkerLeave(1, 2.0), WorkerRejoin(0, 2.0)]
        ).compile(topo)
    # An explicit seed_from sidesteps the automatic-source requirement.
    Timeline(
        [WorkerLeave(0, 1.0), WorkerLeave(1, 2.0), WorkerRejoin(0, 2.0, 1)]
    ).compile(topo)


def test_cursor_consumes_boundaries_once():
    topo = two_cluster_topo()
    comp = Timeline(
        [ClusterOutage(1, 1.0, 3.0), WorkerLeave(3, 1.5), WorkerRejoin(3, 2.5)]
    ).compile(topo)
    cur = ScenarioCursor(comp)
    assert cur.next_time == 1.0
    assert cur.pop_due(0.5) == []
    assert cur.next_time == 1.0
    acts = cur.pop_due(2.0)  # crosses 1.0 (outage) and 1.5 (leave)
    assert [type(a) for a in acts] == [WorkerLeave]
    assert cur.next_time == 2.5
    acts = cur.pop_due(10.0)
    assert [type(a) for a in acts] == [WorkerRejoin]
    assert cur.next_time == float("inf")
    assert cur.pop_due(99.0) == []


def test_random_preset_is_seed_deterministic():
    topo = two_cluster_topo()
    a = presets.random_timeline(topo, seed=7, horizon=100.0)
    b = presets.random_timeline(topo, seed=7, horizon=100.0)
    assert a.events == b.events
    assert a.compile(topo).boundaries == b.compile(topo).boundaries
    assert presets.random_timeline(topo, seed=8, horizon=100.0).events != a.events


# --------------------------------------------------------------------------
# LinkTimeModel integration
# --------------------------------------------------------------------------


def test_dead_link_times_out_and_degrade_applies():
    topo = two_cluster_topo()
    tl = Timeline([ClusterOutage(1, 1.0, 3.0), LinkDegrade(0, 2, 2.0, 4.0, 8.0)])
    model = LinkTimeModel(topo, jitter=0.0, slowdown_range=(1.0, 1.0),
                          scenario=tl, dead_link_timeout=7.0)
    base_cross = model.network_time(0, 7, now=0.0)
    base_intra = model.network_time(0, 2, now=0.0)
    assert model.network_time(0, 7, now=1.5) == 7.0  # timed out, no jitter
    assert model.link_dead(0, 7) and model.link_dead(7, 0)
    assert not model.link_dead(0, 2)
    assert model.network_time(0, 2, now=2.5) == pytest.approx(8.0 * base_intra)
    assert model.network_time(0, 7, now=3.5) == pytest.approx(base_cross)
    assert model.iteration_time(0, 7, now=10.0) >= model.compute_time
    T = model.matrix(now=10.0)  # advance past every boundary, then rewindless
    assert T[0, 7] == pytest.approx(max(model.compute_time, base_cross))


def test_matrix_reflects_outage():
    topo = two_cluster_topo()
    model = LinkTimeModel(topo, jitter=0.0, slowdown_range=(1.0, 1.0),
                          scenario=Timeline([ClusterOutage(0, 1.0, 2.0)]),
                          dead_link_timeout=9.0)
    T = model.matrix(now=1.5)
    cross = cross_mask(topo)
    assert (T[cross] == 9.0).all()
    assert (T[~cross & ~np.eye(8, dtype=bool)] < 9.0).all()


def test_empty_scenario_is_bit_identical():
    """Attaching a scenario must never perturb the rng draw sequence."""
    topo = two_cluster_topo()
    a = LinkTimeModel(topo, jitter=0.05, seed=3)
    b = LinkTimeModel(topo, jitter=0.05, seed=3, scenario=Timeline([]))
    rng = np.random.default_rng(0)
    for _ in range(200):
        i, m = rng.integers(8), rng.integers(8)
        if i == m:
            continue
        now = float(rng.uniform(0, 700))
        assert a.network_time(int(i), int(m), now=now) == b.network_time(
            int(i), int(m), now=now
        )


def test_scenario_topology_shape_checked():
    tl = Timeline([ClusterOutage(0, 0.0, 1.0)]).compile(two_cluster_topo(8))
    with pytest.raises(ValueError, match="workers"):
        LinkTimeModel(Topology(4), scenario=tl)


# --------------------------------------------------------------------------
# Monitor: dead-link detection, escalation, probation, refresh wake
# --------------------------------------------------------------------------


def _monitor(topo=None, M=8, **kw):
    kw.setdefault("K", 4)
    kw.setdefault("R", 4)
    mon = NetworkMonitor(n_workers=M, alpha=0.1, **kw)
    mon.topology = topo
    mon.reroute_delay = 0.5
    return mon


def _feed(mon, M=8):
    T = homogeneous_times(M, 0.02)
    mon.collect({i: T[i] for i in range(M)})


def test_notified_link_is_masked():
    mon = _monitor()
    _feed(mon)
    wake = mon.notify_failure(0, 5, now=3.0)
    assert wake == pytest.approx(3.5)  # now + reroute_delay
    res = mon.step()
    assert res.P[0, 5] == 0
    # The evidence is directed (0's pull from 5 timed out) and so is the
    # mask: under an asymmetric outage the reverse link may be fine, and if
    # it is not, 5's own failed pulls report it independently.
    assert res.P[5, 0] > 0
    assert res.P[1, 5] > 0  # only the reported link is masked


def test_out_of_schedule_wake_is_shared_per_burst():
    mon = _monitor()
    _feed(mon)
    w1 = mon.notify_failure(0, 5, now=3.0)
    w2 = mon.notify_failure(1, 6, now=3.2)  # same burst: one refresh
    assert w1 == w2 == pytest.approx(3.5)
    mon.step()
    assert mon.notify_failure(2, 7, now=9.0) == pytest.approx(9.5)


def test_peer_escalation_needs_same_cluster_evidence():
    """Cross-cluster failures alone must not declare a peer dead — a WAN
    outage produces exactly that signature; only a cluster-mate's failed
    pull disambiguates (a crashed worker fails intra pulls too)."""
    topo = two_cluster_topo()
    mon = _monitor(topo)
    _feed(mon)
    mon.notify_failure(0, 5, now=1.0)  # both pullers in cluster 0,
    mon.notify_failure(1, 5, now=1.1)  # peer 5 in cluster 1
    res = mon.step()
    assert res.P[4, 5] > 0  # peer 5 still reachable from its own cluster
    mon2 = _monitor(topo)
    _feed(mon2)
    mon2.notify_failure(0, 5, now=1.0)
    mon2.notify_failure(4, 5, now=1.1)  # cluster-mate can't reach it either
    res2 = mon2.step()
    assert np.all(res2.P[:, 5] == 0) and np.all(res2.P[5, :] == 0)


def test_peer_escalation_without_topology():
    mon = _monitor(topo=None)
    _feed(mon)
    mon.notify_failure(0, 5, now=1.0)
    mon.notify_failure(1, 5, now=1.1)
    res = mon.step()
    assert np.all(res.P[:, 5] == 0)


def test_cluster_escalation_masks_whole_pair():
    topo = two_cluster_topo()
    mon = _monitor(topo)
    _feed(mon)
    mon.notify_failure(0, 5, now=1.0)  # two distinct unreachable peers in
    mon.notify_failure(1, 6, now=1.1)  # cluster 1 => that WAN direction down
    res = mon.step()
    cl = np.array([topo.cluster_of(i) for i in range(topo.n_workers)])
    fwd = (cl[:, None] == 0) & (cl[None, :] == 1)  # observed direction
    rev = (cl[:, None] == 1) & (cl[None, :] == 0)
    assert res.P[fwd].sum() == 0
    # All the evidence says cluster-0 pulls toward cluster 1 die; the
    # reverse WAN direction has shown nothing wrong and stays routable.
    assert res.P[rev].sum() > 0
    assert res.P[0, 1] > 0 and res.P[5, 4] > 0  # both intra sides alive
    # Mirror evidence from the far side completes the full-pair mask.
    mon.notify_failure(5, 0, now=1.2)
    mon.notify_failure(6, 1, now=1.3)
    _feed(mon)
    assert mon.step().P[cross_mask(topo)].sum() == 0


def test_failure_masks_expire_after_probation():
    topo = two_cluster_topo()
    mon = _monitor(topo, revive_after=2)
    cross = cross_mask(topo)
    _feed(mon)
    mon.notify_failure(0, 5, now=1.0)  # evidence in both WAN directions
    mon.notify_failure(1, 6, now=1.1)
    mon.notify_failure(5, 0, now=1.2)
    mon.notify_failure(6, 1, now=1.3)
    assert mon.step().P[cross].sum() == 0  # masked...
    _feed(mon)
    assert mon.step().P[cross].sum() == 0  # ...still within probation...
    _feed(mon)
    assert mon.step().P[cross].sum() > 0  # ...revived: links get re-probed


# --------------------------------------------------------------------------
# Warm-basis invalidation (ISSUE 5 satellite): step() must DROP the cached
# basis when the effective edge set changes — never rely on the solver's
# shape-validation fallback.
# --------------------------------------------------------------------------


@pytest.fixture()
def warm_spy(monkeypatch):
    captured = []
    real = monitor_mod.generate_policy_matrix

    def spy(*args, **kwargs):
        captured.append(kwargs.get("warm"))
        return real(*args, **kwargs)

    monkeypatch.setattr(monitor_mod, "generate_policy_matrix", spy)
    return captured


def test_basis_dropped_when_live_set_shrinks(warm_spy):
    M = 6
    mon = NetworkMonitor(n_workers=M, alpha=0.1, K=4, R=4, dead_after=2)
    T = homogeneous_times(M, 0.02)
    mon.collect({i: T[i] for i in range(M)})
    mon.step()
    assert warm_spy[0] is None  # first refresh: nothing cached yet
    mon.collect({i: T[i] for i in range(M)})
    mon.step()
    assert warm_spy[1] is not None  # steady state: basis re-threaded
    for _ in range(2):  # worker 5 stops reporting -> live set shrinks
        mon.collect({i: T[i] for i in range(M) if i != 5})
    mon.step()
    assert warm_spy[2] is None  # dropped explicitly, not solver-rejected
    assert 5 not in mon.live_workers
    mon.collect({i: T[i] for i in range(M) if i != 5})
    mon.step()
    assert warm_spy[3] is not None  # stable shrunken set: warm again


def test_basis_dropped_when_links_masked(warm_spy):
    mon = _monitor()
    _feed(mon)
    mon.step()
    _feed(mon)
    mon.step()
    assert warm_spy[1] is not None
    _feed(mon)
    mon.notify_failure(0, 5, now=1.0)  # edge set changes -> invalidate
    mon.step()
    assert warm_spy[2] is None


# --------------------------------------------------------------------------
# Elastic reseed helpers
# --------------------------------------------------------------------------


def test_reseed_row_matches_reseed_replica():
    import jax
    import jax.numpy as jnp

    from repro.train.elastic import reseed_replica, reseed_row

    M = 4
    leaves = [
        {"w": jnp.arange(M * 3, dtype=jnp.float32).reshape(M, 3), "b": jnp.ones((M, 2))}
    ]
    mom = jax.tree_util.tree_map(lambda l: l + 10.0, leaves)
    R2, Mom2 = reseed_row(leaves, mom, worker=2, seed_from=0)
    assert np.array_equal(R2[0]["w"][2], leaves[0]["w"][0])
    assert np.all(Mom2[0]["w"][2] == 0)
    assert np.array_equal(R2[0]["w"][1], leaves[0]["w"][1])  # others untouched

    replicas = [jax.tree_util.tree_map(lambda l: l[i], leaves[0]) for i in range(M)]
    momenta = [jax.tree_util.tree_map(lambda l: l[i] + 10.0, leaves[0]) for i in range(M)]
    reseed_replica(replicas, momenta, worker=2, seed_from=0)
    assert np.array_equal(replicas[2]["w"], R2[0]["w"][2])
    assert np.all(momenta[2]["w"] == 0)


# --------------------------------------------------------------------------
# The partition property: a fully-partitioned cluster yields zero
# cross-partition communication once the Monitor has re-routed
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_data():
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split

    x, y, ex, ey = train_eval_split(1600, 400, 32, 10, seed=0)
    parts = uniform_partition(len(y), 8, seed=0)
    return x, y, parts, ex, ey


def _run_partitioned(algo, sim_data, events=700):
    from repro.train.simulator import SimConfig, simulate

    topo = two_cluster_topo()
    x, y, parts, ex, ey = sim_data
    link = LinkTimeModel(topo, jitter=0.02, seed=5,
                         scenario=presets.partition(topo, start=0.5),
                         dead_link_timeout=1.0)
    cfg = SimConfig(algorithm=algo, n_workers=8, total_events=events, lr=0.05,
                    monitor_period=0.5, seed=0, engine="batched")
    return simulate(cfg, link, x, y, parts, ex, ey, record_every=100), topo


def test_partitioned_cluster_zero_cross_communication(sim_data):
    from repro.algos.netmax import NetMax

    class PatientNetMax(NetMax):
        """Probation disabled: the partition is permanent, so re-probing
        would only re-discover it — this isolates the property."""

        def make_monitor(self, cfg, M, d=None):
            mon = super().make_monitor(cfg, M, d=d)
            mon.revive_after = 10**9
            return mon

    res, topo = _run_partitioned(PatientNetMax(), sim_data)
    cross = cross_mask(topo)
    cl = [topo.cluster_of(i) for i in range(8)]

    # Every timed-out pull is a cross-partition attempt (intra links live).
    assert res.failed_pulls
    assert all(cl[i] != cl[m] for _, i, m in res.failed_pulls)

    # The Monitor re-routes: some refresh publishes zero cross mass...
    reroute_t = next(
        (t for t, _, P in res.policy_log if P[cross].sum() == 0), None
    )
    assert reroute_t is not None
    # ...after which there is zero cross-partition communication: no pull
    # ever crosses the partition again (attempts would fail => be logged).
    assert all(t <= reroute_t for t, _, _ in res.failed_pulls)
    for t, _, P in res.policy_log:
        if t >= reroute_t:
            assert P[cross].sum() == 0
    # The isolated halves keep training.
    assert np.isfinite(res.losses[-1]) and res.losses[-1] < res.losses[0]


def test_partitioned_cluster_nonadaptive_baseline_keeps_failing(sim_data):
    """AD-PSGD has no Monitor: cross-partition attempts (and their
    timeouts) continue for the whole run — the contrast NetMax's
    adaptivity is measured against."""
    res, topo = _run_partitioned("adpsgd", sim_data, events=500)
    cl = [topo.cluster_of(i) for i in range(8)]
    assert len(res.failed_pulls) > 5
    assert all(cl[i] != cl[m] for _, i, m in res.failed_pulls)
    # Failures span the run, not just its start.
    assert res.failed_pulls[-1][0] > 0.5 * res.times[-1]


# --------------------------------------------------------------------------
# Asymmetric (one-direction) outages: directed ClusterOutage end to end
# --------------------------------------------------------------------------


def test_cluster_outage_direction_out():
    """direction='out': pulls BY the cluster's workers across the WAN die;
    pulls toward it keep flowing."""
    topo = two_cluster_topo()
    tl = Timeline([ClusterOutage(0, 1.0, 5.0, direction="out")]).compile(topo)
    link = LinkTimeModel(topo, scenario=tl, seed=0)
    link.advance_to(2.0)
    assert link.link_dead(0, 5) and link.link_dead(3, 6)
    assert not link.link_dead(5, 0) and not link.link_dead(6, 3)
    assert not link.link_dead(0, 1) and not link.link_dead(5, 6)  # intra


def test_cluster_outage_direction_in():
    """direction='in': pulls FROM the cluster die; its own pulls survive."""
    topo = two_cluster_topo()
    tl = Timeline([ClusterOutage(0, 1.0, 5.0, direction="in")]).compile(topo)
    link = LinkTimeModel(topo, scenario=tl, seed=0)
    link.advance_to(2.0)
    assert link.link_dead(5, 0) and link.link_dead(6, 3)
    assert not link.link_dead(0, 5) and not link.link_dead(3, 6)
    seg = tl.segments[1]
    # The dense view agrees with the directed point queries.
    dead = seg.dead
    assert dead[5, 0] and not dead[0, 5]


def test_cluster_outage_bad_direction_rejected():
    with pytest.raises(ValueError, match="direction"):
        Timeline([ClusterOutage(0, 1.0, 5.0, direction="sideways")]).compile(
            two_cluster_topo()
        )


# --------------------------------------------------------------------------
# Home-pinned Monitor (partition tolerance): the control plane shares fate
# with its cluster — far-side reports are lost and publishes don't land
# --------------------------------------------------------------------------


def test_monitor_reach_directed_outage():
    from repro.scenarios.driver import monitor_reach

    topo = two_cluster_topo()
    tl = Timeline([ClusterOutage(1, 1.0, 5.0, direction="out")]).compile(topo)
    link = LinkTimeModel(topo, scenario=tl, seed=0)
    mon = _monitor(topo)
    mon.home_cluster = 0
    far = np.array([topo.cluster_of(j) == 1 for j in range(8)])

    reach_in, reach_out = monitor_reach(mon, link, 0.5)
    assert reach_in.all() and reach_out.all()  # before the outage

    # Cluster 1 lost its outbound WAN: its reports die in flight, but the
    # Monitor's publishes (inbound to cluster 1) still land — reachability
    # is directed, matching the outage.
    reach_in, reach_out = monitor_reach(mon, link, 2.0)
    assert not reach_in[far].any() and reach_in[~far].all()
    assert reach_out.all()

    # Omniscient Monitor (no home cluster): no reach filtering at all.
    assert monitor_reach(_monitor(topo), link, 2.0) is None


def test_monitor_reach_departed_worker():
    from repro.scenarios.driver import monitor_reach

    topo = two_cluster_topo()
    tl = Timeline([WorkerLeave(3, 1.0), WorkerRejoin(3, 5.0)]).compile(topo)
    link = LinkTimeModel(topo, scenario=tl, seed=0)
    mon = _monitor(topo)
    mon.home_cluster = 0
    reach_in, reach_out = monitor_reach(mon, link, 2.0)
    assert not reach_in[3] and not reach_out[3]
    assert reach_in.sum() == 7 and reach_out.sum() == 7


def test_publish_policy_partial_reach():
    from types import SimpleNamespace

    from repro.algos.netmax import NetMax
    from repro.algos.base import guard_policy_rows
    from repro.scenarios.driver import publish_policy
    from repro.train.simulator import SimConfig

    algo, M = NetMax(), 6
    state = algo.init_state(SimConfig(algorithm="netmax", n_workers=M), M)
    old_P, old_rho = state.P.copy(), state.rho
    newP = np.full((M, M), 1.0 / (M - 1))
    np.fill_diagonal(newP, 0.0)
    pol = SimpleNamespace(P=newP, rho=old_rho + 0.5)
    reach = np.array([True, True, True, False, False, False])

    publish_policy(algo, state, pol, reach)
    expect = guard_policy_rows(newP, state.d)
    np.testing.assert_array_equal(state.P[:3], expect[:3])  # delivered
    np.testing.assert_array_equal(state.P[3:], old_P[3:])   # stale rows kept
    # rho is per-worker now: the far side keeps its stale consensus step.
    assert state.rho == pol.rho
    assert state.rho_of(0) == pol.rho and state.rho_of(4) == old_rho

    # A later full publish collapses back to the scalar-rho fast path.
    pol2 = SimpleNamespace(P=newP, rho=old_rho + 1.0)
    publish_policy(algo, state, pol2, np.ones(M, dtype=bool))
    assert state.rho_vec is None and state.rho == pol2.rho


def test_home_pinned_monitor_far_side_keeps_stale_policy(sim_data):
    """The satellite property: partition a home-pinned Monitor off from
    cluster 1 and the far side keeps training on its stale policy — its
    cross-partition attempts (invisible to the Monitor) never stop, while
    the near side is re-routed as usual."""
    from repro.algos.netmax import NetMax
    from repro.train.simulator import SimConfig, simulate

    class PatientNetMax(NetMax):
        def make_monitor(self, cfg, M, d=None):
            mon = super().make_monitor(cfg, M, d=d)
            mon.revive_after = 10**9
            return mon

    topo = two_cluster_topo()
    x, y, parts, ex, ey = sim_data
    link = LinkTimeModel(topo, jitter=0.02, seed=5,
                         scenario=presets.partition(topo, start=0.5),
                         dead_link_timeout=1.0)
    cfg = SimConfig(algorithm=PatientNetMax(), n_workers=8, total_events=700,
                    lr=0.05, monitor_period=0.5, seed=0, engine="batched",
                    monitor_home_cluster=0)
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=100)
    far = {j for j in range(8) if topo.cluster_of(j) == 1}

    # The Monitor (fed only by near-side reports) still converges on a
    # zero-cross policy: near evidence masks near->far, and the silent far
    # side is declared dead after ``dead_after`` missed reports.
    cross = cross_mask(topo)
    reroute_t = next(
        (t for t, _, P in res.policy_log if P[cross].sum() == 0), None
    )
    assert reroute_t is not None
    # Near-side workers received that policy and never cross again...
    assert all(t <= reroute_t for t, i, _ in res.failed_pulls if i not in far)
    # ...but the publish never reaches the far side, which keeps training
    # on its stale cross-heavy policy: its failed attempts span the run.
    far_fail_times = [t for t, i, _ in res.failed_pulls if i in far]
    assert far_fail_times and far_fail_times[-1] > 0.5 * res.times[-1]
    assert max(far_fail_times) > reroute_t
    # Both halves keep making progress despite the split control plane.
    assert np.isfinite(res.losses[-1]) and res.losses[-1] < res.losses[0]


# --------------------------------------------------------------------------
# EventHeap: lazy invalidation == eager pruning (PR 8)
# --------------------------------------------------------------------------


class _EagerHeap:
    """Reference: the historical eager-prune behaviour (O(M) per leave)."""

    def __init__(self):
        self._entries = []  # sorted-on-demand list of (t, i)

    def push(self, t, i):
        self._entries = [(t_, i_) for t_, i_ in self._entries if i_ != i]
        self._entries.append((t, i))

    def invalidate(self, i):
        self._entries = [(t_, i_) for t_, i_ in self._entries if i_ != i]

    def peek_time(self):
        return min(self._entries)[0] if self._entries else float("inf")

    def pop(self):
        e = min(self._entries)
        self._entries.remove(e)
        return e

    def __len__(self):
        return len(self._entries)

    def __bool__(self):
        return bool(self._entries)


def test_event_heap_matches_eager_prune_on_random_schedules():
    """Randomized push/invalidate/pop/peek schedules — including the
    leave-then-rejoin-with-equal-time trap (a stale buried entry whose
    (t, i) equals the live one) — produce identical event sequences."""
    from repro.train.events import EventHeap

    for seed in range(12):
        rng = np.random.default_rng(seed)
        lazy, eager = EventHeap(), _EagerHeap()
        popped_lazy, popped_eager = [], []
        scheduled = set()
        for step in range(400):
            op = rng.uniform()
            i = int(rng.integers(0, 12))
            if op < 0.45:
                # Quantized times force exact duplicates across workers and
                # across a worker's own leave/rejoin cycles.
                t = round(float(rng.uniform(0, 4)), 1)
                lazy.push(t, i)
                eager.push(t, i)
                scheduled.add(i)
            elif op < 0.65:
                lazy.invalidate(i)
                eager.invalidate(i)
                scheduled.discard(i)
            elif op < 0.85 and eager:
                popped_lazy.append(lazy.pop())
                popped_eager.append(popped_eager_e := eager.pop())
                scheduled.discard(popped_eager_e[1])
            else:
                assert lazy.peek_time() == eager.peek_time()
            assert len(lazy) == len(eager) == len(scheduled)
            assert bool(lazy) == bool(eager)
        while eager:
            popped_lazy.append(lazy.pop())
            popped_eager.append(eager.pop())
        assert not lazy
        assert popped_lazy == popped_eager


def test_event_heap_rejoin_with_equal_time_is_not_shadowed():
    """A worker's stale pre-leave entry must not shadow its rejoin entry
    even when both carry the same (t, i) value — liveness is entry
    identity, not tuple equality."""
    from repro.train.events import EventHeap

    h = EventHeap()
    h.push(1.0, 3)
    h.invalidate(3)   # leave: entry (1.0, 3) goes stale but stays buried
    h.push(1.0, 3)    # rejoin at the *same* time
    assert len(h) == 1
    assert h.pop() == (1.0, 3)
    assert not h and h.peek_time() == float("inf")


# --------------------------------------------------------------------------
# Compile-time validation hardening (PR 9 satellite): same-domain overlap,
# negative times, and reseed-source range checks fail loudly at compile
# --------------------------------------------------------------------------


def test_compile_rejects_same_domain_overlap():
    topo = two_cluster_topo()
    with pytest.raises(ValueError, match="overlapping same-domain"):
        Timeline(
            [ClusterOutage(1, 1.0, 3.0), ClusterOutage(1, 2.0, 4.0)]
        ).compile(topo)
    with pytest.raises(ValueError, match="overlapping same-domain"):
        Timeline(
            [LinkDegrade(0, 2, 1.0, 3.0, 8.0), LinkDegrade(0, 2, 2.5, 5.0, 4.0)]
        ).compile(topo)
    # A symmetric degrade occupies both directions: the reverse link in an
    # overlapping window collides with it.
    with pytest.raises(ValueError, match="overlapping same-domain"):
        Timeline(
            [LinkDegrade(0, 2, 1.0, 3.0, 8.0),
             LinkDegrade(2, 0, 2.0, 5.0, 4.0, symmetric=False)]
        ).compile(topo)


def test_compile_allows_disjoint_and_distinct_domains():
    topo = two_cluster_topo()
    # Half-open windows may abut: [1, 3) then [3, 4) on the same cluster.
    Timeline(
        [ClusterOutage(1, 1.0, 3.0), ClusterOutage(1, 3.0, 4.0)]
    ).compile(topo)
    # Opposite directions of the same cluster are distinct failure domains.
    Timeline(
        [ClusterOutage(1, 1.0, 3.0, direction="out"),
         ClusterOutage(1, 2.0, 4.0, direction="in")]
    ).compile(topo)
    # Distinct directed links are distinct domains even between the same
    # endpoints.
    Timeline(
        [LinkDegrade(0, 2, 1.0, 3.0, 8.0, symmetric=False),
         LinkDegrade(2, 0, 2.0, 5.0, 4.0, symmetric=False)]
    ).compile(topo)


def test_compile_rejects_negative_times_and_bad_seed_from():
    topo = two_cluster_topo()
    with pytest.raises(ValueError, match="0 <= start"):
        Timeline([ClusterOutage(1, -1.0, 3.0)]).compile(topo)
    with pytest.raises(ValueError, match="0 <= start"):
        Timeline([LinkDegrade(0, 2, -0.5, 3.0, 8.0)]).compile(topo)
    with pytest.raises(ValueError, match="time invalid"):
        Timeline([WorkerLeave(3, -0.5), WorkerRejoin(3, 1.0)]).compile(topo)
    with pytest.raises(ValueError, match="seed_from"):
        Timeline([WorkerLeave(3, 1.0), WorkerRejoin(3, 2.0, 99)]).compile(topo)
    with pytest.raises(ValueError, match="seed_from"):
        # A worker must not reseed from itself.
        Timeline([WorkerLeave(3, 1.0), WorkerRejoin(3, 2.0, 3)]).compile(topo)


def test_random_timeline_rejects_bad_knobs():
    topo = two_cluster_topo()
    with pytest.raises(ValueError, match="horizon"):
        presets.random_timeline(topo, seed=0, horizon=-5.0)
    with pytest.raises(ValueError, match="n_outages"):
        presets.random_timeline(topo, seed=0, horizon=10.0, n_outages=-1)
    with pytest.raises(ValueError, match="outage_len"):
        presets.random_timeline(topo, seed=0, horizon=10.0,
                                outage_len=(5.0, 1.0))
    with pytest.raises(ValueError, match="degrade_factor"):
        presets.random_timeline(topo, seed=0, horizon=10.0,
                                degrade_factor=(0.0, 2.0))


def test_random_timeline_always_compiles_overlap_free():
    """Generation redraws colliding windows, so every seed compiles."""
    topo = two_cluster_topo()
    for seed in range(12):
        presets.random_timeline(
            topo, seed=seed, horizon=30.0, n_outages=4, n_degrades=6,
            n_churn=3,
        ).compile(topo)


# --------------------------------------------------------------------------
# Cascading-storm hazard process (PR 9 tentpole): seeded Hawkes generator
# --------------------------------------------------------------------------


def four_cluster_topo(M=16):
    return Topology(M, workers_per_host=2, hosts_per_pod=2, pods_per_cluster=1)


def test_storm_deterministic_and_compiles():
    from repro.scenarios import storm

    topo = four_cluster_topo()
    a = storm(topo, seed=3, horizon=400.0, intensity=2.0)
    b = storm(topo, seed=3, horizon=400.0, intensity=2.0)
    assert a.events == b.events
    assert storm(topo, seed=4, horizon=400.0, intensity=2.0).events != a.events
    comp = a.compile(topo)  # generation is overlap-free by construction
    assert list(comp.boundaries) == sorted(comp.boundaries)


def test_storm_trigger_plants_the_first_strike():
    from repro.scenarios import storm

    topo = four_cluster_topo()
    tl = storm(topo, seed=0, horizon=300.0, trigger_cluster=1,
               trigger_time=5.0)
    strikes = [e for e in tl.events
               if isinstance(e, ClusterOutage) and e.cluster == 1
               and e.start == 5.0]
    assert len(strikes) == 1
    tl.compile(topo)


def test_hazard_excitation_cascades_from_the_trigger():
    """With all base rates zero, every event after the trigger is pure
    cascade — the self-exciting part demonstrably fires."""
    from repro.scenarios import hazard_timeline

    topo = four_cluster_topo()
    quiet = hazard_timeline(
        topo, seed=1, horizon=300.0,
        base_cluster_rate=0.0, base_degrade_rate=0.0, base_worker_rate=0.0,
    )
    assert not quiet.events
    stormy = hazard_timeline(
        topo, seed=1, horizon=300.0,
        base_cluster_rate=0.0, base_degrade_rate=0.0, base_worker_rate=0.0,
        excite_spread=2.0, excite_links=2.0, excite_workers=0.0,
        trigger_cluster=0, trigger_time=1.0,
    )
    cascade = [e for e in stormy.events
               if not (isinstance(e, ClusterOutage) and e.start == 1.0)]
    assert cascade, "excitation produced no follow-up events"
    stormy.compile(topo)


def test_storm_worker_blips_off_emits_no_churn():
    from repro.scenarios import storm

    topo = four_cluster_topo()
    tl = storm(topo, seed=2, horizon=400.0, intensity=3.0,
               worker_blips=False)
    assert not any(isinstance(e, (WorkerLeave, WorkerRejoin))
                   for e in tl.events)
    assert tl.events  # the storm itself still happened


def test_storm_event_cap_and_bad_intensity():
    from repro.scenarios import storm

    topo = four_cluster_topo()
    tl = storm(topo, seed=5, horizon=5000.0, intensity=10.0, max_events=20)
    # Each fired hazard emits at most 2 timeline events (leave+rejoin).
    assert len(tl.events) <= 41  # 2 * max_events + the forced trigger
    with pytest.raises(ValueError, match="intensity"):
        storm(topo, seed=0, horizon=10.0, intensity=0.0)


# --------------------------------------------------------------------------
# Monitor failover (PR 9 tentpole): heartbeat leases, deterministic
# election, degraded mode when no quorum
# --------------------------------------------------------------------------


def three_cluster_topo(M=12):
    return Topology(M, workers_per_host=2, hosts_per_pod=2, pods_per_cluster=1)


def test_failover_tick_elects_lowest_reachable_standby():
    from repro.core.monitor import MonitorFailover
    from repro.scenarios.driver import failover_tick

    topo = three_cluster_topo()
    comp = Timeline([ClusterOutage(0, 1.0, 50.0)]).compile(topo)
    mon = _monitor(topo, M=12, home_cluster=0, schedule_period=1.0,
                   failover=MonitorFailover())

    def seg(t):
        return comp.segments[comp.segment_index(t)]

    # Healthy wake: the leader renews every standby's lease.
    assert failover_tick(mon, seg(0.5), 0.5)
    assert mon.failover.last_heartbeat == {0: 0.5, 1: 0.5, 2: 0.5}
    # First partitioned wake: leases still fresh, no election yet.
    assert failover_tick(mon, seg(1.2), 1.2)
    assert mon.home_cluster == 0 and mon.failover.n_failovers == 0
    # Leases expired: both standbys elect; the lowest-id candidate wins
    # with 2 votes >= the majority quorum (3 clusters -> 2).
    assert failover_tick(mon, seg(2.2), 2.2)
    assert mon.home_cluster == 1
    assert mon.failover.n_failovers == 1
    assert mon.failover.leader_log == [(2.2, 1)]
    # Stable afterwards: the new leader renews reachable standbys, the
    # partitioned old home is WAN-cut and ineligible — no flapping.
    assert failover_tick(mon, seg(3.2), 3.2)
    assert failover_tick(mon, seg(4.2), 4.2)
    assert mon.failover.n_failovers == 1


def test_failover_handoff_drops_soft_state():
    """adopt_leader resets the EMA matrix, missed counters, warm basis,
    and failure evidence — all of it was collected at the old vantage."""
    from repro.core.monitor import MonitorFailover

    mon = _monitor(three_cluster_topo(), M=12, home_cluster=0,
                   failover=MonitorFailover())
    mon.collect({i: np.full(12, 2.0) for i in range(12)})
    mon.notify_failure(4, 1, 1.0)
    mon._basis, mon._basis_key = object(), b"stale"
    mon.adopt_leader(2, now=7.0)
    assert mon.home_cluster == 2
    assert not mon._T.any() and not mon._missed.any()
    assert mon._basis is None and mon._basis_key is None
    assert not mon._fail_links and mon._fail_wake is None
    assert mon.failover.leader_log == [(7.0, 2)]
    assert all(hb == 7.0 for hb in mon.failover.last_heartbeat.values())


def test_failover_no_quorum_single_standby():
    """Two clusters: the lone standby can never reach the default majority
    quorum (split-brain guard); an explicit quorum=1 opts in."""
    from repro.core.monitor import MonitorFailover
    from repro.scenarios.driver import failover_tick

    topo = two_cluster_topo()
    comp = Timeline([ClusterOutage(0, 1.0, 50.0)]).compile(topo)
    seg = comp.segments[comp.segment_index(2.0)]

    mon = _monitor(topo, home_cluster=0, schedule_period=1.0,
                   failover=MonitorFailover())
    # The home cluster is alive (WAN-cut, not dead): the refresh proceeds
    # from the partitioned vantage even though no election is possible.
    assert failover_tick(mon, seg, 5.0)
    assert mon.home_cluster == 0 and mon.failover.n_failovers == 0

    mon = _monitor(topo, home_cluster=0, schedule_period=1.0,
                   failover=MonitorFailover(quorum=1))
    assert failover_tick(mon, seg, 5.0)
    assert mon.home_cluster == 1 and mon.failover.n_failovers == 1


def test_failover_dead_home_and_no_quorum_skips_refresh():
    """Churn empties the home cluster and the quorum is unreachable: the
    wake is skipped (degraded mode), and counted."""
    from repro.core.monitor import MonitorFailover
    from repro.scenarios.driver import failover_tick

    topo = two_cluster_topo()
    comp = Timeline(
        [WorkerLeave(w, 1.0) for w in range(4)]  # cluster 0 empties out
    ).compile(topo)
    seg = comp.segments[comp.segment_index(2.0)]
    mon = _monitor(topo, home_cluster=0, schedule_period=1.0,
                   failover=MonitorFailover())  # majority quorum = 2
    assert not failover_tick(mon, seg, 5.0)
    assert mon.failover.n_skipped_refreshes == 1
    # quorum=1: the surviving cluster's standby takes over instead.
    mon = _monitor(topo, home_cluster=0, schedule_period=1.0,
                   failover=MonitorFailover(quorum=1))
    assert failover_tick(mon, seg, 5.0)
    assert mon.home_cluster == 1


def test_prepare_monitor_failover_requires_home():
    from repro.core.monitor import MonitorFailover
    from repro.scenarios.driver import prepare_monitor

    topo = two_cluster_topo()
    link = LinkTimeModel(topo, seed=0)
    mon = _monitor(topo, failover=MonitorFailover())
    with pytest.raises(ValueError, match="home"):
        prepare_monitor(mon, link)


def test_failover_reroutes_what_a_pinned_monitor_never_does(sim_data):
    """The PR's acceptance scenario: an outage kills the Monitor's home
    cluster.  Without failover the far side hammers the dead cluster to
    the end of the run; with failover a standby is elected and the dead
    domain is routed around within two refreshes of the election."""
    from repro.data.partition import uniform_partition
    from repro.train.simulator import SimConfig, simulate

    M = 12
    topo = three_cluster_topo(M)
    x, y, _, ex, ey = sim_data
    parts = uniform_partition(len(y), M, seed=0)
    cl = np.array([topo.cluster_of(w) for w in range(M)])
    period, timeout = 0.5, 0.4
    out = {}
    for failover in (False, True):
        link = LinkTimeModel(topo, jitter=0.02, seed=5,
                             scenario=presets.cluster_outage(0, 1.0, 1e9),
                             dead_link_timeout=timeout)
        cfg = SimConfig(algorithm="netmax", n_workers=M, total_events=1200,
                        monitor_period=period, monitor_home_cluster=0,
                        monitor_failover=failover, seed=3, engine="batched")
        out[failover] = simulate(cfg, link, x, y, parts, ex, ey,
                                 record_every=600)
    pinned, elected = out[False], out[True]

    assert pinned.leader_log == []
    assert elected.leader_log, "no leader was ever elected"
    t_elect, new_home = elected.leader_log[0]
    assert new_home != 0

    def into_dead(res):
        return [t for t, i, m in res.failed_pulls if cl[i] != 0 and cl[m] == 0]

    # Far-side pulls into the dead cluster cease within two refreshes of
    # the election (election wake + failure-evidence refresh), plus the
    # in-flight timeout tail.
    late = [t for t in into_dead(elected) if t > t_elect + 2 * period + timeout]
    assert not late, f"pulls into the dead cluster persisted: {late[:5]}"
    # The pinned Monitor's far side never hears a new policy: timeouts
    # into the dead cluster keep happening deep into the run.
    assert into_dead(pinned) and max(into_dead(pinned)) > 0.75 * pinned.times[-1]
