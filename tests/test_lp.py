"""Unit + property tests for the dense two-phase simplex solver."""

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.solver.lp import solve_lp

try:
    from scipy.optimize import linprog

    HAVE_SCIPY = True
except ImportError:  # pragma: no cover
    HAVE_SCIPY = False


def test_trivial_equality():
    r = solve_lp(np.array([1.0, 1.0]), np.array([[1.0, 1.0]]), np.array([1.0]))
    assert r.ok
    assert r.fun == pytest.approx(1.0)


def test_upper_bounds_bind():
    r = solve_lp(
        np.array([-1.0, -2.0]),
        np.array([[1.0, 1.0]]),
        np.array([1.0]),
        ub=np.array([0.6, 0.6]),
    )
    assert r.ok
    assert r.fun == pytest.approx(-1.6)
    assert r.x == pytest.approx([0.4, 0.6])


def test_infeasible_bounds():
    r = solve_lp(
        np.array([1.0]),
        np.array([[1.0]]),
        np.array([5.0]),
        lb=np.array([0.0]),
        ub=np.array([1.0]),
    )
    assert r.status == "infeasible"


def test_infeasible_constraints():
    # x0 + x1 = 1 and x0 + x1 = 2 simultaneously.
    r = solve_lp(
        np.array([1.0, 1.0]),
        np.array([[1.0, 1.0], [1.0, 1.0]]),
        np.array([1.0, 2.0]),
    )
    assert r.status == "infeasible"


def test_redundant_rows_ok():
    # Duplicated constraint should not break phase-1 artificial removal.
    r = solve_lp(
        np.array([1.0, 2.0]),
        np.array([[1.0, 1.0], [1.0, 1.0]]),
        np.array([1.0, 1.0]),
    )
    assert r.ok
    assert r.fun == pytest.approx(1.0)


def test_lower_bounds_shift():
    # min x0 s.t. x0 + x1 = 3, x >= 1 -> x0 = 1 (x1 = 2).
    r = solve_lp(
        np.array([1.0, 0.0]),
        np.array([[1.0, 1.0]]),
        np.array([3.0]),
        lb=np.array([1.0, 1.0]),
    )
    assert r.ok
    assert r.x[0] == pytest.approx(1.0)


def test_degenerate_vertex_terminates():
    # Multiple constraints meeting at one vertex (degeneracy): Bland's rule
    # must still terminate.
    A = np.array([[1.0, 1.0, 1.0], [1.0, 1.0, 0.0]])
    b = np.array([1.0, 1.0])
    r = solve_lp(np.array([0.0, 1.0, 1.0]), A, b)
    assert r.ok
    assert r.fun == pytest.approx(0.0)


@pytest.mark.skipif(not HAVE_SCIPY, reason="scipy unavailable")
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_matches_scipy_on_random_feasible(seed):
    rng = np.random.default_rng(seed)
    n, m = 8, 3
    A = rng.normal(size=(m, n))
    x0 = rng.uniform(0.1, 1.0, size=n)  # interior point => feasible
    b = A @ x0
    c = rng.normal(size=n)
    lb, ub = np.zeros(n), np.full(n, 2.0)
    mine = solve_lp(c, A, b, lb, ub)
    sp = linprog(c, A_eq=A, b_eq=b, bounds=list(zip(lb, ub)), method="highs")
    assert mine.ok == (sp.status == 0)
    if mine.ok:
        assert mine.fun == pytest.approx(sp.fun, rel=1e-6, abs=1e-8)
        assert np.allclose(A @ mine.x, b, atol=1e-7)
        assert np.all(mine.x >= lb - 1e-9)
        assert np.all(mine.x <= ub + 1e-9)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_solution_always_feasible(seed):
    """Property: whenever the solver claims optimal, the point is feasible."""
    rng = np.random.default_rng(seed)
    n, m = 6, 2
    A = rng.normal(size=(m, n))
    b = A @ rng.uniform(0.0, 1.0, size=n)
    c = rng.normal(size=n)
    r = solve_lp(c, A, b, np.zeros(n), np.full(n, np.inf))
    if r.ok:
        assert np.allclose(A @ r.x, b, atol=1e-7)
        assert np.all(r.x >= -1e-9)
