"""Tests for the consensus-SGD operator math (paper §III-B, §IV)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import consensus, policy, theory

jax.config.update("jax_enable_x64", False)


def _random_policy(M, seed):
    rng = np.random.default_rng(seed)
    P = rng.uniform(0.1, 1.0, size=(M, M))
    P /= P.sum(axis=1, keepdims=True)
    return P


def test_D_matrix_row_stochastic():
    M = 5
    P = _random_policy(M, 0)
    d = np.ones((M, M)) - np.eye(M)
    D = consensus.D_matrix(1, 3, alpha=0.05, rho=1.0, P=P, d=d)
    assert np.allclose(D.sum(axis=1), 1.0)
    # Only row i changes.
    assert np.allclose(D[[0, 2, 4]], np.eye(M)[[0, 2, 4]])


def test_Y_matches_monte_carlo_expectation():
    """Y_P (Eq. 22) == E[(D^k)^T D^k] estimated by sampling events."""
    M = 4
    rng = np.random.default_rng(0)
    d = np.ones((M, M)) - np.eye(M)
    P = policy.uniform_policy(d)
    alpha, rho = 0.1, 1.0
    p = consensus.worker_activation_probs(P, None, d)
    Y = consensus.build_Y(P, alpha, rho, d)
    acc = np.zeros((M, M))
    n = 40_000
    for _ in range(n):
        i, m = consensus.sample_event(rng, P, p)
        D = consensus.D_matrix(i, m, alpha, rho, P, d)
        acc += D.T @ D
    acc /= n
    assert np.allclose(acc, Y, atol=5e-3)


def test_two_step_update_matches_eq16():
    x = {"w": jnp.array([1.0, 2.0]), "b": jnp.array(0.5)}
    g = {"w": jnp.array([0.1, -0.1]), "b": jnp.array(1.0)}
    xp = {"w": jnp.array([0.0, 0.0]), "b": jnp.array(0.0)}
    alpha, w = 0.1, 0.25
    out = consensus.two_step_update(x, g, xp, alpha, w)
    x_half = x["w"] - alpha * g["w"]
    expect = (1 - w) * x_half + w * xp["w"]
    assert jnp.allclose(out["w"], expect)


def test_stacked_round_pulls_preround_params():
    """Eq. 16 pulls x_m^k (pre-round), not the neighbor's post-grad value."""
    M, D = 3, 4
    x = {"p": jnp.arange(M * D, dtype=jnp.float32).reshape(M, D)}
    g = {"p": jnp.ones((M, D))}
    neighbors = jnp.array([1, 2, 0], dtype=jnp.int32)
    weights = jnp.array([0.5, 0.0, 0.25], dtype=jnp.float32)
    alpha = 0.1
    out = consensus.stacked_round(x, g, neighbors, weights, alpha)
    x_half = x["p"] - alpha
    # worker 0 mixes with pre-round x[1]:
    expect0 = 0.5 * x_half[0] + 0.5 * x["p"][1]
    assert jnp.allclose(out["p"][0], expect0, atol=1e-6)
    # worker 1 (weight 0) is pure SGD:
    assert jnp.allclose(out["p"][1], x_half[1], atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.sampled_from([3, 5, 8]))
def test_consensus_round_preserves_mean_when_symmetric(seed, M):
    """With a symmetric pairwise exchange (permutation of transpositions and
    equal weights) the replica mean is preserved up to gradient drift."""
    rng = np.random.default_rng(seed)
    x = {"p": jnp.asarray(rng.normal(size=(M, 7)).astype(np.float32))}
    g = {"p": jnp.zeros((M, 7), dtype=jnp.float32)}
    # pair 2i <-> 2i+1; odd tail self-loops
    nb = np.arange(M)
    for i in range(0, M - 1, 2):
        nb[i], nb[i + 1] = i + 1, i
    w = np.where(nb != np.arange(M), 0.3, 0.0).astype(np.float32)
    out = consensus.stacked_round(x, g, jnp.asarray(nb, dtype=jnp.int32), jnp.asarray(w), 0.0)
    assert jnp.allclose(out["p"].mean(axis=0), x["p"].mean(axis=0), atol=1e-5)


def test_event_chain_reaches_consensus():
    """Pure consensus (zero gradients): replicas contract to a common point,
    and the contraction rate is bounded by Thm 1 with lambda2(Y_P)."""
    M = 6
    rng = np.random.default_rng(1)
    d = np.ones((M, M)) - np.eye(M)
    P = policy.uniform_policy(d)
    alpha, rho = 0.1, 1.5
    p = consensus.worker_activation_probs(P, None, d)
    Y = consensus.build_Y(P, alpha, rho, d)
    lam = theory.effective_lambda(Y)
    assert lam < 1.0

    x = rng.normal(size=(M, 3))
    x_star = x.mean(axis=0)
    dev0 = float(((x - x_star) ** 2).sum())
    K = 400
    trials = 40
    devs = np.zeros(K + 1)
    for _ in range(trials):
        xt = x.copy()
        devs[0] += ((xt - x_star) ** 2).sum()
        for k in range(1, K + 1):
            i, m = consensus.sample_event(rng, P, p)
            gmm = (d[i, m] + d[m, i]) / (2 * P[i, m])
            w = alpha * rho * gmm
            xt[i] = (1 - w) * xt[i] + w * xt[m]
            devs[k] += ((xt - xt.mean(axis=0)) ** 2).sum()
    devs /= trials
    # Empirical deviation must respect the Thm-1 bound (sigma = 0).
    for k in (50, 100, 200, 400):
        assert devs[k] <= lam**k * dev0 * 1.5 + 1e-9
    assert devs[K] < dev0 * 1e-2  # consensus actually reached


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_build_Y_symmetric_rows_sum_one_for_feasible(seed):
    M = 6
    T = np.full((M, M), 0.02)
    rng = np.random.default_rng(seed)
    T += rng.uniform(0, 0.03, size=(M, M))
    T = (T + T.T) / 2
    np.fill_diagonal(T, 0)
    res = policy.generate_policy_matrix(0.1, K=5, R=5, T=T)
    d = np.ones((M, M)) - np.eye(M)
    Y = consensus.build_Y(res.P, 0.1, res.rho, d)
    assert np.allclose(Y, Y.T, atol=1e-10)
    assert np.allclose(Y.sum(axis=1), 1.0, atol=1e-6)
    assert np.all(Y >= -1e-10)


def test_mixing_weight_formula():
    # gamma = (d+d')/(2p); w = alpha*rho*gamma
    assert consensus.mixing_weight(0.1, 2.0, 0.25) == pytest.approx(0.8)
    assert consensus.mixing_weight(0.1, 2.0, 0.5, d_sym=2.0) == pytest.approx(0.4)
