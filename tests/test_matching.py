"""Tests for Birkhoff matched gossip rounds (beyond-paper optimization)."""

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import policy
from repro.core.matching import (
    birkhoff_decompose,
    marginal_matrix,
    matched_sampler,
    sinkhorn,
)


def _random_policy(M, seed):
    rng = np.random.default_rng(seed)
    P = rng.uniform(0.05, 1.0, size=(M, M))
    P /= P.sum(axis=1, keepdims=True)
    return P


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500), st.sampled_from([3, 5, 8, 12]))
def test_sinkhorn_doubly_stochastic(seed, M):
    Q = sinkhorn(_random_policy(M, seed))
    assert np.allclose(Q.sum(axis=1), 1.0, atol=1e-8)
    assert np.allclose(Q.sum(axis=0), 1.0, atol=1e-6)
    assert np.all(Q >= 0)


def test_sinkhorn_preserves_zero_support():
    P = np.array([[0.5, 0.5, 0.0], [0.5, 0.0, 0.5], [0.0, 0.5, 0.5]])
    Q = sinkhorn(P)
    # off-diagonal zeros stay zero (diagonal may gain the escape hatch)
    assert Q[0, 2] == 0.0
    assert Q[1, 1] >= 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500), st.sampled_from([3, 5, 8]))
def test_birkhoff_reconstructs_Q(seed, M):
    Q = sinkhorn(_random_policy(M, seed))
    dec = birkhoff_decompose(Q)
    E = marginal_matrix(dec)
    # Expected permutation matrix equals Q up to the numerical tail.
    assert np.abs(E - Q).max() < 1e-4 + dec.residual
    assert dec.weights.sum() == pytest.approx(1.0)
    assert np.all(dec.weights > 0)


def test_permutations_are_permutations():
    Q = sinkhorn(_random_policy(6, 42))
    dec = birkhoff_decompose(Q)
    for perm in dec.permutations:
        assert sorted(perm.tolist()) == list(range(6))


def test_identity_matrix_single_component():
    dec = birkhoff_decompose(np.eye(4))
    assert dec.n_components == 1
    assert np.array_equal(dec.permutations[0], np.arange(4))


def test_matched_sampler_marginals_close_to_policy():
    """E[pull edge] under the matched sampler ~ Sinkhorn projection of P —
    the heterogeneity preference survives matching."""
    M = 8
    T = np.full((M, M), 0.04)
    for i in range(M):
        for m in range(M):
            if (i < 4) == (m < 4):
                T[i, m] = 0.01
    np.fill_diagonal(T, 0.0)
    T[0, 4] = T[4, 0] = 0.4
    res = policy.generate_policy_matrix(0.1, K=8, R=8, T=T)
    dec = matched_sampler(res.P)
    E = marginal_matrix(dec)
    # Slow link still de-preferred after matching:
    assert E[0, 4] < E[0, 1:4].mean()
    # Sampling marginals match decomposition weights.
    rng = np.random.default_rng(0)
    counts = np.zeros((M, M))
    n = 20_000
    for _ in range(n):
        perm = dec.sample(rng)
        counts[np.arange(M), perm] += 1
    assert np.abs(counts / n - E).max() < 0.02


def test_sample_returns_valid_perm():
    dec = matched_sampler(_random_policy(5, 7))
    rng = np.random.default_rng(1)
    perm = dec.sample(rng)
    assert sorted(perm.tolist()) == list(range(5))
