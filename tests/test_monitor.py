"""Tests for the Network Monitor (Alg. 1) + worker EMA (Alg. 2 l.19-22)."""

import numpy as np
import pytest

from repro.core.monitor import IterationTimeEMA, NetworkMonitor
from repro.core.nettime import LinkTimeModel, Topology, homogeneous_times


def test_ema_update_rule():
    ema = IterationTimeEMA(n_workers=4, beta=0.5)
    ema.update(1, 0.1)  # first observation seeds
    assert ema.times[1] == pytest.approx(0.1)
    ema.update(1, 0.3)
    assert ema.times[1] == pytest.approx(0.5 * 0.1 + 0.5 * 0.3)


def test_ema_tracks_speed_change():
    """Small beta adapts quickly (paper: beta tuned to network dynamics)."""
    fast = IterationTimeEMA(4, beta=0.1)
    slow = IterationTimeEMA(4, beta=0.9)
    for _ in range(10):
        fast.update(0, 0.01)
        slow.update(0, 0.01)
    for _ in range(5):
        fast.update(0, 1.0)
        slow.update(0, 1.0)
    assert fast.times[0] > 0.9  # tracked the slowdown
    assert slow.times[0] < 0.5  # still remembers history


def test_monitor_policy_adapts_to_slow_link():
    M = 6
    mon = NetworkMonitor(n_workers=M, alpha=0.1, K=6, R=6)
    T = homogeneous_times(M, 0.02)
    T[0, 1] = T[1, 0] = 0.5
    mon.collect({i: T[i] for i in range(M)})
    res = mon.step()
    off = res.P[0][[m for m in range(M) if m not in (0, 1)]]
    assert res.P[0, 1] < off.mean()  # slow link de-preferred
    assert res.lambda2 < 1.0


def test_monitor_detects_dead_worker_and_reroutes():
    M = 5
    mon = NetworkMonitor(n_workers=M, alpha=0.1, K=5, R=5, dead_after=2)
    T = homogeneous_times(M, 0.02)
    # Worker 4 reports twice then dies.
    for _ in range(2):
        mon.collect({i: T[i] for i in range(M)})
    res = mon.step()
    assert res.P[0, 4] > 0
    for _ in range(3):
        mon.collect({i: T[i] for i in range(M) if i != 4})
    res = mon.step()
    assert 4 not in mon.live_workers
    assert np.all(res.P[:, 4] == 0)  # nobody pulls from the dead worker
    assert np.all(res.P[4, :4] == 0)
    # Survivors still converge.
    assert res.lambda2 < 1.0


def test_monitor_restart_stateless():
    """A restarted Monitor rebuilds policy purely from worker reports."""
    M = 4
    T = homogeneous_times(M, 0.02)
    m1 = NetworkMonitor(n_workers=M, alpha=0.1, K=5, R=5)
    m1.collect({i: T[i] for i in range(M)})
    r1 = m1.step()
    m2 = NetworkMonitor(n_workers=M, alpha=0.1, K=5, R=5)  # fresh instance
    m2.collect({i: T[i] for i in range(M)})
    r2 = m2.step()
    assert np.allclose(r1.P, r2.P)
    assert r1.rho == pytest.approx(r2.rho)


def test_linktime_model_tiers_and_dynamics():
    topo = Topology(n_workers=8, workers_per_host=4, hosts_per_pod=1)
    model = LinkTimeModel(topo, jitter=0.0, seed=3)
    T0 = model.matrix(now=0.0)
    # intra-host faster than inter-pod
    assert T0[0, 1] < T0[0, 7]
    # the dynamic slow link changes over time (paper: every 5 min)
    mats = [model.matrix(now=t) for t in (0.0, 301.0, 602.0)]
    assert not (np.allclose(mats[0], mats[1]) and np.allclose(mats[1], mats[2]))


def test_linktime_iteration_time_floor_is_compute():
    topo = Topology(n_workers=4, workers_per_host=4)
    model = LinkTimeModel(topo, compute_time=0.05, jitter=0.0, seed=0)
    # intra-host network (0.01) < compute (0.05) -> iteration time = compute
    assert model.iteration_time(0, 1) == pytest.approx(0.05)
