"""RPC front-end, sharding, admission: failure paths and the E2E pin.

The service contract mirrors the in-process server's: every request is
answered (fresh, cached, stale, uniform, or shed — never an exception,
never a hang), and on the fault-free path an RPC answer is *bit-equal*
to the in-process answer (Python json round-trips float64 exactly).
Failure paths pinned here: malformed and oversized frames, client
disconnect mid-request, server restart with a cold cache, shard-routing
stability, and shed-under-overload returning ``ok=False``.
"""

import json
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.core.policy import connectivity_key
from repro.scenarios.chaos import ChaosInjector
from repro.serve import (
    AdmissionController,
    PolicyClient,
    PolicyServer,
    PolicyService,
    RpcError,
    ShardRouter,
)
from repro.serve.rpc import SCHEMA, _recv_frame, _send_frame
from repro.serve.shard import shard_index


def make_T(M, seed, lo=0.5, hi=3.0):
    rng = np.random.default_rng(seed)
    T = rng.uniform(lo, hi, (M, M))
    T = (T + T.T) / 2
    np.fill_diagonal(T, 0.0)
    return T


def ring_d(M, extra=()):
    """Sparse ring edge set (plus optional chords): varied connectivity
    keys so requests actually spread across shards."""
    d = np.zeros((M, M))
    for i in range(M):
        d[i, (i + 1) % M] = d[(i + 1) % M, i] = 1.0
    for i, j in extra:
        d[i, j] = d[j, i] = 1.0
    return d


@pytest.fixture()
def service():
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    svc = PolicyService(srv).start()
    yield svc, srv
    svc.stop()


# --------------------------------------------------------------------------
# Protocol basics
# --------------------------------------------------------------------------


def test_rpc_policy_bit_equal_to_inprocess(service):
    svc, srv = service
    direct = PolicyServer(alpha=0.9, K=4, R=4)
    T = make_T(8, 0)
    with PolicyClient(svc.address) as cli:
        res, meta = cli.request(T, want_meta=True)
    ref = direct.request(T)
    assert meta["rung"] == "fresh"
    assert np.array_equal(res.P, ref.P)
    assert res.rho == ref.rho and res.t_bar == ref.t_bar
    assert res.T_convergence == ref.T_convergence


def test_rpc_roundtrips_nonfinite(service):
    """A degraded answer carries a non-finite T_convergence; Python json
    writes/parses Infinity/NaN, so ok=False survives the wire."""
    svc, _ = service
    T = make_T(6, 1)
    T[:] = np.inf  # every link dead -> degraded answer, ok=False
    np.fill_diagonal(T, 0.0)
    with PolicyClient(svc.address) as cli:
        res = cli.request(T)
    assert not res.ok and not np.isfinite(res.T_convergence)


def test_rpc_ping_stats_invalidate(service):
    svc, srv = service
    T = make_T(6, 2)
    with PolicyClient(svc.address) as cli:
        assert cli.ping()
        cli.request(T)
        st = cli.stats()
        assert st["serving"]["n_requests"] == 1
        cli.invalidate(np.ones((6, 6)) - np.eye(6))
    assert srv.stats.n_invalidations == 1
    assert srv.cache_len() == 0


def test_rpc_tenant_invalidation_via_wire(service):
    """The PR-5 tenant rule works across the wire: a tenant whose edge
    set changes drops its old key's cache lines."""
    svc, srv = service
    M = 8
    with PolicyClient(svc.address) as cli:
        cli.request(make_T(M, 3), tenant="w1")
        assert srv.cache_len() == 1
        d2 = ring_d(M)
        cli.request(make_T(M, 3), d=d2, tenant="w1")
    assert srv.stats.n_invalidations == 1


# --------------------------------------------------------------------------
# Failure paths
# --------------------------------------------------------------------------


def test_malformed_frame_gets_error_then_close(service):
    svc, _ = service
    with socket.create_connection(svc.address, timeout=10) as s:
        garbage = b"this is not json {"
        s.sendall(struct.pack(">I", len(garbage)) + garbage)
        resp = _recv_frame(s)
        assert resp["ok"] is False and "malformed" in resp["error"]
        # server closes the untrustworthy connection afterwards
        assert s.recv(1) == b""
    assert svc.n_bad_frames == 1


def test_oversized_frame_rejected(service):
    svc, _ = service
    with socket.create_connection(svc.address, timeout=10) as s:
        s.sendall(struct.pack(">I", 0xFFFFFFFF))  # 4 GiB declared
        resp = _recv_frame(s)
        assert resp["ok"] is False and "exceeds" in resp["error"]
        assert s.recv(1) == b""


def test_unknown_op_and_schema_are_rpc_errors(service):
    svc, _ = service
    with PolicyClient(svc.address, retries=0) as cli:
        with pytest.raises(RpcError, match="unknown op"):
            cli._call({"op": "frobnicate"})
    with socket.create_connection(svc.address, timeout=10) as s:
        _send_frame(s, {"schema": "repro.trace/v1", "op": "ping", "id": 1})
        resp = _recv_frame(s)
        assert resp["ok"] is False and "schema" in resp["error"]


def test_bad_request_body_does_not_kill_connection(service):
    """A policy op with a garbage T is answered with an error frame and
    the connection stays usable (framing was fine)."""
    svc, _ = service
    with socket.create_connection(svc.address, timeout=10) as s:
        _send_frame(s, {"schema": SCHEMA, "op": "policy", "id": 1,
                        "T": "nonsense"})
        resp = _recv_frame(s)
        assert resp["ok"] is False
        _send_frame(s, {"schema": SCHEMA, "op": "ping", "id": 2})
        assert _recv_frame(s)["ok"] is True


def test_client_disconnect_mid_request_leaves_server_alive(service):
    """A client that sends half a frame (or a full request) and vanishes
    costs one connection; the server keeps answering others."""
    svc, _ = service
    T = make_T(10, 4)
    s = socket.create_connection(svc.address, timeout=10)
    payload = json.dumps(
        {"schema": SCHEMA, "op": "policy", "id": 1, "T": T.tolist()}
    ).encode()
    s.sendall(struct.pack(">I", len(payload)) + payload[: len(payload) // 2])
    s.close()  # mid-frame disconnect
    s2 = socket.create_connection(svc.address, timeout=10)
    _send_frame(s2, {"schema": SCHEMA, "op": "policy", "id": 1,
                     "T": T.tolist()})
    s2.close()  # full request sent, gone before the answer
    deadline = time.time() + 10
    while svc.n_disconnects < 2 and time.time() < deadline:
        time.sleep(0.01)
    assert svc.n_disconnects >= 1
    with PolicyClient(svc.address) as cli:
        assert cli.ping()
        assert cli.request(T).ok


def test_client_retries_across_server_restart():
    """Restarting the service on the same port loses the cache (cold) but
    not the client: its retry loop reconnects and the request succeeds."""
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    svc = PolicyService(srv).start()
    host, port = svc.address
    T = make_T(8, 5)
    cli = PolicyClient((host, port), retries=8, backoff_s=0.05)
    r1, m1 = cli.request(T, want_meta=True)
    assert m1["rung"] == "fresh"
    svc.stop()
    # The client's dead connection pins the port (FIN_WAIT) until its
    # first failed attempt closes it, so the replacement service binds in
    # a retry loop racing the client's own reconnect/backoff path.
    srv2 = PolicyServer(alpha=0.9, K=4, R=4)  # cold cache
    box = {}

    def rebind():
        for _ in range(400):
            try:
                box["svc"] = PolicyService(
                    srv2, host=host, port=port
                ).start()
                return
            except OSError:
                time.sleep(0.02)

    t = threading.Thread(target=rebind)
    t.start()
    try:
        r2, m2 = cli.request(T, want_meta=True)
        assert m2["rung"] == "fresh"  # cold: solved again, not a hit
        assert cli.n_reconnects >= 1
        assert np.array_equal(r1.P, r2.P)
    finally:
        t.join(timeout=30)
        cli.close()
        if "svc" in box:
            box["svc"].stop()


def test_client_raises_after_retries_exhausted(service):
    svc, _ = service
    host, port = svc.address
    svc.stop()
    cli = PolicyClient((host, port), retries=1, backoff_s=0.01)
    with pytest.raises(ConnectionError, match="after 2 attempts"):
        cli.ping()


# --------------------------------------------------------------------------
# Shard routing
# --------------------------------------------------------------------------


def test_shard_index_is_stable_cross_process():
    """blake2b routing must not depend on PYTHONHASHSEED: pin an actual
    value so any silent hash change fails loudly."""
    d = ring_d(8)
    ck = connectivity_key(d)
    assert shard_index(ck, 4) == shard_index(ck, 4)
    import hashlib

    expect = int.from_bytes(
        hashlib.blake2b(ck, digest_size=8).digest(), "big"
    ) % 4
    assert shard_index(ck, 4) == expect


def test_router_key_independent_of_link_times():
    """EMA jitter must never migrate a cluster off its warm shard: the
    route hashes the edge set only."""
    router = ShardRouter.build(4, 0.9, K=4, R=4)
    d = ring_d(10, extra=[(0, 5)])
    assert router.shard_of(make_T(10, 0), d) == router.shard_of(
        make_T(10, 99) * 7.0, d
    )


def test_router_normalizes_before_hashing():
    """An inf link time kills the edge; routing must see the same
    effective edge set the target server keys on."""
    router = ShardRouter.build(4, 0.9, K=4, R=4)
    T = make_T(8, 6)
    Tinf = T.copy()
    Tinf[0, 3] = Tinf[3, 0] = np.inf
    d_masked = np.ones((8, 8)) - np.eye(8)
    d_masked[0, 3] = d_masked[3, 0] = 0.0
    assert router.shard_of(Tinf) == router.shard_of(T, d_masked)


def test_router_locality_and_fanout():
    """Repeat traffic for one edge set stays on one shard (warm hits);
    invalidation reaches every shard."""
    router = ShardRouter.build(4, 0.9, K=4, R=4)
    edge_sets = [ring_d(8), ring_d(8, extra=[(0, 4)]),
                 ring_d(8, extra=[(1, 5)]), None]
    for rep in range(3):
        for i, d in enumerate(edge_sets):
            res, meta = router.request_meta(make_T(8, i), d=d)
            assert meta["shard"] == router.shard_of(make_T(8, i), d)
            assert meta["rung"] == ("fresh" if rep == 0 else "hit")
    st = router.stats()
    assert st["n_requests"] == 12 and st["n_hits"] == 8
    assert st["n_solves"] == 4
    before = router.cache_len()
    router.invalidate(ring_d(8))
    assert st["n_requests"] == 12  # snapshot, not live
    assert router.stats()["n_invalidations"] == 4  # fan-out: all shards
    assert router.cache_len() == before - 1


def test_router_request_many_order_preserved():
    router = ShardRouter.build(3, 0.9, K=4, R=4)
    reqs = []
    for i in range(6):
        d = ring_d(8, extra=[(0, 2 + (i % 3))])
        reqs.append((make_T(8, i % 2), d))
    out = router.request_many(reqs)
    assert len(out) == 6
    for (T, d), res in zip(reqs, out):
        direct = router.request(T, d=d)
        assert np.array_equal(res.P, direct.P)


# --------------------------------------------------------------------------
# Admission control
# --------------------------------------------------------------------------


def test_admission_serves_and_reports_rungs():
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    with AdmissionController(srv, workers=2) as adm:
        T = make_T(8, 7)
        r1, m1 = adm.submit(T)
        r2, m2 = adm.submit(T)
    assert m1["rung"] == "fresh" and m2["rung"] == "hit"
    assert r1.ok and np.array_equal(r1.P, r2.P)
    assert adm.stats.n_served == 2 and adm.stats.n_shed == 0


def test_admission_invalidate_passthrough_over_rpc():
    """The invalidate op must work when an AdmissionController fronts the
    stack (it forwards to the backend instead of queueing)."""
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    with AdmissionController(srv, workers=2) as adm:
        svc = PolicyService(adm).start()
        try:
            with PolicyClient(svc.address) as client:
                T = make_T(8, 3)
                client.request(T)
                assert srv.cache_len() == 1
                client.invalidate(np.ones((8, 8)) - np.eye(8))
                assert srv.cache_len() == 0
        finally:
            svc.stop()


def test_admission_shed_under_overload_is_uniform_not_error():
    """Saturate a tiny queue behind one slow worker: the overflow is shed
    with the ok=False uniform fallback, never an exception or a hang."""
    chaos = ChaosInjector(seed=1, queue_delay_rate=1.0, queue_delay_ms=1e6)
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    adm = AdmissionController(
        srv, max_queue=2, workers=1, chaos=chaos, safety=1.0
    )
    try:
        results = []
        lock = threading.Lock()

        def go(i):
            # every entry gets a hopeless deadline via the chaos queue
            # channel (1e6 ms charged at dispatch >> 50 ms deadline)
            res, meta = adm.submit(make_T(6, i), deadline_ms=50.0)
            with lock:
                results.append((res, meta))

        threads = [threading.Thread(target=go, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert len(results) == 8  # every request answered
        sheds = [r for r, m in results if m["rung"] == "shed"]
        assert sheds, "overload must shed"
        for res, meta in results:
            if meta["rung"] == "shed":
                assert not res.ok and np.isinf(res.T_convergence)
        assert adm.stats.n_shed > 0
        assert adm.stats.n_deadline_violations == 0
    finally:
        adm.close()


def test_admission_priority_order():
    """With one worker wedged on a first entry, later submissions drain
    in (priority, deadline) order, not arrival order."""
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    adm = AdmissionController(srv, max_queue=16, workers=1)
    order = []
    lock = threading.Lock()
    gate = threading.Event()

    real_request_meta = srv.request_meta

    def slow_first(T, d=None, tenant=None):
        res = real_request_meta(T, d=d, tenant=tenant)
        if not gate.is_set():
            gate.set()
            time.sleep(0.3)  # hold the worker while the queue builds
        with lock:
            order.append(tenant)
        return res

    srv.request_meta = slow_first
    try:
        threads = [threading.Thread(
            target=adm.submit, args=(make_T(6, 0),),
            kwargs={"tenant": "first"},
        )]
        threads[0].start()
        gate.wait(timeout=10)
        specs = [("lo-late", 2, 5000.0), ("hi-late", 0, 5000.0),
                 ("lo-soon", 2, 2000.0), ("hi-soon", 0, 2000.0)]
        for tenant, prio, dl in specs:
            t = threading.Thread(
                target=adm.submit, args=(make_T(6, 1),),
                kwargs={"tenant": tenant, "priority": prio,
                        "deadline_ms": dl},
            )
            t.start()
            threads.append(t)
            time.sleep(0.02)  # deterministic arrival order
        for t in threads:
            t.join(timeout=30)
    finally:
        adm.close()
    assert order[0] == "first"
    assert order[1:] == ["hi-soon", "hi-late", "lo-soon", "lo-late"]


def test_admission_displaces_worst_when_full():
    """A full queue sheds its *worst* entry for a better newcomer."""
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    adm = AdmissionController(srv, max_queue=1, workers=1)
    gate = threading.Event()
    real = srv.request_meta

    def slow(T, d=None, tenant=None):
        gate.set()
        time.sleep(0.25)
        return real(T, d=d, tenant=tenant)

    srv.request_meta = slow
    out = {}

    def go(name, prio):
        res, meta = adm.submit(make_T(6, 2), tenant=name, priority=prio)
        out[name] = meta["rung"]

    try:
        t0 = threading.Thread(target=go, args=("busy", 1))
        t0.start()
        gate.wait(timeout=10)
        t1 = threading.Thread(target=go, args=("victim", 2))
        t1.start()
        time.sleep(0.05)  # victim is queued (worker busy, queue full)
        t2 = threading.Thread(target=go, args=("urgent", 0))
        t2.start()
        for t in (t0, t1, t2):
            t.join(timeout=30)
    finally:
        adm.close()
    assert out["victim"] == "shed"
    assert out["urgent"] in ("fresh", "hit", "coalesced")
    assert adm.stats.n_displaced == 1


def test_admission_close_sheds_pending():
    srv = PolicyServer(alpha=0.9, K=4, R=4)
    adm = AdmissionController(srv, max_queue=8, workers=1)
    gate = threading.Event()
    real = srv.request_meta

    def slow(T, d=None, tenant=None):
        gate.set()
        time.sleep(0.3)
        return real(T, d=d, tenant=tenant)

    srv.request_meta = slow
    metas = []
    lock = threading.Lock()

    def go(i):
        _, meta = adm.submit(make_T(6, 3), tenant=f"t{i}")
        with lock:
            metas.append(meta)

    threads = [threading.Thread(target=go, args=(i,)) for i in range(4)]
    threads[0].start()
    gate.wait(timeout=10)
    for t in threads[1:]:
        t.start()
    time.sleep(0.05)
    adm.close()  # queued entries answered as shed, never abandoned
    for t in threads:
        t.join(timeout=30)
    assert len(metas) == 4
    assert sum(m["rung"] == "shed" for m in metas) >= 1


# --------------------------------------------------------------------------
# E2E acceptance: sharded service + admission + chaos over RPC
# --------------------------------------------------------------------------


def test_e2e_sharded_service_under_chaos():
    """ISSUE-10 acceptance: multi-threaded clients against a sharded
    service under seeded chaos — every request answered, zero deadline
    violations among admitted requests, and the fault-free subset
    (rungs fresh/hit/coalesced) bit-equal to a direct in-process
    ``PolicyServer``."""
    chaos = ChaosInjector(
        seed=42,
        solver_fail_rate=0.25,
        solver_delay_rate=0.2,
        solver_delay_ms=5.0,
        queue_delay_rate=0.1,
        queue_delay_ms=10.0,
    )
    router = ShardRouter(
        [
            PolicyServer(alpha=0.9, K=4, R=4, chaos=chaos,
                         max_retries=1, breaker_threshold=100)
            for _ in range(4)
        ]
    )
    adm = AdmissionController(router, max_queue=32, workers=4, chaos=chaos)
    svc = PolicyService(adm).start()

    M = 8
    edge_sets = [None, ring_d(M), ring_d(M, extra=[(0, 4)]),
                 ring_d(M, extra=[(1, 5), (2, 6)])]
    # One T per edge set: every solve is cold, so the fault-free subset
    # is bit-reproducible (warm-start history would change low bits of
    # repeat solves on the same connectivity key).
    jobs = [
        (make_T(M, i % len(edge_sets)), edge_sets[i % len(edge_sets)],
         f"tenant{i % 5}")
        for i in range(40)
    ]

    answers = [None] * len(jobs)

    def worker(lo, hi):
        with PolicyClient(svc.address, retries=3) as cli:
            for i in range(lo, hi):
                T, d, tenant = jobs[i]
                res, meta = cli.request(
                    T, d=d, tenant=tenant, want_meta=True,
                    deadline_ms=10_000.0,
                )
                answers[i] = (res, meta)

    threads = [
        threading.Thread(target=worker, args=(k * 10, (k + 1) * 10))
        for k in range(4)
    ]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
    finally:
        svc.stop()
        adm.close()

    # 1. every request answered
    assert all(a is not None for a in answers)
    # 2. zero deadline violations among admitted (non-shed) requests
    assert adm.stats.n_deadline_violations == 0
    # 3. fault-free subset bit-equal to a direct in-process server
    direct = PolicyServer(alpha=0.9, K=4, R=4)
    n_clean = 0
    for (T, d, _), (res, meta) in zip(jobs, answers):
        assert "rung" in meta
        if meta["rung"] in ("fresh", "hit", "coalesced"):
            ref = direct.request(T, d=d)
            assert np.array_equal(res.P, ref.P)
            assert res.rho == ref.rho
            assert res.t_bar == ref.t_bar
            assert res.T_convergence == ref.T_convergence
            n_clean += 1
        else:
            assert meta["rung"] in ("stale", "uniform", "shed")
    assert n_clean > 0  # the pin is vacuous if chaos degraded everything
    # chaos actually fired (seeded schedule, deterministic)
    assert chaos.n_solver_faults > 0
