"""Engine-parity suite: the batched engine vs the reference loops.

The batched engine's whole value proposition is that it is *faithful*: for
every registered algorithm — async gossip, the serialized-PS-row ps-async
variant, and the stacked synchronous round executor — the same seed must
produce the same virtual timeline (host-side state is bit-identical by
construction) and the same training trajectory (device math agrees to
float tolerance).  These tests are the PR's contract — see DESIGN.md
§11-§12.
"""

import numpy as np
import pytest

from repro.algos import get_algorithm, list_algorithms
from repro.core.nettime import LinkTimeModel, Topology
from repro.data.partition import uniform_partition
from repro.data.synthetic import train_eval_split
from repro.train.simulator import SimConfig, simulate

# Enumerated from the registry so a newly @register'd strategy is covered
# automatically (and the suite fails loudly if it can't be).
GOSSIP = [n for n in list_algorithms() if get_algorithm(n).family == "gossip"]
SYNC = [n for n in list_algorithms() if get_algorithm(n).synchronous]
ASYNC_NON_GOSSIP = [
    n for n in list_algorithms()
    if not get_algorithm(n).synchronous and get_algorithm(n).family != "gossip"
]


@pytest.fixture(scope="module")
def data():
    return train_eval_split(1600, 400, 32, 10, seed=0)


def _sim(algo, engine, data, M=8, events=450, seed=0, topo=None,
         record_every=150, monitor_period=0.6, log=None, parts=None,
         scenario=None, **kw):
    x, y, ex, ey = data
    topo = topo or Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    link = LinkTimeModel(topo, jitter=0.02, seed=5, slow_interval=60.0,
                         scenario=scenario, dead_link_timeout=2.0)
    if parts is None:
        parts = uniform_partition(len(y), M, seed=0)
    # trace=True everywhere: the per-event trace stream (repro.trace) is
    # part of the parity contract, so the whole suite records it.
    cfg = SimConfig(algorithm=algo, n_workers=M, total_events=events, lr=0.05,
                    monitor_period=monitor_period, seed=seed, engine=engine,
                    trace=True, **kw)
    return simulate(cfg, link, x, y, parts, ex, ey,
                    record_every=record_every, _cohort_log=log)


def _skewed_parts(data, M):
    """Shards so small that per-worker batch sizes differ (bsz = min(batch,
    |shard|)) — exercises the scheduler's batch-length level splitting."""
    from repro.data.partition import size_skewed_partition

    _, y, _, _ = data
    return size_skewed_partition(len(y), M, segments=[1 + i % 3 for i in range(M)])


def _assert_parity(ref, bat, loss_tol=5e-4):
    """Host-side trajectory identical; device math within tolerance."""
    assert ref.engine == "reference" and bat.engine == "batched"
    assert bat.events == ref.events
    # Virtual time is produced purely host-side from identical rng draw
    # order, so it must match exactly — not approximately.
    np.testing.assert_array_equal(np.asarray(bat.times), np.asarray(ref.times))
    assert bat.comm_time == ref.comm_time
    assert bat.compute_time == ref.compute_time
    assert bat.policy_updates == ref.policy_updates
    # Scenario telemetry and every published policy are host-side state:
    # exactly equal, including each refresh's full P matrix.
    assert bat.failed_pulls == ref.failed_pulls
    # The trace event stream (SimConfig.trace; repro.trace) is host-side
    # bookkeeping on already-parity-pinned values: bit-exact, tuple for
    # tuple — (t_start, duration, src, dst, kind, comm, compute).
    assert bat.trace_events == ref.trace_events
    assert bat.trace_events  # one record per event (async) or round (sync)
    assert len(bat.policy_log) == len(ref.policy_log)
    for (ta, ra, Pa), (tb, rb, Pb) in zip(ref.policy_log, bat.policy_log):
        assert ta == tb and ra == rb
        np.testing.assert_array_equal(Pa, Pb)
    # Failover telemetry rides the shared monitor_boundary: every election
    # and every skipped refresh must match exactly ([] / 0 when disabled).
    assert bat.leader_log == ref.leader_log
    assert bat.skipped_refreshes == ref.skipped_refreshes
    np.testing.assert_allclose(bat.losses, ref.losses, rtol=loss_tol, atol=loss_tol)
    np.testing.assert_allclose(bat.accs, ref.accs, atol=0.02)


# --------------------------------------------------------------------------
# Parity: every gossip-family algorithm, both with and without the Monitor
# --------------------------------------------------------------------------


def test_every_registered_strategy_is_batchable():
    """Full coverage: every registered strategy rides the batched engine
    (the acceptance criterion of the full-coverage refactor)."""
    names = list_algorithms()
    assert len(names) >= 8, names
    for name in names:
        assert get_algorithm(name).supports_batched, name


@pytest.mark.parametrize("name", GOSSIP)
def test_engine_parity(name, data):
    ref = _sim(name, "reference", data)
    bat = _sim(name, "batched", data)
    assert bat.cohorts > 0 and bat.cohorts < bat.events[-1]
    if get_algorithm(name).wants_monitor(SimConfig()):
        assert bat.policy_updates > 0  # the Monitor path is exercised too
    _assert_parity(ref, bat)


@pytest.mark.parametrize("name", ["netmax", "adpsgd"])
def test_engine_parity_multi_cluster(name, data):
    """Parity on the paper-§V wide-area topology (inter_cluster WAN tier).

    WAN links stretch virtual time ~10x, so the Monitor period is raised
    accordingly — Alg.-3 policy generation at every virtual second would
    dominate the test's wall clock on both engines alike.
    """
    M = 16
    topo = Topology.multi_cluster(M, workers_per_host=4, hosts_per_pod=1,
                                  pods_per_cluster=2)
    assert topo.n_clusters == 2
    assert topo.tier(0, M - 1) == "inter_cluster"
    ref = _sim(name, "reference", data, M=M, topo=topo, monitor_period=6.0)
    bat = _sim(name, "batched", data, M=M, topo=topo, monitor_period=6.0)
    if name == "netmax":
        assert bat.policy_updates > 0
    _assert_parity(ref, bat)


def test_engine_parity_non_uniform_batch_sizes(data):
    """Shard-size skew makes per-worker batch sizes differ, so cohorts must
    stay batch-length-homogeneous without breaking causal order (the
    same-level WAR exemption is only sound within a single dispatch)."""
    parts = _skewed_parts(data, 8)
    kw = dict(parts=parts, batch_size=150)
    sizes = {min(150, len(p)) for p in parts}
    assert len(sizes) > 1  # the skew actually produces mixed batch lengths
    ref = _sim("netmax", "reference", data, **kw)
    bat = _sim("netmax", "batched", data, **kw)
    _assert_parity(ref, bat)


def test_cohort_invariants_non_uniform_batch_sizes(data):
    """The causal-order invariants must also hold on the batch-length
    splitting path (regression: a same-level split used to let a writer
    overtake an earlier-popped reader of its row)."""
    parts = _skewed_parts(data, 8)
    log = []
    _sim("netmax", "batched", data, events=450, parts=parts, batch_size=150,
         log=log)
    placed = {}
    for ci, cohort in enumerate(log):
        for ev_id, i, peer in cohort:
            placed[ev_id] = (ci, i, peer)
    for ev_a in sorted(placed):
        ca, ia, ma = placed[ev_a]
        for ev_b in range(ev_a + 1, min(ev_a + 60, len(placed) + 1)):
            cb, ib, mb = placed[ev_b]
            if cb < ca:
                assert ib != ia and mb != ia and ib != ma
            elif cb == ca:
                assert ib != ia and mb != ia


# --------------------------------------------------------------------------
# Parity: the serialized-PS-row variant (ps-async) and the stacked
# synchronous round executor (ps-sync / allreduce / prague)
# --------------------------------------------------------------------------


def test_engine_parity_ps_async(data):
    """ps-async's peer-replica mutation batches through the ps-serial
    variant: cohort grad steps vmapped, the PS running average folded as a
    pop-ordered chain inside the dispatch."""
    ref = _sim("ps-async", "reference", data)
    bat = _sim("ps-async", "batched", data)
    assert bat.cohorts > 0 and bat.cohorts < bat.events[-1]
    _assert_parity(ref, bat)


def test_engine_parity_ps_async_skewed_batches(data):
    parts = _skewed_parts(data, 8)
    kw = dict(parts=parts, batch_size=150)
    ref = _sim("ps-async", "reference", data, **kw)
    bat = _sim("ps-async", "batched", data, **kw)
    _assert_parity(ref, bat)


def test_engine_parity_ps_async_multi_cluster(data):
    M = 16
    topo = Topology.multi_cluster(M, workers_per_host=4, hosts_per_pod=1,
                                  pods_per_cluster=2)
    ref = _sim("ps-async", "reference", data, M=M, topo=topo)
    bat = _sim("ps-async", "batched", data, M=M, topo=topo)
    _assert_parity(ref, bat)


def test_ps_serial_cohort_invariants(data):
    """ps-serial scheduling contract: every event executed exactly once,
    per-worker order preserved, distinct actors per cohort, and a PS-node
    local step never shares a cohort with an earlier-popped push (its grad
    step must observe every prior push's effect on the PS row)."""
    log = []
    bat = _sim("ps-async", "batched", data, events=450, log=log)
    assert sum(len(c) for c in log) == 450
    assert bat.cohorts == len(log)
    assert max(len(c) for c in log) > 1  # pushes actually batch
    ps = 0  # default cfg.ps_node
    last_cohort_of_worker: dict[int, int] = {}
    seen = set()
    for ci, cohort in enumerate(log):
        actors = [i for (_, i, _) in cohort]
        assert len(set(actors)) == len(actors)
        for k, (ev_id, i, peer) in enumerate(cohort):
            assert ev_id not in seen
            seen.add(ev_id)
            assert last_cohort_of_worker.get(i, -1) < ci
            last_cohort_of_worker[i] = ci
            if i == ps and peer is None:
                # PS local step: no earlier-popped push may share the cohort
                assert all(p is None for (_, _, p) in cohort[:k])


@pytest.mark.parametrize("name", SYNC)
def test_engine_parity_sync(name, data):
    """Synchronous rounds execute as stacked one-segment-mean dispatches;
    host-side timing/group/batch draws are bit-identical to the reference
    round loop."""
    assert name in ("allreduce", "prague", "ps-sync")  # suite covers all
    ref = _sim(name, "reference", data)
    bat = _sim(name, "batched", data)
    assert bat.cohorts == ref.events[-1] // 8  # one logical cohort per round
    assert bat.dispatches < bat.cohorts  # rounds scan-fuse between records
    _assert_parity(ref, bat)


def test_engine_parity_sync_skewed_batches(data):
    """Per-worker batch sizes differ -> the masked-mean grad path."""
    parts = _skewed_parts(data, 8)
    kw = dict(parts=parts, batch_size=150)
    ref = _sim("prague", "reference", data, **kw)
    bat = _sim("prague", "batched", data, **kw)
    _assert_parity(ref, bat)


# --------------------------------------------------------------------------
# Chain fusion: scan-fused execution is an implementation detail — the
# logical cohort structure and results are identical with it on or off
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["netmax", "ps-async"])
def test_chain_fusion_preserves_cohort_structure(name, data):
    log_f, log_u = [], []
    fused = _sim(name, "batched", data, log=log_f)  # fuse_chains defaults on
    plain = _sim(name, "batched", data, log=log_u, fuse_chains=False)
    assert log_f == log_u
    assert fused.cohorts == plain.cohorts == len(log_f)
    assert plain.dispatches == plain.cohorts  # unfused: one dispatch/cohort
    assert fused.dispatches < fused.cohorts  # fusion actually packs
    assert fused.times == plain.times
    assert fused.events == plain.events
    assert fused.comm_time == plain.comm_time
    np.testing.assert_allclose(fused.losses, plain.losses, rtol=1e-5, atol=1e-6)


def test_chain_fusion_dispatch_reduction(data):
    """ISSUE 3 acceptance: chain fusion cuts device dispatches >= 2x vs the
    one-dispatch-per-cohort baseline."""
    bat = _sim("netmax", "batched", data, M=16, events=800, record_every=800,
               monitor_period=1e9)
    assert bat.dispatches * 2 <= bat.cohorts


def test_sync_fusion_preserves_results(data):
    fused = _sim("ps-sync", "batched", data)
    plain = _sim("ps-sync", "batched", data, fuse_chains=False)
    assert fused.cohorts == plain.cohorts
    assert plain.dispatches == plain.cohorts
    assert fused.dispatches < fused.cohorts
    assert fused.times == plain.times
    np.testing.assert_allclose(fused.losses, plain.losses, rtol=1e-5, atol=1e-6)


def test_engine_parity_with_mix_kernel(data):
    """The kernels/ops.mix_rows path computes (1-w)h + w p instead of
    h + w(p-h) — algebraically identical, so slightly looser tolerance."""
    ref = _sim("netmax", "reference", data)
    bat = _sim("netmax", "batched", data, use_mix_kernel=True)
    _assert_parity(ref, bat, loss_tol=2e-3)


def test_auto_engine_consults_supports_batched(data):
    """engine='auto' is a capability check at dispatch time, not a family
    list: every registered strategy routes batched, and a strategy whose
    capability check fails (exotic apply_comm override, no batched variant)
    routes to the reference loop."""
    for name in ("netmax", "ps-async", "ps-sync", "allreduce"):
        assert _sim(name, "auto", data, events=160).engine == "batched", name

    from repro.algos.netmax import GossipAlgorithm

    class ExoticComm(GossipAlgorithm):
        name = "exotic-comm"

        def apply_comm(self, state, cfg, replicas, i, m, x_half):
            replicas[i] = x_half  # side effects the engine can't replay
            return False

    algo = ExoticComm()
    assert not algo.supports_batched
    assert _sim(algo, "auto", data, events=160).engine == "reference"


def test_batched_engine_rejects_unsupported_algorithms(data):
    """Explicit engine='batched' still refuses strategies whose overridden
    per-event/round semantics have no batched form."""
    from repro.algos.collective import Allreduce
    from repro.algos.netmax import GossipAlgorithm

    class ExoticComm(GossipAlgorithm):
        name = "exotic-comm"

        def apply_comm(self, state, cfg, replicas, i, m, x_half):
            replicas[i] = x_half
            return False

    class ExoticReduce(Allreduce):
        name = "exotic-reduce"

        def reduce_groups(self, replicas, groups):
            pass  # non-default group semantics

    for algo in (ExoticComm(), ExoticReduce()):
        assert not algo.supports_batched
        with pytest.raises(ValueError, match="batched"):
            _sim(algo, "batched", data, events=100)


def test_unknown_batched_variant_fails_loudly(data):
    """A declared-but-unimplemented batched_variant must raise, not fall
    through to gossip semantics."""
    from repro.algos.netmax import GossipAlgorithm

    class PushSum(GossipAlgorithm):
        name = "push-sum"

        @property
        def batched_variant(self):
            return "push-sum"

        def apply_comm(self, state, cfg, replicas, i, m, x_half):
            replicas[i] = x_half
            return False

    algo = PushSum()
    assert algo.supports_batched  # the declared variant claims capability
    with pytest.raises(NotImplementedError, match="push-sum"):
        _sim(algo, "batched", data, events=100)


def test_unknown_engine_rejected(data):
    with pytest.raises(ValueError, match="engine"):
        _sim("netmax", "definitely-not-an-engine", data, events=100)


# --------------------------------------------------------------------------
# Scenario timelines (repro.scenarios): outages, degradation, and churn must
# hold EXACT host-side parity across an outage boundary for every registered
# algorithm — windows/blocks split at scenario boundaries, failed pulls and
# published policies are compared verbatim (ISSUE 5)
# --------------------------------------------------------------------------


def _scenario_setup():
    """Two clusters of 4 plus a timeline crossing every event type: a
    cluster outage, a link degradation window, and a leave/rejoin blip."""
    from repro.scenarios import (
        ClusterOutage,
        LinkDegrade,
        Timeline,
        WorkerLeave,
        WorkerRejoin,
    )

    topo = Topology(8, workers_per_host=2, hosts_per_pod=2, pods_per_cluster=1)
    tl = Timeline([
        ClusterOutage(1, 1.0, 3.0),
        LinkDegrade(0, 5, 0.5, 4.0, 8.0),
        WorkerLeave(3, 1.5),
        WorkerRejoin(3, 3.5),
    ])
    return topo, tl


@pytest.mark.parametrize("name", list_algorithms())
def test_engine_parity_scenarios(name, data):
    topo, tl = _scenario_setup()
    kw = dict(M=8, topo=topo, scenario=tl)
    ref = _sim(name, "reference", data, **kw)
    bat = _sim(name, "batched", data, **kw)
    _assert_parity(ref, bat)
    algo = get_algorithm(name)
    if not algo.synchronous:
        # The outage actually bit: some pull timed out on this timeline.
        assert ref.failed_pulls, name
    if algo.wants_monitor(SimConfig()):
        assert ref.policy_updates > 0


def test_engine_parity_directed_outage_home_monitor(data):
    """Asymmetric outage + home-pinned Monitor: reach filtering, dropped
    notifications, and partial policy publishes are all host-side
    decisions — both engines must make them identically."""
    from repro.scenarios import ClusterOutage, Timeline

    topo = Topology(8, workers_per_host=2, hosts_per_pod=2, pods_per_cluster=1)
    tl = Timeline([ClusterOutage(1, 0.4, 2.5, direction="out")])
    kw = dict(M=8, topo=topo, scenario=tl, monitor_period=0.3,
              monitor_home_cluster=0)
    ref = _sim("netmax", "reference", data, **kw)
    bat = _sim("netmax", "batched", data, **kw)
    assert ref.failed_pulls  # the one-direction outage actually bites
    assert ref.policy_updates > 0
    _assert_parity(ref, bat)


def test_scenario_outage_stretches_sync_rounds(data):
    """Round strategies don't re-route: a dead member's ring link prices at
    the timeout, so outage-window rounds dominate the virtual clock."""
    topo, _ = _scenario_setup()
    from repro.scenarios import ClusterOutage, Timeline
    from repro.data.partition import uniform_partition
    from repro.train.simulator import SimConfig, simulate

    x, y, ex, ey = data
    parts = uniform_partition(len(y), 8, seed=0)

    def run(scenario):
        # No jitter / no dynamic slow link: the outage is the only dynamic,
        # so the stretch is attributable (a slowed 100x link can exceed the
        # timeout and mask it otherwise).
        link = LinkTimeModel(topo, jitter=0.0, slowdown_range=(1.0, 1.0),
                             seed=5, scenario=scenario, dead_link_timeout=10.0)
        cfg = SimConfig(algorithm="allreduce", n_workers=8, total_events=160,
                        lr=0.05, seed=0, engine="batched")
        return simulate(cfg, link, x, y, parts, ex, ey, record_every=80)

    base = run(None)
    hit = run(Timeline([ClusterOutage(1, 1.0, 8.0)]))
    # Rounds starting inside [1, 8) price their cross ring links at the
    # 10s timeout instead of the 0.48s WAN base: the clock visibly stalls.
    assert hit.times[-1] > base.times[-1] + 10.0


def test_scenario_chain_fusion_still_exact(data):
    """Chain fusion must not leak across scenario boundaries: fused and
    unfused execution stay identical on a churn+outage timeline."""
    topo, tl = _scenario_setup()
    kw = dict(M=8, topo=topo, scenario=tl)
    fused = _sim("netmax", "batched", data, **kw)
    plain = _sim("netmax", "batched", data, fuse_chains=False, **kw)
    assert fused.times == plain.times
    assert fused.failed_pulls == plain.failed_pulls
    assert fused.comm_time == plain.comm_time
    assert fused.dispatches < plain.dispatches
    np.testing.assert_allclose(fused.losses, plain.losses, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Determinism: same seed ⇒ identical results, on both engines
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["reference", "batched"])
def test_same_seed_is_deterministic(engine, data):
    a = _sim("netmax", engine, data, events=250, seed=3)
    b = _sim("netmax", engine, data, events=250, seed=3)
    assert a.times == b.times
    assert a.losses == b.losses
    assert a.accs == b.accs
    assert a.events == b.events
    assert a.comm_time == b.comm_time
    assert a.policy_updates == b.policy_updates


def test_different_seeds_diverge(data):
    a = _sim("netmax", "batched", data, events=250, seed=0)
    b = _sim("netmax", "batched", data, events=250, seed=1)
    assert a.times != b.times


# --------------------------------------------------------------------------
# Cohort-scheduler invariants (the causal-independence contract)
# --------------------------------------------------------------------------


def test_cohort_scheduler_invariants(data):
    log = []
    bat = _sim("netmax", "batched", data, events=600, log=log)
    assert sum(len(c) for c in log) == 600  # every event executed once
    assert bat.cohorts == len(log)
    assert max(len(c) for c in log) > 1  # it actually batches

    last_cohort_of_worker: dict[int, int] = {}
    seen_ev = set()
    for ci, cohort in enumerate(log):
        actors = [i for (_, i, _) in cohort]
        # (1) a cohort never contains the same actor twice
        assert len(set(actors)) == len(actors)
        for ev_id, i, peer in cohort:
            assert ev_id not in seen_ev
            seen_ev.add(ev_id)
            # (2) per-worker event order is preserved across cohorts
            assert last_cohort_of_worker.get(i, -1) < ci
            last_cohort_of_worker[i] = ci
    # (3) full causal check against reference order: for any two events
    # a, b with a earlier in pop order but b scheduled no later than a's
    # cohort, b must not act as, pull from, or overwrite what a touches.
    placed = {}  # ev_id -> (cohort, actor, peer)
    for ci, cohort in enumerate(log):
        for ev_id, i, peer in cohort:
            placed[ev_id] = (ci, i, peer)
    for ev_a in sorted(placed):
        ca, ia, ma = placed[ev_a]
        for ev_b in range(ev_a + 1, min(ev_a + 50, len(placed) + 1)):
            cb, ib, mb = placed[ev_b]
            if cb < ca:  # b executed strictly before the earlier-popped a
                assert ib != ia  # per-worker order (covered above too)
                assert mb != ia  # b must not read a's pre-update row late
                assert ib != ma  # b must not overwrite what a still reads
            elif cb == ca:
                assert ib != ia
                assert mb != ia  # same cohort: a's write invisible to b


def test_cohorts_respect_record_boundaries(data):
    """No cohort spans a record_every boundary: the evaluation must observe
    the state after exactly k*record_every events."""
    log = []
    _sim("netmax", "batched", data, events=600, record_every=100, log=log)
    for cohort in log:
        evs = [e for (e, _, _) in cohort]
        assert (min(evs) - 1) // 100 == (max(evs) - 1) // 100


def test_batched_faster_dispatch_count(data):
    """The whole point: far fewer device dispatches than events."""
    bat = _sim("netmax", "batched", data, M=16, events=800, record_every=800,
               monitor_period=1e9)
    assert bat.cohorts <= 800 / 2  # at least 2x fewer dispatches than events


def test_engine_parity_storm_failover_chaos(data):
    """PR-9 robustness parity: a cascading storm kills the Monitor's home
    cluster, failover elects a standby, and chaos drops reports / loses
    publishes — every one of those decisions is host-side state made in
    the shared monitor_boundary, so both engines must agree exactly,
    election times and all."""
    from repro.scenarios import ChaosInjector, storm

    topo = Topology(12, workers_per_host=2, hosts_per_pod=2,
                    pods_per_cluster=1)  # 3 clusters of 4
    tl = storm(topo, seed=7, horizon=60.0, intensity=2.0,
               trigger_cluster=0, trigger_time=0.8, worker_blips=True)
    kw = dict(M=12, topo=topo, scenario=tl, events=500, monitor_period=0.4,
              monitor_home_cluster=0, monitor_failover=True)
    # One injector per run: its rng streams advance per call, so sharing
    # an instance across the two runs would desynchronize them.
    ref = _sim("netmax", "reference", data,
               chaos=ChaosInjector(seed=11, report_drop_rate=0.15,
                                   publish_delay_rate=0.15), **kw)
    bat = _sim("netmax", "batched", data,
               chaos=ChaosInjector(seed=11, report_drop_rate=0.15,
                                   publish_delay_rate=0.15), **kw)
    _assert_parity(ref, bat)
    assert ref.leader_log, "the storm never forced an election"
    assert ref.failed_pulls  # the storm actually bit
