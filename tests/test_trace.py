"""repro.trace suite: schema round trip, Chrome export, calibration,
replay exactness, scenario cross-checks, and the what-if API (DESIGN.md
§15).

The load-bearing pin is replay exactness: simulate -> export -> ingest ->
calibrate -> replay must reproduce the *identical* event stream for the
same seed (see replay.py for why), which is far inside the ISSUE's 5%
tolerance.  Engine parity of the trace stream itself is pinned in
tests/test_engines.py.
"""

import io
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.nettime import TIERS, LinkTimeModel, Topology
from repro.data.partition import uniform_partition
from repro.data.synthetic import train_eval_split
from repro.train.simulator import SimConfig, simulate
from repro.trace import (
    MoveWorker,
    ReplayLinkSource,
    SwitchAlgorithm,
    Trace,
    TraceRecord,
    UpgradeLink,
    WhatIf,
    calibrate,
    chrome_trace,
    from_sim_result,
    load_trace,
    read_csv,
    read_jsonl,
    replay_model,
    write_chrome_trace,
    write_jsonl,
)

FIXTURE = Path(__file__).parent / "fixtures" / "trace_hetero_M8.jsonl"

M = 8


@pytest.fixture(scope="module")
def data():
    return train_eval_split(1600, 400, 32, 10, seed=0)


def _topo():
    return Topology.multi_cluster(M, workers_per_host=2, hosts_per_pod=1,
                                  pods_per_cluster=2)  # 2 clusters of 4


def _run(data, algo="netmax", link=None, events=500, seed=0, trace=True):
    x, y, ex, ey = data
    if link is None:
        link = LinkTimeModel(_topo(), jitter=0.05, seed=5)
    cfg = SimConfig(algorithm=algo, n_workers=M, total_events=events,
                    lr=0.05, monitor_period=4.0, seed=seed, trace=trace)
    parts = uniform_partition(len(y), M, seed=0)
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=events // 4)
    return res, cfg, link


@pytest.fixture(scope="module")
def traced(data):
    """One traced netmax run shared by the read-only tests."""
    res, cfg, link = _run(data)
    return res, cfg, link, from_sim_result(res, cfg=cfg, link_model=link)


# --------------------------------------------------------------------------
# schema: record stream, serialization round trip, external ingest
# --------------------------------------------------------------------------


def test_trace_events_stream_shape(traced):
    res, cfg, _, trace = traced
    assert len(res.trace_events) == cfg.total_events
    for (t, dur, src, dst, kind, comm, comp, net) in res.trace_events:
        assert t >= 0 and dur > 0 and comm >= 0 and comp > 0
        assert 0 <= src < M
        assert kind in ("pull", "local", "timeout")
        if kind != "local":
            assert 0 <= dst < M  # pull/timeout always name a peer
            assert net is not None and net > 0  # raw link time rides along
        else:
            assert net is None
    # refreshes ride along from the policy log
    assert trace.counts()["refresh"] == len(res.policy_log) > 0


def test_jsonl_round_trip_bit_exact(traced, tmp_path):
    _, _, _, trace = traced
    p = tmp_path / "t.jsonl"
    write_jsonl(trace, p)
    back = read_jsonl(p)
    assert back.records == trace.records  # repr-level floats: bit-exact
    assert back.meta == trace.meta
    assert back.horizon == trace.horizon


def test_jsonl_rejects_unknown_schema(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"schema": "repro.trace/v999", "meta": {}}\n')
    with pytest.raises(ValueError, match="v999"):
        read_jsonl(p)


def test_record_validation():
    with pytest.raises(ValueError, match="kind"):
        TraceRecord(0.0, 1.0, 0, 1, "teleport").validate()
    with pytest.raises(ValueError, match="duration"):
        TraceRecord(0.0, -1.0, 0, 1, "pull").validate()
    with pytest.raises(ValueError, match="duration"):
        TraceRecord(0.0, float("nan"), 0, 1, "pull").validate()


def test_untraced_result_raises(data):
    res, cfg, link = _run(data, events=200, trace=False)
    assert res.trace_events == []
    with pytest.raises(ValueError, match="trace_events"):
        from_sim_result(res, cfg=cfg, link_model=link)
    with pytest.raises(ValueError, match="trace_events"):
        chrome_trace(res)


def test_csv_ingest_external_timeline(tmp_path):
    """The externally-measured shape: bare columns, kind defaulted."""
    p = tmp_path / "measured.csv"
    p.write_text(
        "t_start,duration,src,dst\n"
        "0.0,0.5,0,1\n"
        "0.2,0.012,1,-1\n"
        "1.0,0.48,1,0\n"
    )
    tr = read_csv(p)
    assert [r.kind for r in tr.records] == ["pull", "pull", "pull"]
    assert tr.horizon == pytest.approx(1.48)
    assert load_trace(p).records == tr.records  # dispatch by extension


def test_csv_missing_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("a,b\n1,2\n")
    with pytest.raises(ValueError, match="columns"):
        read_csv(p)


def test_headerless_jsonl_record_stream(tmp_path):
    """A bare record stream (no header line) ingests with empty meta."""
    p = tmp_path / "bare.jsonl"
    p.write_text('{"t": 0.0, "dur": 0.5, "src": 0, "dst": 1}\n')
    tr = read_jsonl(p)
    assert tr.meta == {} and len(tr.records) == 1
    assert tr.records[0].kind == "pull"


# --------------------------------------------------------------------------
# export: Chrome-trace / Perfetto JSON
# --------------------------------------------------------------------------


def test_chrome_trace_structure(traced, tmp_path):
    res, cfg, _, _ = traced
    doc = chrome_trace(res)
    evs = doc["traceEvents"]
    slices = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert len(slices) == cfg.total_events
    assert len(instants) == len(res.policy_log) > 0
    assert all(e["s"] == "g" for e in instants)
    assert {f"worker {w}" for w in range(M)} <= names
    # µs timestamps; per-worker tracks; comm/compute split in args
    ev0, tr0 = res.trace_events[0], slices[0]
    assert tr0["ts"] == pytest.approx(ev0[0] * 1e6)
    assert tr0["dur"] == pytest.approx(ev0[1] * 1e6)
    assert tr0["tid"] == ev0[2]
    assert tr0["args"]["compute"] == ev0[6]
    p = tmp_path / "trace.json"
    write_chrome_trace(res, p)
    assert json.loads(p.read_text())["traceEvents"]


def test_chrome_trace_from_ingested_trace():
    """An ingested Trace exports too, meta carried into otherData."""
    doc = chrome_trace(load_trace(FIXTURE))
    cats = {e.get("cat") for e in doc["traceEvents"] if "cat" in e}
    assert {"pull", "local", "timeout", "refresh"} <= cats
    assert doc["otherData"]["algorithm"] == "netmax"


def test_chrome_trace_sync_rounds_track(data):
    res, _, _ = _run(data, algo="allreduce", events=160)
    doc = chrome_trace(res)
    labels = {e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert "rounds" in labels
    cats = {e["cat"] for e in doc["traceEvents"] if e["ph"] == "X"}
    # Rounds land on the aggregate track; the per-link network times the
    # round queried land on worker tracks as pull slices.
    assert cats == {"round", "pull"}
    n_rounds = sum(
        1 for e in doc["traceEvents"]
        if e["ph"] == "X" and e["cat"] == "round"
    )
    n_pulls = sum(
        1 for e in doc["traceEvents"]
        if e["ph"] == "X" and e["cat"] == "pull"
    )
    # Ring allreduce queries every directed ring edge once per round (M=8).
    assert n_rounds == 160 // 8
    assert n_pulls == n_rounds * 8


# --------------------------------------------------------------------------
# calibrate: robust fit + invariants on the committed fixture
# --------------------------------------------------------------------------


def test_calibrate_fixture():
    trace = load_trace(FIXTURE)
    cal = calibrate(trace)
    # the fixture's generating model: compute 0.012, tiered bases, 5% jitter
    assert cal.compute_time == pytest.approx(0.012, rel=1e-6)
    vals = [cal.base_times[t] for t in TIERS]
    assert vals == sorted(vals)  # documented TIERS ordering invariant
    assert cal.base_times["inter_pod"] == pytest.approx(0.120, rel=0.15)
    assert cal.base_times["inter_cluster"] == pytest.approx(0.480, rel=0.15)
    assert 0.0 <= cal.jitter <= 0.2  # true sigma is 0.05; MAD is robust
    assert cal.residual < 0.10  # well inside the 5%-per-record regime
    assert cal.n_pulls == trace.counts()["pull"]
    assert "intra_host" in cal.censored_tiers  # 0.010 base < 0.012 compute
    assert (cal.link_scale > 0).all()
    assert "calibrated" in cal.summary()
    # the fitted model must not re-inject the synthetic roaming slow link
    assert cal.model.slowdown_range == (1.0, 1.0)


def test_calibrate_needs_topology(tmp_path):
    p = tmp_path / "bare.jsonl"
    p.write_text('{"t": 0.0, "dur": 0.5, "src": 0, "dst": 1}\n')
    with pytest.raises(ValueError, match="Topology"):
        calibrate(read_jsonl(p))


def test_calibrate_slow_link_robustness():
    """A 50x contaminated minority of pulls must not drag the tier fit:
    per-link medians see straight through it."""
    topo = Topology(4, workers_per_host=4)  # one host: all intra_host
    rng = np.random.default_rng(0)
    recs = []
    t = 0.0
    for k in range(400):
        i, m = int(rng.integers(4)), int(rng.integers(4))
        if i == m:
            continue
        dur = 0.040 * float(np.exp(rng.normal(0, 0.05)))
        if k % 10 == 0:
            dur *= 50.0  # 10% of pulls hit the slow link
        recs.append(TraceRecord(t, dur, i, m, "pull"))
        t += 0.01
    cal = calibrate(Trace(records=recs), topology=topo)
    assert cal.base_times["intra_host"] == pytest.approx(0.040, rel=0.1)


# --------------------------------------------------------------------------
# replay: the tentpole round trip — exact, not merely within 5%
# --------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["netmax", "adpsgd", "ps-async", "netmax-topk"])
def test_round_trip_replay_is_exact(algo, data, tmp_path):
    """simulate -> export -> ingest -> calibrate -> replay reproduces the
    per-record event stream bit-exactly for same-seed async strategies —
    including ps-async and netmax-topk, whose congestion/wire-ratio
    multipliers sit *above* the link seam: the trace records the raw
    pre-multiplier link time (``net``) per event, the seam serves it back,
    and event_timing re-applies the multiplier deterministically."""
    res, cfg, link = _run(data, algo=algo)
    p = tmp_path / "t.jsonl"
    write_jsonl(from_sim_result(res, cfg=cfg, link_model=link), p)
    trace = read_jsonl(p)
    cal = calibrate(trace)
    rep, _, _ = _run(data, algo=algo, link=replay_model(trace, cal))
    assert rep.trace_events == res.trace_events
    assert rep.times == res.times
    assert rep.comm_time == res.comm_time
    assert rep.losses == res.losses  # same mixes, same device math


def test_round_trip_sync_replay_is_exact(data, tmp_path):
    """Sync rounds tap every per-link network time they query into the
    trace (raw values, below the compute floor included), so a sync
    replay serves the recorded draws in query order and reproduces the
    rounds — and the re-emitted trace stream — bit-exactly, jitter and
    roaming slow links included."""
    res, cfg, link = _run(data, algo="allreduce", events=160)
    kinds = {e[4] for e in res.trace_events}
    assert kinds == {"round", "pull"}
    p = tmp_path / "t.jsonl"
    write_jsonl(from_sim_result(res, cfg=cfg, link_model=link), p)
    trace = read_jsonl(p)
    cal = calibrate(trace)
    # No "local" records in a sync trace: compute comes from the exporter
    # meta, not the raw per-link minimum (which dips below it).
    assert cal.compute_time == link.compute_time
    model = replay_model(trace, cal)
    rep, _, _ = _run(data, algo="allreduce", events=160, link=model)
    assert rep.trace_events == res.trace_events
    assert rep.times == res.times
    assert rep.comm_time == res.comm_time
    assert model.time_source.served > 0
    assert model.time_source.fallbacks == 0


def test_replay_falls_back_past_horizon(data):
    """A longer replay run exhausts the measured queues and hands the tail
    to the calibrated model: the run completes, and the source reports
    fallback queries."""
    res, cfg, link = _run(data, events=300)
    trace = from_sim_result(res, cfg=cfg, link_model=link)
    model = replay_model(trace, calibrate(trace))
    rep, _, _ = _run(data, events=600, link=model)
    assert len(rep.trace_events) == 600
    src = model.time_source
    assert src.fallbacks > 0
    assert src.remaining() == 0  # every measurement was consumed
    assert rep.times[-1] > trace.horizon


def test_replay_preserves_scenario_dead_links(data):
    """Dead links resolve BEFORE the time source: replaying under the
    original scenario regenerates the timeouts instead of consuming
    measurements for them."""
    from repro.scenarios import ClusterOutage, Timeline

    link = LinkTimeModel(_topo(), jitter=0.05, seed=5,
                         scenario=Timeline([ClusterOutage(1, 2.0, 4.0)]),
                         dead_link_timeout=2.0)
    res, cfg, link = _run(data, link=link)
    assert res.failed_pulls
    trace = from_sim_result(res, cfg=cfg, link_model=link)
    model = replay_model(
        trace, calibrate(trace),
        scenario=Timeline([ClusterOutage(1, 2.0, 4.0)]),
        dead_link_timeout=2.0,
    )
    rep, _, _ = _run(data, link=model)
    assert rep.failed_pulls == res.failed_pulls
    assert rep.trace_events == res.trace_events


def test_trace_timeouts_fall_in_scenario_dead_intervals(data):
    """Cross-check the exported stream against the scripted timeline:
    every timeout record starts inside a dead window of its link
    (CompiledTimeline.dead_intervals)."""
    from repro.scenarios import ClusterOutage, Timeline

    compiled = Timeline([ClusterOutage(1, 2.0, 4.0)]).compile(_topo())
    link = LinkTimeModel(_topo(), jitter=0.05, seed=5, scenario=compiled,
                         dead_link_timeout=2.0)
    res, cfg, _ = _run(data, link=link)
    timeouts = [r for r in from_sim_result(res, cfg=cfg).records
                if r.kind == "timeout"]
    assert timeouts
    for r in timeouts:
        spans = compiled.dead_intervals(r.src, r.dst)
        assert any(a <= r.t_start < b for a, b in spans), (r, spans)
    # and a live link has no dead window at all
    assert compiled.dead_intervals(0, 1) == ()


def test_time_source_and_link_scale_default_off_bit_identical():
    """The new LinkTimeModel fields must not perturb any draw when unset
    (or when the scale is all-ones)."""
    topo = _topo()
    a = LinkTimeModel(topo, seed=7)
    b = LinkTimeModel(topo, seed=7, link_scale=np.ones((M, M)))
    for k in range(12):
        now = 7.0 * k
        assert a.network_time(0, 5, now=now) == b.network_time(0, 5, now=now)
    with pytest.raises(ValueError, match="link_scale"):
        LinkTimeModel(topo, link_scale=np.ones((M, M + 1)))


def test_replay_source_serves_in_order():
    recs = [TraceRecord(0.0, 0.5, 0, 1, "pull"),
            TraceRecord(1.0, 0.7, 0, 1, "pull"),
            TraceRecord(2.0, 9.9, 1, 0, "timeout")]
    src = ReplayLinkSource(Trace(records=recs))
    assert src.network_time(0, 1, 0.0) == 0.5
    assert src.network_time(0, 1, 5.0) == 0.7  # in order, not by time
    assert src.network_time(0, 1, 9.0) is None  # exhausted -> fallback
    assert src.network_time(1, 0, 0.0) is None  # timeouts excluded
    assert src.expected(0, 1, 0.0) is not None
    inc = ReplayLinkSource(Trace(records=recs), include_timeouts=True)
    assert inc.network_time(1, 0, 0.0) == 9.9


# --------------------------------------------------------------------------
# whatif: mutation deltas over the replayed baseline
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def session(data):
    res, cfg, link = _run(data, algo="adpsgd")
    trace = from_sim_result(res, cfg=cfg, link_model=link)
    x, y, ex, ey = data
    parts = uniform_partition(len(y), M, seed=0)
    return WhatIf(trace, calibrate(trace), cfg, (x, y, parts, ex, ey),
                  record_every=125)


def test_whatif_baseline_is_exact_replay(session):
    """The unmutated replay lands on the measured wall clock exactly.

    The wall clock is the last event's *pop* time (the trace horizon is
    later: it counts in-flight completions past the final pop)."""
    last_pop = max(r.t_start for r in session.trace.records
                   if r.kind != "refresh")
    assert session.baseline.times[-1] == pytest.approx(last_pop, rel=1e-9)


def test_whatif_upgrade_wan_link_speeds_up(session):
    rep = session.query(UpgradeLink(0, 4, speedup=4.0))
    assert rep.mutated_wall_clock < rep.baseline_wall_clock
    assert rep.wall_clock_speedup > 1.0
    assert rep.wall_clock_delta > 0.0
    assert "upgrade link" in rep.summary()


def test_whatif_downgrade_slows_down(session):
    rep = session.query(UpgradeLink(0, 4, speedup=0.25))
    assert rep.mutated_wall_clock > rep.baseline_wall_clock


def test_whatif_move_worker_across_wan(session):
    """Consolidating a worker into the bigger cluster removes its WAN
    pulls: wall-clock improves; deltas are finite and reported."""
    rep = session.query(MoveWorker(7, cluster=0))
    assert rep.mutated_wall_clock < rep.baseline_wall_clock
    assert np.isfinite(rep.time_to_loss_delta)


def test_whatif_switch_algorithm_netmax_beats_adpsgd(session):
    """The paper's headline direction on the replayed heterogeneous
    trace: netmax reaches the loss bar sooner than adpsgd."""
    rep = session.query(SwitchAlgorithm("netmax"))
    assert rep.mutated_time_to_loss < rep.baseline_time_to_loss
    assert rep.time_to_loss_speedup > 1.0


def test_whatif_composed_mutations_and_errors(session):
    rep = session.query([UpgradeLink(0, 4, speedup=4.0),
                         SwitchAlgorithm("netmax")])
    assert "upgrade link" in rep.mutation and "switch" in rep.mutation
    with pytest.raises(TypeError, match="mutation"):
        session.query(object())
    with pytest.raises(ValueError, match="positive"):
        ReplayLinkSource(Trace()).scale_link(0, 1, -2.0)


def test_relocated_topology_tiers():
    from repro.trace.whatif import RelocatedTopology

    base = _topo()
    moved = RelocatedTopology(base, worker=7, cluster=0)
    assert moved.cluster_of(7) == 0
    assert moved.tier(7, 0) == "inter_pod"  # now same cluster, own pod
    assert moved.tier(7, 4) == "inter_cluster"  # old neighbors now WAN
    assert moved.tier(0, 1) == base.tier(0, 1)  # others untouched
    assert moved.n_clusters == base.n_clusters
    with pytest.raises(ValueError, match="worker"):
        RelocatedTopology(base, worker=99, cluster=0)


# --------------------------------------------------------------------------
# summarizer CLI
# --------------------------------------------------------------------------


def test_summarizer_on_fixture():
    from repro.trace.__main__ import summarize

    buf = io.StringIO()
    summarize(FIXTURE, top=3, out=buf)
    out = buf.getvalue()
    assert "per-tier pull latency" in out
    assert "inter_cluster" in out
    assert "slowest directed links" in out
    assert "timeouts:" in out


def test_summarizer_cli_main(capsys):
    from repro.trace.__main__ import main

    assert main([str(FIXTURE), "--top", "2"]) == 0
    assert "slowest directed links" in capsys.readouterr().out
