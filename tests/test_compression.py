"""Tests for gossip compression + error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st

from repro.core.compression import (
    ErrorFeedback,
    dequantize_int8,
    quantize_int8,
    randk_mask,
    topk_mask,
)


def test_topk_keeps_largest():
    x = jnp.array([0.1, -5.0, 0.3, 2.0, -0.01])
    y = topk_mask(x, 2)
    assert jnp.count_nonzero(y) == 2
    assert y[1] == -5.0 and y[3] == 2.0


def test_topk_k_geq_size_identity():
    x = jnp.arange(4.0)
    assert jnp.allclose(topk_mask(x, 10), x)


def test_randk_unbiased():
    x = jnp.ones(100)
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    acc = jnp.zeros(100)
    for k in keys:
        acc += randk_mask(x, 10, k)
    # E[mask*scale] = x
    assert jnp.abs(acc / 200 - 1.0).mean() < 0.35


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=512).astype(np.float32))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert err <= s * 0.51 + 1e-7  # half a quantization step


def test_quantize_stochastic_unbiased():
    x = jnp.full((2048,), 0.3)
    keys = jax.random.split(jax.random.PRNGKey(1), 64)
    acc = jnp.zeros_like(x)
    for k in keys:
        q, s = quantize_int8(x, key=k)
        acc += dequantize_int8(q, s)
    assert jnp.abs(acc / 64 - x).mean() < 0.01


def test_error_feedback_accumulates_residual():
    ef = ErrorFeedback(ratio=0.25, mode="topk")
    tree = {"a": jnp.array([1.0, 0.1, 0.2, 3.0])}
    state = ef.init_state(tree)
    sent, state = ef.compress(tree, state)
    # k = 1 of 4: only the largest goes out, the rest accumulates.
    assert jnp.count_nonzero(sent["a"]) == 1
    assert sent["a"][3] == 3.0
    assert state["a"][0] == 1.0  # dropped, remembered


def test_error_feedback_eventually_transmits_everything():
    """Property: sum(sent over rounds) -> original signal (EF is lossless in
    the limit for a constant input)."""
    ef = ErrorFeedback(ratio=0.25, mode="topk")
    x = {"a": jnp.array([1.0, -2.0, 0.5, 0.25])}
    state = ef.init_state(x)
    total = jnp.zeros(4)
    for _ in range(8):
        sent, state = ef.compress(x, state)
        total += sent["a"]
    # after n rounds total ~ n_rounds-ish * x cumulative; residual bounded
    assert jnp.abs(state["a"]).max() <= 2.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_compress_preserves_treedef_and_shapes(seed):
    rng = np.random.default_rng(seed)
    tree = {
        "w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(3,)).astype(np.float32)),
    }
    ef = ErrorFeedback(ratio=0.5)
    state = ef.init_state(tree)
    sent, new_state = ef.compress(tree, state)
    assert sent["w"].shape == (4, 3) and sent["b"].shape == (3,)
    assert new_state["w"].shape == (4, 3)
    # conservation: sent + residual == input + old state
    assert jnp.allclose(sent["w"] + new_state["w"], tree["w"], atol=1e-6)
