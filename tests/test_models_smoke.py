"""Per-arch smoke tests: REDUCED configs, one forward/train/decode step on CPU.

Asserts output shapes and no NaNs for every assigned architecture family.
Full configs are exercised only via the dry-run (ShapeDtypeStruct).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_archs
from repro.models import lm

ARCHS = sorted(all_archs().keys())


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32),
    }
    if cfg.n_vis_tokens:
        b["vis_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)).astype(np.float32)
        )
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq_len, cfg.d_model)).astype(np.float32)
        )
    return b


@pytest.fixture(scope="module")
def reduced_params():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = all_archs()[name].reduced()
            cache[name] = (cfg, lm.init_params(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_finite(arch, reduced_params):
    cfg, params = reduced_params(arch)
    batch = _batch(cfg)
    loss = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    # untrained model ~ uniform over vocab
    assert float(loss) == pytest.approx(np.log(cfg.vocab_size), rel=0.35)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch, reduced_params):
    cfg, params = reduced_params(arch)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: lm.loss_fn(p, batch, cfg)))(params)
    assert jnp.isfinite(loss)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(l)) for l in leaves), f"{arch}: NaN grads"
    # gradients actually flow to the embedding and deep blocks
    gnorm = sum(jnp.sum(l * l) for l in leaves)
    assert float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch, reduced_params):
    cfg, params = reduced_params(arch)
    B, S = 2, 16
    cache = lm.init_cache(cfg, B, S)
    token = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: lm.decode_step(p, c, t, 3, cfg)
    )(params, cache, token)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: NaN decode logits"
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-7b", "jamba-v0.1-52b"])
def test_decode_matches_forward(arch, reduced_params):
    """Teacher-forced decode step-by-step == train forward logits."""
    cfg, params = reduced_params(arch)
    B, S = 1, 8
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    from repro.models import transformer

    x, _ = transformer.forward(params, tokens, cfg)
    full_logits = transformer.logits_head(params, x, cfg).astype(jnp.float32)

    cache = lm.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg),
                   static_argnames=())
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(params, cache, tokens[:, t], t, cfg)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)  # (B,S,V)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=5e-2, atol=5e-2
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """Full configs build abstract param trees (no allocation) with the
    exact assigned dimensions."""
    cfg = all_archs()[arch]
    tree = lm.abstract_params(cfg)
    n = int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))
    assert n > 0
    emb = tree["embed"]["table"] if "embed" in tree else None
    assert emb.shape == (cfg.vocab_size, cfg.d_model)


def test_param_counts_match_billing():
    """Sanity: headline param counts are in the advertised ballpark."""
    cases = {
        "tinyllama-1.1b": (1.0e9, 1.3e9),
        "qwen1.5-0.5b": (0.4e9, 0.75e9),
        "starcoder2-3b": (2.5e9, 3.5e9),
        "rwkv6-7b": (6.0e9, 9.0e9),
        "stablelm-12b": (10e9, 14e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "jamba-v0.1-52b": (48e9, 58e9),
        "llama4-maverick-400b-a17b": (370e9, 430e9),
    }
    for name, (lo, hi) in cases.items():
        cfg = all_archs()[name]
        n = lm.param_count(cfg)
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = all_archs()["phi3.5-moe-42b-a6.6b"]
    act = lm.active_param_count(cfg)
    assert 5.5e9 <= act <= 8.0e9, f"active {act/1e9:.2f}B"


def test_head_padding_is_inert():
    """TP head padding (§Perf) must not change model outputs: padded q/wo
    slots are zero and group-interleaved so original heads keep their
    kv-group assignment."""
    from dataclasses import replace

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 128, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 128, (2, 16)), jnp.int32),
    }
    for name, pads in [
        ("starcoder2-3b", dict(pad_heads=2)),
        ("whisper-small", dict(pad_heads=2, pad_kv_heads=2)),
        ("tinyllama-1.1b", dict(pad_heads=2)),
    ]:
        cfg = replace(all_archs()[name].reduced(), vocab_size=128)
        cfg_pad = replace(cfg, **pads)
        b = dict(batch)
        if cfg.family == "audio":
            b["frames"] = jnp.asarray(
                rng.normal(size=(2, cfg.enc_seq_len, cfg.d_model)).astype(np.float32)
            )
        p0 = lm.init_params(cfg, jax.random.PRNGKey(0))
        p1 = lm.init_params(cfg_pad, jax.random.PRNGKey(0))
        l0 = float(lm.loss_fn(p0, b, cfg))
        l1 = float(lm.loss_fn(p1, b, cfg_pad))
        assert abs(l0 - l1) < 5e-4, f"{name}: {l0} vs {l1}"
