"""Substrate tests: data pipeline, optimizers, checkpoint, elastic, serve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_archs
from repro.data.synthetic import TokenStream, classification_dataset
from repro.data.partition import non_iid_partition, size_skewed_partition, uniform_partition
from repro.models import lm
from repro.optim import adamw, sgd
from repro.optim.optimizers import clip_by_global_norm, global_norm
from repro.train import checkpoint as ckpt
from repro.train import elastic


# ------------------------------------------------------------------ data


def test_token_stream_deterministic_and_disjoint():
    ts = TokenStream(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    b1 = ts.batch(worker=0, step=3)
    b2 = ts.batch(worker=0, step=3)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # seekable
    b3 = ts.batch(worker=1, step=3)
    assert not np.array_equal(b1["tokens"], b3["tokens"])  # per-worker shards
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_token_stream_learnable_structure():
    ts = TokenStream(vocab_size=50, seq_len=32, batch_size=8, seed=0)
    b = ts.batch(0, 0)
    # successors are concentrated: given token t, next token is one of ~8
    # preferred choices 90% of the time
    hits = 0
    total = 0
    for row_t, row_n in zip(b["tokens"], b["labels"]):
        for t, n in zip(row_t, row_n):
            total += 1
            hits += n in ts._succ[t]
    assert hits / total > 0.7


def test_partitions():
    x, y = classification_dataset(1000, 8, 10, seed=0)
    parts = uniform_partition(len(y), 4, seed=0)
    assert sum(len(p) for p in parts) == 1000
    assert len(set(np.concatenate(parts).tolist())) == 1000  # disjoint cover
    parts = size_skewed_partition(len(y), 4, [1, 1, 2, 2], seed=0)
    assert abs(len(parts[2]) - 2 * len(parts[0])) <= 2
    parts = non_iid_partition(y, 4, lost_labels=[[0, 1], [2, 3], [4, 5], [6, 7]])
    for i, lost in enumerate([[0, 1], [2, 3], [4, 5], [6, 7]]):
        labels = set(y[parts[i]].tolist())
        assert not labels & set(lost)


# ------------------------------------------------------------------ optim


def test_sgd_momentum_matches_reference():
    opt = sgd(momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    st = opt.init(p)
    upd, st = opt.update(g, st, p, lr=0.1)
    assert jnp.allclose(upd["w"], -0.1 * jnp.array([0.5, -0.5]))
    upd, st = opt.update(g, st, p, lr=0.1)
    # m = 0.9*0.5+0.5 = 0.95
    assert jnp.allclose(upd["w"][0], -0.1 * 0.95)


def test_sgd_weight_decay():
    opt = sgd(momentum=0.0, weight_decay=0.1)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.0])}
    upd, _ = opt.update(g, opt.init(p), p, lr=1.0)
    assert jnp.allclose(upd["w"], -0.1)


def test_adamw_converges_quadratic():
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        upd, st = opt.update(g, st, p, lr=0.05)
        p = opt.apply(p, upd)
    assert jnp.abs(p["w"]).max() < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert n == pytest.approx(5.0)
    assert global_norm(clipped) == pytest.approx(1.0, rel=1e-5)


def test_stacked_worker_momenta_independent():
    """NetMax replicas keep per-worker momentum (stacked leading dim)."""
    opt = sgd(momentum=0.9)
    p = {"w": jnp.ones((3, 4))}
    g = {"w": jnp.stack([jnp.ones(4), jnp.zeros(4), -jnp.ones(4)])}
    st = opt.init(p)
    upd, st = opt.update(g, st, p, lr=0.1)
    assert jnp.allclose(st["m"]["w"][1], 0.0)
    assert jnp.allclose(st["m"]["w"][0], 1.0)


# ------------------------------------------------------------------ ckpt


@pytest.mark.slow
def test_checkpoint_roundtrip_bitexact(tmp_path):
    cfg = all_archs()["tinyllama-1.1b"].reduced()
    opt = sgd(momentum=0.9)
    from repro.train.trainer import init_stacked

    params, opt_state = init_stacked(cfg, opt, M=3, key=jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 17, params, opt_state, monitor_state={"rho": 1.5},
              data_cursor={"step": 17})
    p2, o2, man, mon = ckpt.restore(tmp_path, params, opt_state)
    assert man["step"] == 17 and man["n_workers"] == 3
    assert mon["rho"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(opt_state), jax.tree_util.tree_leaves(o2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_overwrite(tmp_path):
    cfg = all_archs()["qwen1.5-0.5b"].reduced()
    opt = sgd(momentum=0.0)
    from repro.train.trainer import init_stacked

    params, opt_state = init_stacked(cfg, opt, M=2, key=jax.random.PRNGKey(1))
    ckpt.save(tmp_path, 1, params, opt_state)
    ckpt.save(tmp_path, 2, params, opt_state)
    assert ckpt.latest_step(tmp_path) == 2


def test_checkpoint_resume_equals_uninterrupted(tmp_path):
    """Train 4 steps; vs train 2, checkpoint, restore, train 2 — identical."""
    cfg = all_archs()["qwen1.5-0.5b"].reduced()
    opt = sgd(momentum=0.9)
    from repro.core import consensus
    from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step

    M = 2
    step_fn = jax.jit(
        make_train_step(cfg, opt, M, TrainStepConfig(gossip_mode="gather")),
        static_argnames=(),
    )
    ts = TokenStream(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2, seed=0)

    def batch_at(step):
        bs = [ts.batch(w, step) for w in range(M)]
        return {
            k: jnp.stack([jnp.asarray(b[k]) for b in bs]) for k in bs[0]
        }

    def gossip_at(step):
        rng = np.random.default_rng(step)
        P = np.full((M, M), 0.5)
        np.fill_diagonal(P, 0.0)
        d = np.ones((M, M)) - np.eye(M)
        nb, wts = consensus.sample_round(rng, P / P.sum(1, keepdims=True), 0.05, 1.0, d)
        return {
            "neighbors": jnp.asarray(nb),
            "weights": jnp.asarray(wts),
            "lr": jnp.float32(0.05),
        }

    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    for s in range(4):
        params, opt_state, _ = step_fn(params, opt_state, batch_at(s), gossip_at(s))
    final_a = jax.tree_util.tree_leaves(params)

    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    for s in range(2):
        params, opt_state, _ = step_fn(params, opt_state, batch_at(s), gossip_at(s))
    ckpt.save(tmp_path, 2, params, opt_state, data_cursor={"step": 2})
    params, opt_state, man, _ = ckpt.restore(tmp_path, params, opt_state)
    for s in range(man["data_cursor"]["step"], 4):
        params, opt_state, _ = step_fn(params, opt_state, batch_at(s), gossip_at(s))
    final_b = jax.tree_util.tree_leaves(params)
    for a, b in zip(final_a, final_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ elastic


def test_elastic_remove_and_add_workers():
    cfg = all_archs()["qwen1.5-0.5b"].reduced()
    opt = sgd(momentum=0.9)
    from repro.train.trainer import init_stacked

    params, opt_state = init_stacked(cfg, opt, M=4, key=jax.random.PRNGKey(0))
    # distinguish replicas
    params = jax.tree_util.tree_map(
        lambda l: l + jnp.arange(4, dtype=l.dtype).reshape((4,) + (1,) * (l.ndim - 1)),
        params,
    )
    p2, o2 = elastic.remove_workers(params, opt_state, np.array([0, 2, 3]))
    leaf = jax.tree_util.tree_leaves(p2)[0]
    assert leaf.shape[0] == 3
    p3, o3 = elastic.add_workers(p2, o2, n_new=2, seed_from=1)
    leaf3 = jax.tree_util.tree_leaves(p3)[0]
    assert leaf3.shape[0] == 5
    # joiners cloned from survivor index 1 (= original worker 2)
    np.testing.assert_array_equal(np.asarray(leaf3[3]), np.asarray(leaf3[1]))
    # momenta zeroed for joiners
    m3 = jax.tree_util.tree_leaves(o3)[0]
    assert np.all(np.asarray(m3[3]) == 0)


def test_elastic_policy_rescale_converges():
    T = np.full((5, 5), 0.02)
    np.fill_diagonal(T, 0)
    res = elastic.rescale_policy(0.1, T)
    assert res.lambda2 < 1.0
    assert res.P.shape == (5, 5)


# ------------------------------------------------------------------ serve


def test_serve_engine_batched_decode():
    cfg = all_archs()["tinyllama-1.1b"].reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(cfg, params, batch_capacity=2, max_seq=32)
    reqs = [
        Request(rid=0, prompt=np.array([1, 2, 3], np.int32), max_new=4),
        Request(rid=1, prompt=np.array([4, 5], np.int32), max_new=4),
    ]
    done = eng.run(reqs)
    assert len(done) == 2
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_stacked_loader_prefetch_and_determinism():
    from repro.data.loader import StackedLoader

    ts = TokenStream(vocab_size=64, seq_len=8, batch_size=2, seed=3)
    ld = StackedLoader(ts, n_workers=3, start_step=5)
    step, batch = next(ld)
    assert step == 5
    assert batch["tokens"].shape == (3, 2, 8)
    step2, batch2 = next(ld)
    assert step2 == 6
    ld.close()
    # determinism: same (worker, step) -> same data
    ld2 = StackedLoader(ts, n_workers=3, start_step=5)
    _, again = next(ld2)
    ld2.close()
    assert np.array_equal(np.asarray(batch["tokens"]), np.asarray(again["tokens"]))


def test_frontend_stubs_shapes():
    import jax

    from repro.configs.base import all_archs
    from repro.models.frontends import frontend_for

    vlm = all_archs()["internvl2-1b"].reduced()
    fn = frontend_for(vlm)
    x = fn(jax.random.PRNGKey(0), vlm, batch=2)
    assert x.shape == (2, vlm.n_vis_tokens, vlm.d_model)
    aud = all_archs()["whisper-small"].reduced()
    fn = frontend_for(aud)
    x = fn(jax.random.PRNGKey(0), aud, batch=2)
    assert x.shape == (2, aud.enc_seq_len, aud.d_model)
    assert frontend_for(all_archs()["tinyllama-1.1b"]) is None
