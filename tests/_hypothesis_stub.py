"""Import guard for ``hypothesis`` (optional dev dependency).

When hypothesis is installed, re-exports the real ``given``/``settings``/
``st``.  When it isn't, provides stand-ins that mark the decorated
property-based tests as skipped — so the module still collects and its
plain pytest tests still run everywhere (the tier-1 contract).

Usage in a test module:

    from _hypothesis_stub import given, settings, st
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Opaque stand-in: any attribute access / call yields another one."""

        def __getattr__(self, name):
            return _Strategy()

        def __call__(self, *args, **kwargs):
            return _Strategy()

    st = _Strategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
