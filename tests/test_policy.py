"""Tests for Algorithm 3 (policy generation) + Theorem-3 properties.

The key paper invariants, checked as properties over random heterogeneous
networks (hypothesis):

  * any Algorithm-3 policy is row-stochastic, respects the Eq.-11 floors,
    and equalizes expected iteration time (Eq. 10 => p_i = 1/M);
  * Y_P is doubly stochastic with lambda2 < 1 (Theorem 3);
  * on heterogeneous networks the optimized policy's modeled convergence
    time beats the uniform (AD-PSGD) policy's;
  * dead links (t -> inf) get zero probability.
"""

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import consensus, policy, theory


def hetero_times(M, seed, slow_factor=10.0):
    rng = np.random.default_rng(seed)
    T = rng.uniform(0.01, 0.05, size=(M, M))
    T = (T + T.T) / 2
    # one slow link
    i, m = rng.choice(M, size=2, replace=False)
    T[i, m] = T[m, i] = T[i, m] * slow_factor
    np.fill_diagonal(T, 0.0)
    return T


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 6, 8]))
def test_policy_feasibility_properties(seed, M):
    T = hetero_times(M, seed)
    alpha = 0.1
    res = policy.generate_policy_matrix(alpha, K=6, R=6, T=T)
    P = res.P
    d = np.ones((M, M)) - np.eye(M)
    # Row stochastic.
    assert np.allclose(P.sum(axis=1), 1.0, atol=1e-7)
    # Eq. 11 floors on edges.
    floor = 2 * alpha * res.rho
    off = P[~np.eye(M, dtype=bool)]
    assert np.all(off >= floor - 1e-8)
    # Eq. 10: equalized expected iteration times -> p_i = 1/M.
    tbar = consensus.mean_iteration_times(P, T, d)
    assert np.allclose(tbar, tbar[0], rtol=1e-5)
    p = consensus.worker_activation_probs(P, T, d)
    assert np.allclose(p, 1.0 / M, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 6, 8]))
def test_theorem3_doubly_stochastic_lambda2(seed, M):
    T = hetero_times(M, seed)
    alpha = 0.1
    res = policy.generate_policy_matrix(alpha, K=6, R=6, T=T)
    d = np.ones((M, M)) - np.eye(M)
    Y = consensus.build_Y(res.P, alpha, res.rho, d)
    assert theory.is_doubly_stochastic(Y)
    assert theory.lambda1(Y) == pytest.approx(1.0, abs=1e-6)
    assert theory.lambda2(Y) < 1.0 - 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_netmax_beats_uniform_on_hetero(seed):
    """The paper's headline: adaptive probabilities reduce modeled
    convergence time vs uniform selection on heterogeneous networks."""
    M = 8
    T = hetero_times(M, seed, slow_factor=25.0)
    alpha = 0.1
    d = np.ones((M, M)) - np.eye(M)
    res = policy.generate_policy_matrix(alpha, K=8, R=8, T=T)
    Pu = policy.uniform_policy(d)
    Yu = consensus.build_Y(Pu, alpha, res.rho, d, T=T)
    Tu = theory.convergence_time(
        theory.global_step_time(Pu, T, d), theory.lambda2(Yu), 1e-2
    )
    assert res.T_convergence < Tu


def test_slow_link_gets_floor_probability():
    M = 8
    T = np.full((M, M), 0.04)
    for i in range(M):
        for m in range(M):
            if (i < 4) == (m < 4):
                T[i, m] = 0.01
    np.fill_diagonal(T, 0.0)
    T[0, 4] = T[4, 0] = 0.4
    res = policy.generate_policy_matrix(0.1, K=8, R=8, T=T)
    floor = 2 * 0.1 * res.rho
    assert res.P[0, 4] == pytest.approx(floor, rel=0.05)
    # Fast intra-host links carry more probability than the slow link.
    assert res.P[0, 1:4].mean() > res.P[0, 4]


def test_dead_link_zero_probability():
    M = 6
    T = np.full((M, M), 0.02)
    np.fill_diagonal(T, 0.0)
    T[1, 3] = T[3, 1] = np.inf  # dead link
    res = policy.generate_policy_matrix(0.1, K=6, R=6, T=T)
    assert res.P[1, 3] == 0.0
    assert res.P[3, 1] == 0.0
    # Still convergent: the remaining graph is connected.
    assert res.lambda2 < 1.0


def test_homogeneous_network_near_uniform():
    """Paper §V-D: on homogeneous networks NetMax behaves like AD-PSGD
    (uniform off-diagonal probabilities)."""
    M = 6
    T = np.full((M, M), 0.02)
    np.fill_diagonal(T, 0.0)
    res = policy.generate_policy_matrix(0.1, K=6, R=8, T=T)
    off = res.P[~np.eye(M, dtype=bool)]
    assert off.std() / off.mean() < 0.2  # near-uniform


def test_uniform_policy_rows():
    d = np.ones((5, 5)) - np.eye(5)
    P = policy.uniform_policy(d)
    assert np.allclose(P.sum(axis=1), 1.0)
    assert np.all(np.diag(P) == 0)


# --------------------------------------------------------------------------
# Vectorization parity: the broadcasted Algorithm-3 hot path must match the
# historical per-(i, m) Python-loop implementation EXACTLY (bit-for-bit),
# including the simplex input (variable order changes pivot paths).
# --------------------------------------------------------------------------


def _t_bar_interval_loop(T, d, alpha, rho):
    """Pre-vectorization reference implementation (verbatim)."""
    M = T.shape[0]
    L = 0.0
    U = np.inf
    for i in range(M):
        Li = alpha * rho / M * sum(
            T[i, m] * (d[i, m] + d[m, i]) for m in range(M) if m != i
        )
        edge_times = [T[i, m] for m in range(M) if m != i and d[i, m]]
        if not edge_times:
            return (np.inf, -np.inf)
        Ui = max(edge_times) / M
        L = max(L, Li)
        U = min(U, Ui)
    return L, U


def _solve_policy_lp_loop(T, d, alpha, rho, t_bar):
    """Pre-vectorization reference implementation (verbatim)."""
    from repro.core.policy import _FLOOR_MARGIN
    from repro.solver.lp import solve_lp

    M = T.shape[0]
    idx = {}
    for i in range(M):
        idx[(i, i)] = len(idx)
        for m in range(M):
            if m != i and d[i, m]:
                idx[(i, m)] = len(idx)
    n = len(idx)
    c = np.zeros(n)
    lb = np.zeros(n)
    ub = np.ones(n)
    for (i, m), j in idx.items():
        if i == m:
            c[j] = 1.0
        else:
            lb[j] = alpha * rho * (d[i, m] + d[m, i]) + _FLOOR_MARGIN
    A = np.zeros((2 * M, n))
    b = np.zeros(2 * M)
    for i in range(M):
        for m in range(M):
            if m != i and d[i, m]:
                A[i, idx[(i, m)]] = T[i, m]
        b[i] = M * t_bar
        A[M + i, idx[(i, i)]] = 1.0
        for m in range(M):
            if m != i and d[i, m]:
                A[M + i, idx[(i, m)]] = 1.0
        b[M + i] = 1.0
    res = solve_lp(c, A, b, lb=lb, ub=ub)
    if not res.ok:
        return None
    P = np.zeros((M, M))
    for (i, m), j in idx.items():
        P[i, m] = max(res.x[j], 0.0)
    return P


def _build_Y_loop(P, alpha, rho, d, T=None):
    """Pre-vectorization reference implementation (verbatim)."""
    M = P.shape[0]
    p = consensus.worker_activation_probs(P, T, d)
    g = consensus.gamma_matrix(P, d)
    ar = alpha * rho
    off = np.zeros((M, M))
    pg = np.where(P > 0, P * g, 0.0)
    pg2 = np.where(P > 0, P * g * g, 0.0)
    for i in range(M):
        for m in range(M):
            if m == i:
                continue
            lin = ar * (p[i] * pg[i, m] + p[m] * pg[m, i])
            quad = ar * ar * (p[i] * pg2[i, m] + p[m] * pg2[m, i])
            off[i, m] = lin - quad
    Y = off.copy()
    for i in range(M):
        lin = 2.0 * ar * (p[i] * pg[i, :]).sum()
        quad = ar * ar * ((p[i] * pg2[i, :]) + (p * pg2[:, i])).sum()
        Y[i, i] = 1.0 - lin + quad
    return Y


def _random_instance(seed, M):
    rng = np.random.default_rng(seed)
    T = hetero_times(M, seed)
    d = np.ones((M, M)) - np.eye(M)
    if seed % 3 == 0:  # masked topologies too (symmetric, no isolated rows)
        d = (rng.uniform(size=(M, M)) < 0.7).astype(float)
        d = np.maximum(d, d.T)
        np.fill_diagonal(d, 0.0)
        for i in range(M):
            if d[i].sum() == 0:
                j = (i + 1) % M
                d[i, j] = d[j, i] = 1.0
    return T, d


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 6, 8, 12]))
def test_vectorized_policy_math_exactly_matches_loop_reference(seed, M):
    T, d = _random_instance(seed, M)
    alpha = 0.1
    rng = np.random.default_rng(seed + 1)
    rho = float(rng.uniform(0.05, 1.0))
    L, U = policy._t_bar_interval(T, d, alpha, rho)
    Lr, Ur = _t_bar_interval_loop(T, d, alpha, rho)
    assert L == Lr and U == Ur  # exact, not approx
    if not np.isfinite(U) or U <= L:
        return
    for frac in (0.25, 0.75):
        t_bar = L + (U - L) * frac
        Pn = policy._solve_policy_lp(T, d, alpha, rho, t_bar)
        Pr = _solve_policy_lp_loop(T, d, alpha, rho, t_bar)
        assert (Pn is None) == (Pr is None)
        if Pn is None:
            continue
        np.testing.assert_array_equal(Pn, Pr)  # bit-identical
        np.testing.assert_array_equal(
            consensus.build_Y(Pn, alpha, rho, d),
            _build_Y_loop(Pn, alpha, rho, d),
        )


def test_vectorized_policy_math_spot_check():
    """Non-hypothesis spot checks so the exact-parity pin runs in stub mode
    (the tier-1 contract) too."""
    for seed, M in ((0, 4), (3, 6), (7, 8), (12, 12)):
        T, d = _random_instance(seed, M)
        rho = 0.3
        assert policy._t_bar_interval(T, d, 0.1, rho) == _t_bar_interval_loop(
            T, d, 0.1, rho
        )
        L, U = policy._t_bar_interval(T, d, 0.1, rho)
        if np.isfinite(U) and U > L:
            t_bar = L + (U - L) * 0.5
            Pn = policy._solve_policy_lp(T, d, 0.1, rho, t_bar)
            Pr = _solve_policy_lp_loop(T, d, 0.1, rho, t_bar)
            assert (Pn is None) == (Pr is None)
            if Pn is not None:
                np.testing.assert_array_equal(Pn, Pr)
                np.testing.assert_array_equal(
                    consensus.build_Y(Pn, 0.1, rho, d),
                    _build_Y_loop(Pn, 0.1, rho, d),
                )


def _rho_grid_upper_loop(alpha, Tm, d):
    """Pre-vectorization reference for the outer-grid clamp (verbatim)."""
    M = Tm.shape[0]
    U_rho = 0.5 / alpha
    deg2 = np.array([(d[i] + d[:, i]).sum() for i in range(M)])
    with np.errstate(invalid="ignore"):
        A = max(
            (Tm[i] * (d[i] + d[:, i])).sum() / M for i in range(M)
        )
    U_t = min(
        (np.max(Tm[i] * d[i]) / M) for i in range(M) if d[i].sum() > 0
    ) if d.sum() > 0 else 0.0
    if A > 0:
        U_rho = min(U_rho, U_t / (A * alpha))
    if deg2.max() > 0:
        U_rho = min(U_rho, 1.0 / (alpha * deg2.max()) * (1.0 - 1e-6))
    return U_rho


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 6, 8, 12, 16]))
def test_vectorized_rho_grid_upper_exactly_matches_loop(seed, M):
    T, d = _random_instance(seed, M)
    Tm = np.where(np.isfinite(T), T, 0.0)
    np.fill_diagonal(d, 0.0)
    assert policy._rho_grid_upper(0.1, Tm, d) == _rho_grid_upper_loop(0.1, Tm, d)


def test_vectorized_rho_grid_upper_spot_check():
    """Stub-mode (tier-1) spot check of the same exact-equality pin,
    including the all-dead-links degenerate branch."""
    for seed, M in ((0, 4), (3, 6), (7, 8), (12, 12), (5, 16)):
        T, d = _random_instance(seed, M)
        Tm = np.where(np.isfinite(T), T, 0.0)
        np.fill_diagonal(d, 0.0)
        assert policy._rho_grid_upper(0.1, Tm, d) == _rho_grid_upper_loop(
            0.1, Tm, d
        )
    z = np.zeros((4, 4))
    assert policy._rho_grid_upper(0.1, z, z) == _rho_grid_upper_loop(0.1, z, z)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([3, 5, 9, 16]))
def test_vectorized_uniform_policy_exactly_matches_loop(seed, M):
    _, d = _random_instance(seed, M)
    P = policy.uniform_policy(d)
    ref = np.zeros((M, M))
    for i in range(M):
        nbrs = [m for m in range(M) if m != i and d[i, m]]
        for m in nbrs:
            ref[i, m] = 1.0 / len(nbrs)
    np.testing.assert_array_equal(P, ref)


def test_approximation_ratio_finite():
    M = 8
    T = hetero_times(M, 0)
    res = policy.generate_policy_matrix(0.1, K=6, R=6, T=T)
    d = np.ones((M, M)) - np.eye(M)
    Y = consensus.build_Y(res.P, 0.1, res.rho, d)
    a = float(Y[Y > 1e-12].min())
    from repro.core.policy import _t_bar_interval

    L, U = _t_bar_interval(T, d, 0.1, res.rho)
    ratio = theory.approximation_ratio(U, L, M, a)
    assert np.isfinite(ratio)
    assert ratio >= 1.0
