"""Tests for Algorithm 3 (policy generation) + Theorem-3 properties.

The key paper invariants, checked as properties over random heterogeneous
networks (hypothesis):

  * any Algorithm-3 policy is row-stochastic, respects the Eq.-11 floors,
    and equalizes expected iteration time (Eq. 10 => p_i = 1/M);
  * Y_P is doubly stochastic with lambda2 < 1 (Theorem 3);
  * on heterogeneous networks the optimized policy's modeled convergence
    time beats the uniform (AD-PSGD) policy's;
  * dead links (t -> inf) get zero probability.
"""

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import consensus, policy, theory


def hetero_times(M, seed, slow_factor=10.0):
    rng = np.random.default_rng(seed)
    T = rng.uniform(0.01, 0.05, size=(M, M))
    T = (T + T.T) / 2
    # one slow link
    i, m = rng.choice(M, size=2, replace=False)
    T[i, m] = T[m, i] = T[i, m] * slow_factor
    np.fill_diagonal(T, 0.0)
    return T


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 6, 8]))
def test_policy_feasibility_properties(seed, M):
    T = hetero_times(M, seed)
    alpha = 0.1
    res = policy.generate_policy_matrix(alpha, K=6, R=6, T=T)
    P = res.P
    d = np.ones((M, M)) - np.eye(M)
    # Row stochastic.
    assert np.allclose(P.sum(axis=1), 1.0, atol=1e-7)
    # Eq. 11 floors on edges.
    floor = 2 * alpha * res.rho
    off = P[~np.eye(M, dtype=bool)]
    assert np.all(off >= floor - 1e-8)
    # Eq. 10: equalized expected iteration times -> p_i = 1/M.
    tbar = consensus.mean_iteration_times(P, T, d)
    assert np.allclose(tbar, tbar[0], rtol=1e-5)
    p = consensus.worker_activation_probs(P, T, d)
    assert np.allclose(p, 1.0 / M, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 6, 8]))
def test_theorem3_doubly_stochastic_lambda2(seed, M):
    T = hetero_times(M, seed)
    alpha = 0.1
    res = policy.generate_policy_matrix(alpha, K=6, R=6, T=T)
    d = np.ones((M, M)) - np.eye(M)
    Y = consensus.build_Y(res.P, alpha, res.rho, d)
    assert theory.is_doubly_stochastic(Y)
    assert theory.lambda1(Y) == pytest.approx(1.0, abs=1e-6)
    assert theory.lambda2(Y) < 1.0 - 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_netmax_beats_uniform_on_hetero(seed):
    """The paper's headline: adaptive probabilities reduce modeled
    convergence time vs uniform selection on heterogeneous networks."""
    M = 8
    T = hetero_times(M, seed, slow_factor=25.0)
    alpha = 0.1
    d = np.ones((M, M)) - np.eye(M)
    res = policy.generate_policy_matrix(alpha, K=8, R=8, T=T)
    Pu = policy.uniform_policy(d)
    Yu = consensus.build_Y(Pu, alpha, res.rho, d, T=T)
    Tu = theory.convergence_time(
        theory.global_step_time(Pu, T, d), theory.lambda2(Yu), 1e-2
    )
    assert res.T_convergence < Tu


def test_slow_link_gets_floor_probability():
    M = 8
    T = np.full((M, M), 0.04)
    for i in range(M):
        for m in range(M):
            if (i < 4) == (m < 4):
                T[i, m] = 0.01
    np.fill_diagonal(T, 0.0)
    T[0, 4] = T[4, 0] = 0.4
    res = policy.generate_policy_matrix(0.1, K=8, R=8, T=T)
    floor = 2 * 0.1 * res.rho
    assert res.P[0, 4] == pytest.approx(floor, rel=0.05)
    # Fast intra-host links carry more probability than the slow link.
    assert res.P[0, 1:4].mean() > res.P[0, 4]


def test_dead_link_zero_probability():
    M = 6
    T = np.full((M, M), 0.02)
    np.fill_diagonal(T, 0.0)
    T[1, 3] = T[3, 1] = np.inf  # dead link
    res = policy.generate_policy_matrix(0.1, K=6, R=6, T=T)
    assert res.P[1, 3] == 0.0
    assert res.P[3, 1] == 0.0
    # Still convergent: the remaining graph is connected.
    assert res.lambda2 < 1.0


def test_homogeneous_network_near_uniform():
    """Paper §V-D: on homogeneous networks NetMax behaves like AD-PSGD
    (uniform off-diagonal probabilities)."""
    M = 6
    T = np.full((M, M), 0.02)
    np.fill_diagonal(T, 0.0)
    res = policy.generate_policy_matrix(0.1, K=6, R=8, T=T)
    off = res.P[~np.eye(M, dtype=bool)]
    assert off.std() / off.mean() < 0.2  # near-uniform


def test_uniform_policy_rows():
    d = np.ones((5, 5)) - np.eye(5)
    P = policy.uniform_policy(d)
    assert np.allclose(P.sum(axis=1), 1.0)
    assert np.all(np.diag(P) == 0)


def test_approximation_ratio_finite():
    M = 8
    T = hetero_times(M, 0)
    res = policy.generate_policy_matrix(0.1, K=6, R=6, T=T)
    d = np.ones((M, M)) - np.eye(M)
    Y = consensus.build_Y(res.P, 0.1, res.rho, d)
    a = float(Y[Y > 1e-12].min())
    from repro.core.policy import _t_bar_interval

    L, U = _t_bar_interval(T, d, 0.1, res.rho)
    ratio = theory.approximation_ratio(U, L, M, a)
    assert np.isfinite(ratio)
    assert ratio >= 1.0
