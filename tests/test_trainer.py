"""Trainer integration: NetMax-DP on a tiny LM actually converges, baselines
behave, compression and the fused-mix path agree with the reference."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core import consensus
from repro.data.synthetic import TokenStream
from repro.optim import sgd
from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step


@pytest.fixture(scope="module")
def tiny_cfg():
    return replace(get_arch("tinyllama-1.1b").reduced(), vocab_size=256,
                   n_layers=2, d_model=64)


def _run_training(cfg, step_cfg=None, M=4, rounds=30, lr=0.05, seed=0, algo=None):
    opt = sgd(momentum=0.9)
    step = jax.jit(make_train_step(cfg, opt, M, algo, step_cfg=step_cfg))
    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=seed)
    rng = np.random.default_rng(seed)
    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / (M - 1), 0.0)
    rho = 0.5 / (2 * lr * (M - 1))
    losses = []
    for r in range(rounds):
        batch = {
            k: jnp.stack([jnp.asarray(stream.batch(w, r)[k]) for w in range(M)])
            for k in ("tokens", "labels")
        }
        nb, wts = consensus.sample_round(rng, P, lr, rho, d)
        gi = {"neighbors": jnp.asarray(nb), "weights": jnp.asarray(wts),
              "lr": jnp.float32(lr)}
        params, opt_state, m = step(params, opt_state, batch, gi)
        losses.append(float(m["loss"]))
    return params, losses


@pytest.mark.slow
def test_netmax_lm_training_converges(tiny_cfg):
    params, losses = _run_training(
        tiny_cfg, TrainStepConfig(gossip_mode="gather"), rounds=60, lr=0.1
    )
    assert np.mean(losses[-5:]) < losses[0] * 0.97
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_replicas_stay_close(tiny_cfg):
    """Consensus: max replica deviation stays bounded during training."""
    params, _ = _run_training(tiny_cfg, TrainStepConfig(gossip_mode="gather"), rounds=40)
    dev = max(
        float(jnp.abs(l.astype(jnp.float32) - l.astype(jnp.float32).mean(0, keepdims=True)).max())
        for l in jax.tree_util.tree_leaves(params)
    )
    assert dev < 1.0


def test_allreduce_baseline_keeps_replicas_identical(tiny_cfg):
    params, losses = _run_training(tiny_cfg, algo="allreduce", rounds=10)
    for l in jax.tree_util.tree_leaves(params):
        lf = np.asarray(l, np.float32)
        np.testing.assert_allclose(lf, np.broadcast_to(lf[:1], lf.shape), atol=1e-5)
    assert losses[-1] < losses[0]


def test_prague_groups_average_within_group(tiny_cfg):
    from repro.algos import get_algorithm

    params, losses = _run_training(
        tiny_cfg, algo=get_algorithm("prague", trainer_groups=2), rounds=8
    )
    assert np.isfinite(losses).all()


def test_legacy_flag_shim_still_warns_and_maps(tiny_cfg):
    """The pre-registry TrainStepConfig booleans stay usable: they warn and
    resolve to the equivalent registered strategies (the only test keeping
    the deprecated spelling alive on purpose)."""
    from repro.train.trainer import resolve_algorithm

    with pytest.deprecated_call():
        assert resolve_algorithm(None, TrainStepConfig(allreduce=True)).name == "allreduce"
    with pytest.deprecated_call():
        algo = resolve_algorithm(None, TrainStepConfig(prague_groups=2))
    assert algo.name == "prague" and algo.trainer_groups == 2
    # and make_train_step accepts the legacy spelling end to end
    with pytest.deprecated_call():
        make_train_step(tiny_cfg, sgd(momentum=0.9), 4, TrainStepConfig(allreduce=True))


def test_masked_psum_equals_gather(tiny_cfg):
    p1, l1 = _run_training(tiny_cfg, TrainStepConfig(gossip_mode="gather"), rounds=6)
    p2, l2 = _run_training(tiny_cfg, TrainStepConfig(gossip_mode="masked_psum"), rounds=6)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_gossip_mix_kernel_path_matches(tiny_cfg):
    """Fused Pallas mix (interpret on CPU via default=False -> ref path) must
    equal the tree-map mix."""
    p1, l1 = _run_training(
        tiny_cfg, TrainStepConfig(gossip_mode="gather", use_gossip_mix_kernel=False), rounds=5
    )
    p2, l2 = _run_training(
        tiny_cfg, TrainStepConfig(gossip_mode="gather", use_gossip_mix_kernel=True), rounds=5
    )
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-3
        )


def test_microbatching_matches_full_batch(tiny_cfg):
    cfg1 = replace(tiny_cfg, microbatches=1)
    cfg2 = replace(tiny_cfg, microbatches=2)
    p1, l1 = _run_training(cfg1, TrainStepConfig(gossip_mode="none"), rounds=4)
    p2, l2 = _run_training(cfg2, TrainStepConfig(gossip_mode="none"), rounds=4)
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)


def test_grad_clip_applies(tiny_cfg):
    _, losses = _run_training(
        tiny_cfg, TrainStepConfig(gossip_mode="gather", grad_clip=0.5), rounds=5
    )
    assert np.isfinite(losses).all()


def test_compression_error_feedback_training():
    """Sparsified gossip (top-k + EF) still converges on the consensus task."""
    from repro.core.compression import ErrorFeedback

    M, D = 6, 32
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32) * 3)
    ef = ErrorFeedback(ratio=0.25)
    states = [ef.init_state({"p": x[i]}) for i in range(M)]
    xs = [{"p": x[i]} for i in range(M)]
    for step in range(300):
        i = step % M
        m = (i + 1 + (step // M) % (M - 1)) % M
        delta = jax.tree_util.tree_map(lambda a, b: b - a, xs[i], xs[m])
        sent, states[i] = ef.compress(delta, states[i])
        xs[i] = jax.tree_util.tree_map(lambda a, s: a + 0.5 * s, xs[i], sent)
    stack = jnp.stack([t["p"] for t in xs])
    dev = float(jnp.abs(stack - stack.mean(0, keepdims=True)).max())
    dev0 = float(jnp.abs(x - x.mean(0, keepdims=True)).max())
    assert dev < dev0 * 0.2, (dev, dev0)
