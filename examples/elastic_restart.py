"""Fault tolerance demo: checkpoint/restart + elastic worker membership.

1. Train 4 workers for 20 rounds, checkpointing.
2. "Crash"; restore from the checkpoint bit-exactly.
3. Worker 3 is lost -> shrink to 3 workers (policy + state rescaled).
4. Two new workers join -> grow to 5 (replicas seeded from a survivor).

    PYTHONPATH=src python examples/elastic_restart.py
"""

import sys
import tempfile
from dataclasses import replace
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import consensus
from repro.core.nettime import homogeneous_times
from repro.data.synthetic import TokenStream
from repro.optim import sgd
from repro.train import checkpoint as ckpt
from repro.train import elastic
from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step


def make_step(cfg, opt, M):
    return jax.jit(make_train_step(cfg, opt, M, TrainStepConfig(gossip_mode="gather")))


def run_rounds(step_fn, params, opt_state, stream, M, rounds, start, lr=0.02, seed=0):
    rng = np.random.default_rng(seed)
    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / max(M - 1, 1), 0.0)
    rho = 0.5 / (2 * lr * max(M - 1, 1))
    loss = None
    for r in range(start, start + rounds):
        batch = {
            k: jnp.stack([jnp.asarray(stream.batch(w, r)[k]) for w in range(M)])
            for k in ("tokens", "labels")
        }
        nb, wts = consensus.sample_round(rng, P, lr, rho, d)
        gossip_in = {"neighbors": jnp.asarray(nb), "weights": jnp.asarray(wts),
                     "lr": jnp.float32(lr)}
        params, opt_state, m = step_fn(params, opt_state, batch, gossip_in)
        loss = float(m["loss"])
    return params, opt_state, loss


def main():
    cfg = replace(get_arch("qwen1.5-0.5b").reduced(), vocab_size=512)
    opt = sgd(momentum=0.9)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4, seed=0)
    ckdir = Path(tempfile.mkdtemp()) / "ck"

    M = 4
    step4 = make_step(cfg, opt, M)
    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    params, opt_state, loss = run_rounds(step4, params, opt_state, stream, M, 20, 0)
    ckpt.save(ckdir, 20, params, opt_state, data_cursor={"round": 20})
    print(f"[1] trained 4 workers, 20 rounds, loss={loss:.4f}; checkpointed")

    # crash + restore
    p2, o2 = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    p2, o2, man, _ = ckpt.restore(ckdir, p2, o2)
    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    print(f"[2] restored at round {man['data_cursor']['round']}; bit-exact={same}")

    # worker 3 dies -> shrink
    keep = np.array([0, 1, 2])
    p3, o3 = elastic.remove_workers(p2, o2, keep)
    T = homogeneous_times(3, 0.02)
    pol = elastic.rescale_policy(0.02, T)
    print(f"[3] shrunk to 3 workers; new policy lambda2={pol.lambda2:.4f} < 1")
    step3 = make_step(cfg, opt, 3)
    p3, o3, loss3 = run_rounds(step3, p3, o3, stream, 3, 10, 20, seed=1)
    print(f"    trained 10 more rounds at M=3, loss={loss3:.4f}")

    # two joiners -> grow (seeded from survivor 0, momentum zeroed)
    p5, o5 = elastic.add_workers(p3, o3, n_new=2, seed_from=0)
    T = homogeneous_times(5, 0.02)
    pol = elastic.rescale_policy(0.02, T)
    print(f"[4] grew to 5 workers; new policy lambda2={pol.lambda2:.4f} < 1")
    step5 = make_step(cfg, opt, 5)
    p5, o5, loss5 = run_rounds(step5, p5, o5, stream, 5, 10, 30, seed=2)
    print(f"    trained 10 more rounds at M=5, loss={loss5:.4f}")
    dev = max(
        float(jnp.abs(l - l.mean(axis=0, keepdims=True)).max())
        for l in jax.tree_util.tree_leaves(p5)
    )
    print(f"    replica max-deviation={dev:.4f} (gossip re-synchronizing joiners)")


if __name__ == "__main__":
    main()
