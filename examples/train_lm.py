"""End-to-end driver: decentralized LM training with NetMax-DP.

Trains a reduced tinyllama-family model (~100M-class scaled down for CPU;
pass --scale full-100m on real hardware) for a few hundred rounds with:
  * M worker replicas (stacked leading dim — same code path the 512-chip
    dry-run lowers),
  * the Network Monitor refreshing (P, rho) from measured round times,
  * checkpoint/restart every N rounds (kill it and rerun: it resumes).

    PYTHONPATH=src python examples/train_lm.py --rounds 60 --workers 4
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--scale", default="cpu", choices=["cpu", "100m"])
    ap.add_argument("--ckpt", default="artifacts/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--gossip", default="gather", choices=["gather", "masked_psum", "none"])
    ap.add_argument("--lr", type=float, default=0.02)
    args = ap.parse_args()

    from dataclasses import replace

    from repro.configs.base import get_arch
    from repro.core import consensus
    from repro.core.monitor import IterationTimeEMA, NetworkMonitor
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.synthetic import TokenStream
    from repro.optim import sgd
    from repro.train import checkpoint as ckpt
    from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step

    M = args.workers
    base = get_arch("tinyllama-1.1b")
    if args.scale == "cpu":
        cfg = replace(
            base.reduced(), n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=512, vocab_size=2048, head_dim=32,
        )
        seq, bsz = 128, 8
    else:  # ~100M: tinyllama dims cut to 12 layers / 768 wide
        cfg = replace(base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                      d_ff=2048, vocab_size=32000, dtype="float32", remat=False)
        seq, bsz = 512, 8

    opt = sgd(momentum=0.9, weight_decay=1e-4)
    step_fn = jax.jit(make_train_step(cfg, opt, M, TrainStepConfig(gossip_mode=args.gossip)))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=seq, batch_size=bsz, seed=0)

    topo = Topology(n_workers=M, workers_per_host=max(1, M // 2), hosts_per_pod=1)
    link = LinkTimeModel(topo, jitter=0.05, seed=3)
    monitor = NetworkMonitor(M, alpha=args.lr, K=6, R=6)
    emas = [IterationTimeEMA(M, beta=0.5) for _ in range(M)]
    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / max(M - 1, 1), 0.0)
    rho = 0.5 / (2 * args.lr * max(M - 1, 1))
    rng = np.random.default_rng(0)

    start = 0
    params = opt_state = None
    if ckpt.latest_step(args.ckpt) is not None:
        params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
        params, opt_state, man, mon_state = ckpt.restore(args.ckpt, params, opt_state)
        start = man["data_cursor"]["round"]
        if mon_state:
            rho = mon_state.get("rho", rho)
            P = np.asarray(mon_state["P"]) if "P" in mon_state else P
        print(f"[resume] restored round {start} from {args.ckpt}")
    else:
        params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))

    n_params = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params)) // M
    print(f"NetMax-DP: {M} workers x {n_params/1e6:.1f}M params, "
          f"gossip={args.gossip}, seq={seq}, batch/worker={bsz}")

    t_virtual = 0.0
    for r in range(start, args.rounds):
        batch = {
            k: jnp.stack([jnp.asarray(stream.batch(w, r)[k]) for w in range(M)])
            for k in ("tokens", "labels")
        }
        nb, wts = consensus.sample_round(rng, P, args.lr, rho, d)
        gossip_in = {
            "neighbors": jnp.asarray(nb),
            "weights": jnp.asarray(wts),
            "lr": jnp.float32(args.lr),
        }
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch, gossip_in)
        dt = time.time() - t0
        # virtual per-worker iteration times (compute overlapped with pull)
        for i in range(M):
            ti = link.iteration_time(i, int(nb[i]), now=t_virtual)
            emas[i].update(int(nb[i]), ti)
        t_virtual += max(link.iteration_time(i, int(nb[i]), now=t_virtual) for i in range(M))

        if (r + 1) % 10 == 0:
            monitor.collect({i: emas[i].snapshot() for i in range(M)})
            pol = monitor.step()
            if np.isfinite(pol.T_convergence):
                P, rho = pol.P, pol.rho
                bad = P.sum(axis=1) <= 0
                P[bad] = np.where(d[bad] > 0, 1.0 / max(M - 1, 1), 0.0)
            print(f"  [monitor] round {r+1}: lambda2={pol.lambda2:.4f} rho={rho:.3f}")

        if (r + 1) % 5 == 0 or r == start:
            print(f"round {r+1:4d}  loss={float(metrics['loss']):.4f}  "
                  f"per-worker={np.round(np.asarray(metrics['loss_per_worker']), 3)}  "
                  f"step={dt:.2f}s")

        if (r + 1) % args.ckpt_every == 0:
            ckpt.save(
                args.ckpt, r + 1, params, opt_state,
                monitor_state={"rho": float(rho), "P": P.tolist()},
                data_cursor={"round": r + 1},
            )
            print(f"  [checkpoint] saved round {r+1}")

    print("\nConsensus check (replica max-deviation per leaf, should be small):")
    dev = max(
        float(jnp.abs(l - l.mean(axis=0, keepdims=True)).max())
        for l in jax.tree_util.tree_leaves(params)
    )
    print(f"  max |x_i - mean| = {dev:.5f}")


if __name__ == "__main__":
    main()
