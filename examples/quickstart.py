"""Quickstart: NetMax in 60 seconds.

Eight workers collaboratively train a classifier over a heterogeneous
network (one slow link, changing over time).  Watch the Network Monitor
reshape the communication policy and beat uniform gossip (AD-PSGD).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.algos import list_algorithms
from repro.core import policy as policy_mod
from repro.core.nettime import LinkTimeModel, Topology
from repro.data.partition import uniform_partition
from repro.data.synthetic import train_eval_split
from repro.train.simulator import SimConfig, simulate


def main():
    M = 8
    print(f"== NetMax quickstart: {M} workers, 2 hosts, one dynamic slow link ==\n")

    # 1) The Network Monitor's core computation (Algorithm 3) in isolation:
    T = np.full((M, M), 0.04)
    for i in range(M):
        for m in range(M):
            if (i < 4) == (m < 4):
                T[i, m] = 0.01
    np.fill_diagonal(T, 0.0)
    T[0, 4] = T[4, 0] = 0.4  # the slow link
    res = policy_mod.generate_policy_matrix(alpha=0.1, K=8, R=8, T=T)
    print("Algorithm 3 on a two-host topology with one slow link:")
    print(f"  rho = {res.rho:.3f}   lambda2 = {res.lambda2:.4f}   "
          f"modeled T_conv = {res.T_convergence:.3f}s")
    print(f"  P[0 -> slow neighbor 4]  = {res.P[0, 4]:.4f}  (floor, Eq. 11)")
    print(f"  P[0 -> fast neighbors]   = {res.P[0, 1:4].mean():.4f}")

    # 2) End-to-end: real training under the async event simulator, once per
    #    registered communication strategy (repro.algos) — a new @register'd
    #    Algorithm automatically shows up here.
    topo = Topology(n_workers=M, workers_per_host=4, hosts_per_pod=1)
    x, y, ex, ey = train_eval_split(4000, 1000, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    algos = list_algorithms()
    print(f"\nTraining the same model under all {len(algos)} registered "
          "protocols (virtual time):")
    print("  (engine='auto': every registered strategy runs on the batched "
          "engine —\n   gossip cohorts, serialized-PS ps-async, stacked "
          "synchronous rounds;\n   DESIGN.md §11-§12)")
    results = {}
    for algo in algos:
        link = LinkTimeModel(topo, jitter=0.02, seed=5, slow_interval=120.0)
        cfg = SimConfig(algorithm=algo, n_workers=M, total_events=4000,
                        lr=0.01, monitor_period=10.0, seed=0)
        r = simulate(cfg, link, x, y, parts, ex, ey, record_every=200)
        results[algo] = r
        eng = f"{r.engine[:3]}/{r.cohorts}c" if r.cohorts else r.engine[:3]
        print(f"  {algo:12s} final_loss={r.losses[-1]:.4f} "
              f"acc={r.accs[-1]:.3f}  virtual_time={r.times[-1]:7.1f}s "
              f"policy_updates={r.policy_updates} [{eng}]")

    target = max(r.losses[-1] for r in results.values()) * 1.3
    t_nm = results["netmax"].time_to_loss(target)
    print(f"\nTime to loss<{target:.3f}:")
    for algo, r in results.items():
        t = r.time_to_loss(target)
        sp = f"{t / t_nm:.2f}x" if algo != "netmax" else "1.00x (ref)"
        print(f"  {algo:12s} {t:7.1f}s   NetMax speedup: {sp}")


if __name__ == "__main__":
    main()
