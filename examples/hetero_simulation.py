"""Reproduce the paper's Fig. 2 scenario: dynamic heterogeneous links.

A link that is fast at T1 becomes slow at T2 (the SAPS-PSGD failure mode,
paper §I).  NetMax's Monitor re-detects and re-routes; a static policy
(frozen after the first refresh) does not.

    PYTHONPATH=src python examples/hetero_simulation.py

With ``--trace-out DIR`` the per-strategy runs also export their event
timelines as repro.trace JSONL files (one per strategy) — inspect them
with ``python -m repro.trace DIR/trace_<algo>.jsonl``, open them in
chrome://tracing / Perfetto via ``repro.trace.chrome_trace``, or feed
them to ``repro.trace.calibrate`` / ``replay_model`` for trace-driven
what-if studies.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.monitor import NetworkMonitor
from repro.core.nettime import homogeneous_times


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "--trace-out", metavar="DIR", default=None,
        help="export per-strategy repro.trace JSONL timelines to DIR",
    )
    args = ap.parse_args(argv)

    M = 6
    alpha = 0.1
    mon = NetworkMonitor(M, alpha=alpha, K=8, R=8)

    # T1: link (2,3) is fast, link (0,1) slow.
    T1 = homogeneous_times(M, 0.02)
    T1[0, 1] = T1[1, 0] = 0.5
    mon.collect({i: T1[i] for i in range(M)})
    p1 = mon.step()
    print("T1: slow link (0,1)")
    print(f"  P[0,1] = {p1.P[0,1]:.4f}  (vs fast mean {p1.P[0,2:].mean():.4f})")
    print(f"  lambda2={p1.lambda2:.4f}  T_conv={p1.T_convergence:.3f}s")

    # T2: the network CHANGES — (0,1) recovers, (2,3) degrades 25x.
    T2 = homogeneous_times(M, 0.02)
    T2[2, 3] = T2[3, 2] = 0.5
    mon.collect({i: T2[i] for i in range(M)})
    p2 = mon.step()
    print("\nT2: slow link moved to (2,3) — Monitor re-detects:")
    print(f"  P[0,1] = {p2.P[0,1]:.4f}  (recovered link re-used)")
    print(f"  P[2,3] = {p2.P[2,3]:.4f}  (newly slow link de-preferred)")
    print(f"  lambda2={p2.lambda2:.4f}  T_conv={p2.T_convergence:.3f}s")

    # A static policy (SAPS-style, frozen from T1) evaluated on T2:
    from repro.core import consensus, theory

    d = np.ones((M, M)) - np.eye(M)
    t_static = theory.convergence_time(
        theory.global_step_time(p1.P, T2, d),
        theory.lambda2(consensus.build_Y(p1.P, alpha, p1.rho, d, T=T2)),
        1e-2,
    )
    import numpy as _np

    print("\nModeled convergence time on the T2 network:")
    if _np.isfinite(t_static):
        print(f"  frozen-T1 policy: {t_static:.3f}s")
        print(f"  re-optimized:     {p2.T_convergence:.3f}s "
              f"({t_static / p2.T_convergence:.2f}x faster by adapting)")
    else:
        print("  frozen-T1 policy: NOT CONVERGENT under the T2 times "
              "(lambda >= 1: the stale policy no longer equalizes worker "
              "progress - the SAPS-PSGD failure mode)")
        print(f"  re-optimized:     {p2.T_convergence:.3f}s")

    # Worker failure: worker 5 stops reporting.
    print("\nWorker 5 dies (3 missed reports) — policy reroutes:")
    for _ in range(3):
        mon.collect({i: T2[i] for i in range(M) if i != 5})
    p3 = mon.step()
    print(f"  live workers: {mon.live_workers.tolist()}")
    print(f"  column P[:,5] = {np.round(p3.P[:, 5], 4).tolist()} (all zero)")
    print(f"  survivors still converge: lambda2={p3.lambda2:.4f} < 1")

    # Every registered strategy on the same dynamic network (repro.algos):
    # new @register'd algorithms are picked up automatically.
    from repro.algos import list_algorithms
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.train.simulator import SimConfig, simulate

    topo = Topology(n_workers=M, workers_per_host=3, hosts_per_pod=1)
    x, y, ex, ey = train_eval_split(2000, 500, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    trace_dir = None
    if args.trace_out:
        trace_dir = Path(args.trace_out)
        trace_dir.mkdir(parents=True, exist_ok=True)
    print(f"\nAll {len(list_algorithms())} registered strategies on the "
          "dynamic network (short runs):")
    for algo in list_algorithms():
        link = LinkTimeModel(topo, jitter=0.02, seed=7, slow_interval=60.0)
        cfg = SimConfig(algorithm=algo, n_workers=M, total_events=1200,
                        lr=0.02, monitor_period=10.0, seed=0,
                        trace=trace_dir is not None)
        r = simulate(cfg, link, x, y, parts, ex, ey, record_every=300)
        print(f"  {algo:12s} loss={r.losses[-1]:.4f} t={r.times[-1]:7.1f}s "
              f"comm={r.comm_time:7.1f}s policy_updates={r.policy_updates}")
        if trace_dir is not None:
            from repro.trace import from_sim_result, write_jsonl

            out = trace_dir / f"trace_{algo}.jsonl"
            write_jsonl(from_sim_result(r, cfg=cfg, link_model=link), out)
            print(f"               trace -> {out}")

    # Wide-area scale-up (paper §V): 32 workers across 2 WAN-separated
    # clusters — the batched cohort engine makes this size interactive,
    # and NetMax's Monitor learns to keep traffic off the inter_cluster
    # tier that AD-PSGD keeps hammering uniformly.
    import time

    M2 = 32
    wan = Topology.multi_cluster(M2, workers_per_host=4, hosts_per_pod=2,
                                 pods_per_cluster=2)
    print(f"\nWAN scale-up: {M2} workers, {wan.n_clusters} clusters "
          f"(inter-cluster links {LinkTimeModel(wan).base_times['inter_cluster'] * 1e3:.0f}ms):")
    parts2 = uniform_partition(len(y), M2, seed=0)
    wall = {}
    for algo in ("netmax", "adpsgd"):
        link = LinkTimeModel(wan, jitter=0.02, seed=7, slow_interval=60.0)
        # Alg.-3 policy generation is O(K*R*M^2)-ish numpy and already costs
        # ~30s per refresh at M=32 (ROADMAP open item) — shrink the search
        # so the Monitor stays a demo, not the wall-clock bottleneck.
        cfg = SimConfig(algorithm=algo, n_workers=M2, total_events=3000,
                        lr=0.02, monitor_period=15.0, seed=0,
                        policy_K=4, policy_R=4)
        t0 = time.time()
        r = simulate(cfg, link, x, y, parts2, ex, ey, record_every=500)
        wall[algo] = time.time() - t0
        print(f"  {algo:12s} loss={r.losses[-1]:.4f} t={r.times[-1]:7.1f}s "
              f"comm={r.comm_time:7.1f}s engine={r.engine} "
              f"cohorts={r.cohorts} (host wall {wall[algo]:.1f}s)")


if __name__ == "__main__":
    main()
