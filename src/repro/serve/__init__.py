"""Serving runtime: KV-cache engine with batched prefill/decode."""
