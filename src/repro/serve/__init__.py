"""Serving runtimes.

* ``repro.serve.engine`` — KV-cache LM engine with batched prefill/decode
  (imports jax; import the submodule directly).
* ``repro.serve.policy`` — ``PolicyServer``, the caching/micro-batching
  front-end over Algorithm 3 policy generation (numpy-only).
* ``repro.serve.shard`` — ``ShardRouter``, connectivity-keyed routing
  across N ``PolicyServer`` workers.
* ``repro.serve.admission`` — ``AdmissionController``, bounded-queue EDF
  admission with deadline-aware shedding.
* ``repro.serve.rpc`` — ``PolicyService``/``PolicyClient``, the
  length-prefixed JSON-over-socket front-end (schema ``repro.serve/v1``).

Everything except ``engine`` is numpy-only and re-exported here.
"""

from repro.serve.admission import AdmissionController, AdmissionStats
from repro.serve.policy import PolicyServer, ServeStats
from repro.serve.rpc import PolicyClient, PolicyService, RpcError
from repro.serve.shard import ShardRouter

__all__ = [
    "AdmissionController",
    "AdmissionStats",
    "PolicyClient",
    "PolicyServer",
    "PolicyService",
    "RpcError",
    "ServeStats",
    "ShardRouter",
]
