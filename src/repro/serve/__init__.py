"""Serving runtimes.

* ``repro.serve.engine`` — KV-cache LM engine with batched prefill/decode
  (imports jax; import the submodule directly).
* ``repro.serve.policy`` — ``PolicyServer``, the caching/micro-batching
  front-end over Algorithm 3 policy generation (numpy-only; re-exported
  here).
"""

from repro.serve.policy import PolicyServer, ServeStats

__all__ = ["PolicyServer", "ServeStats"]
