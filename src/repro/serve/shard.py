"""Connectivity-keyed sharding across ``PolicyServer`` workers.

``ShardRouter`` spreads policy traffic over N independent
``PolicyServer`` instances so that all requests for one effective edge
set land on the same worker.  That placement is the whole point: warm
bases, ``_last_good`` stale entries and cache lines are keyed by
``connectivity_key`` and live inside a single server — routing by
anything else (round-robin, tenant hash) would scatter one cluster's
refreshes across workers and turn every warm hit cold.

Routing is a stable content hash: ``blake2b`` over the normalized edge
set's ``connectivity_key`` bytes, reduced mod N.  Stability matters in
two ways the tests pin down:

* **cross-process** — Python's builtin ``hash()`` is salted per process
  (PYTHONHASHSEED), so a client-side router and a server-side router
  would disagree; blake2b gives the same shard on any process, any
  platform.
* **T-independent** — the key hashes only the edge set, not the link
  times, so EMA jitter never migrates a cluster between shards (which
  would abandon its warm basis).

Invalidation fans out to *all* shards: the router cannot assume the
caller's previous edge set hashed to the same worker as its current one
(the edge set is exactly what changed), so correctness requires the
broadcast.  Per-tenant PR-5 invalidation inside each server still works
for the common case where a tenant's old and new keys co-locate; the
explicit ``invalidate`` broadcast covers the rest.

``stats()`` aggregates counters across shards and keeps the per-shard
snapshots for operators (docs/serving.md).
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core.policy import PolicyResult, connectivity_key
from repro.serve.policy import PolicyServer, normalize_instance


def shard_index(ck: bytes, n_shards: int) -> int:
    """Map a ``connectivity_key`` to a shard by stable content hash.

    ``blake2b`` (8-byte digest) mod ``n_shards`` — deterministic across
    processes and platforms, unlike the salted builtin ``hash()``.
    """
    h = hashlib.blake2b(ck, digest_size=8).digest()
    return int.from_bytes(h, "big") % n_shards


class ShardRouter:
    """Route policy requests across N ``PolicyServer`` shards.

    Implements the same request surface as ``PolicyServer`` (``request``,
    ``request_meta``, ``request_many``, ``invalidate``, ``stats``) so the
    RPC front-end and the admission controller can sit in front of either
    a single server or a sharded pool without caring which.
    """

    def __init__(self, servers):
        """Wrap an ordered, non-empty list of ``PolicyServer`` workers."""
        servers = list(servers)
        if not servers:
            raise ValueError("ShardRouter needs at least one PolicyServer")
        for s in servers:
            if not isinstance(s, PolicyServer):
                raise TypeError(f"not a PolicyServer: {s!r}")
        self.servers = servers

    @classmethod
    def build(cls, n_shards: int, *args, **kwargs) -> "ShardRouter":
        """Build a router over ``n_shards`` identically-configured workers.

        Positional/keyword arguments are forwarded verbatim to each
        ``PolicyServer``.
        """
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        return cls([PolicyServer(*args, **kwargs) for _ in range(n_shards)])

    @property
    def n_shards(self) -> int:
        """Number of workers behind this router."""
        return len(self.servers)

    def shard_of(self, T, d=None) -> int:
        """Shard index a request for ``(T, d)`` routes to.

        Normalizes exactly like the target server's cache keying, so the
        routed-to worker and the hashed edge set always agree.
        """
        _, dn = normalize_instance(T, d)
        return shard_index(connectivity_key(dn), len(self.servers))

    # -- request surface (mirrors PolicyServer) ------------------------------
    def request(self, T, d=None, tenant=None) -> PolicyResult:
        """Serve one request on the owning shard (blocking, total)."""
        return self.servers[self.shard_of(T, d)].request(T, d=d, tenant=tenant)

    def request_meta(self, T, d=None, tenant=None):
        """Serve one request and return ``(result, meta)``.

        The owning shard's index is added to the server's meta dict under
        ``"shard"``.
        """
        i = self.shard_of(T, d)
        res, meta = self.servers[i].request_meta(T, d=d, tenant=tenant)
        meta["shard"] = i
        return res, meta

    def request_many(self, requests) -> list[PolicyResult]:
        """Micro-batch requests, grouped per owning shard.

        Each group goes through that shard's ``request_many`` (keeping
        its same-key coalescing); results return in request order.
        """
        groups: dict[int, list[int]] = {}
        for pos, req in enumerate(requests):
            T, d = req[0], req[1]
            groups.setdefault(self.shard_of(T, d), []).append(pos)
        out: list = [None] * len(requests)
        for i, positions in groups.items():
            sub = [requests[p] for p in positions]
            for p, res in zip(positions, self.servers[i].request_many(sub)):
                out[p] = res
        return out

    def invalidate(self, d) -> None:
        """Fan an edge-set invalidation out to every shard.

        The caller's previous edge set need not hash to the same worker
        as its current one, so only a broadcast keeps every shard's warm
        basis / stale entry / cache lines coherent.
        """
        for s in self.servers:
            s.invalidate(d)

    def cache_len(self) -> int:
        """Total cached policies across shards."""
        return sum(s.cache_len() for s in self.servers)

    def stats(self) -> dict:
        """Aggregate counters across shards (plus per-shard snapshots)."""
        shards = [s.stats.snapshot() for s in self.servers]
        agg: dict = {"n_shards": len(shards), "per_shard": shards}
        for k, v in shards[0].items():
            if k.startswith("n_"):
                agg[k] = sum(snap[k] for snap in shards)
        n_req = agg.get("n_requests", 0)
        served = agg.get("n_hits", 0) + agg.get("n_coalesced", 0)
        agg["hit_rate"] = served / n_req if n_req else 0.0
        lat = np.concatenate(
            [np.asarray(s.stats.latencies_ms, dtype=float)
             for s in self.servers]
        ) if any(s.stats.latencies_ms for s in self.servers) else np.array([])
        agg["p50_ms"] = float(np.percentile(lat, 50)) if lat.size else 0.0
        agg["p99_ms"] = float(np.percentile(lat, 99)) if lat.size else 0.0
        return agg
