"""Serving engine: batched prefill + decode with preallocated KV caches.

Production-shape serving loop for the assigned inference shapes:
  * prefill_32k — full-sequence forward capturing the cache
  * decode_32k  — one-token steps against a 32k cache, batch 128
  * long_500k   — recurrent-state decode (rwkv/jamba)

The engine keeps a fixed-capacity batch; requests are admitted into free
slots (continuous batching).  For the dry-run only ``decode_step`` /
``prefill`` from models.lm are lowered; this module adds the host-side
request plumbing + a cache-capturing prefill used by the examples.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm, transformer


@dataclass
class Request:
    """One generation request: prompt tokens in, generated tokens out."""

    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-host continuous-batching engine for CPU smoke runs and examples.

    The SPMD path reuses the same step functions under pjit
    (launch/dryrun lowers them).
    """

    def __init__(self, cfg: ArchConfig, params, batch_capacity: int, max_seq: int):
        """Preallocate a ``batch_capacity`` x ``max_seq`` KV cache and jit the step."""
        self.cfg = cfg
        self.params = params
        self.B = batch_capacity
        self.S = max_seq
        self.cache = lm.init_cache(cfg, batch_capacity, max_seq)
        self.pos = np.zeros(batch_capacity, np.int32)
        self.slots: list[Request | None] = [None] * batch_capacity
        self._step = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg)
        )

    # -- admission -----------------------------------------------------------
    def admit(self, req: Request) -> bool:
        """Place ``req`` into a free batch slot and prefill it; False if full."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self._prefill_slot(i, req)
                return True
        return False

    def _prefill_slot(self, i: int, req: Request) -> None:
        """Feed the prompt token-by-token into slot ``i``.

        Correct for every family incl. recurrent; batched flash prefill is
        the fast path used at scale.
        """
        for t, tok in enumerate(req.prompt):
            token = jnp.zeros((self.B,), jnp.int32).at[i].set(int(tok))
            logits, self.cache = self._step(self.params, self.cache, token, int(self.pos[i]))
            self.pos[i] += 1

    # -- decode loop ----------------------------------------------------------
    def step(self, greedy: bool = True) -> None:
        """Advance every active slot by one decode token; retire finished slots."""
        token = jnp.zeros((self.B,), jnp.int32)
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        for i in active:
            last = self.slots[i].out[-1] if self.slots[i].out else int(self.slots[i].prompt[-1])
            token = token.at[i].set(last)
        pos = int(self.pos[active[0]])  # homogeneous-pos batches in examples
        logits, self.cache = self._step(self.params, self.cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1) if greedy else jnp.argmax(logits, axis=-1)
        for i in active:
            r = self.slots[i]
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            if len(r.out) >= r.max_new or self.pos[i] >= self.S - 1:
                r.done = True
                self.slots[i] = None

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive admission + decode until every request completes; return them."""
        pending = list(requests)
        done: list[Request] = []
        while pending or any(s is not None for s in self.slots):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step()
            done.extend(r for r in requests if r.done and r not in done)
        return done


def capture_prefill(cfg: ArchConfig, params, tokens: jnp.ndarray, max_seq: int):
    """Run a batched prefill that returns the filled KV cache (attention families).

    Runs the chunked-flash forward while re-projecting K/V into the cache
    layout.
    """
    B, P = tokens.shape
    cache = lm.init_cache(cfg, B, max_seq)
    # Single forward gives last-position logits; cache is filled by replaying
    # projections per layer (cheap relative to the forward at P >> 1).
    logits = transformer.prefill(params, tokens, cfg)
    for t in range(P):
        _, cache = lm.decode_step(params, cache, tokens[:, t], t, cfg)
    return logits, cache
