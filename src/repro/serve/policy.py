"""Policy-serving front-end: cache + micro-batch over Algorithm 3.

``PolicyServer`` turns ``generate_policy_matrix`` from a per-caller
computation into a served endpoint: many tenants (simulated clusters,
what-if probes, Monitor replicas) request policies concurrently; the
server answers most of them from cache and spends solver time only on
genuinely new link-states.

Three mechanisms (DESIGN.md §17):

* **Quantized-key caching.**  A request's key is (M, connectivity key,
  quantized T, alpha, K, R, eps).  Link-state T is snapped to a relative
  grid before keying *and solving* — two tenants whose EMAs differ by
  less than the quantum share one cache line and one solve, and the
  cache stays coherent (a hit returns exactly what a solve of the same
  key would).  Quantization error is bounded by ``quant`` (default 5%),
  well inside the EMA noise the Monitor already tolerates.
* **Warm-basis reuse + PR-5 invalidation.**  Per connectivity key the
  server threads the last optimal basis into the next solve (the
  Monitor's own steady-state trick, core/monitor.py).  The Monitor's
  invalidation rule is mirrored verbatim: when a tenant's edge set
  changes, that tenant's old connectivity key drops its cache lines and
  its warm basis — a shrunken live set must never warm-start or serve a
  stale-layout result.
* **Micro-batching / coalescing.**  ``request_many`` deduplicates
  compatible instances (same key) into one solve; concurrent
  ``request`` calls for the same key coalesce on an in-flight event so
  the solver runs once while every waiter blocks, not once per thread.
  ``sweep="batched"`` routes each miss through the lockstep stacked
  sweep (``generate_policy_matrix_batched``) — useful at small/medium M
  where grid parallelism beats warm restarts.

Latency accounting: every request records wall time; ``stats()`` reports
p50/p99 and the hit rate — the serve benchmark gates the hit rate (a
ratio, hardware-portable) and reports the latencies ungated.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core.policy import (
    PolicyResult,
    connectivity_key,
    generate_policy_matrix,
    generate_policy_matrix_batched,
)


@dataclass
class ServeStats:
    """Counters + latency reservoir for one PolicyServer."""

    n_requests: int = 0
    n_hits: int = 0
    n_coalesced: int = 0
    n_solves: int = 0
    n_invalidations: int = 0
    n_evictions: int = 0
    latencies_ms: list = field(default_factory=list)

    @property
    def hit_rate(self) -> float:
        """Fraction of requests served without running a solver."""
        served = self.n_hits + self.n_coalesced
        return served / self.n_requests if self.n_requests else 0.0

    def latency_ms(self, q: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_ms), q))

    def snapshot(self) -> dict:
        return {
            "n_requests": self.n_requests,
            "n_hits": self.n_hits,
            "n_coalesced": self.n_coalesced,
            "n_solves": self.n_solves,
            "n_invalidations": self.n_invalidations,
            "n_evictions": self.n_evictions,
            "hit_rate": self.hit_rate,
            "p50_ms": self.latency_ms(50),
            "p99_ms": self.latency_ms(99),
        }


class PolicyServer:
    """Concurrent, caching policy endpoint over Algorithm 3.

    Thread-safe: cache/bookkeeping mutations hold one lock; solves run
    outside it (concurrent distinct keys solve in parallel, concurrent
    identical keys coalesce).  ``alpha``/``K``/``R``/``eps`` fix the
    Algorithm-3 configuration for every request this server answers.
    """

    def __init__(
        self,
        alpha: float,
        K: int = 5,
        R: int = 6,
        eps: float = 1e-2,
        quant: float = 0.05,
        cache_size: int = 256,
        sweep: str = "serial",
    ):
        if sweep not in ("serial", "batched"):
            raise ValueError(f"unknown sweep mode {sweep!r}")
        self.alpha = float(alpha)
        self.K = int(K)
        self.R = int(R)
        self.eps = float(eps)
        self.quant = float(quant)
        self.cache_size = int(cache_size)
        self.sweep = sweep
        self.stats = ServeStats()
        self._lock = threading.Lock()
        self._cache: OrderedDict = OrderedDict()  # key -> PolicyResult
        self._warm: dict = {}          # conn_key -> BasisState
        self._tenant_conn: dict = {}   # tenant -> conn_key (PR-5 rule)
        self._inflight: dict = {}      # key -> threading.Event

    # -- request path -------------------------------------------------------
    def _normalize(self, T, d):
        """Mirror generate_policy_matrix's dead-link masking so the cache
        key describes exactly the instance that would be solved.

        T entries off the live edge set (diagonal, dead links, d=0 pairs)
        never enter the Eq.-14 instance, so they are zeroed — otherwise
        irrelevant jitter (or an inf marker) would fragment the cache.
        """
        T = np.asarray(T, dtype=np.float64).copy()
        M = T.shape[0]
        if d is None:
            d = np.ones((M, M)) - np.eye(M)
        d = np.asarray(d, dtype=np.float64).copy()
        dead = ~np.isfinite(T)
        d[dead] = 0.0
        d[dead.T] = 0.0
        np.fill_diagonal(d, 0.0)
        T[d == 0.0] = 0.0
        return T, d

    def _quantize(self, T):
        """Snap finite link times to a relative grid of step ``quant``.

        The quantum is ``quant`` times the matrix's magnitude bucketed to
        a power of two — bucketing keeps the quantum itself stable under
        small EMA jitter (a raw ``max(T)``-proportional quantum would
        shift with every perturbation and defeat the cache).  quant=0
        disables snapping (every distinct T is its own key).
        """
        if self.quant <= 0.0:
            return T
        finite = np.isfinite(T)
        scale = float(T[finite].max()) if finite.any() else 1.0
        if scale <= 0.0:
            return T
        q = self.quant * float(2.0 ** np.ceil(np.log2(scale)))
        return np.where(finite, np.round(T / q) * q, T)

    def _key(self, Tq, d, ck) -> tuple:
        return (
            Tq.shape[0], ck, Tq.tobytes(),
            self.alpha, self.K, self.R, self.eps,
        )

    def _note_tenant(self, tenant, ck):
        """PR-5 Monitor rule: a tenant whose edge set changed invalidates
        its previous connectivity key's cache lines and warm basis."""
        if tenant is None:
            return
        prev = self._tenant_conn.get(tenant)
        if prev is not None and prev != ck:
            self._invalidate_locked(prev)
        self._tenant_conn[tenant] = ck

    def _invalidate_locked(self, ck) -> None:
        self._warm.pop(ck, None)
        stale = [k for k in self._cache if k[1] == ck]
        for k in stale:
            del self._cache[k]
        self.stats.n_invalidations += 1

    def invalidate(self, d) -> None:
        """Explicitly drop cache + warm basis for connectivity ``d``."""
        with self._lock:
            self._invalidate_locked(connectivity_key(np.asarray(d)))

    def _solve(self, Tq, d, ck) -> PolicyResult:
        if self.sweep == "batched":
            return generate_policy_matrix_batched(
                self.alpha, self.K, self.R, Tq, d=d, eps=self.eps
            )
        with self._lock:
            warm = self._warm.get(ck)
        res = generate_policy_matrix(
            self.alpha, self.K, self.R, Tq, d=d, eps=self.eps, warm=warm
        )
        return res

    def request(self, T, d=None, tenant=None) -> PolicyResult:
        """Serve one policy request (blocking; thread-safe).

        ``tenant`` (optional, hashable) enables the edge-set-change
        invalidation rule; anonymous requests only read/populate the
        cache.
        """
        t0 = time.perf_counter()
        T, d = self._normalize(T, d)
        Tq = self._quantize(T)
        ck = connectivity_key(d)
        key = self._key(Tq, d, ck)
        wait_ev = None
        with self._lock:
            self.stats.n_requests += 1
            self._note_tenant(tenant, ck)
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.stats.n_hits += 1
                self.stats.latencies_ms.append(
                    (time.perf_counter() - t0) * 1e3
                )
                return hit
            wait_ev = self._inflight.get(key)
            if wait_ev is None:
                self._inflight[key] = threading.Event()
        if wait_ev is not None:
            # Another thread is already solving this exact key: coalesce.
            wait_ev.wait()
            with self._lock:
                self.stats.n_coalesced += 1
                res = self._cache.get(key)
                self.stats.latencies_ms.append(
                    (time.perf_counter() - t0) * 1e3
                )
            if res is not None:
                return res
            # Solver owner failed to cache (infeasible edge case): fall
            # through and solve independently.
            return self._solve(Tq, d, ck)
        try:
            res = self._solve(Tq, d, ck)
            with self._lock:
                self.stats.n_solves += 1
                if res.basis is not None:
                    self._warm[ck] = res.basis
                self._cache[key] = res
                self._cache.move_to_end(key)
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
                    self.stats.n_evictions += 1
        finally:
            with self._lock:
                ev = self._inflight.pop(key, None)
            if ev is not None:
                ev.set()
        self.stats.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return res

    def request_many(self, requests) -> list[PolicyResult]:
        """Micro-batch a list of (T, d) or (T, d, tenant) requests.

        Compatible instances — identical (M, connectivity, quantized T,
        config) — collapse into one solve; the duplicates are counted as
        coalesced.  Returns results in request order.
        """
        prepared = []
        for req in requests:
            T, d = req[0], req[1]
            tenant = req[2] if len(req) > 2 else None
            T, d = self._normalize(T, d)
            Tq = self._quantize(T)
            ck = connectivity_key(d)
            prepared.append((self._key(Tq, d, ck), Tq, d, ck, tenant))
        first_of: dict = {}
        out: list = [None] * len(prepared)
        for i, (key, Tq, d, ck, tenant) in enumerate(prepared):
            if key in first_of:
                with self._lock:
                    self.stats.n_requests += 1
                    self.stats.n_coalesced += 1
                    self._note_tenant(tenant, ck)
                out[i] = first_of[key]
                continue
            res = self.request(Tq, d, tenant=tenant)
            first_of[key] = res
            out[i] = res
        return out

    def cache_len(self) -> int:
        with self._lock:
            return len(self._cache)
