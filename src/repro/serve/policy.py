"""Policy-serving front-end: cache + micro-batch over Algorithm 3.

``PolicyServer`` turns ``generate_policy_matrix`` from a per-caller
computation into a served endpoint: many tenants (simulated clusters,
what-if probes, Monitor replicas) request policies concurrently; the
server answers most of them from cache and spends solver time only on
genuinely new link-states.

Three mechanisms (DESIGN.md §17):

* **Quantized-key caching.**  A request's key is (M, connectivity key,
  quantized T, alpha, K, R, eps).  Link-state T is snapped to a relative
  grid before keying *and solving* — two tenants whose EMAs differ by
  less than the quantum share one cache line and one solve, and the
  cache stays coherent (a hit returns exactly what a solve of the same
  key would).  Quantization error is bounded by ``quant`` (default 5%),
  well inside the EMA noise the Monitor already tolerates.
* **Warm-basis reuse + PR-5 invalidation.**  Per connectivity key the
  server threads the last optimal basis into the next solve (the
  Monitor's own steady-state trick, core/monitor.py).  The Monitor's
  invalidation rule is mirrored verbatim: when a tenant's edge set
  changes, that tenant's old connectivity key drops its cache lines and
  its warm basis — a shrunken live set must never warm-start or serve a
  stale-layout result.
* **Micro-batching / coalescing.**  ``request_many`` deduplicates
  compatible instances (same key) into one solve; concurrent
  ``request`` calls for the same key coalesce on an in-flight event so
  the solver runs once while every waiter blocks, not once per thread.
  ``sweep="batched"`` routes each miss through the lockstep stacked
  sweep (``generate_policy_matrix_batched``) — useful at small/medium M
  where grid parallelism beats warm restarts.

Latency accounting: every request records wall time; ``stats()`` reports
p50/p99 and the hit rate — the serve benchmark gates the hit rate (a
ratio, hardware-portable) and reports the latencies ungated.

**Degraded-mode serving (DESIGN.md §18).**  A solver failure must never
become a caller-visible exception — a worker that cannot fetch a policy
keeps training on *something*, so the server walks a degradation ladder
on every miss whose solve goes wrong:

1. **Bounded retry with backoff** — up to ``max_retries`` re-attempts,
   exponential backoff charged against the request's ``deadline_ms``
   (backoff and chaos-injected latency are charged *virtually*, not
   slept, so tests are deterministic and fast; wall time still counts).
   A blown deadline stops retrying immediately.
2. **Stale-while-revalidate** — the last good result for the same
   connectivity key (``_last_good``) is served in place of the failed
   solve.  Edge-set invalidation drops it (a stale result for a changed
   layout must never be served), and degraded results are never cached
   and never become ``_last_good`` themselves.
3. **Uniform fallback** — with no stale result to serve, the
   AD-PSGD-style ``uniform_policy`` ships with a safe rho (the
   ``generate_policy_matrix`` infeasible-sweep fallback, core/policy.py)
   and ``T_convergence = inf`` — so ``PolicyResult.ok`` is False, which
   is how callers (and tests) recognize a degraded answer.

A **circuit breaker** guards the solver: ``breaker_threshold``
consecutive failed solves open it, after which misses short-circuit
straight to the ladder's stale/uniform steps without burning a solver
attempt; every ``breaker_probe_every``-th short-circuited miss probes
the solver once (no retries), and a successful probe closes the breaker.
Fault injection for all of this is ``scenarios.chaos.ChaosInjector``
passed as ``chaos=``; every rung is surfaced in ``ServeStats``.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core.policy import (
    PolicyResult,
    connectivity_key,
    generate_policy_matrix,
    generate_policy_matrix_batched,
    uniform_policy,
)


def normalize_instance(T, d):
    """Mirror generate_policy_matrix's dead-link masking.

    Returns ``(T, d)`` float64 copies describing exactly the instance a
    solve would see: T entries off the live edge set (diagonal, dead
    links, d=0 pairs) are zeroed so irrelevant jitter (or an inf marker)
    cannot fragment the cache, and infinite-T links are dropped from
    ``d``.  Shared by ``PolicyServer`` (cache keying) and ``ShardRouter``
    (routing must hash the same effective edge set the target shard will
    key on).
    """
    T = np.asarray(T, dtype=np.float64).copy()
    M = T.shape[0]
    if d is None:
        d = np.ones((M, M)) - np.eye(M)
    d = np.asarray(d, dtype=np.float64).copy()
    dead = ~np.isfinite(T)
    d[dead] = 0.0
    d[dead.T] = 0.0
    np.fill_diagonal(d, 0.0)
    T[d == 0.0] = 0.0
    return T, d


@dataclass
class ServeStats:
    """Counters + latency reservoir for one PolicyServer.

    Thread-safe on its own lock: counters are mutated via ``bump`` and
    latencies via ``note_latency`` from any thread, with or without the
    server's cache lock held — the final latency append of a request
    deliberately happens *after* the server releases its lock, so the
    stats object must not rely on it.
    """

    n_requests: int = 0
    n_hits: int = 0
    n_coalesced: int = 0
    n_solves: int = 0
    n_invalidations: int = 0
    n_evictions: int = 0
    # Degraded-mode ladder (module docstring): every rung is counted.
    n_solve_errors: int = 0
    n_retries: int = 0
    n_deadline_misses: int = 0
    n_stale_served: int = 0
    n_uniform_fallbacks: int = 0
    n_breaker_trips: int = 0
    n_breaker_probes: int = 0
    n_breaker_recoveries: int = 0
    latencies_ms: list = field(default_factory=list)
    _lock: threading.Lock = field(
        init=False, repr=False, compare=False, default_factory=threading.Lock
    )

    def bump(self, name: str, k: int = 1) -> None:
        """Atomically add ``k`` to counter ``name``."""
        with self._lock:
            setattr(self, name, getattr(self, name) + k)

    def note_latency(self, ms: float) -> None:
        """Record one request latency sample in milliseconds."""
        with self._lock:
            self.latencies_ms.append(ms)

    @property
    def hit_rate(self) -> float:
        """Fraction of requests served without running a solver."""
        served = self.n_hits + self.n_coalesced
        return served / self.n_requests if self.n_requests else 0.0

    @property
    def n_degraded(self) -> int:
        """Requests answered from the ladder instead of a fresh solve."""
        return self.n_stale_served + self.n_uniform_fallbacks

    def latency_ms(self, q: float) -> float:
        """Latency percentile ``q`` (0-100) in ms over recorded samples."""
        with self._lock:
            lat = np.asarray(self.latencies_ms)
        if lat.size == 0:
            return 0.0
        return float(np.percentile(lat, q))

    def snapshot(self) -> dict:
        """Export all counters plus derived rates as a plain dict."""
        return {
            "n_requests": self.n_requests,
            "n_hits": self.n_hits,
            "n_coalesced": self.n_coalesced,
            "n_solves": self.n_solves,
            "n_invalidations": self.n_invalidations,
            "n_evictions": self.n_evictions,
            "n_solve_errors": self.n_solve_errors,
            "n_retries": self.n_retries,
            "n_deadline_misses": self.n_deadline_misses,
            "n_stale_served": self.n_stale_served,
            "n_uniform_fallbacks": self.n_uniform_fallbacks,
            "n_breaker_trips": self.n_breaker_trips,
            "n_breaker_probes": self.n_breaker_probes,
            "n_breaker_recoveries": self.n_breaker_recoveries,
            "hit_rate": self.hit_rate,
            "p50_ms": self.latency_ms(50),
            "p99_ms": self.latency_ms(99),
        }


class PolicyServer:
    """Concurrent, caching policy endpoint over Algorithm 3.

    Thread-safe: cache/bookkeeping mutations hold one lock; solves run
    outside it (concurrent distinct keys solve in parallel, concurrent
    identical keys coalesce).  ``alpha``/``K``/``R``/``eps`` fix the
    Algorithm-3 configuration for every request this server answers.
    """

    def __init__(
        self,
        alpha: float,
        K: int = 5,
        R: int = 6,
        eps: float = 1e-2,
        quant: float = 0.05,
        cache_size: int = 256,
        sweep: str = "serial",
        deadline_ms: float | None = None,
        max_retries: int = 2,
        backoff_ms: float = 1.0,
        breaker_threshold: int = 3,
        breaker_probe_every: int = 8,
        chaos=None,
    ):
        """Validate and pin the Algorithm-3 + degradation-ladder configuration."""
        if sweep not in ("serial", "batched"):
            raise ValueError(f"unknown sweep mode {sweep!r}")
        if deadline_ms is not None and not deadline_ms > 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        if max_retries < 0 or backoff_ms < 0:
            raise ValueError("max_retries and backoff_ms must be >= 0")
        if breaker_threshold < 1 or breaker_probe_every < 1:
            raise ValueError(
                "breaker_threshold and breaker_probe_every must be >= 1"
            )
        self.alpha = float(alpha)
        self.K = int(K)
        self.R = int(R)
        self.eps = float(eps)
        self.quant = float(quant)
        self.cache_size = int(cache_size)
        self.sweep = sweep
        self.deadline_ms = None if deadline_ms is None else float(deadline_ms)
        self.max_retries = int(max_retries)
        self.backoff_ms = float(backoff_ms)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_probe_every = int(breaker_probe_every)
        self.chaos = chaos  # scenarios.chaos.ChaosInjector (solver channels)
        self.stats = ServeStats()
        self._lock = threading.Lock()
        self._cache: OrderedDict = OrderedDict()  # key -> PolicyResult
        self._warm: dict = {}          # conn_key -> BasisState
        self._tenant_conn: dict = {}   # tenant -> conn_key (PR-5 rule)
        self._inflight: dict = {}      # key -> threading.Event
        self._last_good: dict = {}     # conn_key -> last fresh PolicyResult
        self._inval_epoch: dict = {}   # conn_key -> invalidation counter
        self._consec_failures = 0
        self._breaker_open = False
        self._probe_tick = 0

    @property
    def breaker_open(self) -> bool:
        """Whether the circuit breaker is currently tripped open."""
        with self._lock:
            return self._breaker_open

    # -- request path -------------------------------------------------------
    def _normalize(self, T, d):
        """Delegate to module-level ``normalize_instance``.

        Shared with the shard router, which must hash the same effective
        edge set.
        """
        return normalize_instance(T, d)

    def _quantize(self, T):
        """Snap finite link times to a relative grid of step ``quant``.

        The quantum is ``quant`` times the matrix's magnitude bucketed to
        a power of two — bucketing keeps the quantum itself stable under
        small EMA jitter (a raw ``max(T)``-proportional quantum would
        shift with every perturbation and defeat the cache).  quant=0
        disables snapping (every distinct T is its own key).
        """
        if self.quant <= 0.0:
            return T
        finite = np.isfinite(T)
        scale = float(T[finite].max()) if finite.any() else 1.0
        if scale <= 0.0:
            return T
        q = self.quant * float(2.0 ** np.ceil(np.log2(scale)))
        return np.where(finite, np.round(T / q) * q, T)

    def _key(self, Tq, d, ck) -> tuple:
        return (
            Tq.shape[0], ck, Tq.tobytes(),
            self.alpha, self.K, self.R, self.eps,
        )

    def _note_tenant(self, tenant, ck):
        """Apply the PR-5 Monitor rule for ``tenant``.

        A tenant whose edge set changed invalidates its previous
        connectivity key's cache lines and warm basis.
        """
        if tenant is None:
            return
        prev = self._tenant_conn.get(tenant)
        if prev is not None and prev != ck:
            self._invalidate_locked(prev)
        self._tenant_conn[tenant] = ck

    def _invalidate_locked(self, ck) -> None:
        self._warm.pop(ck, None)
        # Stale-while-revalidate must respect the same rule: a last-good
        # result for a changed edge set has the wrong layout — drop it
        # (the ladder then falls through to the uniform policy).
        self._last_good.pop(ck, None)
        # Epoch bump: a solve that started before this invalidation must
        # not insert its (stale-layout) result when it finishes.
        self._inval_epoch[ck] = self._inval_epoch.get(ck, 0) + 1
        stale = [k for k in self._cache if k[1] == ck]
        for k in stale:
            del self._cache[k]
        self.stats.bump("n_invalidations")

    def invalidate(self, d) -> None:
        """Explicitly drop cache + warm basis for connectivity ``d``."""
        with self._lock:
            self._invalidate_locked(connectivity_key(np.asarray(d)))

    def _solve(self, Tq, d, ck) -> PolicyResult:
        if self.sweep == "batched":
            return generate_policy_matrix_batched(
                self.alpha, self.K, self.R, Tq, d=d, eps=self.eps
            )
        with self._lock:
            warm = self._warm.get(ck)
        res = generate_policy_matrix(
            self.alpha, self.K, self.R, Tq, d=d, eps=self.eps, warm=warm
        )
        return res

    # -- degradation ladder (module docstring) -------------------------------
    def _solve_guarded(self, Tq, d, ck, t0: float, max_retries: int):
        """Bounded-retry solve under the deadline.

        Returns the fresh ``PolicyResult`` or None when the retry budget
        or the deadline is exhausted.  Backoff and chaos-injected latency
        are charged *virtually* against the deadline (never slept), so
        the ladder is deterministic under test; real wall time counts too.
        """
        charged_ms = 0.0

        def over_deadline() -> bool:
            """Whether wall time plus virtually-charged ms exceeds the deadline."""
            if self.deadline_ms is None:
                return False
            spent = (time.perf_counter() - t0) * 1e3 + charged_ms
            return spent > self.deadline_ms

        for attempt in range(max_retries + 1):
            if self.chaos is not None:
                charged_ms += self.chaos.injected_delay_ms()
            try:
                if self.chaos is not None:
                    self.chaos.maybe_fail_solver()
                res = self._solve(Tq, d, ck)
            except Exception:
                self.stats.bump("n_solve_errors")
                res = None
            if res is not None:
                # A late success is still served (the fresh result is in
                # hand; stale would be strictly worse) — but the miss is
                # counted: the deadline's job is bounding the retry tail.
                if over_deadline():
                    self.stats.bump("n_deadline_misses")
                return res
            if over_deadline():
                self.stats.bump("n_deadline_misses")
                return None
            if attempt < max_retries:
                self.stats.bump("n_retries")
                charged_ms += self.backoff_ms * (2.0 ** attempt)
        return None

    def _degraded(self, d, ck):
        """Walk stale-while-revalidate, then the uniform fallback.

        Degraded results are never cached and never raise — the caller
        always gets a usable policy.  Returns ``(result, rung)`` with
        rung ``"stale"`` or ``"uniform"``.
        """
        with self._lock:
            stale = self._last_good.get(ck)
        if stale is not None:
            self.stats.bump("n_stale_served")
            return stale, "stale"
        self.stats.bump("n_uniform_fallbacks")
        P = uniform_policy(d)
        rho = 0.25 / self.alpha / max(1.0, d.sum(axis=1).max())
        # T_convergence=inf => PolicyResult.ok is False: the degraded
        # marker callers and tests key off.
        return PolicyResult(P, rho, 0.0, 1.0, float("inf")), "uniform"

    def _breaker_gate(self) -> str:
        """Decide how the breaker treats this request.

        'closed' = solve normally, 'probe' = one no-retry attempt,
        'short' = short-circuit straight to the degraded ladder.
        """
        with self._lock:
            if not self._breaker_open:
                return "closed"
            self._probe_tick += 1
            if self._probe_tick >= self.breaker_probe_every:
                self._probe_tick = 0
                probe = True
            else:
                probe = False
        if probe:
            self.stats.bump("n_breaker_probes")
            return "probe"
        return "short"

    def _note_solve_outcome(self, success: bool) -> None:
        tripped = recovered = False
        with self._lock:
            if success:
                self._consec_failures = 0
                if self._breaker_open:
                    self._breaker_open = False
                    recovered = True
            else:
                self._consec_failures += 1
                if (not self._breaker_open
                        and self._consec_failures >= self.breaker_threshold):
                    self._breaker_open = True
                    self._probe_tick = 0
                    tripped = True
        if tripped:
            self.stats.bump("n_breaker_trips")
        if recovered:
            self.stats.bump("n_breaker_recoveries")

    def _serve_miss(self, Tq, d, ck, t0, cache_key=None, epoch=None):
        """Serve one cache miss: breaker -> guarded solve -> ladder.

        ``cache_key``/``epoch`` are set only for the in-flight owner: the
        fresh result is inserted unless the key's invalidation epoch moved
        while the solve ran (a concurrent ``invalidate`` must win — its
        caller's edge set changed, so the just-solved layout is stale).
        Coalesced waiters falling through a degraded owner pass None and
        never populate the cache.  Degraded results are never cached.
        Returns ``(result, rung)`` with rung ``"fresh"``, ``"stale"`` or
        ``"uniform"``.
        """
        gate = self._breaker_gate()
        if gate == "short":
            return self._degraded(d, ck)
        retries = 0 if gate == "probe" else self.max_retries
        res = self._solve_guarded(Tq, d, ck, t0, retries)
        self._note_solve_outcome(res is not None)
        if res is None:
            return self._degraded(d, ck)
        self.stats.bump("n_solves")
        with self._lock:
            fresh = self._inval_epoch.get(ck, 0) == epoch
            if cache_key is not None and fresh:
                if res.basis is not None:
                    self._warm[ck] = res.basis
                self._last_good[ck] = res
                self._cache[cache_key] = res
                self._cache.move_to_end(cache_key)
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
                    self.stats.bump("n_evictions")
        return res, "fresh"

    def request(self, T, d=None, tenant=None) -> PolicyResult:
        """Serve one policy request (blocking; thread-safe; total).

        ``tenant`` (optional, hashable) enables the edge-set-change
        invalidation rule; anonymous requests only read/populate the
        cache.  *Total*: solver failures (real or chaos-injected) never
        escape — the degradation ladder answers instead (module
        docstring), and ``ServeStats`` records which rung did.
        """
        return self.request_meta(T, d=d, tenant=tenant)[0]

    def request_meta(self, T, d=None, tenant=None):
        """Serve one request and report how it was answered.

        Returns ``(result, meta)`` where ``meta`` is a dict with ``rung``
        — one of ``"hit"``, ``"coalesced"``, ``"fresh"``, ``"stale"``,
        ``"uniform"`` — and ``ms`` (wall latency).  Rungs hit/coalesced/
        fresh are bit-equal to a direct solve of the same (quantized)
        instance; stale/uniform are degraded answers.  The RPC front-end
        (``repro.serve.rpc``) forwards ``meta`` to clients that ask.
        """
        t0 = time.perf_counter()
        T, d = self._normalize(T, d)
        Tq = self._quantize(T)
        ck = connectivity_key(d)
        key = self._key(Tq, d, ck)
        wait_ev = None
        with self._lock:
            self.stats.bump("n_requests")
            self._note_tenant(tenant, ck)
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.stats.bump("n_hits")
                ms = (time.perf_counter() - t0) * 1e3
                self.stats.note_latency(ms)
                return hit, {"rung": "hit", "ms": ms}
            wait_ev = self._inflight.get(key)
            if wait_ev is None:
                self._inflight[key] = threading.Event()
                epoch = self._inval_epoch.get(ck, 0)
        if wait_ev is not None:
            # Another thread is already solving this exact key: coalesce.
            wait_ev.wait()
            self.stats.bump("n_coalesced")
            with self._lock:
                res = self._cache.get(key)
            rung = "coalesced"
            if res is None:
                # The owner degraded (or an invalidation raced its insert):
                # walk the guarded ladder ourselves — never the raw solver.
                res, rung = self._serve_miss(Tq, d, ck, time.perf_counter())
            ms = (time.perf_counter() - t0) * 1e3
            self.stats.note_latency(ms)
            return res, {"rung": rung, "ms": ms}
        try:
            res, rung = self._serve_miss(
                Tq, d, ck, t0, cache_key=key, epoch=epoch
            )
        finally:
            with self._lock:
                ev = self._inflight.pop(key, None)
            if ev is not None:
                ev.set()
        ms = (time.perf_counter() - t0) * 1e3
        self.stats.note_latency(ms)
        return res, {"rung": rung, "ms": ms}

    def request_many(self, requests) -> list[PolicyResult]:
        """Micro-batch a list of (T, d) or (T, d, tenant) requests.

        Compatible instances — identical (M, connectivity, quantized T,
        config) — collapse into one solve; the duplicates are counted as
        coalesced.  Returns results in request order.
        """
        prepared = []
        for req in requests:
            T, d = req[0], req[1]
            tenant = req[2] if len(req) > 2 else None
            T, d = self._normalize(T, d)
            Tq = self._quantize(T)
            ck = connectivity_key(d)
            prepared.append((self._key(Tq, d, ck), Tq, d, ck, tenant))
        first_of: dict = {}
        out: list = [None] * len(prepared)
        for i, (key, Tq, d, ck, tenant) in enumerate(prepared):
            if key in first_of:
                self.stats.bump("n_requests")
                self.stats.bump("n_coalesced")
                with self._lock:
                    self._note_tenant(tenant, ck)
                out[i] = first_of[key]
                continue
            res = self.request(Tq, d, tenant=tenant)
            first_of[key] = res
            out[i] = res
        return out

    def cache_len(self) -> int:
        """Number of policy results currently cached."""
        with self._lock:
            return len(self._cache)
