"""Cross-process policy serving: JSON-over-socket RPC (repro.serve/v1).

The wire protocol is deliberately minimal — the same shape as the trace
schema (``repro.trace/v1``): every frame is a 4-byte big-endian unsigned
length followed by that many bytes of UTF-8 JSON.  Requests carry
``{"schema": "repro.serve/v1", "op": ..., "id": ...}`` plus op-specific
fields; responses echo ``id`` and set envelope-``ok`` (RPC success —
distinct from ``PolicyResult.ok``, which marks a non-degraded policy and
rides inside ``result``).  Ops:

* ``policy`` — ``T`` (nested lists), optional ``d``/``tenant``/
  ``want_meta``, optional ``priority``/``deadline_ms`` (honored when the
  service fronts an ``AdmissionController``).  Response ``result`` holds
  P/rho/t_bar/lambda2/T_convergence; ``meta`` (when asked) holds the
  serving rung.  Python's ``json`` writes floats by ``repr`` and accepts
  ``Infinity``, so policies round-trip bit-exactly — the E2E test pins
  RPC answers bit-equal to in-process answers.
* ``invalidate`` — edge-set ``d``; fans out through the backend (all
  shards when the backend is a ``ShardRouter``).
* ``stats`` — backend stats snapshot (plus admission counters when
  present).
* ``ping`` — liveness probe.

``PolicyService`` is a threaded server (one accept loop, one handler
thread per connection) over any backend with the ``PolicyServer``
request surface: a bare ``PolicyServer``, a ``ShardRouter``, or an
``AdmissionController`` wrapping either.  Faulty clients cannot hurt it:
malformed JSON, a bogus schema tag, an oversized length prefix, or a
mid-request disconnect are answered (where possible) with an error frame
and cost only that one connection.

``PolicyClient`` is the retrying counterpart: on connection loss it
reconnects with bounded backoff and re-sends the request.  Retrying a
``policy`` op is safe — serving is read-only-plus-cache, so a duplicate
solve is wasted work, never wrong state; ``invalidate`` is idempotent.
"""

from __future__ import annotations

import json
import socket
import struct
import threading
import time

import numpy as np

from repro.core.policy import PolicyResult

SCHEMA = "repro.serve/v1"
MAX_FRAME = 64 * 1024 * 1024  # 64 MiB: an M=1024 policy is ~20 MB of JSON
_LEN = struct.Struct(">I")


class RpcError(RuntimeError):
    """Server-reported failure for one RPC (connection stays usable)."""


class FrameError(RuntimeError):
    """Unrecoverable wire corruption (oversized/short frame): close."""


def _send_frame(sock: socket.socket, obj: dict) -> None:
    """Serialize ``obj`` and write one length-prefixed frame."""
    payload = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_FRAME:
        raise FrameError(f"frame of {len(payload)} bytes exceeds {MAX_FRAME}")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ``ConnectionError`` on EOF."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(65536, n - len(buf)))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> dict:
    """Read one frame; raise ``FrameError`` on oversized/garbled input."""
    (length,) = _LEN.unpack(_recv_exact(sock, 4))
    if length > MAX_FRAME:
        raise FrameError(f"declared frame of {length} bytes exceeds cap")
    payload = _recv_exact(sock, length)
    try:
        obj = json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FrameError(f"malformed frame: {e}") from None
    if not isinstance(obj, dict):
        raise FrameError("frame payload must be a JSON object")
    return obj


def _result_to_wire(res: PolicyResult) -> dict:
    """Encode a ``PolicyResult`` (floats round-trip exactly via repr)."""
    return {
        "P": np.asarray(res.P).tolist(),
        "rho": float(res.rho),
        "t_bar": float(res.t_bar),
        "lambda2": float(res.lambda2),
        "T_convergence": float(res.T_convergence),
    }


def _result_from_wire(doc: dict) -> PolicyResult:
    """Decode the ``policy`` response body back into a ``PolicyResult``."""
    return PolicyResult(
        np.asarray(doc["P"], dtype=np.float64),
        float(doc["rho"]),
        float(doc["t_bar"]),
        float(doc["lambda2"]),
        float(doc["T_convergence"]),
    )


class PolicyService:
    """Threaded RPC front-end over a policy-serving backend.

    ``backend`` needs the ``PolicyServer`` request surface; when it is an
    ``AdmissionController`` (detected by its ``submit`` method), per-
    request ``priority``/``deadline_ms`` are forwarded into admission.
    ``start()`` binds and returns (serving happens on daemon threads);
    ``stop()`` closes the listener and all live connections.  Use
    ``address`` to reach it (port 0 picks a free port).
    """

    def __init__(self, backend, host: str = "127.0.0.1", port: int = 0):
        """Record the backend and bind address (nothing starts yet)."""
        self.backend = backend
        self._host, self._port = host, int(port)
        self._listener: socket.socket | None = None
        self._conns: set[socket.socket] = set()
        self._lock = threading.Lock()
        self._stopping = False
        self.n_bad_frames = 0
        self.n_disconnects = 0

    @property
    def address(self) -> tuple[str, int]:
        """(host, port) actually bound (valid after ``start``)."""
        if self._listener is None:
            raise RuntimeError("service not started")
        return self._listener.getsockname()[:2]

    def start(self) -> "PolicyService":
        """Bind, listen and spawn the accept loop; returns self."""
        srv = socket.create_server(
            (self._host, self._port), reuse_port=False
        )
        srv.listen(64)
        self._listener = srv
        self._stopping = False
        t = threading.Thread(target=self._accept_loop, daemon=True)
        t.start()
        return self

    def stop(self) -> None:
        """Close the listener and every live connection.

        The listener is ``shutdown()`` before ``close()``: a thread
        blocked inside ``accept(2)`` holds the kernel file description
        open past ``close()``, so without the shutdown the dead service
        could accept (and answer!) one more connection — and pin the
        port against a restart.
        """
        self._stopping = True
        if self._listener is not None:
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass

    def __enter__(self):
        """Context-manager entry: start serving."""
        return self.start()

    def __exit__(self, *exc):
        """Context-manager exit: stop serving."""
        self.stop()

    # -- server internals ----------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return  # listener closed by stop()
            if self._stopping:  # raced stop(): never serve from a dead
                try:            # service
                    conn.close()
                except OSError:
                    pass
                return
            with self._lock:
                self._conns.add(conn)
            threading.Thread(
                target=self._serve_conn, args=(conn,), daemon=True
            ).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while True:
                try:
                    req = _recv_frame(conn)
                except FrameError as e:
                    # Framing is corrupt; answer if the socket still
                    # writes, then drop the connection (only safe move:
                    # the byte stream can no longer be trusted).
                    self.n_bad_frames += 1
                    try:
                        _send_frame(conn, {
                            "schema": SCHEMA, "id": None,
                            "ok": False, "error": str(e),
                        })
                    except OSError:
                        pass
                    return
                resp = self._handle(req)
                _send_frame(conn, resp)
        except (ConnectionError, OSError):
            self.n_disconnects += 1  # client went away: their problem
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, req: dict) -> dict:
        rid = req.get("id")
        head = {"schema": SCHEMA, "id": rid}
        if req.get("schema") != SCHEMA:
            return {**head, "ok": False,
                    "error": f"unknown schema {req.get('schema')!r}"}
        op = req.get("op")
        try:
            if op == "ping":
                return {**head, "ok": True}
            if op == "policy":
                return {**head, "ok": True, **self._op_policy(req)}
            if op == "invalidate":
                self.backend.invalidate(np.asarray(req["d"], dtype=float))
                return {**head, "ok": True}
            if op == "stats":
                return {**head, "ok": True, "stats": self._op_stats()}
            return {**head, "ok": False, "error": f"unknown op {op!r}"}
        except Exception as e:  # total: one bad request != dead server
            return {**head, "ok": False, "error": f"{type(e).__name__}: {e}"}

    def _op_policy(self, req: dict) -> dict:
        T = np.asarray(req["T"], dtype=np.float64)
        d = req.get("d")
        if d is not None:
            d = np.asarray(d, dtype=np.float64)
        tenant = req.get("tenant")
        if hasattr(self.backend, "submit"):  # AdmissionController
            res, meta = self.backend.submit(
                T, d=d, tenant=tenant,
                priority=req.get("priority"),
                deadline_ms=req.get("deadline_ms"),
            )
        else:
            res, meta = self.backend.request_meta(T, d=d, tenant=tenant)
        out = {"result": _result_to_wire(res)}
        if req.get("want_meta"):
            out["meta"] = meta
        return out

    def _op_stats(self) -> dict:
        backend = self.backend
        out: dict = {}
        if hasattr(backend, "submit"):  # AdmissionController in front
            out["admission"] = backend.stats.snapshot()
            backend = backend.backend
        if hasattr(backend, "servers"):  # ShardRouter
            out["serving"] = backend.stats()
        else:
            out["serving"] = backend.stats.snapshot()
        return out


class PolicyClient:
    """Reconnecting RPC client for ``PolicyService``.

    One client holds one connection and is locked per call (share across
    threads freely, or build one per thread for parallelism — they are
    cheap).  On connection failure each op is retried up to ``retries``
    times with exponential backoff, reconnecting first; server-reported
    errors raise ``RpcError`` without a retry (the request itself is
    bad, or the server chose to refuse it — resending cannot help).
    """

    def __init__(
        self,
        address: tuple,
        retries: int = 3,
        backoff_s: float = 0.05,
        timeout_s: float = 60.0,
    ):
        """Record the target address; the first op connects lazily."""
        if retries < 0 or backoff_s < 0:
            raise ValueError("retries and backoff_s must be >= 0")
        self.address = (address[0], int(address[1]))
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.timeout_s = float(timeout_s)
        self._sock: socket.socket | None = None
        self._lock = threading.Lock()
        self._id = 0
        self.n_reconnects = 0

    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self.address, timeout=self.timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _call(self, body: dict) -> dict:
        with self._lock:
            self._id += 1
            body = {"schema": SCHEMA, "id": self._id, **body}
            last_err: Exception | None = None
            for attempt in range(self.retries + 1):
                sent = False
                try:
                    if self._sock is None:
                        self._sock = self._connect()
                        if attempt:
                            self.n_reconnects += 1
                    _send_frame(self._sock, body)
                    sent = True
                    resp = _recv_frame(self._sock)
                    break
                except (ConnectionError, OSError, FrameError) as e:
                    if isinstance(e, FrameError) and not sent:
                        raise  # oversized request — resending cannot help
                    last_err = e
                    if self._sock is not None:
                        try:
                            self._sock.close()
                        except OSError:
                            pass
                        self._sock = None
                    if attempt < self.retries:
                        time.sleep(self.backoff_s * (2.0 ** attempt))
            else:
                raise ConnectionError(
                    f"rpc to {self.address} failed after "
                    f"{self.retries + 1} attempts: {last_err}"
                )
        if not resp.get("ok"):
            raise RpcError(resp.get("error", "unknown server error"))
        return resp

    # -- ops -----------------------------------------------------------------
    def request(self, T, d=None, tenant=None, want_meta=False,
                priority=None, deadline_ms=None):
        """Fetch a policy; returns ``PolicyResult`` (or with meta dict).

        ``priority``/``deadline_ms`` only take effect when the service
        fronts an ``AdmissionController``; other backends ignore them.
        With ``want_meta=True`` returns ``(result, meta)`` where ``meta``
        carries the serving rung (and shard/queueing info when present).
        """
        body: dict = {"op": "policy", "T": np.asarray(T).tolist()}
        if d is not None:
            body["d"] = np.asarray(d).tolist()
        if tenant is not None:
            body["tenant"] = tenant
        if want_meta:
            body["want_meta"] = True
        if priority is not None:
            body["priority"] = priority
        if deadline_ms is not None:
            body["deadline_ms"] = deadline_ms
        resp = self._call(body)
        res = _result_from_wire(resp["result"])
        if want_meta:
            return res, resp.get("meta", {})
        return res

    def invalidate(self, d) -> None:
        """Drop cache/warm state for edge set ``d`` on every shard."""
        self._call({"op": "invalidate", "d": np.asarray(d).tolist()})

    def stats(self) -> dict:
        """Fetch the service's aggregated stats snapshot."""
        return self._call({"op": "stats"})["stats"]

    def ping(self) -> bool:
        """Round-trip a liveness probe (True, or raises)."""
        self._call({"op": "ping"})
        return True

    def close(self) -> None:
        """Close the underlying connection (next op reconnects)."""
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None

    def __enter__(self):
        """Context-manager entry."""
        return self

    def __exit__(self, *exc):
        """Context-manager exit: close the connection."""
        self.close()
