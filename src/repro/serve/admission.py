"""Deadline-aware admission control in front of the policy solve path.

``AdmissionController`` sits between callers (the RPC front-end, or
in-process tenants) and a ``PolicyServer``/``ShardRouter`` backend and
decides *which* requests get solver time when there is not enough of it
for everyone (DESIGN.md §19):

* **Bounded queue.**  At most ``max_queue`` requests wait at once.  When
  the queue is full, the worst pending entry — lowest priority class,
  then latest deadline — competes against the newcomer: whichever loses
  is shed immediately with the ladder's terminal ``ok=False`` uniform
  fallback (``PolicyServer._degraded``'s last rung, core/policy.py's
  AD-PSGD fallback).  Overload therefore displaces *low-priority slack*,
  never high-priority work.
* **EDF within priority class.**  Dispatch order is
  ``(priority, absolute deadline, arrival seq)`` — strict priority
  classes (smaller number = more urgent), earliest-deadline-first inside
  a class, FIFO among no-deadline peers.  Per-tenant default priorities
  are configured up front (``tenant_priority``) and overridable per
  request.
* **Shed-on-hopeless-deadline.**  At dispatch, an entry whose remaining
  deadline budget cannot cover the estimated service time (EWMA of
  observed service, headroom factor ``safety``) is shed rather than
  served late — a deadline violation costs the caller more than an
  honest ``ok=False`` (they keep their previous policy or fall back to
  uniform AD-PSGD locally).  This is what makes "zero deadline
  violations among admitted requests" a testable property.

Chaos seam: ``scenarios.chaos.ChaosInjector.injected_queue_delay_ms``
charges artificial queueing latency against an entry's deadline at
dispatch — charged *virtually* (never slept), so a seeded injector
deterministically steers chosen requests into the shed path while the
controller's real latency stays test-fast.

Shed answers never come from the backend: they are built here from the
normalized edge set, so a shed request costs zero solver/cache work.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field

from repro.core.policy import PolicyResult, uniform_policy
from repro.serve.policy import normalize_instance


@dataclass
class AdmissionStats:
    """Counters for one ``AdmissionController`` (thread-safe bumps)."""

    n_submitted: int = 0
    n_served: int = 0
    n_shed_queue_full: int = 0
    n_shed_hopeless: int = 0
    n_displaced: int = 0
    n_deadline_violations: int = 0
    _lock: threading.Lock = field(
        init=False, repr=False, compare=False, default_factory=threading.Lock
    )

    def bump(self, name: str, k: int = 1) -> None:
        """Atomically increment counter ``name`` by ``k``."""
        with self._lock:
            setattr(self, name, getattr(self, name) + k)

    @property
    def n_shed(self) -> int:
        """Total requests answered with the shed uniform fallback."""
        return self.n_shed_queue_full + self.n_shed_hopeless

    def snapshot(self) -> dict:
        """Plain-dict view of all counters (for stats()/RPC)."""
        return {
            "n_submitted": self.n_submitted,
            "n_served": self.n_served,
            "n_shed_queue_full": self.n_shed_queue_full,
            "n_shed_hopeless": self.n_shed_hopeless,
            "n_shed": self.n_shed,
            "n_displaced": self.n_displaced,
            "n_deadline_violations": self.n_deadline_violations,
        }


class _Entry:
    """One queued request (identity-compared; ordered via its key)."""

    __slots__ = (
        "T", "d", "tenant", "priority", "deadline_ms", "t0",
        "charged_ms", "seq", "done", "result", "meta", "cancelled",
    )

    def __init__(self, T, d, tenant, priority, deadline_ms, seq):
        """Capture the request payload and stamp its arrival time."""
        self.T, self.d, self.tenant = T, d, tenant
        self.priority = priority
        self.deadline_ms = deadline_ms
        self.t0 = time.perf_counter()
        self.charged_ms = 0.0
        self.seq = seq
        self.done = threading.Event()
        self.result = None
        self.meta = None
        self.cancelled = False

    def key(self):
        """EDF ordering: (priority class, absolute deadline, arrival)."""
        dl = (
            self.t0 + self.deadline_ms / 1e3
            if self.deadline_ms is not None
            else float("inf")
        )
        return (self.priority, dl, self.seq)

    def elapsed_ms(self) -> float:
        """Wall time since submit plus virtually-charged chaos delay."""
        return (time.perf_counter() - self.t0) * 1e3 + self.charged_ms


class AdmissionController:
    """Bounded-queue EDF admission in front of a policy backend.

    ``backend`` is anything with the ``PolicyServer`` request surface
    (``request_meta``; a ``ShardRouter`` works unchanged).  ``workers``
    dispatcher threads drain the queue, so up to ``workers`` solves run
    concurrently while everything else waits in deadline order.  Use as
    a context manager or call ``close()`` — pending entries are shed on
    close, never abandoned.
    """

    def __init__(
        self,
        backend,
        max_queue: int = 64,
        workers: int = 2,
        default_priority: int = 1,
        tenant_priority: dict | None = None,
        safety: float = 2.0,
        service_ms_init: float = 10.0,
        ewma: float = 0.2,
        chaos=None,
    ):
        """Validate knobs and start the dispatcher threads."""
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if safety < 1.0:
            raise ValueError(f"safety must be >= 1.0, got {safety}")
        if not (0.0 < ewma <= 1.0):
            raise ValueError(f"ewma must be in (0, 1], got {ewma}")
        self.backend = backend
        self.max_queue = int(max_queue)
        self.default_priority = int(default_priority)
        self.tenant_priority = dict(tenant_priority or {})
        self.safety = float(safety)
        self.ewma = float(ewma)
        self.chaos = chaos
        self.stats = AdmissionStats()
        self._service_ms = float(service_ms_init)
        self._seq = itertools.count()
        self._heap: list = []          # (key, entry), lazy-deleted
        self._n_pending = 0            # live (non-cancelled) queued entries
        self._cond = threading.Condition()
        self._closed = False
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(int(workers))
        ]
        for t in self._threads:
            t.start()

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self):
        """Context-manager entry (controller is already running)."""
        return self

    def __exit__(self, *exc):
        """Context-manager exit: drain and stop the dispatchers."""
        self.close()

    def close(self) -> None:
        """Stop dispatchers; shed (never abandon) still-queued entries."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = [e for _, e in self._heap if not e.cancelled]
            self._heap.clear()
            self._n_pending = 0
            self._cond.notify_all()
        for e in pending:
            self._shed(e, "n_shed_queue_full")
        for t in self._threads:
            t.join(timeout=5.0)

    def invalidate(self, d) -> None:
        """Forward an edge-set invalidation to the backend (not queued).

        Invalidation is control-plane, not a solve: it runs immediately
        rather than competing with policy requests for queue slots.
        """
        self.backend.invalidate(d)

    # -- submission ----------------------------------------------------------
    def submit(self, T, d=None, tenant=None, priority=None,
               deadline_ms=None):
        """Queue one request; block until answered; never raise.

        Returns ``(result, meta)``.  ``meta["rung"]`` is the backend's
        rung (hit/coalesced/fresh/stale/uniform) for served requests or
        ``"shed"`` for requests the controller answered with the uniform
        ``ok=False`` fallback; ``meta["queued_ms"]`` is time spent
        waiting (including virtually-charged chaos delay).  ``priority``
        overrides the tenant's configured class (smaller = more urgent);
        ``deadline_ms`` is a relative deadline from submission.
        """
        if deadline_ms is not None and not deadline_ms > 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        if priority is None:
            priority = self.tenant_priority.get(tenant, self.default_priority)
        entry = _Entry(T, d, tenant, int(priority), deadline_ms,
                       next(self._seq))
        self.stats.bump("n_submitted")
        with self._cond:
            if self._closed:
                self._shed_locked_free(entry, "n_shed_queue_full")
                return entry.result, entry.meta
            victim = None
            if self._n_pending >= self.max_queue:
                worst = max(
                    (e for _, e in self._heap if not e.cancelled),
                    key=lambda e: e.key(),
                    default=None,
                )
                if worst is not None and worst.key() > entry.key():
                    # Newcomer outranks the worst queued entry: displace.
                    worst.cancelled = True
                    self._n_pending -= 1
                    victim = worst
                    self.stats.bump("n_displaced")
                else:
                    self._shed_locked_free(entry, "n_shed_queue_full")
                    return entry.result, entry.meta
            heapq.heappush(self._heap, (entry.key(), entry))
            self._n_pending += 1
            self._cond.notify()
        if victim is not None:
            self._shed(victim, "n_shed_queue_full")
        entry.done.wait()
        return entry.result, entry.meta

    # -- shed path -----------------------------------------------------------
    def _uniform(self, d):
        """The ladder's terminal rung: AD-PSGD uniform, ``ok=False``."""
        P = uniform_policy(d)
        alpha = getattr(self.backend, "alpha", None)
        if alpha is None:  # ShardRouter: all shards share one config
            alpha = self.backend.servers[0].alpha
        rho = 0.25 / alpha / max(1.0, d.sum(axis=1).max())
        return PolicyResult(P, rho, 0.0, 1.0, float("inf"))

    def _shed(self, entry, counter: str) -> None:
        """Answer ``entry`` with the uniform fallback (no backend work)."""
        _, dn = normalize_instance(entry.T, entry.d)
        entry.result = self._uniform(dn)
        entry.meta = {
            "rung": "shed",
            "queued_ms": entry.elapsed_ms(),
            "priority": entry.priority,
        }
        self.stats.bump(counter)
        entry.done.set()

    def _shed_locked_free(self, entry, counter: str) -> None:
        """Shed without ever having queued (entry is thread-local)."""
        self._shed(entry, counter)

    # -- dispatch ------------------------------------------------------------
    def _pop(self):
        """Block for the next live entry (None once closed and drained)."""
        with self._cond:
            while True:
                while self._heap and self._heap[0][1].cancelled:
                    heapq.heappop(self._heap)
                if self._heap:
                    _, entry = heapq.heappop(self._heap)
                    self._n_pending -= 1
                    return entry
                if self._closed:
                    return None
                self._cond.wait()

    def _hopeless(self, entry) -> bool:
        """True when the remaining budget cannot cover estimated service."""
        if entry.deadline_ms is None:
            return False
        with self._cond:
            est = self._service_ms
        budget = entry.deadline_ms - entry.elapsed_ms()
        return budget < self.safety * est

    def _worker(self) -> None:
        while True:
            entry = self._pop()
            if entry is None:
                return
            if self.chaos is not None:
                entry.charged_ms += self.chaos.injected_queue_delay_ms()
            if self._hopeless(entry):
                self._shed(entry, "n_shed_hopeless")
                continue
            queued_ms = entry.elapsed_ms()
            try:
                res, meta = self.backend.request_meta(
                    entry.T, d=entry.d, tenant=entry.tenant
                )
            except Exception:
                # The backend is total by contract; this is belt-and-
                # braces so a dispatcher thread can never die silently.
                self._shed(entry, "n_shed_hopeless")
                continue
            served_ms = meta.get("ms", 0.0)
            with self._cond:
                self._service_ms += self.ewma * (served_ms - self._service_ms)
            meta["queued_ms"] = queued_ms
            meta["priority"] = entry.priority
            total_ms = entry.elapsed_ms()
            if entry.deadline_ms is not None and total_ms > entry.deadline_ms:
                self.stats.bump("n_deadline_violations")
                meta["deadline_violated"] = True
            entry.result = res
            entry.meta = meta
            self.stats.bump("n_served")
            entry.done.set()
