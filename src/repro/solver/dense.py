"""Dense two-phase primal tableau simplex — the differential-testing oracle.

This is the original solver the repo grew up on: a standard-form two-phase
method with Bland's rule, where every finite upper bound becomes an explicit
slack *row* (so the Eq.-14 policy LP at M workers builds an
O(M^2) x O(M^2) tableau).  The production path is the bounded-variable
revised simplex in ``repro.solver.revised``; this implementation is kept
verbatim as the ground-truth oracle for the differential tests in
tests/test_revised.py and for the `method="dense"` escape hatch in the
``repro.solver.lp`` facade — the same role the reference event loop plays
for the batched engine.

No external dependencies beyond numpy.
"""

from __future__ import annotations

import numpy as np

from repro.solver.result import LPResult

_EPS = 1e-9


def _to_standard_form(c, A_eq, b_eq, lb, ub):
    """Shift lower bounds to zero and split upper bounds into slack rows.

    Variables become y = x - lb >= 0.  Finite upper bounds add rows
    y_j + s_j = ub_j - lb_j with slack s_j >= 0.
    """
    n = c.shape[0]
    m = A_eq.shape[0]
    b_shift = b_eq - A_eq @ lb
    finite_ub = np.where(np.isfinite(ub))[0]
    k = finite_ub.shape[0]
    A = np.zeros((m + k, n + k))
    A[:m, :n] = A_eq
    b = np.concatenate([b_shift, ub[finite_ub] - lb[finite_ub]])
    for r, j in enumerate(finite_ub):
        A[m + r, j] = 1.0
        A[m + r, n + r] = 1.0
    c_full = np.concatenate([c, np.zeros(k)])
    return A, b, c_full, n


def _simplex_core(T, basis, n_total, max_iter=20000):
    """Run Bland's-rule simplex on tableau T (last row = objective).

    T layout: [A | b] stacked over [c_reduced | -obj].
    Returns "optimal" or "unbounded"; T and basis are mutated in place.
    """
    m = T.shape[0] - 1
    for _ in range(max_iter):
        obj = T[-1, :n_total]
        # Bland: entering = smallest index with negative reduced cost.
        neg = np.where(obj < -_EPS)[0]
        if neg.size == 0:
            return "optimal"
        j = int(neg[0])
        col = T[:m, j]
        pos = np.where(col > _EPS)[0]
        if pos.size == 0:
            return "unbounded"
        ratios = T[pos, -1] / col[pos]
        rmin = ratios.min()
        # Bland tie-break: smallest basis index among min-ratio rows.
        cand = pos[np.where(ratios <= rmin + _EPS)[0]]
        r = int(cand[np.argmin([basis[i] for i in cand])])
        piv = T[r, j]
        T[r, :] /= piv
        for i in range(T.shape[0]):
            if i != r and abs(T[i, j]) > _EPS:
                T[i, :] -= T[i, j] * T[r, :]
        basis[r] = j
    raise RuntimeError("simplex: iteration limit reached")


def solve_lp_dense(c, A_eq, b_eq, lb=None, ub=None) -> LPResult:
    """Minimize c@x subject to A_eq@x=b_eq, lb<=x<=ub (elementwise)."""
    c = np.asarray(c, dtype=np.float64)
    A_eq = np.asarray(A_eq, dtype=np.float64)
    b_eq = np.asarray(b_eq, dtype=np.float64)
    n = c.shape[0]
    lb = np.zeros(n) if lb is None else np.asarray(lb, dtype=np.float64)
    ub = np.full(n, np.inf) if ub is None else np.asarray(ub, dtype=np.float64)
    if np.any(lb > ub + _EPS):
        return LPResult(None, np.inf, "infeasible")

    A, b, c_std, n_orig = _to_standard_form(c, A_eq, b_eq, lb, ub)
    m, n_std = A.shape
    # Make b >= 0 for phase 1.
    neg_rows = b < 0
    A[neg_rows] *= -1.0
    b[neg_rows] *= -1.0

    # ---- Phase 1: minimize sum of artificials. ----
    n_total = n_std + m
    T = np.zeros((m + 1, n_total + 1))
    T[:m, :n_std] = A
    T[:m, n_std:n_total] = np.eye(m)
    T[:m, -1] = b
    basis = list(range(n_std, n_total))
    # Phase-1 objective: sum artificials -> reduced costs.
    T[-1, :n_std] = -A.sum(axis=0)
    T[-1, -1] = -b.sum()
    status = _simplex_core(T, basis, n_total)
    if status != "optimal" or T[-1, -1] < -1e-7:
        return LPResult(None, np.inf, "infeasible")

    # Drive artificials out of the basis where possible.
    for r in range(m):
        if basis[r] >= n_std:
            row = T[r, :n_std]
            j_cand = np.where(np.abs(row) > _EPS)[0]
            if j_cand.size:
                j = int(j_cand[0])
                piv = T[r, j]
                T[r, :] /= piv
                for i in range(T.shape[0]):
                    if i != r and abs(T[i, j]) > _EPS:
                        T[i, :] -= T[i, j] * T[r, :]
                basis[r] = j
            # else: redundant row, leave degenerate artificial at 0.

    # ---- Phase 2. ----
    T2 = np.zeros((m + 1, n_std + 1))
    T2[:m, :n_std] = T[:m, :n_std]
    T2[:m, -1] = T[:m, -1]
    T2[-1, :n_std] = c_std
    # Zero reduced costs of basic variables.
    for r in range(m):
        j = basis[r]
        if j < n_std and abs(T2[-1, j]) > _EPS:
            T2[-1, :] -= T2[-1, j] * T2[r, :]
    status = _simplex_core(T2, basis, n_std)
    if status == "unbounded":
        return LPResult(None, -np.inf, "unbounded")

    y = np.zeros(n_std)
    for r in range(m):
        if basis[r] < n_std:
            y[basis[r]] = T2[r, -1]
    x = y[:n_orig] + lb
    return LPResult(x, float(c @ x), "optimal")
