"""Bounded-variable revised simplex with warm starts.

Solves   min  c @ x
         s.t. A @ x == b          (m equality rows only)
              lb <= x <= ub       (ub may be +inf; lb must be finite)

Design (ISSUE 4 tentpole, scaled past M=256 by ISSUE 8; DESIGN.md §13/§17):

* **Implicit bounds.**  Upper bounds never become rows.  Every nonbasic
  variable rests at one of its bounds (``AT_LB``/``AT_UB``); a simplex step
  either pivots or merely *flips* a variable between its bounds.  The basis
  is therefore always m x m — for the Eq.-14 policy LP that is 2M x 2M
  instead of the dense oracle's O(M^2) x O(M^2) tableau.
* **Two basis engines.**  Small instances (``m < _LU_MIN_ROWS``) keep the
  historical dense product-form inverse: ``Binv`` maintained by elementary
  eta updates (O(m^2) per pivot), refactorized from scratch every
  ``refactor_every`` pivots.  This path is bit-identical to the pre-ISSUE-8
  solver — the engine-parity and grid-point-pin suites depend on that.
  Large instances switch to a **sparse-LU + eta-file** factorization
  (Bartels–Golub style): ``scipy.sparse.linalg.splu`` on the basis matrix
  plus a bounded list of eta transforms, so FTRAN/BTRAN cost O(lu + k·m)
  instead of O(m^2), and a pivot costs O(m) (append one eta) instead of the
  O(m^2) dense rank-1 update.  Periodic refactorization bounds both the eta
  file and numerical drift.
* **Sparse pricing.**  Eq.-14 columns carry at most two nonzeros (the
  worker's Eq.-10 row and its Eq.-13 row), so reduced costs over all n
  columns are O(nnz) through a CSC store — not the O(m·n) dense matvec
  that dominated wall time at M >= 128.  ``A_eq`` may be passed as a
  ``scipy.sparse`` matrix to skip the dense instance entirely.
* **Pricing rules.**  ``pricing="dantzig"`` (most-negative reduced cost,
  the historical rule), ``"partial"`` (rotating candidate window — prices
  a slice of columns per iteration, cutting per-iteration cost on wide
  instances), ``"devex"`` (Devex reference weights — available for LPs
  where pivot count, not pricing cost, dominates), or ``"auto"`` (dantzig
  below the LU threshold for bit-stability, partial above it — on Eq.-14
  the ratio-test ties make every rule take essentially the same pivot
  path, so the cheapest per-iteration rule wins the wall clock).  All
  rules share the Bland fallback:
  after a stall the iteration reverts to full pricing with Bland's rule,
  which guarantees termination regardless of the steady-state rule.
* **Warm starts.**  ``solve_lp_revised(..., warm=basis)`` accepts the
  ``BasisState`` returned by a previous solve.  The basis is refactorized
  against the *current* A (nonsingularity checked), nonbasic statuses are
  re-forced dual feasible against the *current* costs, and a
  bounded-variable **dual simplex** drives out any primal infeasibility
  introduced by changed ``b`` (the t_bar grid) or changed bound floors
  (the rho grid).  A warm basis is a hint, never a correctness input: any
  validation failure falls back to a cold start.

Cold starts run the textbook artificial-variable phase 1 (signed unit
columns, so the initial basis is a diagonal) followed by primal phase 2.
"""

from __future__ import annotations

import numpy as np

try:  # scipy ships in the target env; gate anyway per repo policy
    import scipy.sparse as _sp
    import scipy.sparse.linalg as _sla
except ImportError:  # pragma: no cover - exercised only without scipy
    _sp = None
    _sla = None

from repro.solver.result import BasisState, LPResult

_EPS = 1e-9      # reduced-cost / pivot-eligibility tolerance
_FEAS = 1e-8     # primal feasibility tolerance on basic variables
_PIV_MIN = 1e-10  # smallest acceptable eta pivot before forcing refactor

# Rows at which "auto" switches from the dense product-form inverse to the
# sparse-LU engine (and from Dantzig to partial pricing).  Every bit-exactness
# pin in the test suite runs at m <= 64 (M <= 32); the switch lives well
# above that so the historical path keeps producing identical bits.
_LU_MIN_ROWS = 96

AT_LB, AT_UB, BASIC = 0, 1, 2

PRICING_RULES = ("auto", "dantzig", "partial", "devex")


def _is_sparse(A) -> bool:
    return _sp is not None and _sp.issparse(A)


def instance_key(A) -> tuple:
    """Cheap fingerprint used to match a BasisState to an instance shape.

    Only the (m, n) prefix gates warm-start acceptance (see ``try_warm``);
    the sums are a debugging aid, O(n) so they stay off the hot path.
    Sparse and dense builds of the same instance produce the same key
    (adding explicit zeros is exact in IEEE float).
    """
    m, n = A.shape
    if _is_sparse(A):
        r = A.tocsr()
        return (m, n, float(r[0].sum()), float(r[m - 1].sum()))
    return (m, n, float(A[0].sum()), float(A[-1].sum()))


class _EtaLU:
    """Sparse-LU basis factorization plus an eta file.

    ``B = B0 E1 ... Ek`` where B0 is the last refactorized basis and each
    eta Ei is the identity with column r_i replaced by w_i (= B_{i-1}^-1
    a_entering).  FTRAN applies B0's LU solve then the etas in order;
    BTRAN applies the transposed etas in reverse then B0's transpose
    solve.  Each eta application is O(m); the caller bounds the file
    length via periodic refactorization.
    """

    __slots__ = ("lu", "etas", "ill_conditioned")

    def __init__(self, B_csc):
        """Factorize the basis matrix; raise RuntimeError when singular."""
        try:
            self.lu = _sla.splu(B_csc)
        except RuntimeError as e:  # exactly singular
            raise RuntimeError(f"revised simplex: singular basis ({e})")
        du = np.abs(self.lu.U.diagonal())
        if not np.isfinite(du).all() or du.min() <= 0.0:
            raise RuntimeError("revised simplex: singular basis (LU)")
        # Warm-start guard analog of the dense |Binv|.max() check.
        self.ill_conditioned = bool(du.max() / du.min() > 1e13)
        self.etas: list = []

    def push(self, r: int, w: np.ndarray) -> None:
        """Append one eta transform (pivot row r, ftran'd entering column w)."""
        self.etas.append((r, w, w[r]))

    def ftran(self, v: np.ndarray) -> np.ndarray:
        """Apply B^-1 v through the LU factors plus the eta file."""
        x = self.lu.solve(v)
        for r, w, wr in self.etas:
            t = x[r] / wr
            x -= w * t
            x[r] = t
        return x

    def btran(self, v: np.ndarray) -> np.ndarray:
        """Apply v B^-1 (transpose solve) through the eta file then the LU."""
        y = np.array(v, dtype=np.float64, copy=True)
        for r, w, wr in reversed(self.etas):
            # (E^-T y)_r = y_r - ((w - e_r) . y) / w_r; other entries fixed.
            y[r] -= (w @ y - y[r]) / wr
        return self.lu.solve(y, trans="T")


class _Simplex:
    """One solve on one instance.  Not reusable across instances."""

    def __init__(self, c, A, b, lb, ub, max_iter=20000, refactor_every=64,
                 pricing="auto", engine="auto"):
        """Set up bound-status arrays and pick the pricing rule + engine."""
        self.m, self.n = A.shape
        m, n = self.m, self.n
        sparse_in = _is_sparse(A)
        if engine == "auto":
            engine = (
                "lu" if _sp is not None and (sparse_in or m >= _LU_MIN_ROWS)
                else "dense"
            )
        if engine == "lu" and _sp is None:  # pragma: no cover - no scipy
            engine = "dense"
        if pricing == "auto":
            pricing = "partial" if engine == "lu" else "dantzig"
        if pricing not in ("dantzig", "partial", "devex"):
            raise ValueError(f"unknown pricing rule {pricing!r}")
        self.engine = engine
        self.pricing = pricing
        # Column stores.  ``self.A`` is the dense matrix (None when the
        # caller handed us a sparse instance); ``self.A_sp`` is the CSC
        # store the LU engine prices through (None on the dense engine —
        # whose arithmetic must stay bit-identical to the legacy solver).
        if engine == "dense":
            self.A = A.toarray() if sparse_in else A
            self.A_sp = None
        else:
            self.A_sp = A.tocsc() if sparse_in else _sp.csc_matrix(A)
            self.A = None if sparse_in else A
        self._ikey = instance_key(A)
        self.b = b
        self.art_sign = np.ones(m)
        self.cost = np.concatenate([c, np.zeros(m)])
        self.lbw = np.concatenate([lb, np.zeros(m)])
        self.ubw = np.concatenate([ub, np.zeros(m)])
        self.vstat = np.full(n + m, AT_LB, dtype=np.int8)
        # Nonbasic variables with no finite lower bound rest at their upper
        # bound; both-infinite (free) variables are unsupported, matching
        # the dense oracle (whose lb-shift also requires finite lb).
        no_lb = ~np.isfinite(self.lbw[:n])
        if np.any(no_lb & ~np.isfinite(self.ubw[:n])):
            raise ValueError("free variables (lb and ub infinite) unsupported")
        self.vstat[:n][no_lb] = AT_UB
        self.basis = np.arange(n, n + m)
        self.Binv = np.eye(m) if engine == "dense" else None
        self._lu: _EtaLU | None = None
        self.xB = np.zeros(m)
        self.xN = np.zeros(n + m)  # nonbasic bound values; basic entries 0
        self._rebuild_xN()
        self.pivots = 0
        self.max_iter = max_iter
        self.refactor_every = refactor_every
        # Partial pricing: rotating window over the working columns.
        self._pp_w = max(64, (n + m + 7) // 8)
        self._pp_ptr = 0
        self._gamma = None  # Devex reference weights (primal() resets)

    # -- columns / factorization -------------------------------------------
    def _col(self, j):
        if j < self.n:
            if self.A is not None:
                return self.A[:, j]
            s, e = self.A_sp.indptr[j], self.A_sp.indptr[j + 1]
            a = np.zeros(self.m)
            a[self.A_sp.indices[s:e]] = self.A_sp.data[s:e]
            return a
        e = np.zeros(self.m)
        e[j - self.n] = self.art_sign[j - self.n]
        return e

    def _cols(self, idx):
        """Dense (m, len(idx)) matrix of working columns."""
        idx = np.asarray(idx)
        out = np.zeros((self.m, len(idx)))
        struct = idx < self.n
        if self.A is not None:
            out[:, struct] = self.A[:, idx[struct]]
        else:
            out[:, struct] = self.A_sp[:, idx[struct]].toarray()
        art = np.flatnonzero(~struct)
        rows = idx[art] - self.n
        out[rows, art] = self.art_sign[rows]
        return out

    def _Ax(self, x):
        """A @ x over the structural columns."""
        if self.A_sp is not None:
            return self.A_sp @ x
        return self.A @ x

    def _ATy(self, y):
        """Compute y @ A over the structural columns (row vector times A)."""
        if self.A_sp is not None:
            return self.A_sp.T @ y
        return y @ self.A

    def _basis_csc(self):
        """Sparse basis matrix in basis order (LU engine refactorization)."""
        idx = self.basis
        struct = idx < self.n
        ns = int(struct.sum())
        nart = self.m - ns
        order = np.empty(self.m, dtype=np.int64)
        order[struct] = np.arange(ns)
        order[~struct] = ns + np.arange(nart)
        parts = []
        if ns:
            parts.append(self.A_sp[:, idx[struct]])
        if nart:
            rows = idx[~struct] - self.n
            parts.append(_sp.csc_matrix(
                (self.art_sign[rows], (rows, np.arange(nart))),
                shape=(self.m, nart),
            ))
        B = parts[0] if len(parts) == 1 else _sp.hstack(parts, format="csc")
        return B.tocsc()[:, order]

    def _refactor(self):
        if self.engine == "lu":
            self._lu = _EtaLU(self._basis_csc())
            return
        B = self._cols(self.basis)
        try:
            Binv = np.linalg.inv(B)
        except np.linalg.LinAlgError as e:
            raise RuntimeError(f"revised simplex: singular basis ({e})")
        if not np.isfinite(Binv).all():
            raise RuntimeError("revised simplex: non-finite basis inverse")
        self.Binv = Binv

    def _ftran(self, v):
        """B^-1 @ v through the active engine."""
        if self.engine == "dense":
            return self.Binv @ v
        return self._lu.ftran(v)

    def _btran(self, v):
        """Compute v @ B^-1 through the active engine."""
        if self.engine == "dense":
            return v @ self.Binv
        return self._lu.btran(v)

    def _row(self, r):
        """Row r of B^-1 (the dual-simplex / drive-out pivot row)."""
        if self.engine == "dense":
            return self.Binv[r]
        e = np.zeros(self.m)
        e[r] = 1.0
        return self._lu.btran(e)

    def _rebuild_xN(self):
        """Recompute the nonbasic-value vector from scratch (status change)."""
        x = np.where(self.vstat == AT_UB, self.ubw, self.lbw)
        x[self.vstat == BASIC] = 0.0
        self.xN = x

    def _compute_xB(self):
        """Recompute basic values from self.xN (start of a run / refactor).

        Between refactorizations xB is maintained incrementally by the
        pivot/flip updates in primal()/dual().
        """
        rhs = self.b - self._Ax(self.xN[: self.n])
        art = self.xN[self.n:]
        if art.any():  # artificial nonbasic values are 0 outside phase 1
            rhs = rhs - self.art_sign * art
        self.xB = self._ftran(rhs)

    def _x_full(self):
        x = self.xN.copy()
        x[self.basis] = self.xB
        return x

    def _reduced_costs(self, cost):
        y = self._btran(cost[self.basis])
        d = np.empty(self.n + self.m)
        d[: self.n] = cost[: self.n] - self._ATy(y)
        d[self.n:] = cost[self.n:] - y * self.art_sign
        return d

    def _do_pivot(self, r, j, leave_to, w, xj_new=None):
        """Swap j into basis row r; leaving variable rests at ``leave_to``.

        ``xj_new`` is the entering variable's value (caller-computed from
        the ratio/dual step); the incremental xB must already reflect the
        step for all *other* basics — this only fixes up row r and xN.
        """
        leaving = self.basis[r]
        self.vstat[leaving] = leave_to
        self.vstat[j] = BASIC
        self.basis[r] = j
        self.xN[leaving] = self.ubw[leaving] if leave_to == AT_UB else self.lbw[leaving]
        if xj_new is None:
            xj_new = self.xN[j]  # degenerate drive-out: enters at its bound
        self.xN[j] = 0.0
        self.pivots += 1
        if self.pivots % self.refactor_every == 0 or abs(w[r]) < _PIV_MIN:
            self._refactor()
            self._compute_xB()  # reset incremental drift at each refactor
        else:
            if self.engine == "dense":
                prow = self.Binv[r] / w[r]
                self.Binv -= np.outer(w, prow)
                self.Binv[r] = prow
            else:
                self._lu.push(r, w)
            self.xB[r] = xj_new

    # -- pricing ------------------------------------------------------------
    def _price_window(self, idx, y, cost):
        """Reduced costs for the working columns ``idx`` given duals y."""
        out = np.empty(len(idx))
        struct = idx < self.n
        js = idx[struct]
        if self.A_sp is not None:
            out[struct] = self.A_sp[:, js].T @ y
        else:
            out[struct] = y @ self.A[:, js]
        rows = idx[~struct] - self.n
        out[~struct] = y[rows] * self.art_sign[rows]
        return cost[idx] - out

    def _price_partial(self, cost, movable):
        """Rotating-window partial pricing.

        Prices one window of columns per call, starting just past the last
        entering column; falls through to the next window when the current
        one has no eligible candidate.  A full rotation with no candidate
        anywhere is a Dantzig-complete optimality certificate (every
        window shares the same duals y).
        """
        y = self._btran(cost[self.basis])
        nt = self.n + self.m
        W = min(self._pp_w, nt)
        ptr = self._pp_ptr
        for _ in range(-(-nt // W) + 1):
            idx = np.arange(ptr, ptr + W) % nt
            d = self._price_window(idx, y, cost)
            st = self.vstat[idx]
            elig = movable[idx] & (
                ((st == AT_LB) & (d < -_EPS)) | ((st == AT_UB) & (d > _EPS))
            )
            hit = np.flatnonzero(elig)
            if hit.size:
                k = int(hit[np.argmax(np.abs(d[hit]))])
                j = int(idx[k])
                self._pp_ptr = (j + 1) % nt
                return j
            ptr = (ptr + W) % nt
        self._pp_ptr = ptr
        return None

    def _devex_update(self, r, j, w):
        """Devex reference-weight update for pivot (row r, entering j).

        Uses the pre-pivot factorization: alpha_row = (B^-1 A)_r over all
        working columns — one BTRAN plus one sparse A-transpose product,
        O(m + nnz) on the LU engine.
        """
        rv = self._row(r)
        arow = np.empty(self.n + self.m)
        arow[: self.n] = self._ATy(rv)
        arow[self.n:] = rv * self.art_sign
        arj = arow[j]
        if abs(arj) < _PIV_MIN:
            return
        g = self._gamma
        gq = float(g[j])
        np.maximum(g, (arow / arj) ** 2 * gq, out=g)
        g[self.basis[r]] = max(gq / (arj * arj), 1.0)

    # -- primal simplex -----------------------------------------------------
    def primal(self, cost) -> str:
        """Bounded-variable primal simplex from the current (feasible) basis.

        Returns "optimal" or "unbounded"; raises RuntimeError at the
        iteration cap.
        """
        bland = False
        stall = 0
        best_obj = np.inf
        movable = (self.ubw - self.lbw) > _EPS  # fixed vars can never enter
        self._compute_xB()
        if self.pricing == "devex":
            self._gamma = np.ones(self.n + self.m)
        for _ in range(self.max_iter):
            obj = float(cost[self.basis] @ self.xB + cost @ self.xN)
            if obj < best_obj - 1e-12:
                best_obj = obj
                stall = 0
                bland = False
            else:
                stall += 1
                if stall > 2 * self.m + 16:
                    bland = True  # Bland's rule: guaranteed termination
            if bland or self.pricing != "partial":
                d = self._reduced_costs(cost)
                elig = movable & (
                    ((self.vstat == AT_LB) & (d < -_EPS))
                    | ((self.vstat == AT_UB) & (d > _EPS))
                )
                cand = np.flatnonzero(elig)
                if cand.size == 0:
                    return "optimal"
                if bland:
                    j = int(cand[0])
                elif self.pricing == "devex":
                    j = int(cand[np.argmax(d[cand] ** 2 / self._gamma[cand])])
                else:
                    j = int(cand[np.argmax(np.abs(d[cand]))])
            else:
                j = self._price_partial(cost, movable)
                if j is None:
                    return "optimal"
            s = 1.0 if self.vstat[j] == AT_LB else -1.0  # x_j moves by s*t
            w = self._ftran(self._col(j))
            dxB = -s * w
            lbB = self.lbw[self.basis]
            ubB = self.ubw[self.basis]
            inc = dxB > _EPS
            dec = dxB < -_EPS
            with np.errstate(divide="ignore", invalid="ignore"):
                t_up = np.where(inc, (ubB - self.xB) / dxB, np.inf)
                t_lo = np.where(dec, (lbB - self.xB) / dxB, np.inf)
            t_up = np.where(np.isnan(t_up), np.inf, np.maximum(t_up, 0.0))
            t_lo = np.where(np.isnan(t_lo), np.inf, np.maximum(t_lo, 0.0))
            t_row = np.minimum(t_up, t_lo)
            rmin = float(t_row.min()) if t_row.size else np.inf
            t_flip = self.ubw[j] - self.lbw[j]
            if not np.isfinite(min(rmin, t_flip)):
                return "unbounded"
            if t_flip < rmin - 1e-12:
                # Bound flip: no basis change, the variable crosses to its
                # other bound (this is the move the dense oracle needs an
                # entire slack row to express).
                self.xB += dxB * t_flip
                self.vstat[j] = AT_UB if self.vstat[j] == AT_LB else AT_LB
                self.xN[j] = (
                    self.ubw[j] if self.vstat[j] == AT_UB else self.lbw[j]
                )
                continue
            rows = np.flatnonzero(t_row <= rmin + _EPS)
            if bland:
                r = int(rows[np.argmin(self.basis[rows])])
            else:
                r = int(rows[np.argmax(np.abs(dxB[rows]))])
            leave_to = AT_UB if t_up[r] <= t_lo[r] else AT_LB
            if self.pricing == "devex" and not bland:
                self._devex_update(r, j, w)
            xj_new = self.xN[j] + s * rmin
            self.xB += dxB * rmin
            self._do_pivot(r, j, leave_to, w, xj_new=xj_new)
        raise RuntimeError("revised simplex: iteration limit reached")

    # -- dual simplex -------------------------------------------------------
    def dual(self, cost) -> str:
        """Bounded-variable dual simplex from a dual-feasible basis.

        Drives primal bound violations of basic variables to zero while
        keeping reduced costs sign-feasible.  Returns "optimal" (primal
        feasible reached) or "infeasible" (dual unbounded); raises
        RuntimeError at the iteration cap.
        """
        stall = 0
        best_viol = np.inf
        movable = (self.ubw - self.lbw) > _EPS
        self._compute_xB()
        for _ in range(self.max_iter):
            lbB = self.lbw[self.basis]
            ubB = self.ubw[self.basis]
            viol_lo = lbB - self.xB
            viol_up = self.xB - ubB
            v = np.maximum(viol_lo, viol_up)
            vmax = float(v.max()) if v.size else 0.0
            if vmax <= _FEAS:
                return "optimal"
            if vmax < best_viol - 1e-12:
                best_viol = vmax
                stall = 0
            else:
                stall += 1
            bland = stall > 2 * self.m + 16
            if bland:
                bad = np.flatnonzero(v > _FEAS)
                r = int(bad[np.argmin(self.basis[bad])])
            else:
                r = int(np.argmax(v))
            below = viol_lo[r] > viol_up[r]
            rv = self._row(r)
            rho = np.empty(self.n + self.m)
            rho[: self.n] = self._ATy(rv)
            rho[self.n:] = rv * self.art_sign
            a = -rho if below else rho
            d = self._reduced_costs(cost)
            nb_lo = movable & (self.vstat == AT_LB) & (a > _EPS)
            nb_up = movable & (self.vstat == AT_UB) & (a < -_EPS)
            cand = np.flatnonzero(nb_lo | nb_up)
            if cand.size == 0:
                return "infeasible"  # dual unbounded
            ratios = d[cand] / a[cand]
            ratios = np.maximum(ratios, 0.0)  # clip tiny dual-degenerate noise
            rmin = ratios.min()
            ties = cand[np.flatnonzero(ratios <= rmin + _EPS)]
            if bland:
                j = int(ties[0])
            else:
                j = int(ties[np.argmax(np.abs(a[ties]))])
            w = self._ftran(self._col(j))
            bound_r = lbB[r] if below else ubB[r]
            delta = (self.xB[r] - bound_r) / w[r]
            xj_new = self.xN[j] + delta
            self.xB -= w * delta
            leave_to = AT_LB if below else AT_UB
            self._do_pivot(r, j, leave_to, w, xj_new=xj_new)
        raise RuntimeError("revised simplex: iteration limit reached")

    # -- phase 1 ------------------------------------------------------------
    def phase1(self) -> str:
        """Artificial-variable phase 1 from the all-artificial basis."""
        self._rebuild_xN()
        r0 = self.b - self._Ax(self.xN[: self.n])
        self.art_sign = np.where(r0 >= 0.0, 1.0, -1.0)
        self.basis = np.arange(self.n, self.n + self.m)
        self.vstat[self.basis] = BASIC
        self.xN[self.basis] = 0.0
        if self.engine == "dense":
            self.Binv = np.diag(self.art_sign)  # diag(s)^-1 == diag(s)
        else:
            self._refactor()
        self.ubw[self.n:] = np.inf  # artificials live during phase 1
        cost1 = np.zeros(self.n + self.m)
        cost1[self.n:] = 1.0
        self.primal(cost1)  # cannot be unbounded (objective >= 0)
        self._compute_xB()
        art_basic = self.basis >= self.n
        obj = float(self.xB[art_basic].sum()) if art_basic.any() else 0.0
        if obj > 1e-7:
            return "infeasible"
        # Drive remaining (degenerate, value-0) artificials out wherever a
        # structural column has a nonzero in their row; rows with no such
        # column are redundant and keep a pinned artificial at 0.
        for r in np.flatnonzero(self.basis >= self.n):
            row = self._ATy(self._row(r))
            free = (self.vstat[: self.n] != BASIC) & (np.abs(row) > 1e-7)
            jc = np.flatnonzero(free)
            if jc.size:
                j = int(jc[0])
                w = self._ftran(self._col(j))
                self._do_pivot(r, j, AT_LB, w)
        self.ubw[self.n:] = 0.0  # pin artificials for phase 2
        return "feasible"

    # -- warm start ---------------------------------------------------------
    def try_warm(self, warm: BasisState) -> str | None:
        """Install a prior basis and re-solve from it.

        Returns "optimal"/"unbounded" when the warm path concluded, None
        when the basis failed validation (caller falls back to cold start).
        Only the *shape* part of the key is checked: the fingerprint is a
        hint, and a same-shaped basis from different data (e.g. a Monitor
        refresh with new EMA times) is exactly the reuse we want — the
        refactorization, dual-feasibility forcing, and final primal polish
        below make any nonsingular basis a correct starting point.
        """
        if warm is None or tuple(warm.key[:2]) != (self.m, self.n):
            return None
        basis = np.asarray(warm.basis, dtype=np.int64)
        if (
            basis.shape != (self.m,)
            or basis.min(initial=0) < 0
            or basis.max(initial=0) >= self.n
            or np.unique(basis).size != self.m
        ):
            return None
        vstat = np.asarray(warm.vstat, dtype=np.int8).copy()
        if vstat.shape != (self.n,):
            return None
        vstat[basis] = BASIC
        # Nonbasic statuses must point at finite bounds.
        at_ub = vstat == AT_UB
        bad_ub = at_ub & ~np.isfinite(self.ubw[: self.n])
        vstat[bad_ub] = AT_LB
        at_lb = vstat == AT_LB
        if np.any(at_lb & ~np.isfinite(self.lbw[: self.n])):
            return None
        saved = (self.basis, self.vstat.copy(), self.Binv, self._lu)
        self.basis = basis
        self.vstat = np.concatenate(
            [vstat, np.full(self.m, AT_LB, dtype=np.int8)]
        )
        try:
            self._refactor()
            # Guard against a nearly-singular inherited basis.
            if self.engine == "dense":
                if np.abs(self.Binv).max() > 1e12:
                    raise RuntimeError("ill-conditioned warm basis")
            elif self._lu.ill_conditioned:
                raise RuntimeError("ill-conditioned warm basis")
            # Re-force dual feasibility against the *current* costs: a
            # nonbasic variable whose reduced cost has the wrong sign flips
            # to its other (finite) bound; if that bound is infinite the
            # warm basis is not dual-feasibilizable — cold start instead.
            d = self._reduced_costs(self.cost)[: self.n]
            nb = self.vstat[: self.n] != BASIC
            wrong_lb = nb & (self.vstat[: self.n] == AT_LB) & (d < -_EPS)
            wrong_ub = nb & (self.vstat[: self.n] == AT_UB) & (d > _EPS)
            if np.any(wrong_lb & ~np.isfinite(self.ubw[: self.n])):
                raise RuntimeError("dual infeasible warm basis (ub=inf)")
            if np.any(wrong_ub & ~np.isfinite(self.lbw[: self.n])):
                raise RuntimeError("dual infeasible warm basis (lb=-inf)")
            self.vstat[: self.n][wrong_lb] = AT_UB
            self.vstat[: self.n][wrong_ub] = AT_LB
            self._rebuild_xN()
            status = self.dual(self.cost)
            if status == "infeasible":
                # Dual unbounded == primal infeasible.  Don't trust a stale
                # basis with a verdict: restore and let the cold two-phase
                # path confirm infeasibility.
                raise RuntimeError("warm dual restart declared infeasible")
            # The dual ratio test tolerates tiny dual-degenerate noise; a
            # final primal polish certifies true optimality (it exits
            # immediately when the dual restart already converged).
            status = self.primal(self.cost)
        except (RuntimeError, ValueError, np.linalg.LinAlgError):
            # ValueError/LinAlgError: numerical breakdown on a pathological
            # inherited basis — same remedy as any other warm failure.
            self.basis, self.vstat, self.Binv, self._lu = saved
            self._rebuild_xN()
            # Don't charge the abandoned attempt's pivots to the cold solve
            # that follows (keeps LPResult.pivots meaning "pivots of the
            # path that produced the answer").
            self.pivots = 0
            return None
        return status

    def export_basis(self) -> BasisState | None:
        """Package the optimal basis as a warm-start token (None if artificial)."""
        if np.any(self.basis >= self.n):  # degenerate artificial left over
            return None
        return BasisState(
            key=self._ikey,
            basis=self.basis.copy(),
            vstat=self.vstat[: self.n].copy(),
        )


def solve_lp_revised(
    c,
    A_eq,
    b_eq,
    lb=None,
    ub=None,
    warm: BasisState | None = None,
    max_iter: int = 20000,
    pricing: str = "auto",
    engine: str = "auto",
) -> LPResult:
    """Minimize c@x s.t. A_eq@x=b_eq, lb<=x<=ub via revised simplex.

    ``warm`` is an opaque ``BasisState`` from a previous solve of a
    same-shaped instance; on acceptance the solve is a dual-simplex restart
    (typically a handful of pivots when only b or the bound floors moved).
    The returned ``LPResult.basis`` is the new token to thread forward.

    ``A_eq`` may be a ``scipy.sparse`` matrix — the LU engine prices
    through it directly, skipping the dense instance entirely (the Eq.-14
    LP at M=256 is ~2 MB sparse vs ~270 MB dense).  ``pricing`` selects
    the entering-variable rule ("auto"/"dantzig"/"partial"/"devex");
    ``engine`` the basis factorization ("auto"/"dense"/"lu").  The
    defaults preserve the historical bit-exact behavior on small
    instances and switch to sparse-LU + partial pricing above
    ``_LU_MIN_ROWS``.
    """
    c = np.asarray(c, dtype=np.float64)
    if _is_sparse(A_eq):
        A = A_eq
    else:
        A = np.asarray(A_eq, dtype=np.float64)
    b = np.asarray(b_eq, dtype=np.float64)
    n = c.shape[0]
    lb = np.zeros(n) if lb is None else np.asarray(lb, dtype=np.float64).copy()
    ub = np.full(n, np.inf) if ub is None else np.asarray(ub, dtype=np.float64).copy()
    if np.any(lb > ub + _EPS):
        return LPResult(None, np.inf, "infeasible")

    S = _Simplex(c, A, b, lb, ub, max_iter=max_iter,
                 pricing=pricing, engine=engine)
    warm_status = S.try_warm(warm) if warm is not None else None
    if warm_status == "unbounded":
        return LPResult(None, -np.inf, "unbounded",
                        pivots=S.pivots, warm_used=True)
    if warm_status == "optimal":
        x = S._x_full()[:n]
        return LPResult(
            x, float(c @ x), "optimal",
            basis=S.export_basis(), pivots=S.pivots, warm_used=True,
        )

    if S.phase1() == "infeasible":
        return LPResult(
            None, np.inf, "infeasible",
            basis=None, pivots=S.pivots, warm_used=False,
        )
    status = S.primal(S.cost)
    if status == "unbounded":
        return LPResult(None, -np.inf, "unbounded", pivots=S.pivots)
    x = S._x_full()[:n]
    return LPResult(
        x, float(c @ x), "optimal",
        basis=S.export_basis(), pivots=S.pivots, warm_used=False,
    )
