"""Bounded-variable revised simplex with warm starts.

Solves   min  c @ x
         s.t. A @ x == b          (m equality rows only)
              lb <= x <= ub       (ub may be +inf; lb must be finite)

Design (ISSUE 4 tentpole; DESIGN.md §13):

* **Implicit bounds.**  Upper bounds never become rows.  Every nonbasic
  variable rests at one of its bounds (``AT_LB``/``AT_UB``); a simplex step
  either pivots or merely *flips* a variable between its bounds.  The basis
  is therefore always m x m — for the Eq.-14 policy LP that is 2M x 2M
  instead of the dense oracle's O(M^2) x O(M^2) tableau.
* **Product-form inverse.**  ``Binv`` is maintained by elementary eta
  updates (O(m^2) per pivot) and refactorized from scratch every
  ``refactor_every`` pivots (or whenever an eta pivot element is too small)
  to bound drift.
* **Anti-cycling.**  Dantzig pricing (most-negative reduced cost) for
  speed, with an automatic switch to Bland's rule after a stretch of
  iterations without objective progress; Bland guarantees termination, the
  iteration cap (``RuntimeError``, same contract as the dense oracle) is
  the backstop.
* **Warm starts.**  ``solve_lp_revised(..., warm=basis)`` accepts the
  ``BasisState`` returned by a previous solve.  The basis is refactorized
  against the *current* A (nonsingularity checked), nonbasic statuses are
  re-forced dual feasible against the *current* costs, and a
  bounded-variable **dual simplex** drives out any primal infeasibility
  introduced by changed ``b`` (the t_bar grid) or changed bound floors
  (the rho grid).  A warm basis is a hint, never a correctness input: any
  validation failure falls back to a cold start.

Cold starts run the textbook artificial-variable phase 1 (signed unit
columns, so the initial basis is a diagonal) followed by primal phase 2.
"""

from __future__ import annotations

import numpy as np

from repro.solver.result import BasisState, LPResult

_EPS = 1e-9      # reduced-cost / pivot-eligibility tolerance
_FEAS = 1e-8     # primal feasibility tolerance on basic variables
_PIV_MIN = 1e-10  # smallest acceptable eta pivot before forcing refactor

AT_LB, AT_UB, BASIC = 0, 1, 2


def instance_key(A: np.ndarray) -> tuple:
    """Cheap fingerprint used to match a BasisState to an instance shape.

    Only the (m, n) prefix gates warm-start acceptance (see ``try_warm``);
    the sums are a debugging aid, O(n) so they stay off the hot path.
    """
    m, n = A.shape
    return (m, n, float(A[0].sum()), float(A[-1].sum()))


class _Simplex:
    """One solve on one instance.  Not reusable across instances."""

    def __init__(self, c, A, b, lb, ub, max_iter=20000, refactor_every=64):
        self.m, self.n = A.shape
        m, n = self.m, self.n
        # Working arrays cover structural columns [0, n) plus one artificial
        # column per row at [n, n+m) (signed unit vectors; bounds pinned to
        # [0, 0] outside phase 1 so they can never re-enter).
        self.A = A
        self.b = b
        self.art_sign = np.ones(m)
        self.cost = np.concatenate([c, np.zeros(m)])
        self.lbw = np.concatenate([lb, np.zeros(m)])
        self.ubw = np.concatenate([ub, np.zeros(m)])
        self.vstat = np.full(n + m, AT_LB, dtype=np.int8)
        # Nonbasic variables with no finite lower bound rest at their upper
        # bound; both-infinite (free) variables are unsupported, matching
        # the dense oracle (whose lb-shift also requires finite lb).
        no_lb = ~np.isfinite(self.lbw[:n])
        if np.any(no_lb & ~np.isfinite(self.ubw[:n])):
            raise ValueError("free variables (lb and ub infinite) unsupported")
        self.vstat[:n][no_lb] = AT_UB
        self.basis = np.arange(n, n + m)
        self.Binv = np.eye(m)
        self.xB = np.zeros(m)
        self.xN = np.zeros(n + m)  # nonbasic bound values; basic entries 0
        self._rebuild_xN()
        self.pivots = 0
        self.max_iter = max_iter
        self.refactor_every = refactor_every

    # -- columns / factorization -------------------------------------------
    def _col(self, j):
        if j < self.n:
            return self.A[:, j]
        e = np.zeros(self.m)
        e[j - self.n] = self.art_sign[j - self.n]
        return e

    def _cols(self, idx):
        """Dense (m, len(idx)) matrix of working columns."""
        idx = np.asarray(idx)
        out = np.zeros((self.m, len(idx)))
        struct = idx < self.n
        out[:, struct] = self.A[:, idx[struct]]
        art = np.flatnonzero(~struct)
        rows = idx[art] - self.n
        out[rows, art] = self.art_sign[rows]
        return out

    def _refactor(self):
        B = self._cols(self.basis)
        try:
            Binv = np.linalg.inv(B)
        except np.linalg.LinAlgError as e:
            raise RuntimeError(f"revised simplex: singular basis ({e})")
        if not np.isfinite(Binv).all():
            raise RuntimeError("revised simplex: non-finite basis inverse")
        self.Binv = Binv

    def _rebuild_xN(self):
        """Recompute the nonbasic-value vector from scratch (status change)."""
        x = np.where(self.vstat == AT_UB, self.ubw, self.lbw)
        x[self.vstat == BASIC] = 0.0
        self.xN = x

    def _compute_xB(self):
        """Recompute basic values from self.xN (start of a run / refactor);
        between refactorizations xB is maintained incrementally by the
        pivot/flip updates in primal()/dual()."""
        rhs = self.b - self.A @ self.xN[: self.n]
        art = self.xN[self.n:]
        if art.any():  # artificial nonbasic values are 0 outside phase 1
            rhs = rhs - self.art_sign * art
        self.xB = self.Binv @ rhs

    def _x_full(self):
        x = self.xN.copy()
        x[self.basis] = self.xB
        return x

    def _reduced_costs(self, cost):
        y = cost[self.basis] @ self.Binv
        d = np.empty(self.n + self.m)
        d[: self.n] = cost[: self.n] - y @ self.A
        d[self.n:] = cost[self.n:] - y * self.art_sign
        return d

    def _do_pivot(self, r, j, leave_to, w, xj_new=None):
        """Swap j into basis row r; leaving variable rests at ``leave_to``.

        ``xj_new`` is the entering variable's value (caller-computed from
        the ratio/dual step); the incremental xB must already reflect the
        step for all *other* basics — this only fixes up row r and xN.
        """
        leaving = self.basis[r]
        self.vstat[leaving] = leave_to
        self.vstat[j] = BASIC
        self.basis[r] = j
        self.xN[leaving] = self.ubw[leaving] if leave_to == AT_UB else self.lbw[leaving]
        if xj_new is None:
            xj_new = self.xN[j]  # degenerate drive-out: enters at its bound
        self.xN[j] = 0.0
        self.pivots += 1
        if self.pivots % self.refactor_every == 0 or abs(w[r]) < _PIV_MIN:
            self._refactor()
            self._compute_xB()  # reset incremental drift at each refactor
        else:
            prow = self.Binv[r] / w[r]
            self.Binv -= np.outer(w, prow)
            self.Binv[r] = prow
            self.xB[r] = xj_new

    # -- primal simplex -----------------------------------------------------
    def primal(self, cost) -> str:
        """Bounded-variable primal simplex from the current (feasible) basis.

        Returns "optimal" or "unbounded"; raises RuntimeError at the
        iteration cap.
        """
        bland = False
        stall = 0
        best_obj = np.inf
        movable = (self.ubw - self.lbw) > _EPS  # fixed vars can never enter
        self._compute_xB()
        for _ in range(self.max_iter):
            obj = float(cost[self.basis] @ self.xB + cost @ self.xN)
            if obj < best_obj - 1e-12:
                best_obj = obj
                stall = 0
                bland = False
            else:
                stall += 1
                if stall > 2 * self.m + 16:
                    bland = True  # Bland's rule: guaranteed termination
            d = self._reduced_costs(cost)
            elig = movable & (
                ((self.vstat == AT_LB) & (d < -_EPS))
                | ((self.vstat == AT_UB) & (d > _EPS))
            )
            cand = np.flatnonzero(elig)
            if cand.size == 0:
                return "optimal"
            if bland:
                j = int(cand[0])
            else:
                j = int(cand[np.argmax(np.abs(d[cand]))])
            s = 1.0 if self.vstat[j] == AT_LB else -1.0  # x_j moves by s*t
            w = self.Binv @ self._col(j)
            dxB = -s * w
            lbB = self.lbw[self.basis]
            ubB = self.ubw[self.basis]
            inc = dxB > _EPS
            dec = dxB < -_EPS
            with np.errstate(divide="ignore", invalid="ignore"):
                t_up = np.where(inc, (ubB - self.xB) / dxB, np.inf)
                t_lo = np.where(dec, (lbB - self.xB) / dxB, np.inf)
            t_up = np.where(np.isnan(t_up), np.inf, np.maximum(t_up, 0.0))
            t_lo = np.where(np.isnan(t_lo), np.inf, np.maximum(t_lo, 0.0))
            t_row = np.minimum(t_up, t_lo)
            rmin = float(t_row.min()) if t_row.size else np.inf
            t_flip = self.ubw[j] - self.lbw[j]
            if not np.isfinite(min(rmin, t_flip)):
                return "unbounded"
            if t_flip < rmin - 1e-12:
                # Bound flip: no basis change, the variable crosses to its
                # other bound (this is the move the dense oracle needs an
                # entire slack row to express).
                self.xB += dxB * t_flip
                self.vstat[j] = AT_UB if self.vstat[j] == AT_LB else AT_LB
                self.xN[j] = (
                    self.ubw[j] if self.vstat[j] == AT_UB else self.lbw[j]
                )
                continue
            rows = np.flatnonzero(t_row <= rmin + _EPS)
            if bland:
                r = int(rows[np.argmin(self.basis[rows])])
            else:
                r = int(rows[np.argmax(np.abs(dxB[rows]))])
            leave_to = AT_UB if t_up[r] <= t_lo[r] else AT_LB
            xj_new = self.xN[j] + s * rmin
            self.xB += dxB * rmin
            self._do_pivot(r, j, leave_to, w, xj_new=xj_new)
        raise RuntimeError("revised simplex: iteration limit reached")

    # -- dual simplex -------------------------------------------------------
    def dual(self, cost) -> str:
        """Bounded-variable dual simplex from a dual-feasible basis.

        Drives primal bound violations of basic variables to zero while
        keeping reduced costs sign-feasible.  Returns "optimal" (primal
        feasible reached) or "infeasible" (dual unbounded); raises
        RuntimeError at the iteration cap.
        """
        stall = 0
        best_viol = np.inf
        movable = (self.ubw - self.lbw) > _EPS
        self._compute_xB()
        for _ in range(self.max_iter):
            lbB = self.lbw[self.basis]
            ubB = self.ubw[self.basis]
            viol_lo = lbB - self.xB
            viol_up = self.xB - ubB
            v = np.maximum(viol_lo, viol_up)
            vmax = float(v.max()) if v.size else 0.0
            if vmax <= _FEAS:
                return "optimal"
            if vmax < best_viol - 1e-12:
                best_viol = vmax
                stall = 0
            else:
                stall += 1
            bland = stall > 2 * self.m + 16
            if bland:
                bad = np.flatnonzero(v > _FEAS)
                r = int(bad[np.argmin(self.basis[bad])])
            else:
                r = int(np.argmax(v))
            below = viol_lo[r] > viol_up[r]
            rho = np.empty(self.n + self.m)
            rho[: self.n] = self.Binv[r] @ self.A
            rho[self.n:] = self.Binv[r] * self.art_sign
            a = -rho if below else rho
            d = self._reduced_costs(cost)
            nb_lo = movable & (self.vstat == AT_LB) & (a > _EPS)
            nb_up = movable & (self.vstat == AT_UB) & (a < -_EPS)
            cand = np.flatnonzero(nb_lo | nb_up)
            if cand.size == 0:
                return "infeasible"  # dual unbounded
            ratios = d[cand] / a[cand]
            ratios = np.maximum(ratios, 0.0)  # clip tiny dual-degenerate noise
            rmin = ratios.min()
            ties = cand[np.flatnonzero(ratios <= rmin + _EPS)]
            if bland:
                j = int(ties[0])
            else:
                j = int(ties[np.argmax(np.abs(a[ties]))])
            w = self.Binv @ self._col(j)
            bound_r = lbB[r] if below else ubB[r]
            delta = (self.xB[r] - bound_r) / w[r]
            xj_new = self.xN[j] + delta
            self.xB -= w * delta
            leave_to = AT_LB if below else AT_UB
            self._do_pivot(r, j, leave_to, w, xj_new=xj_new)
        raise RuntimeError("revised simplex: iteration limit reached")

    # -- phase 1 ------------------------------------------------------------
    def phase1(self) -> str:
        """Artificial-variable phase 1 from the all-artificial basis."""
        self._rebuild_xN()
        r0 = self.b - self.A @ self.xN[: self.n]
        self.art_sign = np.where(r0 >= 0.0, 1.0, -1.0)
        self.basis = np.arange(self.n, self.n + self.m)
        self.vstat[self.basis] = BASIC
        self.xN[self.basis] = 0.0
        self.Binv = np.diag(self.art_sign)  # diag(s)^-1 == diag(s)
        self.ubw[self.n:] = np.inf  # artificials live during phase 1
        cost1 = np.zeros(self.n + self.m)
        cost1[self.n:] = 1.0
        self.primal(cost1)  # cannot be unbounded (objective >= 0)
        self._compute_xB()
        art_basic = self.basis >= self.n
        obj = float(self.xB[art_basic].sum()) if art_basic.any() else 0.0
        if obj > 1e-7:
            return "infeasible"
        # Drive remaining (degenerate, value-0) artificials out wherever a
        # structural column has a nonzero in their row; rows with no such
        # column are redundant and keep a pinned artificial at 0.
        for r in np.flatnonzero(self.basis >= self.n):
            row = self.Binv[r] @ self.A
            free = (self.vstat[: self.n] != BASIC) & (np.abs(row) > 1e-7)
            jc = np.flatnonzero(free)
            if jc.size:
                j = int(jc[0])
                w = self.Binv @ self._col(j)
                self._do_pivot(r, j, AT_LB, w)
        self.ubw[self.n:] = 0.0  # pin artificials for phase 2
        return "feasible"

    # -- warm start ---------------------------------------------------------
    def try_warm(self, warm: BasisState) -> str | None:
        """Install a prior basis and re-solve from it.

        Returns "optimal"/"unbounded" when the warm path concluded, None
        when the basis failed validation (caller falls back to cold start).
        Only the *shape* part of the key is checked: the fingerprint is a
        hint, and a same-shaped basis from different data (e.g. a Monitor
        refresh with new EMA times) is exactly the reuse we want — the
        refactorization, dual-feasibility forcing, and final primal polish
        below make any nonsingular basis a correct starting point.
        """
        if warm is None or tuple(warm.key[:2]) != (self.m, self.n):
            return None
        basis = np.asarray(warm.basis, dtype=np.int64)
        if (
            basis.shape != (self.m,)
            or basis.min(initial=0) < 0
            or basis.max(initial=0) >= self.n
            or np.unique(basis).size != self.m
        ):
            return None
        vstat = np.asarray(warm.vstat, dtype=np.int8).copy()
        if vstat.shape != (self.n,):
            return None
        vstat[basis] = BASIC
        # Nonbasic statuses must point at finite bounds.
        at_ub = vstat == AT_UB
        bad_ub = at_ub & ~np.isfinite(self.ubw[: self.n])
        vstat[bad_ub] = AT_LB
        at_lb = vstat == AT_LB
        if np.any(at_lb & ~np.isfinite(self.lbw[: self.n])):
            return None
        saved = (self.basis, self.vstat.copy(), self.Binv)
        self.basis = basis
        self.vstat = np.concatenate(
            [vstat, np.full(self.m, AT_LB, dtype=np.int8)]
        )
        try:
            self._refactor()
            # Guard against a nearly-singular inherited basis.
            if np.abs(self.Binv).max() > 1e12:
                raise RuntimeError("ill-conditioned warm basis")
            # Re-force dual feasibility against the *current* costs: a
            # nonbasic variable whose reduced cost has the wrong sign flips
            # to its other (finite) bound; if that bound is infinite the
            # warm basis is not dual-feasibilizable — cold start instead.
            d = self._reduced_costs(self.cost)[: self.n]
            nb = self.vstat[: self.n] != BASIC
            wrong_lb = nb & (self.vstat[: self.n] == AT_LB) & (d < -_EPS)
            wrong_ub = nb & (self.vstat[: self.n] == AT_UB) & (d > _EPS)
            if np.any(wrong_lb & ~np.isfinite(self.ubw[: self.n])):
                raise RuntimeError("dual infeasible warm basis (ub=inf)")
            if np.any(wrong_ub & ~np.isfinite(self.lbw[: self.n])):
                raise RuntimeError("dual infeasible warm basis (lb=-inf)")
            self.vstat[: self.n][wrong_lb] = AT_UB
            self.vstat[: self.n][wrong_ub] = AT_LB
            self._rebuild_xN()
            status = self.dual(self.cost)
            if status == "infeasible":
                # Dual unbounded == primal infeasible.  Don't trust a stale
                # basis with a verdict: restore and let the cold two-phase
                # path confirm infeasibility.
                raise RuntimeError("warm dual restart declared infeasible")
            # The dual ratio test tolerates tiny dual-degenerate noise; a
            # final primal polish certifies true optimality (it exits
            # immediately when the dual restart already converged).
            status = self.primal(self.cost)
        except (RuntimeError, ValueError, np.linalg.LinAlgError):
            # ValueError/LinAlgError: numerical breakdown on a pathological
            # inherited basis — same remedy as any other warm failure.
            self.basis, self.vstat, self.Binv = saved
            self._rebuild_xN()
            # Don't charge the abandoned attempt's pivots to the cold solve
            # that follows (keeps LPResult.pivots meaning "pivots of the
            # path that produced the answer").
            self.pivots = 0
            return None
        return status

    def export_basis(self) -> BasisState | None:
        if np.any(self.basis >= self.n):  # degenerate artificial left over
            return None
        return BasisState(
            key=instance_key(self.A),
            basis=self.basis.copy(),
            vstat=self.vstat[: self.n].copy(),
        )


def solve_lp_revised(
    c,
    A_eq,
    b_eq,
    lb=None,
    ub=None,
    warm: BasisState | None = None,
    max_iter: int = 20000,
) -> LPResult:
    """Minimize c@x s.t. A_eq@x=b_eq, lb<=x<=ub via revised simplex.

    ``warm`` is an opaque ``BasisState`` from a previous solve of a
    same-shaped instance; on acceptance the solve is a dual-simplex restart
    (typically a handful of pivots when only b or the bound floors moved).
    The returned ``LPResult.basis`` is the new token to thread forward.
    """
    c = np.asarray(c, dtype=np.float64)
    A = np.asarray(A_eq, dtype=np.float64)
    b = np.asarray(b_eq, dtype=np.float64)
    n = c.shape[0]
    lb = np.zeros(n) if lb is None else np.asarray(lb, dtype=np.float64).copy()
    ub = np.full(n, np.inf) if ub is None else np.asarray(ub, dtype=np.float64).copy()
    if np.any(lb > ub + _EPS):
        return LPResult(None, np.inf, "infeasible")

    S = _Simplex(c, A, b, lb, ub, max_iter=max_iter)
    warm_status = S.try_warm(warm) if warm is not None else None
    if warm_status == "unbounded":
        return LPResult(None, -np.inf, "unbounded",
                        pivots=S.pivots, warm_used=True)
    if warm_status == "optimal":
        x = S._x_full()[:n]
        return LPResult(
            x, float(c @ x), "optimal",
            basis=S.export_basis(), pivots=S.pivots, warm_used=True,
        )

    if S.phase1() == "infeasible":
        return LPResult(
            None, np.inf, "infeasible",
            basis=None, pivots=S.pivots, warm_used=False,
        )
    status = S.primal(S.cost)
    if status == "unbounded":
        return LPResult(None, -np.inf, "unbounded", pivots=S.pivots)
    x = S._x_full()[:n]
    return LPResult(
        x, float(c @ x), "optimal",
        basis=S.export_basis(), pivots=S.pivots, warm_used=False,
    )
