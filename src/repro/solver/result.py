"""Shared result/state types for the LP solver layer.

Kept in their own leaf module so both solver backends (`repro.solver.dense`,
`repro.solver.revised`) and the `repro.solver.lp` facade can import them
without cycles.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class LPResult:
    """Outcome of one LP solve: point, objective, status, warm-start extras."""

    x: np.ndarray | None
    fun: float
    status: str  # "optimal" | "infeasible" | "unbounded"
    # Revised-simplex extras (dense backend leaves the defaults):
    # ``basis`` is an opaque warm-start token (see BasisState) valid for the
    # next solve of a same-shaped instance; ``pivots`` counts simplex pivots
    # (bound flips excluded); ``warm_used`` records whether a caller-supplied
    # basis was accepted (vs silently falling back to a cold start).
    basis: "BasisState | None" = None
    pivots: int = 0
    warm_used: bool = False

    @property
    def ok(self) -> bool:
        """Whether the solve reached an optimal point."""
        return self.status == "optimal"


@dataclass
class BasisState:
    """Opaque warm-start token: an optimal basis + nonbasic bound statuses.

    ``key`` fingerprints the instance shape ((m, n) plus two cheap sums of
    A) so a stale token from a differently-shaped problem is rejected up
    front.  A token whose shape matches but whose A differs (fingerprint
    collisions are possible in principle) is still *safe*: the solver
    re-factorizes B from the current columns, re-forces dual feasibility
    against the current costs, and runs the dual simplex to optimality — a
    wrong-but-nonsingular basis only costs extra pivots, never correctness.
    """

    key: tuple
    basis: np.ndarray  # (m,) structural column indices forming B
    vstat: np.ndarray  # (n,) int8: 0 = nonbasic at lb, 1 = at ub, 2 = basic
    meta: dict = field(default_factory=dict)
