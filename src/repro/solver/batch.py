"""Lockstep batched bounded-variable simplex over same-layout instances.

``solve_lp_batch`` solves S instances that share one constraint matrix A
(and hence one variable layout) but differ in the right-hand side ``b``
and/or the bounds — exactly the shape of an Eq.-14 (rho, t_bar) grid
sweep, where ``b`` carries t_bar and the lower-bound floors carry rho.

All S instances advance in lockstep: one iteration prices every active
instance with a single (S, m) x (m, n) matmul, runs every ratio test as
one stacked reduction, and applies every eta update as one batched rank-1
— "price and ratio-test in one dispatch" instead of S sequential solver
runs.  Instances converge (or fail) independently: finished ones drop out
of the active set while the rest keep iterating.

The algorithm is the same bounded-variable two-phase simplex as
``repro.solver.revised`` (implicit bounds, bound flips, Dantzig pricing
with per-instance Bland fallback, periodic batched refactorization via
``np.linalg.inv`` on the (K, m, m) basis stack).  It is a cold-start
path: no warm bases in or out — the sweep's parallelism replaces the
serial sweep's dual-simplex restarts.  Numerics follow a different
summation order than the serial solver (batched GEMMs), so results agree
with the serial path to solver tolerance, not bit-for-bit; callers that
need bit-stable policies (the engine-parity suites) use the serial path.
"""

from __future__ import annotations

import numpy as np

from repro.solver.result import LPResult

_EPS = 1e-9
_FEAS = 1e-8
_PIV_MIN = 1e-10

AT_LB, AT_UB, BASIC = 0, 1, 2
# Per-instance terminal states.
RUN, OPT, INFEAS, UNB, LIMIT = 0, 1, 2, 3, 4
_STATUS = {OPT: "optimal", INFEAS: "infeasible", UNB: "unbounded",
           LIMIT: "iteration_limit"}


class _BatchSimplex:
    """One lockstep run over S same-layout instances."""

    def __init__(self, c, A, b, lb, ub, max_iter=20000, refactor_every=64):
        """Stack the S instances into lockstep arrays and init bound statuses."""
        self.S, self.m = b.shape
        self.n = c.shape[0]
        S, m, n = self.S, self.m, self.n
        self.A = A
        self.b = b
        self.cost = np.concatenate([c, np.zeros(m)])
        self.lbw = np.concatenate([lb, np.zeros((S, m))], axis=1)
        self.ubw = np.concatenate([ub, np.zeros((S, m))], axis=1)
        self.vstat = np.full((S, n + m), AT_LB, dtype=np.int8)
        no_lb = ~np.isfinite(self.lbw[:, :n])
        if np.any(no_lb & ~np.isfinite(self.ubw[:, :n])):
            raise ValueError("free variables (lb and ub infinite) unsupported")
        self.vstat[:, :n][no_lb] = AT_UB
        self.art_sign = np.ones((S, m))
        self.basis = np.tile(np.arange(n, n + m), (S, 1))
        self.Binv = np.tile(np.eye(m), (S, 1, 1))
        self.xB = np.zeros((S, m))
        self.xN = np.zeros((S, n + m))
        self.status = np.full(S, RUN, dtype=np.int8)
        self.pivots = np.zeros(S, dtype=np.int64)
        self.max_iter = max_iter
        self.refactor_every = refactor_every
        self._run = np.zeros(S, dtype=bool)  # active mask of current phase

    # -- shared helpers -----------------------------------------------------
    def _rebuild_xN(self, idx):
        x = np.where(self.vstat[idx] == AT_UB, self.ubw[idx], self.lbw[idx])
        x[self.vstat[idx] == BASIC] = 0.0
        self.xN[idx] = x

    def _compute_xB(self, idx):
        rhs = self.b[idx] - self.xN[idx, : self.n] @ self.A.T
        rhs = rhs - self.art_sign[idx] * self.xN[idx, self.n:]
        self.xB[idx] = np.einsum("kmn,kn->km", self.Binv[idx], rhs)

    def _basis_mats(self, idx):
        basisK = self.basis[idx]
        K, m, n = len(idx), self.m, self.n
        B = np.zeros((K, m, m))
        struct = basisK < n
        kk, cc = np.nonzero(struct)
        B[kk, :, cc] = self.A[:, basisK[kk, cc]].T
        ka, ca = np.nonzero(~struct)
        rows = basisK[ka, ca] - n
        B[ka, rows, ca] = self.art_sign[idx[ka], rows]
        return B

    def _refactor(self, idx):
        if idx.size == 0:
            return
        B = self._basis_mats(idx)
        try:
            Binv = np.linalg.inv(B)
        except np.linalg.LinAlgError:
            Binv = np.empty_like(B)
            for k in range(len(idx)):
                try:
                    Binv[k] = np.linalg.inv(B[k])
                except np.linalg.LinAlgError:
                    Binv[k] = np.nan
        ok = np.isfinite(Binv).all(axis=(1, 2))
        self.Binv[idx[ok]] = Binv[ok]
        dead = idx[~ok]  # numerical breakdown: give up on those instances
        self.status[dead] = LIMIT
        self._run[dead] = False

    def _work_cols(self, idx, j):
        """(K, m) dense working columns j (per instance)."""
        cols = np.zeros((len(idx), self.m))
        struct = j < self.n
        cols[struct] = self.A[:, j[struct]].T
        arti = np.flatnonzero(~struct)
        rows = j[arti] - self.n
        cols[arti, rows] = self.art_sign[idx[arti], rows]
        return cols

    def _do_pivot(self, pi, r, j, leave_to, w, xj_new):
        """Batched basis swap: instance pi[k] pivots column j[k] into row r[k]."""
        K = pi.size
        ar = np.arange(K)
        leaving = self.basis[pi, r]
        self.vstat[pi, leaving] = leave_to
        self.vstat[pi, j] = BASIC
        self.basis[pi, r] = j
        self.xN[pi, leaving] = np.where(
            leave_to == AT_UB, self.ubw[pi, leaving], self.lbw[pi, leaving]
        )
        self.xN[pi, j] = 0.0
        self.pivots[pi] += 1
        wr = w[ar, r]
        need_rf = (self.pivots[pi] % self.refactor_every == 0) | (
            np.abs(wr) < _PIV_MIN
        )
        upd = np.flatnonzero(~need_rf)
        if upd.size:
            u, ru = pi[upd], r[upd]
            prow = self.Binv[u, ru] / wr[upd][:, None]
            self.Binv[u] -= w[upd][:, :, None] * prow[:, None, :]
            self.Binv[u, ru] = prow
            self.xB[u, ru] = xj_new[upd]
        rf = np.flatnonzero(need_rf)
        if rf.size:
            self._refactor(pi[rf])
            alive = pi[rf][self._run[pi[rf]]]
            self._compute_xB(alive)

    # -- primal simplex (lockstep) ------------------------------------------
    def _primal(self, cost):
        """Advance every ``self._run`` instance to phase optimality.

        Clears ``self._run`` as instances finish; terminal failures
        (unbounded / iteration cap / breakdown) also set ``self.status``.
        """
        S = self.S
        bland = np.zeros(S, dtype=bool)
        stall = np.zeros(S, dtype=np.int64)
        best = np.full(S, np.inf)
        movable = (self.ubw - self.lbw) > _EPS
        self._compute_xB(np.flatnonzero(self._run))
        for _ in range(self.max_iter):
            idx = np.flatnonzero(self._run)
            if idx.size == 0:
                return
            costB = cost[self.basis[idx]]
            obj = np.einsum("km,km->k", costB, self.xB[idx]) + self.xN[idx] @ cost
            better = obj < best[idx] - 1e-12
            best[idx] = np.where(better, obj, best[idx])
            new_stall = np.where(better, 0, stall[idx] + 1)
            stall[idx] = new_stall
            bland[idx] = np.where(
                better, False, bland[idx] | (new_stall > 2 * self.m + 16)
            )
            # Pricing: one stacked GEMM covers every active instance.
            y = np.einsum("km,kmn->kn", costB, self.Binv[idx])
            d = np.empty((idx.size, self.n + self.m))
            d[:, : self.n] = cost[: self.n] - y @ self.A
            d[:, self.n:] = cost[self.n:] - y * self.art_sign[idx]
            st = self.vstat[idx]
            elig = movable[idx] & (
                ((st == AT_LB) & (d < -_EPS)) | ((st == AT_UB) & (d > _EPS))
            )
            has = elig.any(axis=1)
            self._run[idx[~has]] = False  # phase optimal
            if not has.any():
                continue
            idx, d, elig = idx[has], d[has], elig[has]
            j = np.argmax(np.where(elig, np.abs(d), -1.0), axis=1)
            j = np.where(bland[idx], np.argmax(elig, axis=1), j)
            K = idx.size
            ar = np.arange(K)
            sdir = np.where(self.vstat[idx, j] == AT_LB, 1.0, -1.0)
            w = np.einsum(
                "kmn,kn->km", self.Binv[idx], self._work_cols(idx, j)
            )
            dxB = -sdir[:, None] * w
            lbB = np.take_along_axis(self.lbw[idx], self.basis[idx], axis=1)
            ubB = np.take_along_axis(self.ubw[idx], self.basis[idx], axis=1)
            xB = self.xB[idx]
            inc = dxB > _EPS
            dec = dxB < -_EPS
            with np.errstate(divide="ignore", invalid="ignore"):
                t_up = np.where(inc, (ubB - xB) / dxB, np.inf)
                t_lo = np.where(dec, (lbB - xB) / dxB, np.inf)
            t_up = np.where(np.isnan(t_up), np.inf, np.maximum(t_up, 0.0))
            t_lo = np.where(np.isnan(t_lo), np.inf, np.maximum(t_lo, 0.0))
            t_row = np.minimum(t_up, t_lo)
            rmin = t_row.min(axis=1)
            t_flip = self.ubw[idx, j] - self.lbw[idx, j]
            unb = ~np.isfinite(np.minimum(rmin, t_flip))
            if unb.any():
                u = idx[unb]
                self.status[u] = UNB
                self._run[u] = False
            flip = ~unb & (t_flip < rmin - 1e-12)
            if flip.any():
                f = np.flatnonzero(flip)
                fi, jf = idx[f], j[f]
                self.xB[fi] += dxB[f] * t_flip[f, None]
                new = np.where(
                    self.vstat[fi, jf] == AT_LB, AT_UB, AT_LB
                ).astype(np.int8)
                self.vstat[fi, jf] = new
                self.xN[fi, jf] = np.where(
                    new == AT_UB, self.ubw[fi, jf], self.lbw[fi, jf]
                )
            piv = ~unb & ~flip
            if piv.any():
                p = np.flatnonzero(piv)
                pi = idx[p]
                cand = t_row[p] <= (rmin[p] + _EPS)[:, None]
                r = np.argmax(np.where(cand, np.abs(dxB[p]), -1.0), axis=1)
                rb = np.argmax(
                    np.where(cand, -self.basis[pi].astype(float), -np.inf),
                    axis=1,
                )
                r = np.where(bland[pi], rb, r)
                pr = np.arange(p.size)
                leave_to = np.where(
                    t_up[p, r] <= t_lo[p, r], AT_UB, AT_LB
                ).astype(np.int8)[pr]
                xj_new = self.xN[pi, j[p]] + sdir[p] * rmin[p]
                self.xB[pi] += dxB[p] * rmin[p][:, None]
                self._do_pivot(pi, r, j[p], leave_to, w[p], xj_new)
        left = np.flatnonzero(self._run)
        self.status[left] = LIMIT
        self._run[left] = False

    # -- two-phase driver ---------------------------------------------------
    def solve(self):
        """Run phase 1 then phase 2 to completion on every live instance."""
        S, m, n = self.S, self.m, self.n
        live = self.status == RUN
        idx = np.flatnonzero(live)
        self._rebuild_xN(idx)
        r0 = self.b[idx] - self.xN[idx, : n] @ self.A.T
        self.art_sign[idx] = np.where(r0 >= 0.0, 1.0, -1.0)
        self.basis[idx] = np.arange(n, n + m)
        self.vstat[idx, n:] = BASIC
        self.xN[idx, n:] = 0.0
        self.Binv[idx] = np.eye(m) * self.art_sign[idx][:, :, None]
        self.ubw[idx, n:] = np.inf  # artificials live during phase 1
        cost1 = np.zeros(n + m)
        cost1[n:] = 1.0
        self._run = live.copy()
        self._primal(cost1)
        idx = np.flatnonzero(self.status == RUN)
        self._compute_xB(idx)
        art_obj = np.where(self.basis[idx] >= n, self.xB[idx], 0.0).sum(axis=1)
        bad = idx[art_obj > 1e-7]
        self.status[bad] = INFEAS
        # Drive leftover degenerate artificials out per instance (rarely
        # more than a handful of rows — not worth stacking).
        for s in np.flatnonzero(self.status == RUN):
            for r in np.flatnonzero(self.basis[s] >= n):
                row = self.Binv[s, r] @ self.A
                free = (self.vstat[s, :n] != BASIC) & (np.abs(row) > 1e-7)
                jc = np.flatnonzero(free)
                if jc.size:
                    jj = int(jc[0])
                    w = self.Binv[s] @ self._work_cols(
                        np.array([s]), np.array([jj])
                    )[0]
                    self._run[s] = True  # _do_pivot may refactor; keep alive
                    self._do_pivot(
                        np.array([s]), np.array([r]), np.array([jj]),
                        np.array([AT_LB], dtype=np.int8), w[None, :],
                        np.array([self.xN[s, jj]]),
                    )
        self.ubw[:, n:] = 0.0  # pin artificials for phase 2
        self._run = self.status == RUN
        self._primal(self.cost)
        self.status[self.status == RUN] = OPT


def solve_lp_batch(
    c,
    A,
    b_stack,
    lb_stack=None,
    ub_stack=None,
    max_iter: int = 20000,
) -> list[LPResult]:
    """Solve S instances min c@x s.t. A@x=b_s, lb_s<=x<=ub_s in lockstep.

    ``c`` (n,) and ``A`` (m, n) are shared; ``b_stack`` is (S, m);
    ``lb_stack``/``ub_stack`` broadcast from (n,) to (S, n).  Returns one
    ``LPResult`` per instance (no warm-basis export — the batched path is
    cold-start by design).  A sparse ``A`` is densified: the batched
    GEMMs want contiguous storage.
    """
    c = np.asarray(c, dtype=np.float64)
    if hasattr(A, "toarray") and not isinstance(A, np.ndarray):
        A = A.toarray()
    A = np.asarray(A, dtype=np.float64)
    b = np.atleast_2d(np.asarray(b_stack, dtype=np.float64))
    S = b.shape[0]
    n = c.shape[0]
    lb = np.zeros(n) if lb_stack is None else np.asarray(lb_stack, np.float64)
    ub = (
        np.full(n, np.inf) if ub_stack is None
        else np.asarray(ub_stack, np.float64)
    )
    lb = np.broadcast_to(lb, (S, n)).copy()
    ub = np.broadcast_to(ub, (S, n)).copy()

    solver = _BatchSimplex(c, A, b, lb, ub, max_iter=max_iter)
    solver.status[(lb > ub + _EPS).any(axis=1)] = INFEAS
    solver.solve()

    out = []
    for s in range(S):
        st = _STATUS[int(solver.status[s])]
        piv = int(solver.pivots[s])
        if st != "optimal":
            fun = -np.inf if st == "unbounded" else np.inf
            out.append(LPResult(None, fun, st, pivots=piv))
            continue
        x = solver.xN[s].copy()
        x[solver.basis[s]] = solver.xB[s]
        x = x[:n]
        out.append(LPResult(x, float(c @ x), "optimal", pivots=piv))
    return out
