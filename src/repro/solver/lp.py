"""LP solver facade.

Solves   min  c @ x
         s.t. A_eq @ x == b_eq
              lb <= x <= ub        (ub may be +inf)

Two backends live behind ``solve_lp``:

* ``"revised"`` (default) — the bounded-variable revised simplex in
  ``repro.solver.revised``: no tableau, no ub-slack rows (bounds are
  implicit in the nonbasic-at-bound statuses), an m x m product-form basis
  inverse with periodic refactorization, and a warm-start protocol
  (``warm=``/``LPResult.basis``) that turns the Algorithm-3 (rho, t_bar)
  grid sweep into dual-simplex restarts.  This is what makes M=128 policy
  generation cheap (see DESIGN.md §13).
* ``"dense"`` — the original two-phase tableau simplex, kept verbatim in
  ``repro.solver.dense`` as the differential-testing oracle (the role the
  reference event loop plays for the batched engine) and as an escape
  hatch.

``lp_method("dense")`` switches the process-wide default inside a ``with``
block — that is how the differential tests and the policy benchmark drive
the whole Algorithm-3 stack through the oracle.
"""

from __future__ import annotations

from contextlib import contextmanager

from repro.solver.dense import solve_lp_dense
from repro.solver.result import BasisState, LPResult
from repro.solver.revised import solve_lp_revised

__all__ = [
    "BasisState",
    "LPResult",
    "lp_method",
    "solve_lp",
    "solve_lp_dense",
    "solve_lp_revised",
]

_DEFAULT_METHOD = "revised"


@contextmanager
def lp_method(name: str):
    """Temporarily switch the default ``solve_lp`` backend ("revised"/"dense")."""
    global _DEFAULT_METHOD
    if name not in ("revised", "dense"):
        raise ValueError(f"unknown LP method {name!r}")
    old, _DEFAULT_METHOD = _DEFAULT_METHOD, name
    try:
        yield
    finally:
        _DEFAULT_METHOD = old


def default_method() -> str:
    return _DEFAULT_METHOD


def solve_lp(
    c,
    A_eq,
    b_eq,
    lb=None,
    ub=None,
    warm: BasisState | None = None,
    method: str | None = None,
) -> LPResult:
    """Minimize c@x subject to A_eq@x=b_eq, lb<=x<=ub (elementwise).

    ``warm`` threads a ``BasisState`` from a prior solve into the revised
    backend (ignored by the dense oracle); the result's ``.basis`` is the
    token to pass to the next same-shaped solve.
    """
    method = method or _DEFAULT_METHOD
    if method == "dense":
        return solve_lp_dense(c, A_eq, b_eq, lb=lb, ub=ub)
    if method == "revised":
        return solve_lp_revised(c, A_eq, b_eq, lb=lb, ub=ub, warm=warm)
    raise ValueError(f"unknown LP method {method!r}")
