"""LP solver facade.

Solves   min  c @ x
         s.t. A_eq @ x == b_eq
              lb <= x <= ub        (ub may be +inf)

Two backends live behind ``solve_lp``:

* ``"revised"`` (default) — the bounded-variable revised simplex in
  ``repro.solver.revised``: no tableau, no ub-slack rows (bounds are
  implicit in the nonbasic-at-bound statuses), an m x m basis
  factorization (dense product-form on small instances, sparse-LU + eta
  file above ``_LU_MIN_ROWS``) with periodic refactorization, selectable
  pricing (Dantzig / partial / Devex), and a warm-start protocol
  (``warm=``/``LPResult.basis``) that turns the Algorithm-3 (rho, t_bar)
  grid sweep into dual-simplex restarts.  This is what makes M=128+
  policy generation cheap (see DESIGN.md §13/§17).
* ``"dense"`` — the original two-phase tableau simplex, kept verbatim in
  ``repro.solver.dense`` as the differential-testing oracle (the role the
  reference event loop plays for the batched engine) and as an escape
  hatch.

``lp_method("dense")`` switches the process-wide default inside a ``with``
block — that is how the differential tests and the policy benchmark drive
the whole Algorithm-3 stack through the oracle.  ``lp_pricing("dantzig")``
does the same for the revised backend's pricing rule — that is how the
serve benchmark measures the Dantzig pivot baseline at M >= 128 without
threading a parameter through Algorithm 3.
"""

from __future__ import annotations

from contextlib import contextmanager

import numpy as np

from repro.solver.dense import solve_lp_dense
from repro.solver.result import BasisState, LPResult
from repro.solver.revised import PRICING_RULES, solve_lp_revised

__all__ = [
    "BasisState",
    "LPResult",
    "lp_method",
    "lp_pricing",
    "solve_lp",
    "solve_lp_dense",
    "solve_lp_revised",
]

_DEFAULT_METHOD = "revised"
_DEFAULT_PRICING = "auto"


@contextmanager
def lp_method(name: str):
    """Temporarily switch the default ``solve_lp`` backend ("revised"/"dense")."""
    global _DEFAULT_METHOD
    if name not in ("revised", "dense"):
        raise ValueError(f"unknown LP method {name!r}")
    old, _DEFAULT_METHOD = _DEFAULT_METHOD, name
    try:
        yield
    finally:
        _DEFAULT_METHOD = old


@contextmanager
def lp_pricing(name: str):
    """Temporarily pin the revised backend's pricing rule.

    "auto" (default) prices small instances with Dantzig (bit-identical to
    the historical solver) and large ones with a partial rotating window;
    "dantzig"/"partial"/"devex" force one rule at every size — benchmarks
    use this to compare pivot counts across rules on the same instance
    stream.
    """
    global _DEFAULT_PRICING
    if name not in PRICING_RULES:
        raise ValueError(f"unknown LP pricing rule {name!r}")
    old, _DEFAULT_PRICING = _DEFAULT_PRICING, name
    try:
        yield
    finally:
        _DEFAULT_PRICING = old


def default_method() -> str:
    """Name of the backend ``solve_lp`` uses when ``method`` is not given."""
    return _DEFAULT_METHOD


def default_pricing() -> str:
    """Name of the pricing rule ``solve_lp`` uses when ``pricing`` is not given."""
    return _DEFAULT_PRICING


def solve_lp(
    c,
    A_eq,
    b_eq,
    lb=None,
    ub=None,
    warm: BasisState | None = None,
    method: str | None = None,
    pricing: str | None = None,
) -> LPResult:
    """Minimize c@x subject to A_eq@x=b_eq, lb<=x<=ub (elementwise).

    ``warm`` threads a ``BasisState`` from a prior solve into the revised
    backend (ignored by the dense oracle); the result's ``.basis`` is the
    token to pass to the next same-shaped solve.  ``A_eq`` may be a
    ``scipy.sparse`` matrix (densified for the dense oracle).
    """
    method = method or _DEFAULT_METHOD
    if method == "dense":
        if hasattr(A_eq, "toarray") and not isinstance(A_eq, np.ndarray):
            A_eq = A_eq.toarray()
        return solve_lp_dense(c, A_eq, b_eq, lb=lb, ub=ub)
    if method == "revised":
        return solve_lp_revised(
            c, A_eq, b_eq, lb=lb, ub=ub, warm=warm,
            pricing=pricing or _DEFAULT_PRICING,
        )
    raise ValueError(f"unknown LP method {method!r}")
