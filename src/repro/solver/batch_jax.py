"""Lockstep batched bounded-variable simplex as jitted jax device code.

``solve_lp_batch_jax`` is the device twin of
``repro.solver.batch.solve_lp_batch``: S same-layout instances (shared
``c``/``A``, per-instance ``b`` and bounds — the Eq.-14 (rho, t_bar)
grid shape) advance in lockstep, but here the whole two-phase simplex is
one jitted program: a ``lax.while_loop`` whose body prices every
instance with a stacked GEMM, runs every ratio test as a stacked
reduction, and applies every basis update as a batched rank-1 — with
**masked per-instance termination** (finished instances keep iterating
as no-ops under a ``run`` mask instead of leaving the dispatch) and
FTRAN/BTRAN as batched einsums over the (S, m, m) inverse stack.

The pivot rules mirror ``solver.batch`` exactly — Dantzig pricing with
per-instance Bland fallback, bound flips, largest-|pivot| ratio-test
tie-breaking, periodic batched refactorization (``jnp.linalg.inv`` over
the basis stack, selected per instance) — so the two backends follow
the same pivot path up to floating-point reduction order.  Like the
numpy path it is cold-start by design (no warm bases in or out), and it
agrees with the serial solver to solver tolerance, not bit-for-bit:
callers that need bit-stable policies keep the serial path.

Everything runs in float64 under a local ``enable_x64`` scope — the
simplex is not a float32 algorithm — so importing this module never
flips global jax precision for the rest of the process.
"""

from __future__ import annotations

import numpy as np

from repro.solver.batch import (
    _EPS,
    _PIV_MIN,
    _STATUS,
    AT_LB,
    AT_UB,
    BASIC,
    INFEAS,
    LIMIT,
    OPT,
    RUN,
    UNB,
)
from repro.solver.result import LPResult

_SOLVE_CACHE: dict = {}


def _get_solver(max_iter: int, refactor_every: int):
    """Build (and cache) the jitted two-phase driver for the given caps."""
    key = (max_iter, refactor_every)
    if key in _SOLVE_CACHE:
        return _SOLVE_CACHE[key]

    import jax
    import jax.numpy as jnp
    from jax import lax

    def compute_xB(Binv, b, xN, art_sign, A):
        """Basic values B^-1 (b - N xN) for the whole stack."""
        n = A.shape[1]
        rhs = b - xN[:, :n] @ A.T - art_sign * xN[:, n:]
        return jnp.einsum("kmn,kn->km", Binv, rhs)

    def basis_mats(basis, art_sign, A):
        """Stacked (S, m, m) basis matrices rebuilt from column indices."""
        m, n = A.shape
        struct = basis < n
        gath = A.T[jnp.clip(basis, 0, n - 1)]  # (S, m_col, m_row)
        rows = jnp.clip(basis - n, 0, m - 1)
        sign = jnp.take_along_axis(art_sign, rows, axis=1)
        art = (jnp.arange(m)[None, None, :] == rows[:, :, None]) * sign[
            :, :, None
        ]
        cols = jnp.where(struct[:, :, None], gath, art)
        return jnp.swapaxes(cols, 1, 2)  # (S, row, col)

    def work_cols(j, art_sign, A):
        """(S, m) dense working column j per instance (masked gather)."""
        m, n = A.shape
        struct = (j < n)[:, None]
        wc_struct = A.T[jnp.clip(j, 0, n - 1)]
        rows = jnp.clip(j - n, 0, m - 1)
        S = j.shape[0]
        sign = art_sign[jnp.arange(S), rows]
        wc_art = (jnp.arange(m)[None, :] == rows[:, None]) * sign[:, None]
        return jnp.where(struct, wc_struct, wc_art)

    def masked_pivot(state, mask, r, j, leave_to, w, xj_new, io):
        """Apply one batched basis swap where ``mask`` holds.

        Mirrors ``_BatchSimplex._do_pivot``: bookkeeping scatter updates,
        a batched rank-1 product-form inverse update, and — where the
        pivot count hits the refactor schedule or the pivot element is
        tiny — a full stacked refactorization with per-instance
        breakdown detection (singular inverse => LIMIT).
        """
        vstat, basis, Binv, xB, xN, status, pivots, run = state
        A, b, art_sign, lbw, ubw = io
        S = vstat.shape[0]
        sidx = jnp.arange(S)
        leaving = basis[sidx, r]
        vstat = vstat.at[sidx, leaving].set(
            jnp.where(mask, leave_to, vstat[sidx, leaving])
        )
        vstat = vstat.at[sidx, j].set(
            jnp.where(mask, BASIC, vstat[sidx, j])
        )
        basis = basis.at[sidx, r].set(jnp.where(mask, j, basis[sidx, r]))
        leave_x = jnp.where(
            leave_to == AT_UB, ubw[sidx, leaving], lbw[sidx, leaving]
        )
        xN = xN.at[sidx, leaving].set(
            jnp.where(mask, leave_x, xN[sidx, leaving])
        )
        xN = xN.at[sidx, j].set(jnp.where(mask, 0.0, xN[sidx, j]))
        pivots = pivots + mask.astype(pivots.dtype)
        wr = w[sidx, r]
        need_rf = mask & (
            (pivots % refactor_every == 0) | (jnp.abs(wr) < _PIV_MIN)
        )
        upd = mask & ~need_rf
        # Product-form rank-1 update (guard the divide; masked out anyway).
        safe_wr = jnp.where(jnp.abs(wr) > 0.0, wr, 1.0)
        prow = Binv[sidx, r] / safe_wr[:, None]
        Binv_upd = Binv - w[:, :, None] * prow[:, None, :]
        Binv_upd = Binv_upd.at[sidx, r].set(prow)
        Binv = jnp.where(upd[:, None, None], Binv_upd, Binv)
        xB = xB.at[sidx, r].set(jnp.where(upd, xj_new, xB[sidx, r]))

        def refactor(ops):
            """Rebuild B^-1 from scratch for instances whose eta drift is due."""
            Binv, status, run, xB = ops
            B = basis_mats(basis, art_sign, A)
            Binv_new = jnp.linalg.inv(B)
            okm = jnp.isfinite(Binv_new).all(axis=(1, 2))
            use = need_rf & okm
            dead = need_rf & ~okm  # numerical breakdown: give up on those
            Binv = jnp.where(use[:, None, None], Binv_new, Binv)
            status = jnp.where(dead, LIMIT, status)
            run = run & ~dead
            xB_new = compute_xB(Binv, b, xN, art_sign, A)
            xB = jnp.where((use & ~dead)[:, None], xB_new, xB)
            return Binv, status, run, xB

        Binv, status, run, xB = lax.cond(
            need_rf.any(), refactor, lambda ops: ops, (Binv, status, run, xB)
        )
        return (vstat, basis, Binv, xB, xN, status, pivots, run)

    def phase(state, cost, io):
        """Advance every running instance to phase optimality (masked)."""
        A, b, art_sign, lbw, ubw = io
        S, nm = state[0].shape
        m = b.shape[1]
        n = nm - m
        sidx = jnp.arange(S)
        movable = (ubw - lbw) > _EPS

        vstat, basis, Binv, xB, xN, status, pivots, run = state
        xB0 = compute_xB(Binv, b, xN, art_sign, A)
        xB = jnp.where(run[:, None], xB0, xB)
        bland = jnp.zeros(S, dtype=bool)
        stall = jnp.zeros(S, dtype=jnp.int32)
        best = jnp.full(S, jnp.inf)
        carry = (
            (vstat, basis, Binv, xB, xN, status, pivots, run),
            bland,
            stall,
            best,
            jnp.int32(0),
        )

        def cond(carry):
            """Keep iterating while any instance runs and the cap isn't hit."""
            state, _, _, _, it = carry
            return state[7].any() & (it < max_iter)

        def body(carry):
            """One masked simplex pivot (or bound flip) across the stack."""
            state, bland, stall, best, it = carry
            vstat, basis, Binv, xB, xN, status, pivots, run = state
            costB = cost[basis]
            obj = (costB * xB).sum(axis=1) + xN @ cost
            better = obj < best - 1e-12
            best = jnp.where(run & better, obj, best)
            stall_new = jnp.where(better, 0, stall + 1)
            stall = jnp.where(run, stall_new, stall)
            bland = jnp.where(
                run,
                jnp.where(better, False, bland | (stall_new > 2 * m + 16)),
                bland,
            )
            # Pricing: one stacked GEMM covers every running instance.
            y = jnp.einsum("km,kmn->kn", costB, Binv)
            dred = jnp.concatenate(
                [cost[:n] - y @ A, cost[n:] - y * art_sign], axis=1
            )
            elig = movable & (
                ((vstat == AT_LB) & (dred < -_EPS))
                | ((vstat == AT_UB) & (dred > _EPS))
            )
            elig = elig & run[:, None]
            has = elig.any(axis=1)
            run = run & has  # phase-optimal instances retire in place
            act = run
            j_dz = jnp.argmax(jnp.where(elig, jnp.abs(dred), -1.0), axis=1)
            j = jnp.where(bland, jnp.argmax(elig, axis=1), j_dz)
            sdir = jnp.where(vstat[sidx, j] == AT_LB, 1.0, -1.0)
            w = jnp.einsum("kmn,kn->km", Binv, work_cols(j, art_sign, A))
            dxB = -sdir[:, None] * w
            lbB = jnp.take_along_axis(lbw, basis, axis=1)
            ubB = jnp.take_along_axis(ubw, basis, axis=1)
            inc = dxB > _EPS
            dec = dxB < -_EPS
            t_up = jnp.where(inc, (ubB - xB) / jnp.where(inc, dxB, 1.0),
                             jnp.inf)
            t_lo = jnp.where(dec, (lbB - xB) / jnp.where(dec, dxB, 1.0),
                             jnp.inf)
            t_up = jnp.where(jnp.isnan(t_up), jnp.inf, jnp.maximum(t_up, 0.0))
            t_lo = jnp.where(jnp.isnan(t_lo), jnp.inf, jnp.maximum(t_lo, 0.0))
            t_row = jnp.minimum(t_up, t_lo)
            rmin = t_row.min(axis=1)
            t_flip = ubw[sidx, j] - lbw[sidx, j]
            unb = act & ~jnp.isfinite(jnp.minimum(rmin, t_flip))
            status = jnp.where(unb, UNB, status)
            run = run & ~unb
            flip = act & ~unb & (t_flip < rmin - 1e-12)
            xB = jnp.where(flip[:, None], xB + dxB * t_flip[:, None], xB)
            newst = jnp.where(vstat[sidx, j] == AT_LB, AT_UB, AT_LB)
            vstat = vstat.at[sidx, j].set(
                jnp.where(flip, newst, vstat[sidx, j])
            )
            flip_x = jnp.where(newst == AT_UB, ubw[sidx, j], lbw[sidx, j])
            xN = xN.at[sidx, j].set(jnp.where(flip, flip_x, xN[sidx, j]))
            piv = act & ~unb & ~flip
            cand = t_row <= (rmin + _EPS)[:, None]
            r_dz = jnp.argmax(jnp.where(cand, jnp.abs(dxB), -1.0), axis=1)
            r_bl = jnp.argmax(
                jnp.where(cand, -basis.astype(jnp.float64), -jnp.inf), axis=1
            )
            r = jnp.where(bland, r_bl, r_dz)
            leave_to = jnp.where(
                t_up[sidx, r] <= t_lo[sidx, r], AT_UB, AT_LB
            )
            xj_new = xN[sidx, j] + sdir * rmin
            xB = jnp.where(piv[:, None], xB + dxB * rmin[:, None], xB)
            state = masked_pivot(
                (vstat, basis, Binv, xB, xN, status, pivots, run),
                piv, r, j, leave_to, w, xj_new,
                (A, b, art_sign, lbw, ubw),
            )
            return (state, bland, stall, best, it + 1)

        carry = lax.while_loop(cond, body, carry)
        state, _, _, _, _ = carry
        vstat, basis, Binv, xB, xN, status, pivots, run = state
        status = jnp.where(run, LIMIT, status)  # iteration cap
        run = jnp.zeros_like(run)
        return (vstat, basis, Binv, xB, xN, status, pivots, run)

    def solve(c, A, b, lb, ub, live):
        """Two-phase bounded-variable simplex over the stacked instances."""
        S, m = b.shape
        n = c.shape[0]
        sidx = jnp.arange(S)
        cost2 = jnp.concatenate([c, jnp.zeros(m)])
        cost1 = jnp.concatenate([jnp.zeros(n), jnp.ones(m)])
        lbw = jnp.concatenate([lb, jnp.zeros((S, m))], axis=1)
        ubw0 = jnp.concatenate([ub, jnp.zeros((S, m))], axis=1)
        vstat = jnp.full((S, n + m), AT_LB, dtype=jnp.int32)
        no_lb = ~jnp.isfinite(lbw[:, :n])
        vstat = vstat.at[:, :n].set(
            jnp.where(no_lb, AT_UB, vstat[:, :n])
        )
        xN = jnp.where(vstat == AT_UB, ubw0, lbw)
        xN = jnp.where(vstat == BASIC, 0.0, xN)
        r0 = b - xN[:, :n] @ A.T
        art_sign = jnp.where(r0 >= 0.0, 1.0, -1.0)
        basis = jnp.tile(jnp.arange(n, n + m), (S, 1))
        vstat = vstat.at[:, n:].set(BASIC)
        xN = xN.at[:, n:].set(0.0)
        Binv = jnp.eye(m)[None, :, :] * art_sign[:, :, None]
        ubw1 = ubw0.at[:, n:].set(jnp.inf)  # artificials live in phase 1
        status = jnp.where(live, RUN, INFEAS).astype(jnp.int32)
        pivots = jnp.zeros(S, dtype=jnp.int32)
        xB = jnp.zeros((S, m))
        run = live
        state = (vstat, basis, Binv, xB, xN, status, pivots, run)
        io1 = (A, b, art_sign, lbw, ubw1)
        state = phase(state, cost1, io1)
        vstat, basis, Binv, xB, xN, status, pivots, run = state
        still = status == RUN
        xB_new = compute_xB(Binv, b, xN, art_sign, A)
        xB = jnp.where(still[:, None], xB_new, xB)
        art_obj = jnp.where(basis >= n, xB, 0.0).sum(axis=1)
        status = jnp.where(still & (art_obj > 1e-7), INFEAS, status)

        def drive_row(r, state):
            """Pivot a leftover degenerate artificial out of row ``r``."""
            vstat, basis, Binv, xB, xN, status, pivots, run = state
            isart = (status == RUN) & (basis[:, r] >= n)
            row = jnp.einsum("km,mn->kn", Binv[:, r, :], A)
            free = (vstat[:, :n] != BASIC) & (jnp.abs(row) > 1e-7)
            mask = isart & free.any(axis=1)
            jj = jnp.argmax(free, axis=1)  # first eligible column
            w = jnp.einsum(
                "kmn,kn->km", Binv, work_cols(jj, art_sign, A)
            )
            rvec = jnp.full((S,), r, dtype=basis.dtype)
            leave = jnp.full((S,), AT_LB, dtype=vstat.dtype)
            xj_new = xN[sidx, jj]
            run = jnp.where(mask, True, run)  # refactor path needs liveness
            state = masked_pivot(
                (vstat, basis, Binv, xB, xN, status, pivots, run),
                mask, rvec, jj, leave, w, xj_new, io1,
            )
            vstat, basis, Binv, xB, xN, status, pivots, run = state
            run = jnp.where(mask, False, run)
            return (vstat, basis, Binv, xB, xN, status, pivots, run)

        state = lax.fori_loop(
            0, m, drive_row,
            (vstat, basis, Binv, xB, xN, status, pivots, run),
        )
        vstat, basis, Binv, xB, xN, status, pivots, run = state
        run = status == RUN
        io2 = (A, b, art_sign, lbw, ubw0)  # artificials pinned for phase 2
        state = phase(
            (vstat, basis, Binv, xB, xN, status, pivots, run), cost2, io2
        )
        vstat, basis, Binv, xB, xN, status, pivots, run = state
        status = jnp.where(status == RUN, OPT, status)
        x_full = xN.at[sidx[:, None], basis].set(xB)
        return x_full[:, :n], status, pivots

    fn = jax.jit(solve)
    _SOLVE_CACHE[key] = fn
    return fn


def solve_lp_batch_jax(
    c,
    A,
    b_stack,
    lb_stack=None,
    ub_stack=None,
    max_iter: int = 20000,
    refactor_every: int = 64,
) -> list[LPResult]:
    """Solve S instances min c@x s.t. A@x=b_s, lb_s<=x<=ub_s on device.

    Drop-in for ``repro.solver.batch.solve_lp_batch`` with identical
    call/return conventions (one ``LPResult`` per instance, cold-start,
    sparse ``A`` densified), executed as one jitted two-phase lockstep
    simplex in float64 under a local ``enable_x64`` scope.  Compilation
    is cached per (shape, caps); repeat sweeps over the same layout —
    the Eq.-14 grid shape — pay tracing once.
    """
    from jax.experimental import enable_x64

    c = np.asarray(c, dtype=np.float64)
    if hasattr(A, "toarray") and not isinstance(A, np.ndarray):
        A = A.toarray()
    A = np.asarray(A, dtype=np.float64)
    b = np.atleast_2d(np.asarray(b_stack, dtype=np.float64))
    S = b.shape[0]
    n = c.shape[0]
    lb = np.zeros(n) if lb_stack is None else np.asarray(lb_stack, np.float64)
    ub = (
        np.full(n, np.inf) if ub_stack is None
        else np.asarray(ub_stack, np.float64)
    )
    lb = np.broadcast_to(lb, (S, n)).copy()
    ub = np.broadcast_to(ub, (S, n)).copy()
    if np.any(~np.isfinite(lb) & ~np.isfinite(ub)):
        raise ValueError("free variables (lb and ub infinite) unsupported")
    live = ~(lb > ub + _EPS).any(axis=1)

    # Pad the stack axis to the next power of two so sweeps whose
    # feasibility pre-filter keeps a varying number of grid points share
    # one compiled program per (m, n) layout.  Padded instances enter
    # dead (live=False -> INFEAS, never iterated) and are sliced off.
    S_pad = 1 << max(0, S - 1).bit_length()
    if S_pad > S:
        pad = S_pad - S
        b = np.concatenate([b, np.zeros((pad, b.shape[1]))])
        lb = np.concatenate([lb, np.zeros((pad, n))])
        ub = np.concatenate([ub, np.ones((pad, n))])
        live = np.concatenate([live, np.zeros(pad, dtype=bool)])

    with enable_x64():
        fn = _get_solver(int(max_iter), int(refactor_every))
        x, status, pivots = fn(c, A, b, lb, ub, live)
        x = np.asarray(x)[:S]
        status = np.asarray(status)[:S]
        pivots = np.asarray(pivots)[:S]

    out = []
    for s in range(S):
        st = _STATUS[int(status[s])]
        piv = int(pivots[s])
        if st != "optimal":
            fun = -np.inf if st == "unbounded" else np.inf
            out.append(LPResult(None, fun, st, pivots=piv))
            continue
        xs = x[s]
        out.append(LPResult(xs, float(c @ xs), "optimal", pivots=piv))
    return out
