"""LP solver layer: dense/revised simplex backends behind the solve_lp facade."""

from repro.solver.lp import (
    BasisState,
    LPResult,
    lp_method,
    solve_lp,
    solve_lp_dense,
    solve_lp_revised,
)

__all__ = [
    "BasisState",
    "LPResult",
    "lp_method",
    "solve_lp",
    "solve_lp_dense",
    "solve_lp_revised",
]
