from repro.solver.lp import LPResult, solve_lp

__all__ = ["LPResult", "solve_lp"]
