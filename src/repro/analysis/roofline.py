"""Three-term roofline model from dry-run records (spec formulas).

    compute    = HLO_FLOPs_total   / (chips * 197e12)      [s]
    memory     = HLO_bytes_total   / (chips * 819e9)       [s]
    collective = collective_bytes  / (chips * 50e9)        [s]

HLO numbers from analysis.hlo are PER DEVICE (post-SPMD module), so
``total = per_device * chips`` and the chips cancel: each term is simply
per_device / per_chip_rate.  MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D
(MoE); for decode shapes D = tokens per step = global_batch.
"""

from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e class)
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    dominant: str
    note: str = ""

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the ideal MODEL-FLOPS compute roof achieved assuming
        perfect overlap: ideal_time / bound_time."""
        chips = 512 if self.mesh == "2x16x16" else 256
        ideal = self.model_flops / (chips * PEAK_FLOPS)
        return ideal / self.bound_time if self.bound_time > 0 else 0.0


def tokens_per_step(shape_name: str, seq: int, batch: int, kind: str) -> float:
    if kind == "train" or kind == "prefill":
        return float(seq * batch)
    return float(batch)  # decode: one token per sequence


def model_flops(arch_cfg, shape, n_active_params: float) -> float:
    """6*N*D for train; 2*N*D for inference (fwd only)."""
    toks = tokens_per_step(shape.name, shape.seq_len, shape.global_batch, shape.kind)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active_params * toks


def from_record(rec: dict, shape) -> Roofline | None:
    if not rec.get("ok"):
        return None
    flops_dev = rec["hlo_flops_per_device"]
    bytes_dev = rec["hlo_bytes_per_device"]
    coll_dev = sum(rec["collective_bytes_per_device"].values())
    chips = rec["chips"]
    mf = model_flops(None, shape, rec["active_params"])
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    collective = coll_dev / LINK_BW
    dom = max(
        [("compute", compute), ("memory", memory), ("collective", collective)],
        key=lambda kv: kv[1],
    )[0]
    total_flops = flops_dev * chips
    return Roofline(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        compute_s=compute,
        memory_s=memory,
        collective_s=collective,
        model_flops=mf,
        hlo_flops_total=total_flops,
        useful_ratio=mf / total_flops if total_flops else 0.0,
        dominant=dom,
    )


def fix_suggestion(r: Roofline) -> str:
    """One sentence on what would move the dominant term down."""
    if r.dominant == "compute":
        if r.useful_ratio < 0.5:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "(policy: save attention outputs) and skip fully-masked "
                    "causal KV blocks")
        return "compute-bound near useful peak: only larger per-chip batch helps"
    if r.dominant == "memory":
        return ("memory-bound: fuse elementwise chains (gossip_mix kernel), "
                "larger matmul tiles, bf16 loss accumulators, widen per-chip batch")
    return ("collective-bound: shrink TP degree for this model size, switch "
            "gossip to matched ppermute, overlap pulls with grad compute, "
            "or compress pulls (top-k/int8)")
