"""Per-op cost breakdown from optimized HLO — the dry-run "profiler".

No wall-clock exists on CPU, so §Perf iterations read this instead: top
contributors to FLOPs / HBM bytes / collective bytes, each scaled by the
enclosing while-loop trip counts, tagged with the op_name metadata (which
carries jax scopes like 'train_step/while/body/...attention...').
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from repro.analysis.hlo import (
    HloCostModel,
    _shape_elems_bytes,
)


@dataclass
class Contributor:
    kind: str  # flops | bytes | collective
    value: float
    opcode: str
    scope: str
    shape: str


_META_RE = re.compile(r'op_name="([^"]+)"')


class Breakdown(HloCostModel):
    def top(self, n: int = 15):
        """Returns dict(kind -> [Contributor]) for the entry computation."""
        contributions: list[Contributor] = []

        def walk(comp_name: str, scale: float, count_bytes: bool = True):
            comp = self.comps.get(comp_name)
            if comp is None:
                return
            for op in comp.ops:
                oc = op.opcode
                meta = _META_RE.search(op.rest)
                scope = meta.group(1) if meta else ""
                if oc == "while":
                    body = re.search(r"body=%?([\w.\-]+)", op.rest)
                    cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                    tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                    trips = float(tm.group(1)) if tm else (
                        self._trip_count(cond.group(1)) or 1.0 if cond else 1.0
                    )
                    if body:
                        walk(body.group(1), scale * trips, count_bytes)
                elif oc in ("fusion", "call", "async-start"):
                    cm = re.search(r"calls=%?([\w.\-]+)", op.rest)
                    if cm:
                        walk(cm.group(1), scale, count_bytes and oc != "fusion")
                    if oc == "fusion" and count_bytes:
                        b = self._fusion_bytes(op, comp) * scale
                        contributions.append(
                            Contributor("bytes", b, oc, scope, op.type_str[:48])
                        )
                elif any(oc.startswith(c) for c in (
                    "all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute",
                )):
                    if oc.endswith("-done"):
                        continue
                    b = 0.0
                    for o in op.operands:
                        _, ob = _shape_elems_bytes(comp.var_types.get(o, ""))
                        b += ob
                    contributions.append(
                        Contributor("collective", b * scale, oc, scope, op.type_str[:48])
                    )
                elif oc == "dot":
                    f = self._dot_flops(op, comp) * scale
                    contributions.append(
                        Contributor("flops", f, oc, scope, op.type_str[:48])
                    )
                    if count_bytes:
                        contributions.append(
                            Contributor("bytes", self._op_bytes(op, comp) * scale, oc,
                                        scope, op.type_str[:48])
                        )
                else:
                    b = self._op_bytes(op, comp) * scale if count_bytes else 0.0
                    if b:
                        contributions.append(
                            Contributor("bytes", b, oc, scope, op.type_str[:48])
                        )

        walk(self.entry, 1.0)
        out = {}
        for kind in ("flops", "bytes", "collective"):
            rows = [c for c in contributions if c.kind == kind]
            rows.sort(key=lambda c: -c.value)
            out[kind] = rows[:n]
        return out


def print_breakdown(compiled_or_text, n: int = 12) -> None:
    text = compiled_or_text if isinstance(compiled_or_text, str) else compiled_or_text.as_text()
    bd = Breakdown(text)
    tops = bd.top(n)
    for kind, rows in tops.items():
        total = sum(r.value for r in rows)
        print(f"\n== top {kind} (sum of top-{n}: {total:.3e}) ==")
        for r in rows:
            scope = r.scope.split("/")[-1][:60] if r.scope else "?"
            print(f"  {r.value:12.3e}  {r.opcode:22s} {r.shape:40s} {scope}")
