"""Compiled-artifact analysis: HLO cost model + roofline terms."""
