"""HLO cost model: FLOPs / HBM bytes / collective bytes from optimized HLO.

Why not ``compiled.cost_analysis()``?  XLA counts while-loop bodies ONCE, so
scanned models (layers, KV chunks, recurrences) are undercounted by the trip
count.  This parser walks ``compiled.as_text()`` (the post-SPMD per-device
module), multiplies loop bodies by their trip counts (parsed from the loop
condition's compare-against-constant), recurses through fusions/calls, and
accounts:

  * flops: dot (2*out*contract), elementwise/reduce (1/elem), conv (approx)
  * bytes: operand+output at materialization boundaries (fusion level),
    with dynamic-slice reads counted at slice size (scan weight slicing)
  * collective bytes by opcode (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), operand-summed per the roofline spec

Numbers are PER DEVICE (the module is the partitioned per-device program).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_op_line(line: str):
    """Split an HLO op line into (name, type_str, opcode, rest) or None.

    Handles tuple types containing /*index=N*/ comments by balanced-paren
    scanning instead of a single regex.
    """
    m = _OP_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":  # tuple type: scan to matching paren
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i : j + 1]
        k = j + 1
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        type_str = line[i:j]
        k = j
    rest = line[k:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    return name, type_str, om.group(1), rest[om.end() :]


def _shape_elems_bytes(type_str: str) -> tuple[float, float]:
    """(elements, bytes) of a possibly-tuple HLO type string."""
    elems = bts = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)
    operands: list = field(default_factory=list)  # var names


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    var_types: dict = field(default_factory=dict)  # var name -> type str
    param_vars: dict = field(default_factory=dict)  # param index -> var name


@dataclass
class CostReport:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = field(default_factory=dict)  # opcode -> bytes
    collective_count: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "CostReport":
        return CostReport(
            self.flops * k,
            self.bytes_accessed * k,
            {o: b * k for o, b in self.collective_bytes.items()},
            {o: c * k for o, c in self.collective_count.items()},
            self.unknown_trip_loops,
        )

    def add(self, other: "CostReport") -> None:
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        for o, b in other.collective_bytes.items():
            self.collective_bytes[o] = self.collective_bytes.get(o, 0.0) + b
        for o, c in other.collective_count.items():
            self.collective_count[o] = self.collective_count.get(o, 0.0) + c
        self.unknown_trip_loops += other.unknown_trip_loops

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done", "opt-barrier",
}
_VIEW_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
             "reshape", "copy", "transpose", "broadcast", "iota"}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, Computation] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo: dict[str, CostReport] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str) -> None:
        cur: Computation | None = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_RE.match(line)
                if m and line.rstrip().endswith("{"):
                    cur = Computation(m.group(1))
                    if line.startswith("ENTRY"):
                        self.entry = cur.name
                    continue
            else:
                if line.startswith("}"):
                    self.comps[cur.name] = cur
                    cur = None
                    continue
                parsed = _parse_op_line(line)
                if parsed is None:
                    continue
                name, tstr, opcode, rest = parsed
                op = Op(name, tstr, opcode, rest)
                # operand variable names: %foo tokens before any attr section
                args_part = rest.split("), ")[0] if "), " in rest else rest
                op.operands = re.findall(r"%([\w.\-]+)", args_part)
                cur.ops.append(op)
                cur.var_types[name] = tstr
                if opcode == "parameter":
                    pm = re.match(r"(\d+)\)", rest)
                    if pm:
                        cur.param_vars[int(pm.group(1))] = name

    # ------------------------------------------------------------- trip count
    def _trip_count(self, cond_name: str) -> float | None:
        cond = self.comps.get(cond_name)
        if cond is None:
            return None
        consts: dict[str, int] = {}
        for op in cond.ops:
            if op.opcode == "constant":
                cm = re.match(r"([\-\d]+)\)", op.rest)
                if cm:
                    consts[op.name] = int(cm.group(1))
        # direct compare in the condition
        for op in cond.ops:
            if op.opcode == "compare" and "direction=LT" in op.rest:
                for o in op.operands:
                    if o in consts:
                        return float(consts[o])
        # fused compare: the constant is an operand of a fusion that calls a
        # computation containing the compare.
        for op in cond.ops:
            if op.opcode == "fusion":
                for o in op.operands:
                    if o in consts:
                        return float(consts[o])
        return None

    # ------------------------------------------------------------------ flops
    def _dot_flops(self, op: Op, comp: Computation) -> float:
        out_elems, _ = _shape_elems_bytes(op.type_str)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        contract = 1.0
        if m and op.operands:
            lhs_type = comp.var_types.get(op.operands[0], "")
            sm = _SHAPE_RE.search(lhs_type)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, op: Op, comp: Computation) -> float:
        out_elems, _ = _shape_elems_bytes(op.type_str)
        if len(op.operands) >= 2:
            k_type = comp.var_types.get(op.operands[1], "")
            k_elems, _ = _shape_elems_bytes(k_type)
            # approx: 2 * out * (kernel elems / out_features)
            sm = _SHAPE_RE.search(k_type)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                ofeat = max(dims[-1], 1)
                return 2.0 * out_elems * k_elems / ofeat
        return 2.0 * out_elems

    # ------------------------------------------------------------------ bytes
    def _op_bytes(self, op: Op, comp: Computation) -> float:
        """HBM traffic estimate at materialization boundaries."""
        if op.opcode in _ZERO_COST_OPS or op.opcode in ("fusion",):
            return 0.0  # fusion handled by caller with slice-awareness
        _, out_b = _shape_elems_bytes(op.type_str)
        total = out_b
        if op.opcode == "dynamic-slice":
            return 2.0 * out_b  # read slice + write out
        if op.opcode == "dynamic-update-slice":
            if len(op.operands) >= 2:
                _, upd_b = _shape_elems_bytes(comp.var_types.get(op.operands[1], ""))
                return 2.0 * upd_b  # in-place slice write (+read)
            return out_b
        if op.opcode == "scatter":
            # in-place: traffic ~ updates + indices (operand aliased)
            b = 0.0
            for o in op.operands[1:]:
                _, ob = _shape_elems_bytes(comp.var_types.get(o, ""))
                b += ob
            return 2.0 * b
        if op.opcode == "gather":
            return 2.0 * out_b  # reads gathered elements + writes output
        for o in op.operands:
            _, b = _shape_elems_bytes(comp.var_types.get(o, ""))
            total += b
        return total

    def _fusion_bytes(self, op: Op, comp: Computation) -> float:
        """Fusion = one HBM materialization: operands + output.

        Special cases matching XLA's fusion emitters:
        * dynamic-slice consumers: a param consumed (possibly through
          elementwise ops) only toward dynamic-slice reads slice-sized data;
        * in-place DUS fusions (root is a dynamic-update-slice, possibly
          followed by converts/bitcasts): the big operand is aliased with the
          output and only the update window is computed/written — traffic is
          2 x update bytes, not 2 x full-stack bytes.
        """
        _, out_b = _shape_elems_bytes(op.type_str)
        called = None
        cm = re.search(r"calls=%?([\w.\-]+)", op.rest)
        if cm:
            called = self.comps.get(cm.group(1))
        if called is None:
            total = out_b
            for o in op.operands:
                _, b = _shape_elems_bytes(comp.var_types.get(o, ""))
                total += b
            return total

        # Trace elementwise-unary forwarding: var -> transitive source params.
        fwd_src: dict[str, set] = {}
        param_names = set(called.param_vars.values())
        for cop in called.ops:
            if cop.opcode == "parameter":
                fwd_src[cop.name] = {cop.name}
            elif cop.opcode in ("convert", "bitcast", "copy", "reshape", "transpose"):
                srcs = set()
                for o in cop.operands:
                    srcs |= fwd_src.get(o, set())
                fwd_src[cop.name] = srcs
            else:
                fwd_src[cop.name] = set()

        dus_updates = 0.0
        aliased_params: set = set()
        sliced_params: set = set()
        has_dus = False
        for cop in called.ops:
            if cop.opcode == "dynamic-slice":
                for o in cop.operands[:1]:
                    sliced_params |= fwd_src.get(o, {o} if o in param_names else set())
            if cop.opcode == "dynamic-update-slice" and len(cop.operands) >= 2:
                has_dus = True
                _, ub = _shape_elems_bytes(called.var_types.get(cop.operands[1], ""))
                dus_updates += ub
                aliased_params |= fwd_src.get(
                    cop.operands[0], {cop.operands[0]} if cop.operands[0] in param_names else set()
                )

        total = 0.0
        # Output: in-place DUS fusions write only the update window.
        total += 2.0 * dus_updates if has_dus else out_b
        for idx, o in enumerate(op.operands):
            _, b = _shape_elems_bytes(comp.var_types.get(o, ""))
            pv = called.param_vars.get(idx)
            if pv is not None and pv in aliased_params:
                continue  # aliased with output; traffic already counted
            if pv is not None and pv in sliced_params:
                b = min(b, out_b)  # slice-sized read
            total += b
        return total

    # ------------------------------------------------------------------ walk
    def computation_cost(self, name: str, count_bytes: bool = True) -> CostReport:
        """Cost of one computation.  ``count_bytes=False`` when reached
        through a fusion: inner ops contribute FLOPs (they execute) but no
        HBM traffic (the fusion boundary is the only materialization)."""
        key = (name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        rep = CostReport()
        if comp is None:
            return rep
        self._memo[key] = rep  # guard recursion
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                # Preferred: XLA's own analysis in backend_config.
                tm = _TRIP_RE.search(op.rest)
                trips = float(tm.group(1)) if tm else None
                if trips is None and cond:
                    trips = self._trip_count(cond.group(1))
                if trips is None:
                    trips = 1.0
                    rep.unknown_trip_loops += 1
                if body:
                    rep.add(self.computation_cost(body.group(1), count_bytes).scaled(trips))
                if cond:
                    rep.add(self.computation_cost(cond.group(1), count_bytes).scaled(trips))
            elif oc in ("fusion", "call", "async-start"):
                cm = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if cm:
                    inner_bytes = count_bytes and oc != "fusion"
                    rep.add(self.computation_cost(cm.group(1), inner_bytes))
                if oc == "fusion" and count_bytes:
                    rep.bytes_accessed += self._fusion_bytes(op, comp)
            elif oc == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))", op.rest)
                names = []
                for b in branches:
                    for part in b:
                        if part:
                            names.extend(n.strip().lstrip("%") for n in part.split(","))
                if names:
                    costs = [self.computation_cost(n, count_bytes) for n in names]
                    best = max(costs, key=lambda c: c.flops)
                    rep.add(best)
            elif any(oc.startswith(c) for c in _COLLECTIVES):
                base = next(c for c in _COLLECTIVES if oc.startswith(c))
                b = 0.0
                for o in op.operands:
                    _, ob = _shape_elems_bytes(comp.var_types.get(o, ""))
                    b += ob
                if b == 0.0:  # e.g. -done ops reference the start tuple
                    _, b = _shape_elems_bytes(op.type_str)
                if oc.endswith("-done"):
                    continue  # counted at -start
                rep.collective_bytes[base] = rep.collective_bytes.get(base, 0.0) + b
                rep.collective_count[base] = rep.collective_count.get(base, 0.0) + 1
                if count_bytes:
                    rep.bytes_accessed += self._op_bytes(op, comp)
            else:
                # flops
                if oc == "dot":
                    rep.flops += self._dot_flops(op, comp)
                elif oc == "convolution":
                    rep.flops += self._conv_flops(op, comp)
                elif oc in ("reduce", "reduce-window"):
                    in_elems = 0.0
                    for o in op.operands[: max(1, len(op.operands) // 2)]:
                        e, _ = _shape_elems_bytes(comp.var_types.get(o, ""))
                        in_elems += e
                    rep.flops += in_elems
                elif oc not in _ZERO_COST_OPS and oc not in _VIEW_OPS:
                    e, _ = _shape_elems_bytes(op.type_str)
                    rep.flops += e
                if count_bytes:
                    rep.bytes_accessed += self._op_bytes(op, comp)
        self._memo[key] = rep
        return rep

    def entry_cost(self) -> CostReport:
        assert self.entry is not None, "no ENTRY computation found"
        return self.computation_cost(self.entry)


def analyze_compiled(compiled) -> CostReport:
    return HloCostModel(compiled.as_text()).entry_cost()
