"""Distributed substrate: gossip collectives + sharding plans (DESIGN.md §5/§6)."""
