"""Sharding plans: map (arch config, mesh) -> PartitionSpecs (DESIGN.md §5).

NetMax-DP shards the *stacked* training state: every leaf carries a leading
worker axis enumerated over ``cfg.worker_axes`` (single-pod meshes drop the
'pod' axis automatically); the trailing feature dim rides the 'model' axis
when divisible (TP).  Serving drops the worker dim and keeps TP only.

Heuristics, not a search: the dry-run harness (launch/dryrun.py) exists to
measure what these plans lower to.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import worker_axis_names, worker_count


@dataclass(frozen=True)
class ShardingPlan:
    mesh: object
    n_workers: int
    worker_axes: tuple  # worker-enumeration axes present in this mesh
    model_axis: str = "model"

    def axis_size(self, name: str) -> int:
        return int(self.mesh.shape.get(name, 1))  # Mesh.shape is an OrderedDict


def plan_for(cfg, mesh, serve: bool = False) -> ShardingPlan:
    """Resolve the worker/TP split for this config on this mesh."""
    if serve:
        return ShardingPlan(mesh=mesh, n_workers=1, worker_axes=())
    waxes = worker_axis_names(mesh, getattr(cfg, "worker_axes", ("pod", "data")))
    return ShardingPlan(mesh=mesh, n_workers=worker_count(mesh, waxes),
                        worker_axes=waxes)


def _tp(plan: ShardingPlan) -> int:
    return plan.axis_size(plan.model_axis)


def _leaf_spec(leaf, plan: ShardingPlan, stacked: bool) -> P:
    """Leading worker axes (stacked), trailing dim on 'model' when divisible."""
    ndim = leaf.ndim
    tp = _tp(plan)
    lead = [tuple(plan.worker_axes)] if stacked else []
    body_ndim = ndim - (1 if stacked else 0)
    body = [None] * body_ndim
    if body_ndim >= 1 and tp > 1:
        last = leaf.shape[-1]
        if last % tp == 0 and last >= tp:
            body[-1] = plan.model_axis
    return P(*lead, *body)


def param_specs(cfg, params, plan: ShardingPlan, stacked: bool = True):
    """PartitionSpec tree for (stacked) parameters."""
    return jax.tree_util.tree_map(
        lambda l: _leaf_spec(l, plan, stacked), params
    )


def batch_specs(cfg, plan: ShardingPlan, shape, stacked: bool = True):
    """Specs for the training batch: leading worker dim, rest replicated."""
    from repro.launch import specs as sp

    abstract = sp.train_batch_specs(cfg, shape, max(plan.n_workers, 1))
    lead = tuple(plan.worker_axes)
    return jax.tree_util.tree_map(
        lambda l: P(lead, *([None] * (l.ndim - 1))), abstract
    )


def _data_axis_spec(plan: ShardingPlan, dim: int) -> object:
    data = plan.axis_size("data")
    return "data" if data > 1 and dim % data == 0 else None


def prefill_batch_specs(cfg, plan: ShardingPlan, batch):
    """Serve prefill: shard the batch dim over 'data', rest replicated."""
    return jax.tree_util.tree_map(
        lambda l: P(_data_axis_spec(plan, l.shape[0]), *([None] * (l.ndim - 1))),
        batch,
    )


def cache_specs(cfg, cache, plan: ShardingPlan, global_batch: int):
    """Decode cache: shard the batch-sized axis over 'data' when present."""

    def leaf(l):
        body = [None] * l.ndim
        for ax, dim in enumerate(l.shape):
            if dim == global_batch and _data_axis_spec(plan, dim) is not None:
                body[ax] = "data"
                break
        return P(*body)

    return jax.tree_util.tree_map(leaf, cache)


def serve_batch_spec(plan: ShardingPlan, global_batch: int) -> P:
    return P(_data_axis_spec(plan, global_batch))
