"""Gossip pull lowerings + consensus mix on stacked replicas.

Three equivalent lowerings of "worker i pulls the pre-round params of
neighbor m_i" over leaves stacked (M, ...) on the worker mesh axes:

  pull_gather       jnp.take along the worker dim — XLA lowers the cross-
                    shard gather to all-gather + dynamic-slice.  Simplest;
                    moves O(M) params per worker in the worst case.
  pull_masked_psum  one-hot matmul along the worker dim — lowers to a
                    masked all-reduce; same wire cost as an all-reduce but
                    a single fused collective.
  pull_ppermute     shard_map + lax.ppermute — a true point-to-point
                    collective-permute, O(1) params per link, but only
                    valid when the neighbor draw is a permutation (the
                    host-side sampler can always re-draw into one).

All three agree numerically (tests/test_spmd.py); the dry-run harness
compares their lowered collective bytes per DESIGN.md §6.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pull_gather(params, neighbors):
    """pulled[i] = params[neighbors[i]] via take along the stacked dim."""
    return jax.tree_util.tree_map(
        lambda x: jnp.take(x, neighbors, axis=0), params
    )


def pull_masked_psum(params, neighbors, M: int):
    """One-hot contraction over the worker dim (lowers to a masked psum)."""
    oh = jax.nn.one_hot(neighbors, M)

    def leaf(x):
        sel = jnp.einsum("ij,j...->i...", oh.astype(x.dtype), x)
        return sel.astype(x.dtype)

    return jax.tree_util.tree_map(leaf, params)


def pull_ppermute(params, perm, mesh, worker_axes, specs=None):
    """Point-to-point pull for permutation draws: device i receives the
    replica of device perm[i] via lax.ppermute over the worker mesh axes.

    ``perm``: tuple of source indices (pulled[i] = params[perm[i]]).
    ``specs``: optional PartitionSpec tree for the params (defaults to
    leading-axis sharding over ``worker_axes``, everything else replicated).
    """
    axes = tuple(worker_axes)
    if not axes:
        return pull_gather(params, jnp.asarray(perm, dtype=jnp.int32))
    axis_name = axes if len(axes) > 1 else axes[0]
    # ppermute pairs are (source_device, destination_device): destination i
    # receives from source perm[i].
    pairs = [(int(perm[i]), i) for i in range(len(perm))]

    if specs is None:
        specs = jax.tree_util.tree_map(
            lambda x: P(axes, *([None] * (x.ndim - 1))), params
        )

    def inner(tree):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.ppermute(x, axis_name=axis_name, perm=pairs), tree
        )

    return shard_map(
        inner, mesh=mesh, in_specs=(specs,), out_specs=specs,
        check_rep=False,
    )(params)


def mix(x_half, pulled, weights):
    """Consensus mix on stacked replicas (Alg. 2 lines 13-15):
    out_i = (1 - w_i) * x_half_i + w_i * pulled_i."""

    def leaf(h, p):
        w = weights.reshape((-1,) + (1,) * (h.ndim - 1)).astype(h.dtype)
        return (1.0 - w) * h + w * p

    return jax.tree_util.tree_map(leaf, x_half, pulled)
