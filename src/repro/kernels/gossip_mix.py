"""Pallas TPU kernel: fused NetMax two-step update (gossip_mix).

The consensus update  out = (1-w) * (x + u) + w * pulled  (Alg. 2 lines
11+13-15, with u = optimizer delta) is pure HBM traffic: naively it is three
elementwise passes (apply update, subtract, mix) over every parameter.  The
fused kernel streams x, u, pulled through VMEM once:

    reads  3 x bytes   writes 1 x bytes      (vs 5R/3W unfused)

which at 819 GB/s HBM is the dominant non-matmul cost of a NetMax round at
small per-worker batch.  Block layout: flat 1-D tiles of 64k elements (f32)
— bandwidth-bound, no MXU alignment needed, lane-dim 128-aligned.

Two entry points share the kernel body:

* ``gossip_mix``       — one replica, scalar w (the trainer's per-slice path)
* ``gossip_mix_rows``  — a stacked (R, ...) block with per-row weights, one
  grid row per worker/cohort member (the batched engine / stacked trainer
  path; w lives in SMEM indexed by the row program id).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BLOCK = 65536  # elements per tile (256 KiB f32 in VMEM x 4 buffers)


def _mix_kernel(x_ref, u_ref, p_ref, w_ref, o_ref):
    w = w_ref[0]
    x_half = x_ref[...].astype(jnp.float32) + u_ref[...].astype(jnp.float32)
    out = (1.0 - w) * x_half + w * p_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def gossip_mix(x, u, pulled, w, *, interpret: bool = False, block: int = _BLOCK):
    """out = (1-w)*(x+u) + w*pulled, elementwise; w scalar (per worker).

    x/u/pulled: same-shape arrays (any dtype); w: f32 scalar array.
    """
    shape, dtype = x.shape, x.dtype
    n = x.size
    xf, uf, pf = (a.reshape(-1) for a in (x, u, pulled))
    pad = (-n) % block
    if pad:
        xf = jnp.pad(xf, (0, pad))
        uf = jnp.pad(uf, (0, pad))
        pf = jnp.pad(pf, (0, pad))
    nb = xf.size // block
    wv = jnp.asarray(w, jnp.float32).reshape(1)

    out = pl.pallas_call(
        _mix_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xf.size,), dtype),
        interpret=interpret,
    )(xf, uf, pf, wv)
    return out[:n].reshape(shape)


def _mix_rows_kernel(x_ref, u_ref, p_ref, w_ref, o_ref):
    w = w_ref[0]  # this grid row's weight (SMEM)
    x_half = x_ref[...].astype(jnp.float32) + u_ref[...].astype(jnp.float32)
    out = (1.0 - w) * x_half + w * p_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def gossip_mix_rows(x, u, pulled, w, *, interpret: bool = False, block: int = _BLOCK):
    """Per-row fused mix: out[r] = (1-w[r])*(x[r]+u[r]) + w[r]*pulled[r].

    x/u/pulled: (R, ...) same-shape stacked arrays (any dtype); w: (R,) f32.
    Grid is (rows, tiles): each program streams one 1-D tile of one row
    through VMEM with that row's scalar weight prefetched into SMEM, so the
    batched engine mixes a whole cohort in a single kernel launch instead of
    R separate ``gossip_mix`` calls.
    """
    shape, dtype = x.shape, x.dtype
    R = shape[0]
    n = x.size // max(R, 1)
    # Shrink the tile for small rows (lane-dim 128-aligned) so padding never
    # dominates; n is static under jit, so this is trace-time arithmetic.
    block = min(block, max(128, ((n + 127) // 128) * 128))
    xf, uf, pf = (a.reshape(R, -1) for a in (x, u, pulled))
    pad = (-n) % block
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
        uf = jnp.pad(uf, ((0, 0), (0, pad)))
        pf = jnp.pad(pf, ((0, 0), (0, pad)))
    nb = (n + pad) // block
    wv = jnp.asarray(w, jnp.float32).reshape(R)

    out = pl.pallas_call(
        _mix_rows_kernel,
        grid=(R, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda r, b: (r, b)),
            pl.BlockSpec((1, block), lambda r, b: (r, b)),
            pl.BlockSpec((1, block), lambda r, b: (r, b)),
            pl.BlockSpec((1,), lambda r, b: (r,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, block), lambda r, b: (r, b)),
        out_shape=jax.ShapeDtypeStruct((R, n + pad), dtype),
        interpret=interpret,
    )(xf, uf, pf, wv)
    return out[:, :n].reshape(shape)
