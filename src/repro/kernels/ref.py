"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """Naive O(S^2) GQA attention. q: (B,S,H,hd); k/v: (B,Sk,Hk,hd)."""
    B, S, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, S, Hk, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bshgd,bkhd->bhgsk", qg, kf) / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgsk,bkhd->bshgd", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def reference_rwkv(r, k, v, w, u) -> jnp.ndarray:
    """Sequential WKV recurrence.  r/k/v/w: (B,S,H,N); u: (H,N).

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    B, S, H, N = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp  # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, state + uf[None, :, :, None] * kv)
        return wt[..., :, None] * state + kv, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, N, N), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)


def reference_gossip_mix(x, u, pulled, w) -> jnp.ndarray:
    """out = (1-w)*(x+u) + w*pulled (f32 math, cast back)."""
    xf = x.astype(jnp.float32) + u.astype(jnp.float32)
    out = (1.0 - w) * xf + w * pulled.astype(jnp.float32)
    return out.astype(x.dtype)


def reference_gossip_mix_rows(x, u, pulled, w) -> jnp.ndarray:
    """Per-row mix: out[r] = (1-w[r])*(x[r]+u[r]) + w[r]*pulled[r].

    x/u/pulled: (R, ...); w: (R,) broadcast over the trailing dims.
    """
    wf = jnp.asarray(w, jnp.float32).reshape((-1,) + (1,) * (x.ndim - 1))
    xf = x.astype(jnp.float32) + u.astype(jnp.float32)
    out = (1.0 - wf) * xf + wf * pulled.astype(jnp.float32)
    return out.astype(x.dtype)
