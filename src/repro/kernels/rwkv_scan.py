"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.

TPU adaptation of the data-dependent-decay recurrence (DESIGN.md §3): the
per-token update

    y_t   = r_t (S_{t-1} + u k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

is reformulated in CHUNK form so the MXU does the work.  For a chunk of C
tokens with per-token decays w, define cumulative decays
A_i = prod_{j<=i} diag(w_j).  Then within a chunk:

    y_i = r_i A_{i-1} S_0  +  sum_{j<i} r_i (A_{i-1}/A_j) (k_j v_j^T)
                            +  r_i (u k_i v_i^T)
        = (r_i A_{i-1}) S_0 + sum_j [(r_i A_{i-1}/A_j) k_j] 1[j<i] v_j + u-term
    S_C = A_C S_0 + sum_j (A_C / A_j) k_j v_j^T

which is two (C x N) x (N x N) matmuls + a (C x C) masked score matmul —
exactly flash-attention-shaped compute with decay-weighted scores.  The
kernel walks chunks sequentially (grid dim 1) carrying S in VMEM scratch;
each (batch*head) is an independent grid row.

Numerical care: A ratios are computed in log space (log w <= 0) and
exponentiated at use; f32 accumulation throughout.  The factored matmul form
computes exp(+La) * exp(-La) pairs that cancel analytically but can overflow
f32 when the per-chunk cumulative decay passes ~e^-75; the wrapper therefore
clamps per-step log-decay to >= -(75/chunk).  Contributions whose true decay
is stronger than that are below f32 resolution anyway (error <= e^-75 per
pair) — the allclose tests cover both trained-range decays (no clamp active)
and the extreme-decay clamped semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


_SUB = 16  # sub-chunk length: bounds exp() exponent ranges for f32 accuracy


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)  # (1, N) bonus
    sub = min(_SUB, chunk)

    # Process the VMEM block in sub-chunks: the factored matmul form computes
    # exp(+La)*exp(-La) pairs whose f32 rounding error grows like
    # exp(|decay range|); sub-chunking bounds the range (DESIGN.md kernels).
    for s0 in range(0, chunk, sub):
        r = r_ref[0, s0 : s0 + sub].astype(jnp.float32)  # (c, N)
        k = k_ref[0, s0 : s0 + sub].astype(jnp.float32)
        v = v_ref[0, s0 : s0 + sub].astype(jnp.float32)
        lw = lw_ref[0, s0 : s0 + sub].astype(jnp.float32)
        S = s_ref[...]  # (N, N) carry

        # cumulative log decay INCLUSIVE: La[i] = sum_{j<=i} lw[j]
        La = jnp.cumsum(lw, axis=0)  # (c, N)
        r_dec = r * jnp.exp(La - lw)  # r_i A_{i-1}
        k_inv = k * jnp.exp(-La)  # k_j / A_j
        scores = jax.lax.dot_general(
            r_dec, k_inv, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (c, c)
        row = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(row > col, scores, 0.0)
        diag = jnp.sum(r * u * k, axis=1)  # (c,) u-bonus on the diagonal
        y = (
            jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
            + diag[:, None] * v
        )
        # state update: S <- diag(A_c) S + sum_j diag(A_c/A_j) k_j v_j^T
        A_C = jnp.exp(La[-1])  # (N,)
        k_scaled = k_inv * A_C[None, :]
        s_ref[...] = A_C[:, None] * S + jax.lax.dot_general(
            k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        o_ref[0, s0 : s0 + sub] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan(
    r: jnp.ndarray,  # (B, S, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,  # (B, S, H, N) decays in (0, 1)
    u: jnp.ndarray,  # (H, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns y (B, S, H, N) == the sequential WKV recurrence output."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    rr, kk, vv = fold(r), fold(k), fold(v)
    lw_bound = 75.0 / min(_SUB, chunk)  # f32-safe exponent range (module doc)
    lw = fold(
        jnp.clip(jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30)), -lw_bound, 0.0)
    )
    uu = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)

    out = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, N), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, N), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, uu)

    return out.reshape(B, H, S, N).transpose(0, 2, 1, 3)
