"""Jit'd dispatchers for the Pallas kernels.

``use_pallas`` picks the execution path:
  * True  -> compiled Pallas (TPU)
  * False -> pure-jnp reference (XLA; used for dry-run lowering on CPU)
  * "interpret" -> Pallas interpret mode (CPU correctness testing)

Default: Pallas on TPU backends, reference elsewhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gossip_mix import gossip_mix, gossip_mix_rows
from repro.kernels.rwkv_scan import rwkv_scan


def _default_mode():
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, use_pallas=None, block_q=128, block_k=128):
    mode = _default_mode() if use_pallas is None else use_pallas
    if mode == "interpret":
        return flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=True)
    if mode:
        return flash_attention(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return ref.reference_attention(q, k, v, causal=causal)


def rwkv(r, k, v, w, u, *, use_pallas=None, chunk=64):
    mode = _default_mode() if use_pallas is None else use_pallas
    if mode == "interpret":
        return rwkv_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    if mode:
        return rwkv_scan(r, k, v, w, u, chunk=chunk)
    return ref.reference_rwkv(r, k, v, w, u)


def mix(x, u, pulled, w, *, use_pallas=None):
    mode = _default_mode() if use_pallas is None else use_pallas
    if mode == "interpret":
        return gossip_mix(x, u, pulled, w, interpret=True)
    if mode:
        return gossip_mix(x, u, pulled, w)
    return ref.reference_gossip_mix(x, u, pulled, w)


def mix_rows(x, u, pulled, w, *, use_pallas=None):
    """Stacked mix with per-row weights (leading worker/cohort axis)."""
    mode = _default_mode() if use_pallas is None else use_pallas
    if mode == "interpret":
        return gossip_mix_rows(x, u, pulled, w, interpret=True)
    if mode:
        return gossip_mix_rows(x, u, pulled, w)
    return ref.reference_gossip_mix_rows(x, u, pulled, w)


def segment_mean_rows(x, seg, num_segments):
    """Replace each row of ``x`` by the mean of the rows sharing its segment.

    ``x`` is (M, ...) stacked replicas, ``seg`` an (M,) i32 segment id per
    row.  Rows alone in their segment pass through exactly (sum of one row
    divided by 1.0).  This is the one-dispatch group averaging the batched
    sync engine and ``Algorithm.reduce_groups_stacked`` build on — a single
    segment-sum + gather instead of a Python loop over groups."""
    ones = jnp.ones((x.shape[0],), x.dtype)
    sums = jax.ops.segment_sum(x, seg, num_segments=num_segments)
    counts = jax.ops.segment_sum(ones, seg, num_segments=num_segments)
    cnt = counts[seg].reshape((-1,) + (1,) * (x.ndim - 1))
    return sums[seg] / cnt


def gossip_mix_tree(x_half, pulled, weights, *, use_pallas=None):
    """Tree-level fused mix used by the trainer and the batched simulator
    engine (x_half already includes the optimizer update, so u = 0):
    out = (1-w_i) x_half + w_i pulled, one ``mix_rows`` launch per leaf
    instead of the former per-worker-slice Python loop."""

    def one(h, p):
        return mix_rows(h, jnp.zeros_like(h), p, weights, use_pallas=use_pallas)

    return jax.tree_util.tree_map(one, x_half, pulled)
