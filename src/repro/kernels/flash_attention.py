"""Pallas TPU flash attention (GQA, causal) with explicit VMEM BlockSpecs.

TPU adaptation of the paper's compute hot spot (train_4k / prefill_32k):
blocked online-softmax with the KV loop as the innermost grid dimension,
tile shapes aligned to the MXU (128-multiples), accumulators resident in
VMEM scratch across KV steps.  Grid: (batch*kv_heads, q_blocks, kv_blocks);
the KV dimension iterates fastest so the (acc, m, l) scratch carries across
kv steps for one (bh, q_block).

Validated against ref.reference_attention in interpret mode (CPU); compiled
path targets real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,  # VMEM refs
    acc_ref, m_ref, l_ref,  # scratch (VMEM)
    *, causal: bool, block_q: int, block_k: int, scale: float, G: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (G*block_q, hd)
        k = k_ref[0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G*bq, bk)
        if causal:
            # q rows are s-major, g-minor: row r -> position offset r // G
            row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
            col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = (q_start + row) >= (k_start + col)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # Skip KV blocks strictly in the future of the whole Q block.
        @pl.when(k_start <= q_start + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, Sk, Hk, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    assert S % block_q == 0 and Sk % block_k == 0

    # Layout: fold G into the q rows so one grid cell serves a whole KV head.
    # q: (B*Hk, G*S, hd) — rows [g*S + s]; kernel blocks are (G*block_q, hd)
    # covering the SAME s-range for all g (transpose to (s_block, g) order).
    qr = (
        q.reshape(B, S, Hk, G, hd)
        .transpose(0, 2, 1, 3, 4)  # (B, Hk, S, G, hd)
        .reshape(B * Hk, S, G, hd)
        .reshape(B * Hk, S * G, hd)
    )
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hk, Sk, hd)

    nq, nk = S // block_q, Sk // block_k
    grid = (B * Hk, nq, nk)
    scale = float(1.0 / (hd ** 0.5))

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, causal=causal, block_q=block_q, block_k=block_k, scale=scale, G=G
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G * block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G * block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hk, S * G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, hd), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    # rows within a block are (block_q major? no: we built S*G as s-major of
    # G-contiguous rows) — restore (B, S, H, hd).
    out = (
        out.reshape(B, Hk, S, G, hd)
        .transpose(0, 2, 1, 3, 4)  # (B, S, Hk, G, hd)
        .reshape(B, S, H, hd)
    )
    return out
