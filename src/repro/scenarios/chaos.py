"""Deterministic fault injection for control-plane robustness tests.

``ChaosInjector`` is the seeded seam through which tests and benchmarks
exercise the degraded-mode machinery without any real failure happening:

* **solver faults** — ``maybe_fail_solver()`` raises ``ChaosError`` with
  probability ``solver_fail_rate`` per attempt; ``solver_delay_ms()``
  injects artificial latency that counts against a ``PolicyServer``
  deadline (the serve degradation ladder: retry -> stale -> uniform,
  DESIGN.md §18).
* **dropped Monitor reports** — ``drop_report(worker, t)`` decides
  whether a worker's EMA report is lost on the way to the Monitor this
  refresh (``report_drop_rate``).
* **admission-queue delay** — ``injected_queue_delay_ms()`` charges
  artificial queueing latency against a request's deadline before the
  ``serve.admission`` controller dispatches it (``queue_delay_rate``),
  deterministically steering chosen requests into the hopeless-deadline
  shed path.
* **delayed policy publishes** — ``publish_lost(t, period)`` models a
  publish delayed past the point of usefulness: a delay drawn beyond the
  refresh period is superseded by the next refresh before it lands, so
  the workers keep their stale rows (``scenarios.driver.monitor_boundary``
  treats it as a lost publish and counts it here).

Each channel draws from its own ``np.random.default_rng`` stream (spawned
from one ``SeedSequence``), so e.g. raising the solver fault rate never
perturbs the report-drop decisions.  Determinism is per *call order*: two
runs that make the same sequence of calls see the same faults — which is
exactly the situation for the reference and batched engines, whose shared
``monitor_boundary`` makes identical calls at identical virtual times, so
engine parity survives chaos injection.  Reuse one injector across runs
and the streams continue where they left off; build a fresh one per run
when comparing runs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class ChaosError(RuntimeError):
    """Injected failure (distinguishable from real solver errors)."""


@dataclass
class ChaosInjector:
    """Seeded fault-injection harness (module docstring)."""

    seed: int = 0
    solver_fail_rate: float = 0.0
    solver_delay_rate: float = 0.0
    solver_delay_ms: float = 0.0
    report_drop_rate: float = 0.0
    publish_delay_rate: float = 0.0
    # Injected publish delay, in units of the Monitor refresh period; >= 1
    # means the publish is superseded before it lands (treated as lost).
    publish_delay_periods: float = 1.0
    # Admission-queue channel (serve.admission): artificial queueing
    # latency charged against a request's deadline before it is served.
    queue_delay_rate: float = 0.0
    queue_delay_ms: float = 0.0
    # Fault counters (surfaced by tests/benchmarks next to ServeStats).
    n_solver_faults: int = field(init=False, default=0)
    n_injected_delays: int = field(init=False, default=0)
    n_dropped_reports: int = field(init=False, default=0)
    n_lost_publishes: int = field(init=False, default=0)
    n_queue_delays: int = field(init=False, default=0)

    def __post_init__(self):
        for name in (
            "solver_fail_rate",
            "solver_delay_rate",
            "report_drop_rate",
            "publish_delay_rate",
            "queue_delay_rate",
        ):
            p = getattr(self, name)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        # Spawned children are deterministic by index, so appending the
        # queue stream leaves the first four channels' draws untouched —
        # existing seeded tests/benchmarks see identical fault schedules.
        solver, delay, report, publish, queue = (
            np.random.default_rng(s)
            for s in np.random.SeedSequence(self.seed).spawn(5)
        )
        self._solver_rng = solver
        self._delay_rng = delay
        self._report_rng = report
        self._publish_rng = publish
        self._queue_rng = queue

    # -- solver channel (PolicyServer) --------------------------------------
    def maybe_fail_solver(self) -> None:
        """Raise ``ChaosError`` for this solve attempt with the configured
        probability (each retry re-rolls, so bounded retry can recover)."""
        if self.solver_fail_rate and self._solver_rng.uniform() < self.solver_fail_rate:
            self.n_solver_faults += 1
            raise ChaosError("injected solver failure")

    def injected_delay_ms(self) -> float:
        """Artificial solve latency charged against the serve deadline."""
        if (
            self.solver_delay_rate
            and self._delay_rng.uniform() < self.solver_delay_rate
        ):
            self.n_injected_delays += 1
            return float(self.solver_delay_ms)
        return 0.0

    # -- admission-queue channel (serve.admission) ---------------------------
    def injected_queue_delay_ms(self) -> float:
        """Artificial queueing latency charged against a request deadline.

        Drawn by ``AdmissionController`` when an entry is dequeued; like
        the solver delay it is charged *virtually* (never slept), so a
        seeded injector pushes specific requests past their deadline —
        deterministically — to exercise the hopeless-deadline shed path.
        """
        if (
            self.queue_delay_rate
            and self._queue_rng.uniform() < self.queue_delay_rate
        ):
            self.n_queue_delays += 1
            return float(self.queue_delay_ms)
        return 0.0

    # -- Monitor control-plane channels -------------------------------------
    def drop_report(self, worker: int, t: float) -> bool:
        """True when ``worker``'s EMA report is lost this refresh."""
        if self.report_drop_rate and self._report_rng.uniform() < self.report_drop_rate:
            self.n_dropped_reports += 1
            return True
        return False

    def publish_lost(self, t: float, period: float) -> bool:
        """True when this refresh's policy publish is delayed past the next
        refresh (and therefore never lands; workers keep stale rows)."""
        if not self.publish_delay_rate:
            return False
        if self._publish_rng.uniform() < self.publish_delay_rate:
            if self.publish_delay_periods >= 1.0:
                self.n_lost_publishes += 1
                return True
        return False
