"""Network-dynamics scenarios: declarative timelines of outages, link
degradation, and worker churn, compiled into the piecewise link-state
machine that ``core.nettime.LinkTimeModel`` executes (DESIGN.md §14)."""

from repro.scenarios import presets
from repro.scenarios.chaos import ChaosError, ChaosInjector
from repro.scenarios.hazard import HazardConfig, hazard_timeline, storm
from repro.scenarios.timeline import (
    ACTION_EVENTS,
    ClusterOutage,
    CompiledTimeline,
    LinkDegrade,
    ScenarioCursor,
    Timeline,
    WorkerLeave,
    WorkerRejoin,
)

__all__ = [
    "ACTION_EVENTS",
    "ChaosError",
    "ChaosInjector",
    "ClusterOutage",
    "CompiledTimeline",
    "HazardConfig",
    "LinkDegrade",
    "ScenarioCursor",
    "Timeline",
    "WorkerLeave",
    "WorkerRejoin",
    "hazard_timeline",
    "presets",
    "storm",
]
