"""Timeline builders: the paper-§V scenario sweeps and a seedable generator.

These return plain declarative ``Timeline``s — composition is list
concatenation, and every randomized builder takes an explicit seed so a
scenario is reproducible from ``(topology, seed, knobs)`` alone.
"""

from __future__ import annotations

import numpy as np

from repro.scenarios.timeline import (
    ClusterOutage,
    LinkDegrade,
    Timeline,
    WorkerLeave,
    WorkerRejoin,
)


def cluster_outage(cluster: int, start: float, end: float) -> Timeline:
    """The Fig.-7-style headline scenario: one cluster falls off the WAN."""
    return Timeline([ClusterOutage(cluster, start, end)])


def partition(topology, start: float, end: float = float("inf")) -> Timeline:
    """Full network partition: every inter-cluster link dead during
    [start, end) — clusters train on, isolated from each other."""
    return Timeline([ClusterOutage(c, start, end) for c in range(topology.n_clusters)])


def degrade_links(links, start: float, end: float, factor: float) -> Timeline:
    """Degrade each (i, m) in ``links`` by ``factor`` over [start, end)."""
    return Timeline([LinkDegrade(i, m, start, end, factor) for i, m in links])


def worker_blip(
    worker: int, leave: float, rejoin: float, seed_from: int | None = None
) -> Timeline:
    """One worker departs and later rejoins (elastic churn)."""
    return Timeline(
        [WorkerLeave(worker, leave), WorkerRejoin(worker, rejoin, seed_from)]
    )


def federated_cohorts(
    topology,
    seed: int,
    horizon: float,
    rounds: int,
    cohort_size: int,
    carryover: int = 1,
) -> Timeline:
    """Federated-style participation over a large churning population.

    ``[0, horizon)`` splits into ``rounds`` equal windows; in each, only a
    ``cohort_size``-strong active cohort trains while the rest of the
    population is away (elastic churn, so a fleet-sized M never pays for
    idle workers).  Between consecutive windows ``carryover`` members stay
    on: equal-time leaves fire before rejoins, so without carryover a
    disjoint swap would transiently strand the rejoiners with no live
    replica to reseed from — the carryover members are both the reseed
    source and the thread of consensus state across rounds.

    Deterministic from ``(topology, seed, knobs)``, like every preset.
    """
    M = topology.n_workers
    if not 0 < cohort_size <= M:
        raise ValueError(f"cohort_size must be in [1, {M}], got {cohort_size}")
    if not 0 < carryover <= cohort_size:
        raise ValueError(
            f"carryover must be in [1, cohort_size={cohort_size}], "
            f"got {carryover}"
        )
    if cohort_size - carryover > M - cohort_size:
        raise ValueError(
            f"not enough away workers to refresh the cohort: need "
            f"{cohort_size - carryover} fresh members from a pool of "
            f"{M - cohort_size}"
        )
    if rounds < 1 or not (horizon > 0 and np.isfinite(horizon)):
        raise ValueError(f"need rounds >= 1 and finite horizon > 0, got "
                         f"{rounds}, {horizon}")
    rng = np.random.default_rng(seed)
    period = float(horizon) / rounds
    tl = Timeline()
    cohort = {int(w) for w in rng.choice(M, size=cohort_size, replace=False)}
    for w in sorted(set(range(M)) - cohort):  # everyone starts live
        tl.add(WorkerLeave(w, 0.0))
    for r in range(1, rounds):
        t = r * period
        stay = {int(w) for w in
                rng.choice(sorted(cohort), size=carryover, replace=False)}
        pool = sorted(set(range(M)) - cohort)
        fresh = {
            int(w)
            for w in rng.choice(pool, size=cohort_size - carryover, replace=False)
        }
        for w in sorted(cohort - stay):
            tl.add(WorkerLeave(w, t))
        for w in sorted(fresh):
            tl.add(WorkerRejoin(w, t))
        cohort = stay | fresh
    return tl


def random_timeline(
    topology,
    seed: int,
    horizon: float,
    n_outages: int = 1,
    outage_len: tuple[float, float] = (10.0, 60.0),
    n_degrades: int = 2,
    degrade_factor: tuple[float, float] = (2.0, 100.0),
    degrade_len: tuple[float, float] = (20.0, 120.0),
    n_churn: int = 1,
    churn_len: tuple[float, float] = (10.0, 60.0),
) -> Timeline:
    """Seedable composite scenario over ``[0, horizon)``.

    Draws outage targets/windows, degraded links (factor range mirrors the
    paper's 2x-100x slow-link sweep), and worker leave/rejoin blips from
    ``np.random.default_rng(seed)``; the result is declarative, so the same
    (topology, seed) always produces the same timeline.

    Generation is overlap-free by construction: candidate windows that
    would collide with an earlier event on the same failure domain (same
    cluster+direction, same directed link) are redrawn a bounded number of
    times, then dropped — the compiled timeline always passes the
    same-domain overlap validation ``Timeline.compile`` enforces.
    """
    if not (np.isfinite(horizon) and horizon > 0):
        raise ValueError(f"need finite horizon > 0, got {horizon}")
    for name, n in (
        ("n_outages", n_outages),
        ("n_degrades", n_degrades),
        ("n_churn", n_churn),
    ):
        if n < 0:
            raise ValueError(f"{name} must be >= 0, got {n}")
    for name, pair in (
        ("outage_len", outage_len),
        ("degrade_len", degrade_len),
        ("churn_len", churn_len),
        ("degrade_factor", degrade_factor),
    ):
        lo, hi = pair
        if not (np.isfinite(lo) and np.isfinite(hi) and 0 < lo <= hi):
            raise ValueError(f"{name} must be a finite ordered range > 0, got {pair}")
    rng = np.random.default_rng(seed)
    M = topology.n_workers
    nc = topology.n_clusters
    tl = Timeline()

    def place(spans, domain, t0, t1):
        """Claim [t0, t1) on ``domain`` unless it overlaps a prior claim."""
        for a, b in spans.setdefault(domain, []):
            if t0 < b and a < t1:
                return False
        spans[domain].append((t0, t1))
        return True

    outage_spans: dict = {}
    for _ in range(n_outages if nc > 1 else 0):
        for _attempt in range(8):
            c = int(rng.integers(nc))
            t0 = float(rng.uniform(0.0, horizon))
            t1 = t0 + float(rng.uniform(*outage_len))
            if place(outage_spans, c, t0, t1):
                tl.add(ClusterOutage(c, t0, t1))
                break
    degrade_spans: dict = {}
    for _ in range(n_degrades):
        for _attempt in range(8):
            i = int(rng.integers(M))
            m = int(rng.integers(M - 1))
            m = m if m < i else m + 1
            t0 = float(rng.uniform(0.0, horizon))
            t1 = t0 + float(rng.uniform(*degrade_len))
            factor = float(rng.uniform(*degrade_factor))
            # Degrades default symmetric: the domain is the unordered pair.
            if place(degrade_spans, (min(i, m), max(i, m)), t0, t1):
                tl.add(LinkDegrade(i, m, t0, t1, factor))
                break
    # Churn blips use distinct workers so leave/rejoin pairs never overlap.
    churned = rng.choice(M, size=min(n_churn, M - 1), replace=False)
    for w in churned:
        t0 = float(rng.uniform(0.0, horizon))
        t1 = t0 + float(rng.uniform(*churn_len))
        tl.add(WorkerLeave(int(w), t0), WorkerRejoin(int(w), t1))
    return tl
