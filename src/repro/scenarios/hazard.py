"""Cascading failure storms: a seedable self-exciting hazard process.

Real outages cluster: a WAN cut stresses re-routed links, a rack power
event takes hosts with it, and recovery overlaps the next incident.  The
classic model is a **Hawkes process** — each event adds an exponentially
decaying kick to the failure intensity of *correlated* domains, so one
outage raises the short-term hazard of the next and storms emerge from a
single trigger.

``hazard_timeline`` runs Ogata thinning over three domain families:

* **cluster outages** — one intensity per cluster; a firing emits a
  ``ClusterOutage`` and kicks (a) every *other* cluster (the cascade
  term, ``excite_spread``), (b) the WAN link-degrade intensity of every
  directed cluster pair touching the outaged cluster (``excite_links``),
  and (c) the worker-churn intensity of the cluster itself
  (``excite_workers``) — the "same cluster → its WAN links → its
  workers" correlation chain.
* **WAN link degrades** — one intensity per *directed cluster pair*
  (O(n_clusters^2) state, never O(M^2)); a firing degrades one concrete
  cross-cluster link drawn uniformly from the pair.
* **worker churn blips** — one intensity per cluster; a firing emits a
  leave/rejoin pair for one present worker of the cluster, capped so the
  timeline can never depopulate the run.

Intensities recover exponentially (rate ``decay``), so a storm burns
itself out.  Everything is drawn from one ``np.random.default_rng(seed)``
in a fixed order, and the output is a plain declarative ``Timeline`` —
compilation into the piecewise segment machinery is unchanged and
consumes no RNG, which is exactly what keeps reference-vs-batched engine
parity *exact* under a storm and ``scenario=None`` bit-identical
(DESIGN.md §18).

Same-domain overlap is avoided at generation time (a cluster in outage,
a degraded directed link, or a departed worker cannot re-fire until it
recovers), so the generated timeline always passes the compile-time
overlap validation that ``Timeline.compile`` enforces.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.scenarios.timeline import (
    ClusterOutage,
    LinkDegrade,
    Timeline,
    WorkerLeave,
    WorkerRejoin,
)


@dataclass(frozen=True)
class HazardConfig:
    """Knobs of the self-exciting hazard process (rates are per virtual
    second; excitations are kick magnitudes added to the target domain's
    intensity and decaying at ``decay``)."""

    # Spontaneous (background) rates per domain instance.
    base_cluster_rate: float = 0.002
    base_degrade_rate: float = 0.0005  # per directed cross-cluster pair
    base_worker_rate: float = 0.0005  # per cluster (churn blips)
    # Excitation kicks fired by a cluster outage.
    excite_spread: float = 0.02  # -> each other cluster's outage hazard
    excite_links: float = 0.05  # -> each WAN pair touching the cluster
    excite_workers: float = 0.04  # -> the cluster's own churn hazard
    decay: float = 0.05  # intensity recovery rate (1/s)
    # Event-duration / magnitude draws.
    outage_len: tuple = (20.0, 80.0)
    degrade_len: tuple = (30.0, 120.0)
    degrade_factor: tuple = (4.0, 50.0)
    blip_len: tuple = (20.0, 90.0)
    # Safety rails.
    max_events: int = 200  # declarative events (outage/degrade/blip)
    max_departed_frac: float = 0.5  # churn can never strand the run
    worker_blips: bool = True  # off when composing with churn presets


def _check_range(name, rng_pair, positive=True):
    lo, hi = rng_pair
    if not (
        np.isfinite(lo) and np.isfinite(hi) and lo <= hi and (lo > 0 or not positive)
    ):
        raise ValueError(f"{name} must be a finite ordered range, got {rng_pair}")


def hazard_timeline(
    topology,
    seed: int,
    horizon: float,
    config: HazardConfig | None = None,
    *,
    trigger_cluster: int | None = None,
    trigger_time: float = 0.0,
    **overrides,
) -> Timeline:
    """Generate a storm Timeline over ``[0, horizon)`` (module docstring).

    ``trigger_cluster`` plants one exogenous ``ClusterOutage`` at
    ``trigger_time`` — the storm's deterministic first strike (the
    failover acceptance scenario pins it on the Monitor's home cluster);
    the cascade then evolves from the seeded Hawkes dynamics.  Keyword
    ``overrides`` patch individual ``HazardConfig`` fields.
    """
    cfg = config or HazardConfig()
    if overrides:
        cfg = replace(cfg, **overrides)
    if not (np.isfinite(horizon) and horizon > 0):
        raise ValueError(f"need finite horizon > 0, got {horizon}")
    for name in (
        "base_cluster_rate",
        "base_degrade_rate",
        "base_worker_rate",
        "excite_spread",
        "excite_links",
        "excite_workers",
    ):
        if getattr(cfg, name) < 0:
            raise ValueError(f"{name} must be >= 0, got {getattr(cfg, name)}")
    if not (cfg.decay > 0 and np.isfinite(cfg.decay)):
        raise ValueError(f"decay must be finite > 0, got {cfg.decay}")
    _check_range("outage_len", cfg.outage_len)
    _check_range("degrade_len", cfg.degrade_len)
    _check_range("blip_len", cfg.blip_len)
    _check_range("degrade_factor", cfg.degrade_factor)
    M = topology.n_workers
    nc = topology.n_clusters
    if trigger_cluster is not None and not (0 <= trigger_cluster < nc):
        raise ValueError(
            f"trigger_cluster {trigger_cluster} out of range "
            f"(topology has {nc} clusters)"
        )
    cluster_of = np.array([topology.cluster_of(w) for w in range(M)])
    members = [np.where(cluster_of == c)[0] for c in range(nc)]

    rng = np.random.default_rng(seed)
    # Hawkes excess (sum of decaying kicks) per domain family; base rates
    # are added on evaluation.  Cross-cluster degrade pairs only exist for
    # nc > 1; a single-cluster topology degenerates to worker churn.
    exc_cluster = np.zeros(nc)
    exc_pair = np.zeros((nc, nc))
    exc_worker = np.zeros(nc)
    pair_mask = ~np.eye(nc, dtype=bool)

    # Recovery bookkeeping: suppressed domains re-enter the hazard pool at
    # these times (sorted ascending; merged into the thinning walk because
    # re-activation *raises* total intensity and would break the bound).
    outage_until = np.zeros(nc)  # cluster in outage until t
    busy_links: dict[tuple[int, int], float] = {}
    departed: dict[int, float] = {}  # worker -> rejoin time
    wakeups: list[float] = []

    events: list = []
    t = 0.0
    forced = float(trigger_time) if trigger_cluster is not None else np.inf

    def intensities(now):
        lam_c = np.where(outage_until > now, 0.0, cfg.base_cluster_rate + exc_cluster)
        lam_p = np.where(pair_mask, cfg.base_degrade_rate + exc_pair, 0.0)
        max_departed = int(cfg.max_departed_frac * M)
        churn_open = cfg.worker_blips and len(departed) < max(1, max_departed)
        lam_w = (cfg.base_worker_rate + exc_worker) if churn_open else np.zeros(nc)
        return lam_c, lam_p, lam_w

    def advance(dt):
        f = np.exp(-cfg.decay * dt)
        exc_cluster[:] *= f
        exc_pair[:] *= f
        exc_worker[:] *= f

    def purge(now):
        for w in [w for w, tr in departed.items() if tr <= now]:
            del departed[w]
        for k in [k for k, te in busy_links.items() if te <= now]:
            del busy_links[k]

    def fire_cluster(c, now):
        dur = float(rng.uniform(*cfg.outage_len))
        events.append(ClusterOutage(int(c), now, now + dur))
        outage_until[c] = now + dur
        wakeups.append(now + dur)
        exc_cluster[:] += cfg.excite_spread
        exc_cluster[c] = 0.0  # in outage; kick is moot until recovery
        exc_pair[c, :] += cfg.excite_links
        exc_pair[:, c] += cfg.excite_links
        exc_worker[c] += cfg.excite_workers

    def fire_pair(ca, cb, now):
        # One concrete directed cross link of the pair; busy links are
        # skipped (the candidate is thinned, no event).
        i = int(rng.choice(members[ca]))
        m = int(rng.choice(members[cb]))
        if (i, m) in busy_links:
            return
        dur = float(rng.uniform(*cfg.degrade_len))
        factor = float(rng.uniform(*cfg.degrade_factor))
        events.append(LinkDegrade(i, m, now, now + dur, factor, symmetric=False))
        busy_links[(i, m)] = now + dur

    def fire_worker(c, now):
        present = [int(w) for w in members[c] if w not in departed]
        if not present:
            return
        w = int(rng.choice(present))
        dur = float(rng.uniform(*cfg.blip_len))
        events.append(WorkerLeave(w, now))
        events.append(WorkerRejoin(w, now + dur))
        departed[w] = now + dur
        wakeups.append(now + dur)

    while t < horizon and len(events) < cfg.max_events:
        purge(t)
        lam_c, lam_p, lam_w = intensities(t)
        total = float(lam_c.sum() + lam_p.sum() + lam_w.sum())
        pending = sorted(w for w in wakeups if w > t)
        next_wake = min(pending[0] if pending else np.inf, forced)
        if total <= 1e-12:
            if next_wake >= horizon:
                break
            advance(next_wake - t)
            t = next_wake
            if t == forced:
                if outage_until[trigger_cluster] <= t:
                    fire_cluster(trigger_cluster, t)
                forced = np.inf
            continue
        dt = float(rng.exponential(1.0 / total))
        if t + dt >= next_wake:
            # A suppressed domain re-enters (or the forced trigger fires)
            # before the candidate: jump there and rebuild the bound.
            advance(next_wake - t)
            t = next_wake
            if t == forced:
                if outage_until[trigger_cluster] <= t:
                    fire_cluster(trigger_cluster, t)
                forced = np.inf
            continue
        advance(dt)
        t += dt
        if t >= horizon:
            break
        # Thinning: accept with prob lambda(t)/bound, then pick the domain
        # proportional to its share of the *current* intensity.
        purge(t)
        lam_c, lam_p, lam_w = intensities(t)
        now_total = float(lam_c.sum() + lam_p.sum() + lam_w.sum())
        if rng.uniform() * total > now_total:
            continue
        u = rng.uniform() * now_total
        if u < lam_c.sum():
            fire_cluster(int(np.searchsorted(np.cumsum(lam_c), u)), t)
            continue
        u -= lam_c.sum()
        if u < lam_p.sum():
            flat = int(np.searchsorted(np.cumsum(lam_p.ravel()), u))
            fire_pair(flat // nc, flat % nc, t)
            continue
        u -= lam_p.sum()
        fire_worker(int(np.searchsorted(np.cumsum(lam_w), u)), t)

    if np.isfinite(forced) and forced < horizon and len(events) < cfg.max_events:
        # Candidate stream ended before reaching the trigger (tiny rates):
        # the exogenous first strike still fires.
        if outage_until[trigger_cluster] <= forced:
            fire_cluster(trigger_cluster, forced)
    return Timeline(events)


def storm(
    topology,
    seed: int,
    horizon: float,
    *,
    intensity: float = 1.0,
    trigger_cluster: int | None = None,
    trigger_time: float = 0.0,
    worker_blips: bool = True,
    max_events: int = 200,
) -> Timeline:
    """The headline cascading-storm preset (tuned for fleet populations).

    ``intensity`` scales every rate and excitation together: 1.0 is a
    rough storm over a 4-cluster fleet; the PR-7 ``federated_cohorts``
    populations compose via ``worker_blips=False`` (the cohort preset
    already owns worker churn — double-booking a worker would fail the
    leave-twice validation, by design).
    """
    s = float(intensity)
    if not (s > 0 and np.isfinite(s)):
        raise ValueError(f"intensity must be finite > 0, got {intensity}")
    cfg = HazardConfig(
        base_cluster_rate=0.002 * s,
        base_degrade_rate=0.0005 * s,
        base_worker_rate=0.0005 * s,
        excite_spread=0.02 * s,
        excite_links=0.05 * s,
        excite_workers=0.04 * s,
        worker_blips=worker_blips,
        max_events=max_events,
    )
    return hazard_timeline(
        topology,
        seed,
        horizon,
        cfg,
        trigger_cluster=trigger_cluster,
        trigger_time=trigger_time,
    )
