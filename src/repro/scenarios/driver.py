"""Engine-side scenario machinery shared by ALL four execution loops.

The reference and batched engines must make byte-identical host-side
decisions on the same timeline — that is the engine-parity contract — so
every decision that a scenario adds to a loop lives here, written once:

* ``attempt_fails``      — does this event's pull cross a currently-dead
  link?  (Consumes no RNG; advances the link model to the event time, which
  is exactly what ``event_timing`` would do a moment later.)
* ``notify_monitor``     — forward a timeout to the Monitor; returns the
  new (possibly earlier) wake time for the out-of-schedule refresh.
* ``monitor_reach``      — which workers can currently exchange control
  traffic with a home-cluster-pinned Monitor (None = omniscient legacy
  Monitor, i.e. ``home_cluster`` unset or no scenario attached).
* ``publish_policy``     — deliver (P, rho) only to reachable workers;
  the far side of a partition keeps training on its stale policy.
* ``monitor_boundary``   — one whole Monitor wake: failover
  heartbeat/lease tick and deterministic re-election (DESIGN.md §18),
  chaos-injected report drops / lost publishes, collect, step, publish.
  Both engines call this one function at identical virtual times, so
  every failover and chaos decision is made exactly once per wake and
  parity is preserved by construction.
* ``apply_action``       — apply one churn action to loop state: heap
  membership, active set, EMA reset, and replica reseeding (via a
  caller-supplied callback, because the two engines store replicas
  differently — per-replica lists vs stacked trees).
* ``prepare_monitor``    — give the Monitor the topology (for failure-
  domain escalation) and a reroute delay derived from the link timeout.
"""

from __future__ import annotations

import numpy as np

from repro.core.monitor import IterationTimeEMA
from repro.scenarios.timeline import WorkerLeave, WorkerRejoin


def prepare_monitor(monitor, link_model) -> None:
    """Default the Monitor's scenario knobs off the link model.

    The reroute delay models detection honestly: a worker only *knows* a
    pull failed once the timeout elapses, so the out-of-schedule refresh
    fires one ``dead_link_timeout`` after the first failure — by which
    point every worker that touched the dead domain has evidence pending,
    and one refresh masks the whole failure domain.
    """
    if monitor is None:
        return
    if monitor.failover is not None and monitor.home_cluster is None:
        raise ValueError(
            "Monitor failover requires a home-pinned control plane: set "
            "monitor_home_cluster (an omniscient Monitor has no home to "
            "fail over from)"
        )
    if link_model.compiled_scenario is None:
        return
    if monitor.topology is None:
        monitor.topology = link_model.topology
    if monitor.reroute_delay is None:
        monitor.reroute_delay = link_model.dead_link_timeout


def attempt_fails(link_model, algo, state, i, m, t: float) -> bool:
    """True when the event's pull would cross a scenario-dead link.

    Called only when a scenario is attached; advancing the link model here
    (instead of inside ``event_timing``) is idempotent for the same ``t``,
    so RNG consumption is unchanged and identical across engines.
    """
    if m is None or not algo.would_communicate(state, i, m):
        return False
    link_model.advance_to(t)
    return link_model.link_dead(i, m)


def monitor_reach(monitor, link_model, t: float):
    """Per-worker control-plane reachability for a home-pinned Monitor.

    Returns ``(reach_in, reach_out)`` boolean (M,) arrays — worker ``j``'s
    reports arrive at the Monitor iff ``reach_in[j]``, and the Monitor's
    policy publish lands on ``j`` iff ``reach_out[j]`` — or None for the
    legacy omniscient Monitor (``home_cluster`` unset, or no scenario, so
    the control plane shares fate with nothing).  Both directions follow
    the sparse segment's *directed* semantics: a one-direction WAN outage
    can lose reports while publishes still land, and vice versa.
    """
    if monitor is None or monitor.home_cluster is None or link_model is None:
        return None
    link_model.advance_to(t)
    seg = link_model.current_segment
    if seg is None:
        return None
    home = int(monitor.home_cluster)
    cl = seg.cluster
    cross = cl != home
    reach_in = ~(seg.dead_out | (cross & (seg.wan_out[cl] | seg.wan_in[home])))
    reach_out = ~(seg.dead_in | (cross & (seg.wan_out[home] | seg.wan_in[cl])))
    return reach_in, reach_out


def publish_policy(algo, state, pol, reach_out=None) -> None:
    """Deliver a fresh (P, rho) — but only to workers the Monitor reaches.

    ``reach_out=None`` (omniscient Monitor) is the legacy full publish.
    Otherwise unreachable workers keep their stale P rows and their stale
    per-worker consensus step (``AlgoState.rho_vec``): the far side of a
    partition keeps training on the last policy it heard.
    """
    if reach_out is None:
        algo.on_policy(state, pol)
        return
    reach_out = np.asarray(reach_out, dtype=bool)
    if reach_out.all():
        algo.on_policy(state, pol)
        state.rho_vec = None  # everyone heard the same rho again
        return
    old_P = state.P.copy()
    old_rho = np.array([state.rho_of(i) for i in range(state.M)])
    algo.on_policy(state, pol)
    stale = ~reach_out
    P = np.array(state.P, copy=True)  # never mutate pol.P via aliasing
    P[stale, :] = old_P[stale, :]
    state.P = P
    rho_vec = np.full(state.M, state.rho, dtype=float)
    rho_vec[stale] = old_rho[stale]
    state.rho_vec = None if np.all(rho_vec == state.rho) else rho_vec


def failover_tick(monitor, seg, t: float) -> bool:
    """One heartbeat/lease/election step for a failover-enabled Monitor.

    Pure function of ``(segment, virtual time, failover state)`` — no RNG —
    called once per Monitor wake by ``monitor_boundary``.  Returns True
    when a live leader holds the control plane after the tick (the refresh
    proceeds, from the *new* vantage point if an election just happened)
    and False when the leader's cluster is dead and no standby quorum
    could elect (the refresh is skipped; workers keep training on their
    last published per-worker policy rows).

    Semantics (DESIGN.md §18):

    * A cluster hosts a standby iff at least one of its workers is present
      (``~seg.dead_out`` — churn can empty a cluster and take the standby
      with it).  WAN outages partition a standby but do not kill it.
    * Heartbeats ride the directed WAN: a live leader that can transmit
      (``not wan_out[home]``) renews the lease of every live standby that
      can receive (``not wan_in[c]``) at this wake.  Leases are lazily
      initialised to 0.0, so a leader partitioned from boot is already
      lease-expired at the first wake past the lease.
    * A standby whose lease has been silent for ``lease_periods`` schedule
      periods becomes an elector.  The lowest-id live, fully-WAN-connected
      elector wins if its votes (itself plus every other elector whose
      vote can reach it) meet the quorum (default: majority of clusters —
      a minority partition can then never elect a second leader).
    * ``adopt_leader`` re-homes the Monitor and renews every lease, so the
      old leader's cluster coming back does not immediately re-elect.
    """
    fo = monitor.failover
    home = int(monitor.home_cluster)
    cl = seg.cluster
    nc = len(seg.wan_out)
    alive = np.zeros(nc, dtype=bool)
    alive[np.unique(cl[~seg.dead_out])] = True
    for c in range(nc):
        fo.last_heartbeat.setdefault(c, 0.0)
    if alive[home]:
        fo.last_heartbeat[home] = t
        if not seg.wan_out[home]:
            for c in range(nc):
                if c != home and alive[c] and not seg.wan_in[c]:
                    fo.last_heartbeat[c] = t
    lease = fo.lease_periods * monitor.schedule_period
    electors = [
        c
        for c in range(nc)
        if c != home and alive[c] and t - fo.last_heartbeat[c] >= lease
    ]
    if electors:
        quorum = fo.quorum if fo.quorum is not None else nc // 2 + 1
        for cand in electors:  # ascending cluster id: deterministic winner
            if seg.wan_out[cand] or seg.wan_in[cand]:
                continue  # a WAN-cut candidate could not lead anyone
            votes = 1 + sum(1 for s in electors if s != cand and not seg.wan_out[s])
            if votes >= quorum:
                monitor.adopt_leader(cand, t)
                return True
    if alive[home]:
        return True  # leader present (possibly partitioned): refresh runs
    fo.n_skipped_refreshes += 1
    return False


def monitor_boundary(
    monitor, algo, state, link_model, emas, active, t: float, chaos=None
):
    """One whole Monitor wake, shared verbatim by every engine loop.

    Failover tick (maybe re-homing the Monitor), chaos-filtered report
    collection, Algorithm-1 step, chaos-aware publish.  Returns the fresh
    ``PolicyResult`` — or None when a dead leader and no quorum skipped
    the refresh — and the caller logs it and advances ``next_monitor``.
    Both engines call this at identical virtual times with identical
    arguments, so every failover and chaos decision is made exactly once
    per wake and reference-vs-batched parity holds by construction.
    """
    if monitor.failover is not None and link_model is not None:
        link_model.advance_to(t)
        seg = link_model.current_segment
        if seg is not None and not failover_tick(monitor, seg, t):
            return None
    reach = monitor_reach(monitor, link_model, t)
    reports = {
        j: emas[j].snapshot()
        for j in range(monitor.n_workers)
        if j in active and (reach is None or reach[0][j])
    }
    if chaos is not None:
        reports = {j: r for j, r in reports.items() if not chaos.drop_report(j, t)}
    monitor.collect(reports)
    pol = monitor.step()
    if chaos is not None and chaos.publish_lost(t, monitor.schedule_period):
        # Publish delayed past the next refresh: it never lands anywhere.
        publish_policy(algo, state, pol, np.zeros(monitor.n_workers, dtype=bool))
    else:
        publish_policy(algo, state, pol, None if reach is None else reach[1])
    return pol


def notify_monitor(
    monitor, i: int, m: int, t: float, next_monitor: float, link_model=None
) -> float:
    """Report a timed-out pull; possibly pull the next Monitor wake earlier
    (the out-of-schedule Eq.-14 refresh).  A home-pinned Monitor never sees
    reports from workers it cannot currently reach — the notification is
    simply lost in the partition."""
    if monitor is None:
        return next_monitor
    if link_model is not None:
        reach = monitor_reach(monitor, link_model, t)
        if reach is not None and not reach[0][i]:
            return next_monitor
    wake = monitor.notify_failure(i, m, t)
    if wake is not None and wake < next_monitor:
        return wake
    return next_monitor


def apply_action(
    act,
    *,
    active: set,
    reseed,
    rng=None,
    heap=None,
    emas: list | None = None,
    ema_beta: float = 0.5,
) -> None:
    """Apply one churn action to loop state (see module docstring).

    ``reseed(worker, src)`` copies ``src``'s replica into ``worker``'s row
    and zeroes its momentum — ``train/elastic.py`` provides both storage
    forms.  Async loops pass ``heap``/``emas``/``rng``; the synchronous
    round loops have none of the three (churn there is link-state plus the
    rejoin reseed; the barrier still spans all M workers — non-adaptive
    round strategies pay the timeout, which is the point).

    ``heap`` is a ``train.events.EventHeap``: a leave marks the worker's
    entry dead in O(1) (lazy invalidation — the stale entry is skipped when
    it surfaces) instead of the old O(M) prune-and-reheapify, which made
    the ``federated_cohorts`` t=0 leave storm O(M^2) at boot.
    """
    w = act.worker
    if isinstance(act, WorkerLeave):
        active.discard(w)
        if heap is not None:
            heap.invalidate(w)
    elif isinstance(act, WorkerRejoin):
        active.add(w)
        src = act.seed_from
        if src is None:
            others = [a for a in active if a != w]
            if not others:  # compile() validates this away; be loud anyway
                raise RuntimeError(
                    f"rejoin of worker {w} at t={act.time}: no live worker "
                    "to reseed from"
                )
            src = min(others)
        reseed(w, src)
        if emas is not None:
            emas[w] = IterationTimeEMA(len(emas), beta=ema_beta)
        if heap is not None:
            heap.push(act.time + rng.exponential(0.005), w)
    else:  # pragma: no cover - compile() only emits churn actions
        raise TypeError(f"unexpected scenario action {act!r}")
