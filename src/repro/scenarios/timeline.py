"""Declarative network-dynamics timelines (DESIGN.md §14).

A ``Timeline`` is a plain list of scheduled events over *virtual* time:

* ``ClusterOutage``   — WAN (``inter_cluster``) links touching one cluster
  are dead during ``[start, end)`` (paper §V: a whole cluster drops off
  the wide-area network; the Monitor must re-route around it).
  ``direction`` narrows the cut: ``"out"`` kills only pulls *originating*
  in the cluster, ``"in"`` only pulls *targeting* it, ``"both"`` (default)
  kills both directions.
* ``LinkDegrade``     — one link's transfer time is multiplied by
  ``factor`` during ``[start, end)`` (bandwidth degradation/restoration).
* ``WorkerLeave`` / ``WorkerRejoin`` — elastic churn: a departed worker
  generates no events, all its links are dead, and on rejoin its replica is
  reseeded from a live neighbor (``train/elastic.py``).

``Timeline.compile(topology)`` turns the event list into an immutable
piecewise **link-state machine**: a sorted sequence of segments, each
holding *sparse* directed link state — per-worker dead flags, per-cluster
WAN-outage flags, and a degraded-edge map, O(M) per segment instead of
(M, M) — plus the sorted churn *actions* the simulation loops must apply
(heap membership and replica reseeding are loop-side effects; pure link
state is not).  Dense ``Segment.dead`` / ``Segment.degrade`` matrices are
still available as lazily-materialized views for dense consumers
(``LinkTimeModel.matrix``, tests); fleet-scale hot paths use the O(1)
``Segment.link_dead`` / ``Segment.degrade_factor`` queries and never
allocate (M, M).

The compiled form is runtime-free: ``LinkTimeModel`` keeps its own segment
pointer (advanced by ``advance_to``) and every engine loop walks its own
``ScenarioCursor``, so one compiled timeline can drive any number of
independent, bit-identical runs.

Everything here is deterministic and consumes **no RNG** — scenario state
is a pure function of virtual time, which is what keeps the reference and
batched engines bit-exact on the same timeline (tests/test_engines.py).
Seedable *generation* of timelines lives in ``repro.scenarios.presets``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class ClusterOutage:
    """``inter_cluster`` links touching ``cluster`` are dead during
    ``[start, end)``; intra-cluster links keep working.  ``direction``
    selects which directed links die: ``"out"`` — pulls *by* the cluster's
    workers across the WAN; ``"in"`` — pulls *from* the cluster by outside
    workers; ``"both"`` (default) — the symmetric cut."""

    cluster: int
    start: float
    end: float
    direction: str = "both"


@dataclass(frozen=True)
class LinkDegrade:
    """Multiply the transfer time of link (i, m) by ``factor`` during
    ``[start, end)``; ``symmetric`` applies it to both directions."""

    i: int
    m: int
    start: float
    end: float
    factor: float
    symmetric: bool = True


@dataclass(frozen=True)
class WorkerLeave:
    """Worker departs at ``time``: no more events, all its links dead."""

    worker: int
    time: float


@dataclass(frozen=True)
class WorkerRejoin:
    """Worker returns at ``time``; its replica is reseeded from
    ``seed_from`` (default: the lowest-indexed active worker)."""

    worker: int
    time: float
    seed_from: int | None = None


#: Churn event types the simulation loops must act on (vs pure link state).
ACTION_EVENTS = (WorkerLeave, WorkerRejoin)


class Segment:
    """One piece of the piecewise link state: valid on [start, next start).

    Link state is **sparse** — O(M + n_clusters + #degraded-edges) per
    segment, never (M, M):

    * ``dead_out[i]``  — every link *from* worker ``i`` is dead (churn).
    * ``dead_in[m]``   — every link *to* worker ``m`` is dead (churn).
    * ``wan_out[c]``   — WAN pulls *by* workers in cluster ``c`` are dead.
    * ``wan_in[c]``    — WAN pulls *from* cluster ``c`` are dead.
    * ``degrade_map``  — ``{(i, m): factor}`` for degraded directed links.

    Directed link i->m is dead iff ``dead_out[i] or dead_in[m]`` or the
    endpoints sit in different clusters and ``wan_out[cluster[i]] or
    wan_in[cluster[m]]``.  The dense ``.dead`` / ``.degrade`` matrices
    materialize lazily for dense consumers (``LinkTimeModel.matrix``,
    tests); fleet-scale hot paths use ``link_dead`` / ``degrade_factor``
    and never allocate (M, M).
    """

    __slots__ = (
        "start", "dead_out", "dead_in", "wan_out", "wan_in",
        "degrade_map", "cluster", "_dead_dense", "_degrade_dense",
    )

    def __init__(
        self, start, dead_out, dead_in, wan_out, wan_in, degrade_map, cluster
    ):
        self.start = float(start)
        self.dead_out = dead_out  # (M,) bool
        self.dead_in = dead_in  # (M,) bool
        self.wan_out = wan_out  # (n_clusters,) bool
        self.wan_in = wan_in  # (n_clusters,) bool
        self.degrade_map = degrade_map  # {(i, m): float}
        self.cluster = cluster  # (M,) int, shared across segments
        self._dead_dense = None
        self._degrade_dense = None

    # -- O(1) directed queries (the fleet-scale hot path) --------------------
    def link_dead(self, i: int, m: int) -> bool:
        if i == m:
            return False
        if self.dead_out[i] or self.dead_in[m]:
            return True
        ci, cm = self.cluster[i], self.cluster[m]
        return bool(ci != cm and (self.wan_out[ci] or self.wan_in[cm]))

    def degrade_factor(self, i: int, m: int) -> float:
        return self.degrade_map.get((i, m), 1.0)

    @property
    def nbytes(self) -> int:
        """Host memory held by this segment's link state (O(M), pinned by
        the fleet-scale regression test)."""
        arrays = (self.dead_out, self.dead_in, self.wan_out, self.wan_in)
        return sum(a.nbytes for a in arrays) + 64 * len(self.degrade_map)

    # -- dense views (lazy; Monitor/matrix()/test paths only) ----------------
    @property
    def dead(self) -> np.ndarray:
        """(M, M) bool, directed: link i->m is dead.  Materialized lazily —
        O(M^2); never touched by the event loops."""
        if self._dead_dense is None:
            c = self.cluster
            wan = c[:, None] != c[None, :]
            dead = (
                self.dead_out[:, None]
                | self.dead_in[None, :]
                | (wan & (self.wan_out[c][:, None] | self.wan_in[c][None, :]))
            )
            np.fill_diagonal(dead, False)
            self._dead_dense = dead
        return self._dead_dense

    @property
    def degrade(self) -> np.ndarray:
        """(M, M) float multiplier on transfer time (lazy dense view)."""
        if self._degrade_dense is None:
            M = len(self.dead_out)
            degrade = np.ones((M, M))
            for (i, m), f in self.degrade_map.items():
                degrade[i, m] = f
            self._degrade_dense = degrade
        return self._degrade_dense


@dataclass(frozen=True)
class CompiledTimeline:
    """Immutable compiled form; see module docstring."""

    n_workers: int
    segments: tuple  # Segment, ascending start; segments[0].start == -inf
    actions: tuple  # churn events sorted by (time, worker-leave-first)
    boundaries: tuple  # every distinct event time (window-split points)
    events: tuple  # the original declarative events, for introspection

    def segment_index(self, now: float, hint: int = 0) -> int:
        """Index of the segment containing ``now`` (monotonic ``hint``
        makes repeated forward queries O(1) amortized)."""
        k = hint
        segs = self.segments
        while k + 1 < len(segs) and now >= segs[k + 1].start:
            k += 1
        return k

    def dead_intervals(self, i: int, m: int) -> tuple:
        """Maximal ``[start, end)`` windows during which directed link
        i->m is scenario-dead.  Every ``timeout`` record a traced run
        (repro.trace) carries for that link must start inside one of these
        windows — the cross-check tests/test_trace.py pins."""
        out = []
        open_start = None
        for seg in self.segments:
            dead = seg.link_dead(i, m)
            if dead and open_start is None:
                open_start = seg.start
            elif not dead and open_start is not None:
                out.append((open_start, seg.start))
                open_start = None
        if open_start is not None:
            out.append((open_start, float("inf")))
        return tuple(out)

    @property
    def nbytes(self) -> int:
        """Total host memory of the compiled link state — O(M) per segment
        (the fleet-scale memory regression pin sums this)."""
        return sum(seg.nbytes for seg in self.segments)

    def active_workers(self, now: float) -> np.ndarray:
        """Workers present at ``now`` (before applying actions at ``now``
        itself: an action at exactly ``now`` counts as already fired,
        matching the loops' fire-before-the-crossing-event convention)."""
        active = np.ones(self.n_workers, dtype=bool)
        for act in self.actions:
            if act.time > now:
                break
            active[act.worker] = isinstance(act, WorkerRejoin)
        return active


class ScenarioCursor:
    """A loop's private walk over a compiled timeline's boundaries.

    The engines use two operations, both pure host logic so the reference
    and batched loops stay bit-identical:

    * ``next_time`` — the earliest unprocessed boundary.  The batched
      engine flushes its current window/round block before this time, so
      no fused cohort or scan chain ever spans a scenario boundary.
    * ``pop_due(t)`` — consume every boundary with time <= ``t`` (the next
      unit of work's start time) and return the churn actions among them,
      in order.  Link-state boundaries return nothing (the LinkTimeModel
      advances itself); they still split windows.
    """

    def __init__(self, compiled: CompiledTimeline):
        self._boundaries = compiled.boundaries
        self._actions = compiled.actions
        self._bi = 0
        self._ai = 0

    @property
    def next_time(self) -> float:
        if self._bi >= len(self._boundaries):
            return float("inf")
        return self._boundaries[self._bi]

    def pop_due(self, t: float) -> list:
        while self._bi < len(self._boundaries) and self._boundaries[self._bi] <= t:
            self._bi += 1
        due = []
        while self._ai < len(self._actions) and self._actions[self._ai].time <= t:
            due.append(self._actions[self._ai])
            self._ai += 1
        return due


@dataclass
class Timeline:
    """Declarative event list; ``compile`` validates and freezes it."""

    events: list = field(default_factory=list)

    def add(self, *events) -> "Timeline":
        self.events.extend(events)
        return self

    # -- validation ---------------------------------------------------------
    def _validate(self, topology) -> None:
        M = topology.n_workers
        nc = topology.n_clusters
        pending: dict[int, bool] = {}  # worker -> currently departed
        # Overlap detection per failure domain: two events occupying the
        # same directed domain over intersecting [start, end) windows would
        # compile into an ambiguous segment machine (outage flags OR
        # silently, degrade factors *multiply* silently) — reject loudly
        # instead.  Domains: (cluster, wan-direction) for outages, the
        # directed link (i, m) for degrades (a symmetric degrade occupies
        # both directions).
        outage_spans: dict[tuple, list] = {}
        degrade_spans: dict[tuple, list] = {}
        # Same (time, rank) order compile() and the runtime use — equal-time
        # leaves fire before rejoins, and validation must see that order.
        for e in sorted(self.events, key=lambda e: (_event_time(e), _event_rank(e))):
            if isinstance(e, ClusterOutage):
                if not (0 <= e.cluster < nc):
                    raise ValueError(
                        f"ClusterOutage cluster {e.cluster} out of range "
                        f"(topology has {nc} clusters)"
                    )
                if not (np.isfinite(e.start) and e.start >= 0 and e.start < e.end):
                    raise ValueError(f"ClusterOutage needs 0 <= start < end, got {e}")
                if e.direction not in ("both", "out", "in"):
                    raise ValueError(
                        f"ClusterOutage direction must be 'both', 'out' or "
                        f"'in', got {e.direction!r}"
                    )
                dirs = ("out", "in") if e.direction == "both" else (e.direction,)
                for dr in dirs:
                    _note_span(
                        outage_spans,
                        (e.cluster, dr),
                        e,
                        f"cluster {e.cluster} WAN-{dr} outage",
                    )
            elif isinstance(e, LinkDegrade):
                if not (0 <= e.i < M and 0 <= e.m < M and e.i != e.m):
                    raise ValueError(f"LinkDegrade endpoints invalid: {e}")
                if not (e.factor > 0 and np.isfinite(e.factor)):
                    raise ValueError(f"LinkDegrade factor must be finite > 0: {e}")
                if not (np.isfinite(e.start) and e.start >= 0 and e.start < e.end):
                    raise ValueError(f"LinkDegrade needs 0 <= start < end, got {e}")
                links = ((e.i, e.m), (e.m, e.i)) if e.symmetric else ((e.i, e.m),)
                for lk in links:
                    _note_span(degrade_spans, lk, e, f"link {lk[0]}->{lk[1]} degrade")
            elif isinstance(e, WorkerLeave):
                if not (0 <= e.worker < M) or not (np.isfinite(e.time) and e.time >= 0):
                    raise ValueError(f"WorkerLeave worker/time invalid: {e}")
                if pending.get(e.worker, False):
                    raise ValueError(f"worker {e.worker} leaves twice without a rejoin")
                pending[e.worker] = True
            elif isinstance(e, WorkerRejoin):
                if not (0 <= e.worker < M) or not (np.isfinite(e.time) and e.time >= 0):
                    raise ValueError(f"WorkerRejoin worker/time invalid: {e}")
                if e.seed_from is not None and not (
                    0 <= e.seed_from < M and e.seed_from != e.worker
                ):
                    raise ValueError(f"WorkerRejoin seed_from invalid: {e}")
                if not pending.get(e.worker, False):
                    raise ValueError(f"worker {e.worker} rejoins without having left")
                pending[e.worker] = False
            else:
                raise TypeError(f"unknown scenario event {e!r}")

    # -- compilation --------------------------------------------------------
    def compile(self, topology) -> CompiledTimeline:
        """Freeze into the piecewise link-state machine (module docstring)."""
        self._validate(topology)
        M = topology.n_workers
        events = tuple(
            sorted(self.events, key=lambda e: (_event_time(e), _event_rank(e)))
        )
        actions = tuple(e for e in events if isinstance(e, ACTION_EVENTS))

        times = set()
        for e in events:
            if isinstance(e, ACTION_EVENTS):
                times.add(float(e.time))
            else:
                times.add(float(e.start))
                times.add(float(e.end))
        boundaries = tuple(sorted(t for t in times if np.isfinite(t)))

        # Churn compiles to dead-link intervals too: a departed worker's
        # links are down from leave to rejoin (or forever).
        churn_intervals: list[tuple[int, float, float]] = []
        open_since: dict[int, float] = {}
        for a in actions:
            if isinstance(a, WorkerLeave):
                open_since[a.worker] = a.time
            else:
                churn_intervals.append((a.worker, open_since.pop(a.worker), a.time))
        for w, t0 in open_since.items():
            churn_intervals.append((w, t0, float("inf")))

        # Sparse link state needs only the cluster id per worker — the old
        # dense (M, M) WAN mask is recovered lazily by Segment.dead.
        cluster = np.array([topology.cluster_of(i) for i in range(M)])
        nc = topology.n_clusters

        def state_at(t0: float) -> Segment:
            dead_out = np.zeros(M, dtype=bool)
            dead_in = np.zeros(M, dtype=bool)
            wan_out = np.zeros(nc, dtype=bool)
            wan_in = np.zeros(nc, dtype=bool)
            degrade_map: dict[tuple[int, int], float] = {}
            for e in events:
                if isinstance(e, ClusterOutage) and e.start <= t0 < e.end:
                    if e.direction in ("both", "out"):
                        wan_out[e.cluster] = True
                    if e.direction in ("both", "in"):
                        wan_in[e.cluster] = True
                elif isinstance(e, LinkDegrade) and e.start <= t0 < e.end:
                    key = (e.i, e.m)
                    degrade_map[key] = degrade_map.get(key, 1.0) * e.factor
                    if e.symmetric:
                        rkey = (e.m, e.i)
                        degrade_map[rkey] = degrade_map.get(rkey, 1.0) * e.factor
            for w, a, b in churn_intervals:
                if a <= t0 < b:
                    dead_out[w] = True
                    dead_in[w] = True
            return Segment(
                t0, dead_out, dead_in, wan_out, wan_in, degrade_map, cluster
            )

        # Segment 0 covers (-inf, first boundary): nothing is active yet.
        pre = boundaries[0] - 1.0 if boundaries else 0.0
        seg0 = state_at(pre)
        seg0.start = float("-inf")
        segments = (seg0,) + tuple(state_at(s) for s in boundaries)

        # A timeline must never depopulate the run, and every automatic
        # rejoin needs a live reseed source — validated by replaying the
        # actions in the exact runtime order (equal-time leaves fire before
        # rejoins; the active set may be empty transiently *within* one
        # instant, but never after it, and a rejoin's automatic source is
        # whatever is live at its own fire point).
        live = set(range(M))
        for k, a in enumerate(actions):
            if isinstance(a, WorkerLeave):
                live.discard(a.worker)
            else:
                if a.seed_from is None and not (live - {a.worker}):
                    raise ValueError(
                        f"worker {a.worker} rejoins at t={a.time} with no "
                        "live worker to reseed from"
                    )
                live.add(a.worker)
            group_ends = k + 1 == len(actions) or actions[k + 1].time != a.time
            if group_ends and not live:
                raise ValueError(
                    f"timeline leaves zero active workers at t={a.time}"
                )

        return CompiledTimeline(
            n_workers=M,
            segments=segments,
            actions=actions,
            boundaries=boundaries,
            events=events,
        )


def _note_span(spans: dict, domain, e, what: str) -> None:
    """Record ``e``'s [start, end) against ``domain``; raise on overlap.

    Events arrive in ascending start order (the caller iterates the sorted
    list), so overlap with the previous span on the same domain is the
    only case to check — half-open windows may abut (a.end == b.start)."""
    prev = spans.get(domain)
    if prev is not None and e.start < prev[1]:
        raise ValueError(
            f"overlapping same-domain events: {what} [{e.start}, {e.end}) "
            f"overlaps an earlier event on the same domain "
            f"[{prev[0]}, {prev[1]})"
        )
    if prev is None or e.end > prev[1]:
        spans[domain] = (e.start, e.end)


def _event_time(e) -> float:
    return float(e.time if isinstance(e, ACTION_EVENTS) else e.start)


def _event_rank(e) -> int:
    """Equal-time determinism: leaves before rejoins, link events last."""
    if isinstance(e, WorkerLeave):
        return 0
    if isinstance(e, WorkerRejoin):
        return 1
    return 2
