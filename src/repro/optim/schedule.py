"""Learning-rate schedules (host-side callables: step -> lr)."""

from __future__ import annotations

import numpy as np


def constant(lr: float):
    return lambda step: lr


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = min(step / max(total_steps, 1), 1.0)
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + np.cos(np.pi * t)))

    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)

    def f(step):
        if step < warmup:
            return lr * (step + 1) / warmup
        return cos(step - warmup)

    return f


class step_decay_on_plateau:
    """Paper §V: 'lr starts at 0.1 and decays by 10x once the loss stops
    decreasing'.  Stateful host-side schedule."""

    def __init__(self, lr: float, factor: float = 0.1, patience: int = 200, tol: float = 1e-3):
        self.lr = lr
        self.factor = factor
        self.patience = patience
        self.tol = tol
        self.best = np.inf
        self.bad = 0

    def observe(self, loss: float) -> None:
        if loss < self.best - self.tol:
            self.best = loss
            self.bad = 0
        else:
            self.bad += 1
            if self.bad >= self.patience:
                self.lr *= self.factor
                self.bad = 0

    def __call__(self, step: int) -> float:
        return self.lr
