"""Optimizers (own implementation — no optax): SGD+momentum+WD, AdamW.

Functional API mirroring the standard (init, update) pair; update returns
*updates* (deltas) so the trainer controls application order — NetMax applies
the consensus mix AFTER the local step (Alg. 2: first update then pull-mix).

All states are pytrees matching params; elementwise ops broadcast over any
leading stacking dims (NetMax worker replicas keep independent momenta).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, state)

    def apply(self, params, updates):
        return jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
            params,
            updates,
        )


def sgd(momentum: float = 0.9, weight_decay: float = 0.0, nesterov: bool = False) -> Optimizer:
    """Paper §V config: SGD, momentum 0.9, weight decay 1e-4."""

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, lr):
        def one(g, p, m=None):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if m is None:
                return -lr * g, None
            m_new = momentum * m + g
            step = g + momentum * m_new if nesterov else m_new
            return -lr * step, m_new

        if momentum == 0.0:
            upd = jax.tree_util.tree_map(lambda g, p: one(g, p)[0], grads, params)
            return upd, state
        out = jax.tree_util.tree_map(one, grads, params, state["m"])
        upd = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return upd, {"m": m}

    return Optimizer(init, update)


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def one(g, p, m, v):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step, m_new, v_new

        out = jax.tree_util.tree_map(one, grads, params, state["m"], state["v"])
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return pick(0), {"m": pick(1), "v": pick(2), "t": t}

    return Optimizer(init, update)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), n
