"""Host-side data loading: stacked worker batches with background prefetch.

Wraps a seekable source (TokenStream-style ``batch(worker, step)``) into the
(M, b, ...) stacked arrays the trainer consumes, overlapping host batch
assembly with device compute via a one-deep prefetch thread — the standard
input-pipeline shape for a synchronous training loop.

Determinism contract: batches are a pure function of (worker, step), so
checkpoint resume replays the identical stream (test_substrates.py).
"""

from __future__ import annotations

import queue
import threading

import jax.numpy as jnp
import numpy as np


class StackedLoader:
    def __init__(self, source, n_workers: int, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.M = n_workers
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _assemble(self, step: int) -> dict:
        per = [self.source.batch(w, step) for w in range(self.M)]
        return {
            k: jnp.asarray(np.stack([p[k] for p in per])) for k in per[0]
        }

    def _produce(self) -> None:
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._assemble(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
