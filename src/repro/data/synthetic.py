"""Synthetic datasets: LM token streams + classification sets.

The LM stream is a deterministic, seekable generator (worker, step) ->
batch, so checkpoint/restart reproduces the exact data order (tested in
test_checkpoint.py).  Classification sets power the paper-reproduction
benchmarks (convergence/accuracy claims on small models).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenStream:
    """Markov-chain token stream with learnable structure (so loss actually
    decreases) — per-worker shards are disjoint by seed."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    order: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish transition structure: each token prefers ~8 successors
        k = 8
        self._succ = rng.integers(0, self.vocab_size, size=(self.vocab_size, k))

    def batch(self, worker: int, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + worker) * 1_000_003 + step
        )
        B, S = self.batch_size, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=B)
        for t in range(S):
            choice = rng.integers(0, self._succ.shape[1], size=B)
            nxt = self._succ[toks[:, t], choice]
            noise = rng.random(B) < 0.1
            nxt = np.where(noise, rng.integers(0, self.vocab_size, size=B), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def classification_dataset(
    n: int, dim: int, n_classes: int, seed: int = 0, margin: float = 1.0
):
    """Linearly-separable-ish gaussian blobs (paper-repro small models)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, dim)) * margin * 2
    y = rng.integers(0, n_classes, size=n)
    x = centers[y] + rng.normal(size=(n, dim))
    return x.astype(np.float32), y.astype(np.int32)


def train_eval_split(n_train: int, n_eval: int, dim: int, n_classes: int,
                     seed: int = 0, margin: float = 1.0):
    """Train/eval from the SAME distribution (same class centers)."""
    x, y = classification_dataset(n_train + n_eval, dim, n_classes, seed=seed, margin=margin)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]


def mnist_like(n: int = 8192, seed: int = 0):
    """28x28-ish synthetic digits: 10 classes, blob + structured noise."""
    x, y = classification_dataset(n, 64, 10, seed=seed, margin=1.2)
    return x, y
