"""Data pipeline: synthetic streams, partitioning (uniform / non-IID), loaders."""
