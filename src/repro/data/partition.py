"""Dataset partitioning across workers (paper §V-A/F).

uniform:     equal IID shards
size_skewed: workers get <2,1,2,1,...> segments (paper §V-F non-uniform)
non_iid:     label-skewed shards — each worker LOSES a set of labels
             (paper Table IV / Table VII cross-cloud setup)
"""

from __future__ import annotations

import numpy as np


def uniform_partition(n: int, M: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(idx, M)]


def size_skewed_partition(
    n: int, M: int, segments: list[int], seed: int = 0
) -> list[np.ndarray]:
    """Worker i receives segments[i] shares of the data (paper: batch size
    scales with segment count)."""
    assert len(segments) == M
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    total = sum(segments)
    bounds = np.cumsum([0] + [int(round(n * s / total)) for s in segments])
    bounds[-1] = n
    return [np.sort(idx[bounds[i] : bounds[i + 1]]) for i in range(M)]


def non_iid_partition(
    labels: np.ndarray, M: int, lost_labels: list[list[int]], seed: int = 0
) -> list[np.ndarray]:
    """Each worker sees all data EXCEPT its lost labels, partitioned
    disjointly among the workers that can hold each label."""
    rng = np.random.default_rng(seed)
    n = len(labels)
    assert len(lost_labels) == M
    holders: dict[int, list[int]] = {}
    for lab in np.unique(labels):
        holders[int(lab)] = [i for i in range(M) if int(lab) not in lost_labels[i]]
    parts: list[list[int]] = [[] for _ in range(M)]
    for lab, workers in holders.items():
        idx = np.where(labels == lab)[0]
        idx = rng.permutation(idx)
        if not workers:
            continue
        for j, chunk in enumerate(np.array_split(idx, len(workers))):
            parts[workers[j]].extend(chunk.tolist())
    return [np.sort(np.asarray(p, dtype=np.int64)) for p in parts]
