"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Attention-free recurrence (paper arXiv:2404.05892).  Per head (dim N):

    state_t = diag(w_t) @ state_{t-1} + k_t v_t^T          (N x N state)
    y_t     = r_t @ (state_{t-1} + diag(u) k_t v_t^T)

with w_t = exp(-exp(w0 + lora_w(x_t))) the data-dependent decay.  The
training path scans over time (XLA); the chunked matmul-form TPU kernel
lives in repro.kernels.rwkv_scan with this as its oracle.  Decode carries
(state, shift) — O(1) per token, which is why rwkv6 serves long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import lecun_normal, rmsnorm, rmsnorm_init


def timemix_init(key, cfg, dtype):
    D = cfg.d_model
    N = cfg.rwkv.head_dim
    H = D // N
    L = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 9)
    return {
        "wr": lecun_normal(ks[0], (D, D), dtype),
        "wk": lecun_normal(ks[1], (D, D), dtype),
        "wv": lecun_normal(ks[2], (D, D), dtype),
        "wg": lecun_normal(ks[3], (D, D), dtype),
        "wo": lecun_normal(ks[4], (D, D), dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + (x A) B))
        "w0": jnp.zeros((D,), jnp.float32) - 6.0,
        "wA": lecun_normal(ks[5], (D, L), dtype),
        "wB": lecun_normal(ks[6], (L, D), dtype),
        "u": (jax.random.normal(ks[7], (H, N), jnp.float32) * 0.1),
        # token-shift mixing coefficients
        "mu_r": jnp.full((D,), 0.5, dtype),
        "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_v": jnp.full((D,), 0.5, dtype),
        "mu_g": jnp.full((D,), 0.5, dtype),
        "mu_w": jnp.full((D,), 0.5, dtype),
        "ln_x": {"scale": jnp.ones((D,), dtype)},
    }


def _token_shift(x, x_prev):
    """shift: x_{t-1} for t>0; x_prev feeds position 0. x: (B,S,D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def timemix_apply(p, x, cfg, state=None, x_prev=None):
    """x: (B,S,D) -> (y, (state, last_x)).  state: (B,H,N,N) f32."""
    B, S, D = x.shape
    N = cfg.rwkv.head_dim
    H = D // N
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    if x_prev is None:
        x_prev = jnp.zeros((B, D), x.dtype)

    xs = _token_shift(x, x_prev)
    xr = x + (xs - x) * p["mu_r"]
    xk = x + (xs - x) * p["mu_k"]
    xv = x + (xs - x) * p["mu_v"]
    xg = x + (xs - x) * p["mu_g"]
    xw = x + (xs - x) * p["mu_w"]

    r = (xr @ p["wr"]).reshape(B, S, H, N).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, S, H, N).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, S, H, N).astype(jnp.float32)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    # data-dependent decay in (0,1): w = exp(-exp(w0 + lora))
    lora = (xw @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))  # (B,S,D)
    w = w.reshape(B, S, H, N)
    u = p["u"]  # (H,N)

    def step(st, inp):
        rt, kt, vt, wt = inp  # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, st + u[None, :, :, None] * kv)
        st_new = wt[..., :, None] * st + kv
        return st_new, y

    rs = jnp.moveaxis(r, 1, 0)  # (S,B,H,N)
    ks_ = jnp.moveaxis(k, 1, 0)
    vs = jnp.moveaxis(v, 1, 0)
    ws = jnp.moveaxis(w, 1, 0)
    from repro.models.scan_utils import chunked_scan

    state, ys = chunked_scan(step, state, (rs, ks_, vs, ws), chunk=64)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)  # (B,S,D)
    y = rmsnorm(p["ln_x"], y.astype(x.dtype))
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    return y @ p["wo"], (state, x[:, -1, :])


def channelmix_init(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wk": lecun_normal(ks[0], (D, F), dtype),
        "wv": lecun_normal(ks[1], (F, D), dtype, fan_in=F),
        "wr": lecun_normal(ks[2], (D, D), dtype),
        "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_r": jnp.full((D,), 0.5, dtype),
    }


def channelmix_apply(p, x, x_prev=None):
    B, S, D = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, D), x.dtype)
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid((xr @ p["wr"]).astype(jnp.float32)).astype(x.dtype)
    return r * (k @ p["wv"]), x[:, -1, :]


def rwkv_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "time_mix": timemix_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "channel_mix": channelmix_init(k2, cfg, dtype),
    }


def rwkv_block_apply(p, x, cfg, state=None):
    """state: None (train from zeros) or dict(tm_state, tm_x, cm_x)."""
    tm_state = state["tm_state"] if state else None
    tm_x = state["tm_x"] if state else None
    cm_x = state["cm_x"] if state else None
    h, (tm_state, tm_x) = timemix_apply(p["time_mix"], rmsnorm(p["ln1"], x), cfg, tm_state, tm_x)
    x = x + h
    h, cm_x = channelmix_apply(p["channel_mix"], rmsnorm(p["ln2"], x), cm_x)
    x = x + h
    return x, {"tm_state": tm_state, "tm_x": tm_x, "cm_x": cm_x}


def rwkv_init_state(cfg, B, dtype):
    D = cfg.d_model
    N = cfg.rwkv.head_dim
    H = D // N
    return {
        "tm_state": jnp.zeros((B, H, N, N), jnp.float32),
        "tm_x": jnp.zeros((B, D), dtype),
        "cm_x": jnp.zeros((B, D), dtype),
    }
