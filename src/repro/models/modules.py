"""Primitive NN modules as (init, apply) function pairs over dict pytrees."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# -- initializers -----------------------------------------------------------


def lecun_normal(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# -- linear -------------------------------------------------------------------


def linear_init(key, d_in, d_out, dtype, bias=False):
    p = {"w": lecun_normal(key, (d_in, d_out), dtype, fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# -- norms --------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(kind)


# -- rotary position embeddings ----------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- activations ---------------------------------------------------------------


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def mlp_init(key, d_model, d_ff, dtype, activation="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": lecun_normal(k1, (d_model, d_ff), dtype),
            "w_up": lecun_normal(k2, (d_model, d_ff), dtype),
            "w_down": lecun_normal(k3, (d_ff, d_model), dtype, fan_in=d_ff),
        }
    return {
        "w_up": lecun_normal(k1, (d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": lecun_normal(k2, (d_ff, d_model), dtype, fan_in=d_ff),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def mlp(p, x, activation="swiglu"):
    if activation == "swiglu":
        h = swiglu(x @ p["w_gate"], x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu((x @ p["w_up"] + p["b_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ p["w_down"] + p["b_down"]


# -- embeddings -----------------------------------------------------------------


def embedding_init(key, vocab, d_model, dtype):
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embedding_lookup(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def sinusoidal_positions(S: int, d: int) -> jnp.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((S, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def pick_chunk(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (chunked scans need S % c == 0)."""
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))
