"""Mamba (selective SSM) block — the SSM component of Jamba (arXiv:2403.19887).

    x, z = in_proj(u)                       # (B,S,Di) each, Di = expand*D
    x = silu(causal_depthwise_conv(x))
    dt, B_, C = x_proj(x)                   # dt: (B,S,Di) via dt_rank
    h_t = exp(dt*A) * h_{t-1} + dt*B_ * x_t  # per-channel state (Di, N)
    y = C . h + D_skip*x ;  out = out_proj(y * silu(z))

Training scans over time (XLA while loop — O(1) HLO); decode is a single
state update, so Jamba's mamba layers serve long_500k in O(1) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import lecun_normal


def mamba_init(key, cfg, dtype):
    D = cfg.d_model
    mc = cfg.mamba
    Di = mc.expand * D
    N = mc.d_state
    R = mc.dt_rank or max(1, D // 16)
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (Di, 1))
    return {
        "w_in": lecun_normal(ks[0], (D, 2 * Di), dtype),
        "conv_w": lecun_normal(ks[1], (mc.d_conv, Di), dtype, fan_in=mc.d_conv),
        "conv_b": jnp.zeros((Di,), dtype),
        "w_x": lecun_normal(ks[2], (Di, R + 2 * N), dtype),
        "w_dt": lecun_normal(ks[3], (R, Di), dtype, fan_in=R),
        "b_dt": jnp.log(jnp.expm1(jnp.full((Di,), 0.01, jnp.float32))),  # softplus^-1
        "A_log": jnp.log(A),
        "D_skip": jnp.ones((Di,), jnp.float32),
        "w_out": lecun_normal(ks[4], (Di, D), dtype, fan_in=Di),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv along S. x: (B,S,Di); w: (K,Di).

    Returns (y, new_conv_state) where conv_state caches the last K-1 inputs
    for decode.
    """
    B, S, Di = x.shape
    K = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, Di), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)  # (B, S+K-1, Di)
    # sum_k w[k] * x[t-K+1+k]
    y = sum(xp[:, k : k + S, :] * w[k] for k in range(K)) + b
    return y, xp[:, -(K - 1) :, :]


def mamba_apply(p, u, cfg, state=None):
    """u: (B,S,D) -> (y, new_state). state = dict(ssm (B,Di,N) f32, conv)."""
    B, S, D = u.shape
    mc = cfg.mamba
    Di = mc.expand * D
    N = mc.d_state
    R = mc.dt_rank or max(1, D // 16)

    xz = u @ p["w_in"]
    x, z = jnp.split(xz, 2, axis=-1)  # (B,S,Di)
    conv_state = state["conv"] if state else None
    x, conv_state = _causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    x = jax.nn.silu(x.astype(jnp.float32)).astype(u.dtype)

    proj = x @ p["w_x"]  # (B,S,R+2N)
    dt_r, B_, C = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["w_dt"]).astype(jnp.float32) + p["b_dt"])  # (B,S,Di)
    A = -jnp.exp(p["A_log"])  # (Di,N)

    xf = x.astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,Di),(B,Di),(B,N),(B,N)
        dA = jnp.exp(dtt[..., None] * A)  # (B,Di,N)
        dBx = (dtt * xt)[..., None] * bt[:, None, :]  # (B,Di,N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = state["ssm"] if state else jnp.zeros((B, Di, N), jnp.float32)
    xs = jnp.moveaxis(xf, 1, 0)
    dts = jnp.moveaxis(dt, 1, 0)
    bs = jnp.moveaxis(Bf, 1, 0)
    cs = jnp.moveaxis(Cf, 1, 0)
    from repro.models.scan_utils import chunked_scan

    h, ys = chunked_scan(step, h0, (xs, dts, bs, cs), chunk=64)
    y = jnp.moveaxis(ys, 0, 1) + xf * p["D_skip"]  # (B,S,Di)
    y = y.astype(u.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    out = y @ p["w_out"]
    return out, {"ssm": h, "conv": conv_state}


def mamba_init_state(cfg, B, dtype):
    mc = cfg.mamba
    Di = mc.expand * cfg.d_model
    return {
        "ssm": jnp.zeros((B, Di, mc.d_state), jnp.float32),
        "conv": jnp.zeros((B, mc.d_conv - 1, Di), dtype),
    }
