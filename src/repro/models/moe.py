"""Mixture-of-Experts MLP with top-k routing and capacity-based dispatch.

Dispatch uses scatter/gather (not the one-hot (T,E,C) einsum) so the buffers
stay O(E*C*D) — required at 1M-token global batches.  Tokens route per
"group" (= one sequence), giving the partitioner a batch dim to shard; with
experts sharded over the model axis the expert einsum induces the canonical
EP all-to-all in the lowered collective schedule.

Aux losses: load-balancing (Switch-style) returned for the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.modules import lecun_normal


def moe_init(key, cfg, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    return {
        "w_router": lecun_normal(ks[0], (D, E), jnp.float32),
        "w_gate": lecun_normal(ks[1], (E, D, F), dtype),
        "w_up": lecun_normal(ks[2], (E, D, F), dtype),
        "w_down": lecun_normal(ks[3], (E, F, D), dtype, fan_in=F),
    }


def _capacity(tokens_per_group: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(tokens_per_group * top_k * factor / n_experts)
    return max(c, top_k)


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (y, aux_loss).  Groups = sequences (B)."""
    B, S, D = x.shape
    E = cfg.moe.n_experts
    K = cfg.moe.top_k
    C = _capacity(S, E, K, cfg.moe.capacity_factor)

    logits = (x.astype(jnp.float32) @ p["w_router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, k) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (B,S,K,E)
    flat = onehot.reshape(B, S * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (B,S*K,E) exclusive
    pos = (pos_in_expert * flat).sum(-1).reshape(B, S, K)  # (B,S,K)
    keep = pos < C  # dropped tokens beyond capacity
    gate_vals = gate_vals * keep

    # Scatter tokens into (B, E, C, D).
    e_flat = expert_idx.reshape(B, S * K)
    pos_flat = jnp.where(keep, pos, C).reshape(B, S * K)  # C = overflow slot
    xk = jnp.repeat(x[:, :, None, :], K, axis=2).reshape(B, S * K, D)
    buf = jnp.zeros((B, E, C + 1, D), x.dtype)
    b_idx = jnp.arange(B)[:, None]
    buf = buf.at[b_idx, e_flat, pos_flat].add(xk)
    buf = buf[:, :, :C]  # drop overflow slot

    # Expert FFN: (B,E,C,D) x (E,D,F) — EP-sharded over the model axis.
    h = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum("becf,efd->becd", h, p["w_down"])  # (B,E,C,D)

    # Gather back and combine with gate weights.
    out_pad = jnp.concatenate([out, jnp.zeros((B, E, 1, D), out.dtype)], axis=2)
    picked = out_pad[b_idx, e_flat, pos_flat]  # (B,S*K,D)
    picked = picked.reshape(B, S, K, D)
    y = (picked.astype(jnp.float32) * gate_vals[..., None]).sum(axis=2).astype(x.dtype)

    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    me = probs.mean(axis=(0, 1))  # mean router prob per expert
    ce = (onehot.sum(2).reshape(B, S, E).mean(axis=(0, 1))).astype(jnp.float32) / K
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_param_count(cfg) -> tuple[int, int]:
    """(total expert params, active expert params) per layer."""
    D, F, E, K = cfg.d_model, cfg.d_ff, cfg.moe.n_experts, cfg.moe.top_k
    per_expert = 3 * D * F
    return E * per_expert + D * E, K * per_expert + D * E
