"""LM entry points: loss, train forward, prefill, decode — family-dispatched.

The loss is computed in *sequence chunks* (scan) so the (B, S, V) logits
tensor is never materialized — at vocab 152k x 1M tokens that buffer would
be 320 GB; chunked it stays O(B * chunk * V / devices).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer, whisper
from repro.models.modules import pick_chunk


def chunked_ce_loss(x, w_head, labels, mask=None, chunk: int = 512):
    """Cross-entropy over vocab without materializing full logits.

    x: (B,S,D); w_head: (D,V); labels: (B,S) int32; mask: (B,S) or None.
    """
    B, S, D = x.shape
    chunk = pick_chunk(S, chunk)
    n = S // chunk
    xs = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    ms = (
        jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)
        if mask is not None
        else jnp.ones((n, B, chunk), jnp.float32)
    )

    def body(acc, inp):
        xc, lc, mc = inp
        logits = (xc @ w_head).astype(jnp.float32)  # (B,chunk,V)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig, aux_weight: float = 0.01):
    """batch: {'tokens': (B,S), 'labels': (B,S), ['vis_embeds'|'frames']}."""
    if cfg.family == "audio":
        enc_out = whisper.encode(params, batch["frames"], cfg)
        x = whisper.decode_train(params, batch["tokens"], enc_out, cfg)
        w = params["lm_head"]["w"]
        return chunked_ce_loss(x, w, batch["labels"])
    x, aux = transformer.forward(
        params, batch["tokens"], cfg, vis_embeds=batch.get("vis_embeds")
    )
    if cfg.n_vis_tokens:
        x = x[:, cfg.n_vis_tokens :, :]  # loss over text positions only
    w = params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    loss = chunked_ce_loss(x, w, batch["labels"])
    return loss + aux_weight * aux


def init_params(cfg: ArchConfig, key):
    if cfg.family == "audio":
        return whisper.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def abstract_params(cfg: ArchConfig):
    if cfg.family == "audio":
        return whisper.abstract_params(cfg)
    return transformer.abstract_params(cfg)


def init_cache(cfg: ArchConfig, B: int, S: int):
    if cfg.family == "audio":
        return whisper.init_cache(cfg, B, S)
    return transformer.init_cache(cfg, B, S)


def abstract_cache(cfg: ArchConfig, B: int, S: int):
    if cfg.family == "audio":
        return whisper.abstract_cache(cfg, B, S)
    return transformer.abstract_cache(cfg, B, S)


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    if cfg.family == "audio":
        return whisper.decode_step(params, cache, token, pos, cfg)
    return transformer.decode_step(params, cache, token, pos, cfg)


def prefill_logits(params, batch, cfg: ArchConfig):
    if cfg.family == "audio":
        enc_out = whisper.encode(params, batch["frames"], cfg)
        x = whisper.decode_train(params, batch["tokens"], enc_out, cfg)
        return (x[:, -1, :] @ params["lm_head"]["w"]).astype(jnp.float32)
    return transformer.prefill(
        params, batch["tokens"], cfg, vis_embeds=batch.get("vis_embeds")
    )[:, 0, :]


def param_count(cfg: ArchConfig) -> int:
    import numpy as np

    tree = abstract_params(cfg)
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k of n_experts expert params)."""
    import numpy as np

    total = 0
    tree = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        sz = int(np.prod(leaf.shape))
        names = "/".join(str(p) for p in path)
        if cfg.moe is not None and any(k in names for k in ("w_gate", "w_up", "w_down")) and "moe" in names:
            sz = sz * cfg.moe.top_k // cfg.moe.n_experts
        total += sz
    return total
