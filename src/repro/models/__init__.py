"""Model zoo: pure-JAX (pytree params + functions), no framework deps.

transformer.py builds every assigned decoder-LM family (dense / MoE / SSM /
hybrid) from the blocks in attention.py / moe.py / rwkv.py / mamba.py;
whisper.py adds the encoder-decoder; lm.py provides train/serve entry points.
"""
