"""Modality frontend STUBS (per assignment: `[audio]`/`[vlm]` entries
specify the transformer BACKBONE; the frontend supplies precomputed
frame/patch embeddings).

These helpers generate the stand-in embeddings used by input_specs() and
the smoke tests, with the *shapes and scaling* a real frontend would
produce, so swapping in a trained ViT/conv encoder is a drop-in change.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def vit_patch_stub(key, cfg: ArchConfig, batch: int) -> jnp.ndarray:
    """InternViT patch embeddings: (B, n_vis_tokens, d_model), unit RMS.

    A real InternViT-300M runs 448x448 crops -> 1024 patches -> pixel
    shuffle to 256 tokens -> MLP projector into the LM width; the stub
    reproduces the interface contract (token count + width + scale).
    """
    x = jax.random.normal(key, (batch, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    return x / jnp.sqrt(jnp.float32(cfg.d_model)) * jnp.float32(cfg.d_model) ** 0.5 * 0.02


def audio_frame_stub(key, cfg: ArchConfig, batch: int) -> jnp.ndarray:
    """Whisper frame embeddings: (B, enc_seq_len, d_model).

    A real frontend is two strided 1-D convs over an 80-bin log-mel
    spectrogram (3000 frames -> 1500); the stub provides the post-conv
    activations at the encoder's expected scale.
    """
    x = jax.random.normal(key, (batch, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    return x * 0.02


def frontend_for(cfg: ArchConfig):
    if cfg.family == "vlm":
        return vit_patch_stub
    if cfg.family == "audio":
        return audio_frame_stub
    return None
