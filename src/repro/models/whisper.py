"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, D).  The transformer
backbone is real: a bidirectional encoder and a causal decoder with
cross-attention, learned positions, LayerNorm + GELU (whisper conventions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.modules import (
    embedding_init,
    embedding_lookup,
    layernorm,
    layernorm_init,
    lecun_normal,
    mlp,
    pick_chunk,
    mlp_init,
    sinusoidal_positions,
)


def _dt(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, "gelu"),
    }


def dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model, dtype),
        "self_attn": attn.attn_init(k1, cfg, dtype),
        "ln_x": layernorm_init(cfg.d_model, dtype),
        "cross_attn": attn.attn_init(k2, cfg, dtype),
        "ln2": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype, "gelu"),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = _dt(cfg)
    ks = jax.random.split(key, cfg.n_enc_layers + cfg.n_layers + 3)
    enc = _stack([enc_block_init(ks[i], cfg, dtype) for i in range(cfg.n_enc_layers)])
    dec = _stack(
        [dec_block_init(ks[cfg.n_enc_layers + i], cfg, dtype) for i in range(cfg.n_layers)]
    )
    return {
        "embed": embedding_init(ks[-1], cfg.vocab_size, cfg.d_model, dtype),
        "dec_pos": {"table": lecun_normal(ks[-2], (32768, cfg.d_model), dtype)},  # sized to the max assigned decode shape
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_norm": layernorm_init(cfg.d_model, dtype),
        "final_norm": layernorm_init(cfg.d_model, dtype),
        "lm_head": {"w": lecun_normal(ks[-3], (cfg.d_model, cfg.vocab_size), dtype)},
    }


def abstract_params(cfg: ArchConfig) -> dict:
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def encode(params, frames, cfg: ArchConfig):
    """frames: (B, S_enc, D) precomputed embeddings (frontend stub)."""
    S = frames.shape[1]
    x = frames + sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)

    def body(carry, blk):
        h = attn.attn_apply(blk["attn"], layernorm(blk["ln1"], carry), cfg,
                            causal=False, rope=False,
                            q_chunk=pick_chunk(S, 512), kv_chunk=pick_chunk(S, 1024))
        carry = carry + h
        h = mlp(blk["mlp"], layernorm(blk["ln2"], carry), "gelu")
        return carry + h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layernorm(params["enc_norm"], x)


def decode_train(params, tokens, enc_out, cfg: ArchConfig):
    """Teacher-forced decoder -> hidden states (B, S, D)."""
    B, S = tokens.shape
    x = embedding_lookup(params["embed"], tokens)
    x = x + params["dec_pos"]["table"][:S]
    qc, kc = pick_chunk(S, 512), pick_chunk(S, 1024)

    def body(carry, blk):
        h = attn.attn_apply(blk["self_attn"], layernorm(blk["ln1"], carry), cfg,
                            causal=True, rope=False, q_chunk=qc, kv_chunk=kc)
        carry = carry + h
        h = attn.cross_attn_apply(blk["cross_attn"], layernorm(blk["ln_x"], carry),
                                  enc_out, cfg, q_chunk=qc,
                                  kv_chunk=pick_chunk(enc_out.shape[1], 1024))
        carry = carry + h
        h = mlp(blk["mlp"], layernorm(blk["ln2"], carry), "gelu")
        return carry + h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return layernorm(params["final_norm"], x)


def init_cache(cfg: ArchConfig, B: int, S: int):
    """Decoder self-attn KV cache + cross-attn KV (computed at prefill)."""
    dtype = _dt(cfg)
    Hk, hd = cfg.n_kv_heads, cfg.hd
    Se = cfg.enc_seq_len
    one = lambda: {
        "k": jnp.zeros((B, S, Hk, hd), dtype),
        "v": jnp.zeros((B, S, Hk, hd), dtype),
        "xk": jnp.zeros((B, Se, Hk, hd), dtype),
        "xv": jnp.zeros((B, Se, Hk, hd), dtype),
    }
    return _stack([one() for _ in range(cfg.n_layers)])


def abstract_cache(cfg: ArchConfig, B: int, S: int):
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    """One decoder token against self cache + fixed cross KV."""
    x = embedding_lookup(params["embed"], token[:, None])
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"]["table"], pos, 1, axis=0)
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd

    def body(carry, blk_cache):
        blk, c = blk_cache
        h = layernorm(blk["ln1"], carry)
        q = (h @ blk["self_attn"]["wq"]).reshape(B, 1, H, hd)
        k = (h @ blk["self_attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v = (h @ blk["self_attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        c = dict(c)
        c["k"] = jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype), pos, axis=1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype), pos, axis=1)
        o = attn.decode_attention(q, c["k"], c["v"], length=pos + 1)
        carry = carry + o.reshape(B, 1, -1) @ blk["self_attn"]["wo"]
        # cross attention against precomputed encoder KV
        h = layernorm(blk["ln_x"], carry)
        q = (h @ blk["cross_attn"]["wq"]).reshape(B, 1, H, hd)
        o = attn.decode_attention(q, c["xk"], c["xv"])
        carry = carry + o.reshape(B, 1, -1) @ blk["cross_attn"]["wo"]
        h = mlp(blk["mlp"], layernorm(blk["ln2"], carry), "gelu")
        return carry + h, c

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = layernorm(params["final_norm"], x)
    logits = x[:, 0, :] @ params["lm_head"]["w"]
    return logits.astype(jnp.float32), new_cache
