"""GQA attention: chunked (flash-style) training/prefill + KV-cache decode.

The training path is an online-softmax computation chunked over the KV axis
(lax.scan) so no O(S^2) buffer is ever materialized — the same algorithm the
Pallas kernel (repro.kernels.flash_attention) implements with explicit VMEM
BlockSpecs; this XLA version is its reference and the path used for dry-run
lowering on the CPU backend.

Causal block skipping: KV chunks strictly in the future of a whole Q chunk
contribute nothing; the scan skips their compute via jnp.where on the chunk
index (lax.cond is avoided to stay vmap-friendly; the select lets XLA skip
the masked FLOPs on TPU via predication, and the roofline accounting treats
the skip explicitly — see analysis/roofline.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.modules import apply_rope, lecun_normal

NEG_INF = -1e30

# §Perf hillclimb toggle ("noselect"): the explicit carry select for fully
# masked causal KV blocks is mathematically redundant — masked scores are
# NEG_INF, so exp() = 0 and the online update is already the identity
# (corr = exp(m - max(m, -inf)) = 1).  The select costs 3 full-carry
# read/writes per KV step in the XLA lowering.  Baseline keeps it (explicit
# skip semantics); the optimized variant drops it.
CAUSAL_CARRY_SELECT = True


def _pad_q(w, D, Hk, G, Hke, Gn, hd):
    """Pad q-projection (D, Hk*G*hd) -> (D, Hke*Gn*hd) with zeros placed
    PER GROUP so original q heads keep their kv-group assignment."""
    w4 = w.reshape(D, Hk, G, hd)
    w4 = jnp.pad(w4, ((0, 0), (0, Hke - Hk), (0, Gn - G), (0, 0)))
    return w4.reshape(D, Hke * Gn * hd)


def _pad_o(w, Hk, G, Hke, Gn, hd, D):
    """Pad out-projection rows (H*hd, D) group-aligned with _pad_q."""
    w4 = w.reshape(Hk, G, hd, D)
    w4 = jnp.pad(w4, ((0, Hke - Hk), (0, Gn - G), (0, 0), (0, 0)))
    return w4.reshape(Hke * Gn * hd, D)


def attn_init(key, cfg, dtype):
    """Projections sized to the EFFECTIVE (TP-padded) head counts.

    Padding is group-interleaved and zero-initialized, so padded heads are
    exactly inert: their q rows are zero AND their wo rows are zero, and
    original heads keep their kv-group mapping (tested in test_models_smoke).
    """
    H, Hk, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    He, Hke = cfg.n_heads_eff, cfg.n_kv_heads_eff
    G, Gn = H // Hk, He // Hke
    assert He == Hke * Gn, "pad_heads must keep H_eff = Hk_eff * G_eff"
    ks = jax.random.split(key, 4)
    wq = lecun_normal(ks[0], (D, H * hd), dtype)
    wk = lecun_normal(ks[1], (D, Hk * hd), dtype)
    wv = lecun_normal(ks[2], (D, Hk * hd), dtype)
    wo = lecun_normal(ks[3], (H * hd, D), dtype, fan_in=H * hd)
    if He != H or Hke != Hk:
        wq = _pad_q(wq, D, Hk, G, Hke, Gn, hd)
        wo = _pad_o(wo, Hk, G, Hke, Gn, hd, D)
        if Hke != Hk:
            wk = jnp.pad(wk.reshape(D, Hk, hd), ((0, 0), (0, Hke - Hk), (0, 0))).reshape(
                D, Hke * hd
            )
            wv = jnp.pad(wv.reshape(D, Hk, hd), ((0, 0), (0, Hke - Hk), (0, 0))).reshape(
                D, Hke * hd
            )
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((He * hd,), dtype)
        p["bk"] = jnp.zeros((Hke * hd,), dtype)
        p["bv"] = jnp.zeros((Hke * hd,), dtype)
    return p


def qkv_project(p, x, cfg, positions=None, rope=True):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,Hk,hd), with RoPE applied."""
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads_eff, cfg.n_kv_heads_eff, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hk, hd)
    v = v.reshape(B, S, Hk, hd)
    if rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


@partial(jax.jit, static_argnames=("causal", "q_chunk", "kv_chunk"))
def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention. q: (B,S,H,hd); k,v: (B,Sk,Hk,hd) -> (B,S,H,hd).

    GQA via head grouping: q heads are reshaped to (Hk, G) groups so the
    score einsum contracts against un-broadcast KV (no KV duplication).
    """
    B, S, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = S // q_chunk, Sk // kv_chunk
    assert S % q_chunk == 0 and Sk % kv_chunk == 0
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qg = q.reshape(B, nq, q_chunk, Hk, G, hd)
    ks = k.reshape(B, nk, kv_chunk, Hk, hd)
    vs = v.reshape(B, nk, kv_chunk, Hk, hd)
    # scan over kv chunks; carry the online-softmax stats for all q chunks.
    ks_t = jnp.moveaxis(ks, 1, 0)  # (nk, B, kv_chunk, Hk, hd)
    vs_t = jnp.moveaxis(vs, 1, 0)

    q_pos = jnp.arange(S).reshape(nq, q_chunk)  # global positions

    def step(carry, blk):
        acc, m, l = carry
        kb, vb, kidx = blk
        s = jnp.einsum(
            "bnqhgd,bkhd->bnqhgk", qg.astype(jnp.float32), kb.astype(jnp.float32)
        ) * scale  # (B,nq,qc,Hk,G,kc)
        if causal:
            k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
            mask = q_pos[None, :, :, None, None, None] >= k_pos[None, None, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bnqhgk,bkhd->bnqhgd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        if causal and CAUSAL_CARRY_SELECT:
            # Whole chunk in the future of every query in this q-chunk:
            # keep the previous carry.  (Redundant with the NEG_INF masking —
            # see CAUSAL_CARRY_SELECT; retained in the baseline lowering.)
            fully_masked = (kidx * kv_chunk) > q_pos[:, -1]  # (nq,)
            fm = fully_masked[None, :, None, None, None]
            acc_new = jnp.where(fm[..., None], acc, acc_new)
            l_new = jnp.where(fm, l, l_new)
            m_new = jnp.where(fm, m, m_new)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, nq, q_chunk, Hk, G, hd), jnp.float32)
    m0 = jnp.full((B, nq, q_chunk, Hk, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, q_chunk, Hk, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (ks_t, vs_t, jnp.arange(nk))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length=None):
    """Single-token attention against a (possibly sharded) KV cache.

    q: (B, 1, H, hd); caches: (B, S, Hk, hd).  Softmax over a sharded S axis
    is handled by the SPMD partitioner (all-reduce of max/sum — the
    flash-decoding LSE combine falls out of the einsum formulation).
    """
    B, _, H, hd = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, hd)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if length is not None:
        mask = jnp.arange(S)[None, None, None, :] < length
        s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attn_apply(p, x, cfg, *, causal=True, positions=None, rope=True,
               q_chunk=512, kv_chunk=1024):
    """Full attention sub-layer (projections + chunked attention + out proj)."""
    q, k, v = qkv_project(p, x, cfg, positions=positions, rope=rope)
    o = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


def cross_attn_apply(p, x, kv_src, cfg, q_chunk=512, kv_chunk=1024):
    """Encoder-decoder cross attention (whisper): KV from encoder output."""
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads_eff, cfg.n_kv_heads_eff, cfg.hd
    Se = kv_src.shape[1]
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (kv_src @ p["wk"]).reshape(B, Se, Hk, hd)
    v = (kv_src @ p["wv"]).reshape(B, Se, Hk, hd)
    o = chunked_attention(q, k, v, causal=False, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def decode_qkv(p, x, cfg, position):
    """One-token projections for serve_step. x: (B, 1, D)."""
    B = x.shape[0]
    H, Hk, hd = cfg.n_heads_eff, cfg.n_kv_heads_eff, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, Hk, hd)
    v = v.reshape(B, 1, Hk, hd)
    pos = jnp.full((B, 1), position) if jnp.ndim(position) == 0 else position[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v
