"""Decoder-LM assembly for every assigned family (dense/MoE/SSM/hybrid/VLM).

Layers are *stacked* (leading L axis per leaf) and applied with lax.scan so
the HLO stays O(1) in depth — a 48-layer 400B config lowers on one CPU core.
Hybrid (Jamba) stacks per *period* (7 mamba + 1 attention) and scans over
periods.  Each block style provides:

    init(key, cfg, dtype) -> params            (single layer)
    apply(params, x, cfg) -> x                 (train/prefill, stateless)
    decode(params, x, cache, cfg, pos) -> (x, cache)   (one token)

Caches are pytrees stacked over layers and scanned alongside params.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.modules import (
    embedding_init,
    embedding_lookup,
    lecun_normal,
    make_norm,
    mlp,
    mlp_init,
)


def _dt(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# Dense / MoE transformer block
# ---------------------------------------------------------------------------


def dense_block_init(key, cfg: ArchConfig, dtype, use_moe: bool):
    k1, k2 = jax.random.split(key)
    norm_init, _ = make_norm(cfg.norm)
    p = {
        "ln1": norm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.d_model, dtype),
    }
    if use_moe:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.activation)
    return p


def dense_block_apply(p, x, cfg: ArchConfig, causal=True, q_chunk=512, kv_chunk=1024):
    _, norm = make_norm(cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    h = attn.attn_apply(
        p["attn"], norm(p["ln1"], x), cfg, causal=causal,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    x = x + h
    if "moe" in p:
        h, aux = moe_mod.moe_apply(p["moe"], norm(p["ln2"], x), cfg)
    else:
        h = mlp(p["mlp"], norm(p["ln2"], x), cfg.activation)
    return x + h, aux


def dense_block_decode(p, x, cache, cfg: ArchConfig, pos):
    """x: (B,1,D); cache: {'k','v'}: (B,S,Hk,hd); write at pos, attend <=pos."""
    _, norm = make_norm(cfg.norm)
    h = norm(p["ln1"], x)
    q, k, v = attn.decode_qkv(p["attn"], h, cfg, pos)
    cache = {
        "k": _dus_seq(cache["k"], k, pos),
        "v": _dus_seq(cache["v"], v, pos),
    }
    o = attn.decode_attention(q, cache["k"], cache["v"], length=pos + 1)
    B = x.shape[0]
    x = x + o.reshape(B, 1, -1) @ p["attn"]["wo"]
    h = norm(p["ln2"], x)
    if "moe" in p:
        h, _ = moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        h = mlp(p["mlp"], h, cfg.activation)
    return x + h, cache


def _dus_seq(buf, val, pos):
    """Write val (B,1,...) into buf (B,S,...) at seq index pos."""
    return jax.lax.dynamic_update_slice_in_dim(buf, val.astype(buf.dtype), pos, axis=1)


def dense_cache_init(cfg: ArchConfig, B: int, S: int, dtype):
    Hk, hd = cfg.n_kv_heads_eff, cfg.hd
    return {
        "k": jnp.zeros((B, S, Hk, hd), dtype),
        "v": jnp.zeros((B, S, Hk, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MoE-interleaved period (llama4-style "every_2"): pos0 = MoE MLP,
# pos1 = dense MLP; both attention mixers.  Scanned as periods of 2 so the
# stacked-layer scan stays homogeneous.
# ---------------------------------------------------------------------------


def moe_period_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "pos0": dense_block_init(k1, cfg, dtype, use_moe=True),
        "pos1": dense_block_init(k2, cfg, dtype, use_moe=False),
    }


def moe_period_apply(p, x, cfg: ArchConfig, causal=True, q_chunk=512, kv_chunk=1024):
    x, aux0 = dense_block_apply(p["pos0"], x, cfg, causal, q_chunk, kv_chunk)
    x, aux1 = dense_block_apply(p["pos1"], x, cfg, causal, q_chunk, kv_chunk)
    return x, aux0 + aux1


def moe_period_decode(p, x, cache, cfg: ArchConfig, pos):
    x, c0 = dense_block_decode(p["pos0"], x, cache["pos0"], cfg, pos)
    x, c1 = dense_block_decode(p["pos1"], x, cache["pos1"], cfg, pos)
    return x, {"pos0": c0, "pos1": c1}


def _moe_interleaved(cfg: ArchConfig) -> bool:
    return cfg.moe is not None and cfg.moe.layout == "every_2" and cfg.family != "hybrid"


# ---------------------------------------------------------------------------
# Hybrid (Jamba) period block: (attn_period-1) mamba + 1 attention layer;
# MLPs alternate MoE (even position) / dense (odd position).
# ---------------------------------------------------------------------------


def hybrid_period_init(key, cfg: ArchConfig, dtype):
    norm_init, _ = make_norm(cfg.norm)
    P = cfg.attn_period
    ks = jax.random.split(key, 2 * P)
    p = {}
    for j in range(P):
        mixer_is_attn = j == P - 1
        use_moe = cfg.moe is not None and j % 2 == 0
        sub = {"ln1": norm_init(cfg.d_model, dtype), "ln2": norm_init(cfg.d_model, dtype)}
        if mixer_is_attn:
            sub["attn"] = attn.attn_init(ks[2 * j], cfg, dtype)
        else:
            sub["mamba"] = mam.mamba_init(ks[2 * j], cfg, dtype)
        if use_moe:
            sub["moe"] = moe_mod.moe_init(ks[2 * j + 1], cfg, dtype)
        else:
            sub["mlp"] = mlp_init(ks[2 * j + 1], cfg.d_model, cfg.d_ff, dtype, cfg.activation)
        p[f"pos{j}"] = sub
    return p


def hybrid_period_apply(p, x, cfg: ArchConfig, q_chunk=512, kv_chunk=1024):
    _, norm = make_norm(cfg.norm)
    aux_total = jnp.zeros((), jnp.float32)
    for j in range(cfg.attn_period):
        sub = p[f"pos{j}"]
        h = norm(sub["ln1"], x)
        if "attn" in sub:
            h = attn.attn_apply(sub["attn"], h, cfg, causal=True,
                                q_chunk=q_chunk, kv_chunk=kv_chunk)
        else:
            h, _ = mam.mamba_apply(sub["mamba"], h, cfg)
        x = x + h
        h = norm(sub["ln2"], x)
        if "moe" in sub:
            h, aux = moe_mod.moe_apply(sub["moe"], h, cfg)
            aux_total = aux_total + aux
        else:
            h = mlp(sub["mlp"], h, cfg.activation)
        x = x + h
    return x, aux_total


def hybrid_period_decode(p, x, cache, cfg: ArchConfig, pos):
    _, norm = make_norm(cfg.norm)
    for j in range(cfg.attn_period):
        sub = p[f"pos{j}"]
        h = norm(sub["ln1"], x)
        if "attn" in sub:
            q, k, v = attn.decode_qkv(sub["attn"], h, cfg, pos)
            c = cache[f"pos{j}"]
            c = {"k": _dus_seq(c["k"], k, pos), "v": _dus_seq(c["v"], v, pos)}
            cache[f"pos{j}"] = c
            o = attn.decode_attention(q, c["k"], c["v"], length=pos + 1)
            h = o.reshape(x.shape[0], 1, -1) @ sub["attn"]["wo"]
        else:
            h, new_state = mam.mamba_apply(sub["mamba"], h, cfg, state=cache[f"pos{j}"])
            cache[f"pos{j}"] = new_state
        x = x + h
        h = norm(sub["ln2"], x)
        if "moe" in sub:
            h, _ = moe_mod.moe_apply(sub["moe"], h, cfg)
        else:
            h = mlp(sub["mlp"], h, cfg.activation)
        x = x + h
    return x, cache


def hybrid_cache_init(cfg: ArchConfig, B: int, S: int, dtype):
    c = {}
    for j in range(cfg.attn_period):
        if j == cfg.attn_period - 1:
            c[f"pos{j}"] = dense_cache_init(cfg, B, S, dtype)
        else:
            c[f"pos{j}"] = mam.mamba_init_state(cfg, B, dtype)
    return c


# ---------------------------------------------------------------------------
# Whole-model init / apply
# ---------------------------------------------------------------------------


def n_blocks(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_period == 0
        return cfg.n_layers // cfg.attn_period
    if _moe_interleaved(cfg):
        assert cfg.n_layers % 2 == 0
        return cfg.n_layers // 2
    return cfg.n_layers


def _block_init_fn(cfg: ArchConfig):
    if cfg.family == "hybrid":
        return partial(hybrid_period_init, cfg=cfg)
    if cfg.family == "ssm":
        return partial(rwkv_mod.rwkv_block_init, cfg=cfg)
    if _moe_interleaved(cfg):
        return partial(moe_period_init, cfg=cfg)
    use_moe = cfg.moe is not None
    return lambda key, cfg=cfg, dtype=None: dense_block_init(key, cfg, dtype, use_moe)


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = _dt(cfg)
    nb = n_blocks(cfg)
    keys = jax.random.split(key, nb + 3)
    binit = _block_init_fn(cfg)
    blocks = _stack([binit(keys[i], dtype=dtype) for i in range(nb)])
    norm_init, _ = make_norm(cfg.norm)
    p = {
        "embed": embedding_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": lecun_normal(keys[-2], (cfg.d_model, cfg.vocab_size), dtype)}
    if cfg.n_vis_tokens:
        # VLM stub projection applied to precomputed patch embeddings.
        p["vis_proj"] = {"w": lecun_normal(keys[-3], (cfg.d_model, cfg.d_model), dtype)}
    return p


def abstract_params(cfg: ArchConfig) -> dict:
    """Shape-only params for the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def _chunks_for(cfg: ArchConfig, S: int) -> tuple[int, int]:
    from repro.models.modules import pick_chunk

    # q chunks chosen so the chunk count divides the model axis when the
    # sequence is model-sharded (seq-parallel attention fallback), and so
    # chunks always divide S exactly (VLM sequences are 4096-256=3840).
    target_q = max(128, min(512, S // 16)) if S >= 2048 else S
    return pick_chunk(S, target_q), pick_chunk(S, 1024)


def forward(params, tokens, cfg: ArchConfig, vis_embeds=None):
    """Train/prefill forward -> final hidden states (B, S, D) and aux loss."""
    x = embedding_lookup(params["embed"], tokens)
    if cfg.n_vis_tokens:
        assert vis_embeds is not None
        v = vis_embeds @ params["vis_proj"]["w"]
        x = jnp.concatenate([v.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    q_chunk, kv_chunk = _chunks_for(cfg, S)

    if cfg.family == "ssm":

        def body(carry, blk):
            y, _ = rwkv_mod.rwkv_block_apply(blk, carry, cfg)
            return y, jnp.zeros((), jnp.float32)

    elif cfg.family == "hybrid":

        def body(carry, blk):
            return hybrid_period_apply(blk, carry, cfg, q_chunk, kv_chunk)

    elif _moe_interleaved(cfg):

        def body(carry, blk):
            return moe_period_apply(blk, carry, cfg, True, q_chunk, kv_chunk)

    else:

        def body(carry, blk):
            return dense_block_apply(blk, carry, cfg, True, q_chunk, kv_chunk)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, params["blocks"])
    _, norm = make_norm(cfg.norm)
    x = norm(params["final_norm"], x)
    return x, auxs.sum()


def logits_head(params, x, cfg: ArchConfig):
    w = params["embed"]["table"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    return x @ w


# -- decode -----------------------------------------------------------------


def init_cache(cfg: ArchConfig, B: int, S: int):
    """Stacked per-layer decode cache (leading axis = blocks)."""
    dtype = _dt(cfg)
    nb = n_blocks(cfg)
    if cfg.family == "ssm":
        one = lambda: rwkv_mod.rwkv_init_state(cfg, B, dtype)
    elif cfg.family == "hybrid":
        one = lambda: hybrid_cache_init(cfg, B, S, dtype)
    elif _moe_interleaved(cfg):
        one = lambda: {
            "pos0": dense_cache_init(cfg, B, S, dtype),
            "pos1": dense_cache_init(cfg, B, S, dtype),
        }
    else:
        one = lambda: dense_cache_init(cfg, B, S, dtype)
    return _stack([one() for _ in range(nb)])


def abstract_cache(cfg: ArchConfig, B: int, S: int):
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    """One serve step: token (B,) int32, pos scalar -> (logits (B,V), cache)."""
    x = embedding_lookup(params["embed"], token[:, None])  # (B,1,D)

    if cfg.family == "ssm":

        def body(carry, blk_and_cache):
            blk, c = blk_and_cache
            y, c = rwkv_mod.rwkv_block_apply(blk, carry, cfg, state=c)
            return y, c

    elif cfg.family == "hybrid":

        def body(carry, blk_and_cache):
            blk, c = blk_and_cache
            return hybrid_period_decode(blk, carry, c, cfg, pos)

    elif _moe_interleaved(cfg):

        def body(carry, blk_and_cache):
            blk, c = blk_and_cache
            return moe_period_decode(blk, carry, c, cfg, pos)

    else:

        def body(carry, blk_and_cache):
            blk, c = blk_and_cache
            return dense_block_decode(blk, carry, c, cfg, pos)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    _, norm = make_norm(cfg.norm)
    x = norm(params["final_norm"], x)
    logits = logits_head(params, x[:, 0, :], cfg)
    return logits.astype(jnp.float32), new_cache


def prefill(params, tokens, cfg: ArchConfig, vis_embeds=None):
    """Prefill: forward + return logits of the last position + (for attention
    families) the KV cache is rebuilt by re-projecting — see serve.engine for
    the cache-capturing variant used in production serving."""
    x, _ = forward(params, tokens, cfg, vis_embeds=vis_embeds)
    return logits_head(params, x[:, -1:, :], cfg).astype(jnp.float32)
