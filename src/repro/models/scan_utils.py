"""Memory-bounded sequence scans: chunked remat for recurrent layers.

A plain ``lax.scan`` over S timesteps saves the carry at every step for the
backward pass — for RWKV's (B,H,N,N) state at S=4k that is tens of GB per
device.  ``chunked_scan`` reshapes time into (n_chunks, chunk) and runs an
outer scan whose body (a full inner scan over ``chunk`` steps) is wrapped in
``jax.checkpoint``: the backward pass stores only n_chunks carries and
recomputes inside each chunk.  Peak state memory drops from
O(S * state) to O((S/chunk + chunk) * state).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def chunked_scan(step_fn, init, xs, chunk: int = 64):
    """Like lax.scan(step_fn, init, xs) but with chunked rematerialization.

    xs leaves: (S, ...); returns (final_carry, ys stacked (S, ...)).
    S must be divisible by chunk (callers pad or pick chunk accordingly).
    """
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if S <= chunk:
        return jax.lax.scan(step_fn, init, xs)
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    n = S // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs
    )

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(carry, xc):
        return jax.lax.scan(step_fn, carry, xc)

    carry, ys = jax.lax.scan(chunk_body, init, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys
    )
    return carry, ys


def microbatch_scan(grad_fn, params, batch, n_micro: int):
    """Gradient accumulation: split batch leaves (M, b, ...) into n_micro
    slices along b, scan-accumulate (losses, grads).

    grad_fn(params, micro_batch) -> (loss (M,), grads).  Returns
    (mean loss (M,), mean grads).
    """
    b = jax.tree_util.tree_leaves(batch)[0].shape[1]
    n_micro = min(n_micro, b)  # dpworkers: per-worker batch may be tiny
    if n_micro <= 1:
        return grad_fn(params, batch)
    assert b % n_micro == 0, f"per-worker batch {b} not divisible by {n_micro}"
    bm = b // n_micro
    # (M, b, ...) -> (n_micro, M, bm, ...)
    split = jax.tree_util.tree_map(
        lambda a: jnp.moveaxis(
            a.reshape((a.shape[0], n_micro, bm) + a.shape[2:]), 1, 0
        ),
        batch,
    )

    def body(acc, mb):
        losses, grads = grad_fn(params, mb)
        acc_l, acc_g = acc
        acc_g = jax.tree_util.tree_map(
            lambda x, g: x + g.astype(jnp.float32), acc_g, grads
        )
        return (acc_l + losses, acc_g), None

    M = jax.tree_util.tree_leaves(batch)[0].shape[0]
    zeros_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (losses, grads), _ = jax.lax.scan(body, (jnp.zeros((M,)), zeros_g), split)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: (g * inv), grads)
    return losses * inv, grads
