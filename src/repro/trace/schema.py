"""Versioned trace schema + ingest (DESIGN.md §15).

One trace = a header (schema tag + free-form meta) and a time-ordered list
of records ``(t_start, duration, src, dst, kind)``:

* ``pull``    — worker ``src`` pulled from ``dst``; duration is the full
  event time max(compute, transfer) the simulator charged (or a measured
  pull time when ingested from an external timeline).  Pulls emitted
  *inside a synchronous round* instead carry the raw per-link network
  time the round queried (no compute floor) — that is what makes sync
  replay exact;
* ``local``   — a compute-only event (no peer, or a masked edge); dst = -1;
* ``timeout`` — the pull crossed a dead link and stalled for the timeout;
* ``round``   — one synchronous round (src = dst = -1), preceded at the
  same ``t_start`` by the per-link pulls it drew;
* ``refresh`` — a Monitor policy publish (instant; duration = 0).

Async records additionally carry ``net`` — the raw link time the event
drew before any strategy multiplier (ps-async congestion, netmax-topk
wire ratio).  Replay serves ``net`` back through the link seam so the
multipliers re-apply deterministically, making replay bit-exact for every
strategy; absent (older traces), replay falls back to ``duration``.

On disk the canonical form is JSONL: a header line ``{"schema":
"repro.trace/v1", "meta": {...}}`` followed by one object per record.  A
bare record stream (no header) is accepted on read — that is the shape an
external measurement harness most easily produces — as is CSV with columns
``t_start,duration,src,dst[,kind]`` (``read_csv``).
"""

from __future__ import annotations

import csv as _csv
import json
from dataclasses import dataclass, field

SCHEMA = "repro.trace/v1"
KINDS = ("pull", "local", "timeout", "round", "refresh")


@dataclass(frozen=True)
class TraceRecord:
    t_start: float
    duration: float
    src: int  # -1 when not worker-attributed (round / refresh)
    dst: int  # -1 when there is no peer
    kind: str
    # Raw link time the event drew (``Timing.net``), before any strategy
    # multiplier — ps-async congestion, netmax-topk wire ratio.  Replay
    # serves it back through the link seam so ``event_timing`` re-applies
    # the multipliers deterministically (bit-exact async replay for every
    # strategy).  None for records that never drew a link time and for
    # legacy/v1-early traces — replay then falls back to ``duration``,
    # exact for the unit-multiplier gossip family.
    net: float | None = None

    def validate(self) -> "TraceRecord":
        if self.kind not in KINDS:
            raise ValueError(f"unknown record kind {self.kind!r}")
        if not (self.duration >= 0.0):  # also rejects NaN
            raise ValueError(f"bad duration {self.duration!r}")
        if not (self.t_start >= 0.0):
            raise ValueError(f"bad t_start {self.t_start!r}")
        if self.net is not None and not (self.net >= 0.0):
            raise ValueError(f"bad net {self.net!r}")
        return self


@dataclass
class Trace:
    """An ingested trace: validated records in t_start order + meta."""

    records: list = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    @property
    def horizon(self) -> float:
        """Virtual time the measurements cover (max record end time)."""
        return max((r.t_start + r.duration for r in self.records), default=0.0)

    def pulls(self) -> list:
        return [r for r in self.records if r.kind == "pull"]

    def by_link(self, kinds=("pull",)) -> dict:
        """records grouped by directed link (src, dst), each in time order."""
        out: dict = {}
        for r in self.records:
            if r.kind in kinds and r.src >= 0 and r.dst >= 0:
                out.setdefault((r.src, r.dst), []).append(r)
        return out

    def counts(self) -> dict:
        out = {k: 0 for k in KINDS}
        for r in self.records:
            out[r.kind] += 1
        return out

    def topology(self):
        """Reconstruct the Topology recorded in meta (None if absent)."""
        t = self.meta.get("topology")
        if not t:
            return None
        from repro.core.nettime import Topology

        return Topology(
            n_workers=int(t["n_workers"]),
            workers_per_host=int(t.get("workers_per_host", 4)),
            hosts_per_pod=int(t.get("hosts_per_pod", 2)),
            pods_per_cluster=t.get("pods_per_cluster"),
        )


def from_sim_result(res, cfg=None, link_model=None) -> Trace:
    """Build a Trace from a ``SimConfig.trace``-enabled run.

    ``res.trace_events`` carries the per-event stream; Monitor publishes
    from ``res.policy_log`` become ``refresh`` records.  ``cfg`` and
    ``link_model`` (both optional) stamp provenance into meta — with a
    link model attached the topology round-trips, which is what lets
    ``calibrate`` map links to tiers without being told the placement.
    """
    if not res.trace_events and res.times and res.events and res.events[-1]:
        raise ValueError(
            "SimResult has no trace_events; run simulate() with "
            "SimConfig(trace=True)"
        )
    records = [
        TraceRecord(
            float(t), float(dur), int(src), int(dst), str(kind),
            net=None if net is None else float(net),
        ).validate()
        for (t, dur, src, dst, kind, _comm, _comp, net) in res.trace_events
    ]
    records.extend(
        TraceRecord(float(t), 0.0, -1, -1, "refresh")
        for (t, _rho, _P) in res.policy_log
    )
    records.sort(key=lambda r: (r.t_start, r.kind))
    meta: dict = {"engine": res.engine}
    if cfg is not None:
        meta["algorithm"] = getattr(cfg.algorithm, "name", cfg.algorithm)
        meta["n_workers"] = cfg.n_workers
        meta["seed"] = cfg.seed
        meta["total_events"] = cfg.total_events
    if link_model is not None:
        topo = link_model.topology
        meta["topology"] = {
            "n_workers": topo.n_workers,
            "workers_per_host": topo.workers_per_host,
            "hosts_per_pod": topo.hosts_per_pod,
            "pods_per_cluster": topo.pods_per_cluster,
        }
        meta["compute_time"] = link_model.compute_time
    return Trace(records=records, meta=meta)


# -- serialization -----------------------------------------------------------


def write_jsonl(trace: Trace, path) -> None:
    with open(path, "w") as f:
        f.write(json.dumps({"schema": SCHEMA, "meta": trace.meta}) + "\n")
        for r in trace.records:
            # repr-level floats: a written trace round-trips bit-exactly
            # (the replay-exactness pin in tests/test_trace.py relies on it)
            obj = {
                "t": r.t_start,
                "dur": r.duration,
                "src": r.src,
                "dst": r.dst,
                "kind": r.kind,
            }
            if r.net is not None:
                obj["net"] = r.net
            f.write(json.dumps(obj) + "\n")


def _record_from_obj(obj: dict) -> TraceRecord:
    net = obj.get("net")
    return TraceRecord(
        t_start=float(obj["t"]),
        duration=float(obj["dur"]),
        src=int(obj.get("src", -1)),
        dst=int(obj.get("dst", -1)),
        kind=str(obj.get("kind", "pull")),
        net=None if net is None else float(net),
    ).validate()


def read_jsonl(path) -> Trace:
    meta: dict = {}
    records: list = []
    with open(path) as f:
        for n, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if "schema" in obj:
                if obj["schema"] != SCHEMA:
                    raise ValueError(
                        f"{path}: unsupported trace schema {obj['schema']!r} "
                        f"(this reader speaks {SCHEMA})"
                    )
                meta = dict(obj.get("meta", {}))
                continue
            try:
                records.append(_record_from_obj(obj))
            except (KeyError, ValueError, TypeError) as e:
                raise ValueError(f"{path}:{n + 1}: bad trace record: {e}") from e
    records.sort(key=lambda r: (r.t_start, r.kind))
    return Trace(records=records, meta=meta)


def read_csv(path) -> Trace:
    """Externally-measured timeline: ``t_start,duration,src,dst[,kind]``.

    The minimal shape a measurement harness produces — kind defaults to
    ``pull``.  Extra columns are ignored; header row required.
    """
    records: list = []
    with open(path, newline="") as f:
        reader = _csv.DictReader(f)
        need = {"t_start", "duration", "src", "dst"}
        cols = set(reader.fieldnames or [])
        if not need <= cols:
            raise ValueError(
                f"{path}: CSV trace needs columns {sorted(need)}, "
                f"got {sorted(cols)}"
            )
        for n, row in enumerate(reader):
            try:
                records.append(
                    TraceRecord(
                        t_start=float(row["t_start"]),
                        duration=float(row["duration"]),
                        src=int(row["src"]),
                        dst=int(row["dst"]),
                        kind=(row.get("kind") or "pull").strip(),
                    ).validate()
                )
            except (ValueError, TypeError) as e:
                raise ValueError(f"{path}:{n + 2}: bad trace row: {e}") from e
    records.sort(key=lambda r: (r.t_start, r.kind))
    return Trace(records=records, meta={"source": "csv"})


def load_trace(path) -> Trace:
    """Load a trace by extension: ``.csv`` -> read_csv, else JSONL."""
    if str(path).endswith(".csv"):
        return read_csv(path)
    return read_jsonl(path)
