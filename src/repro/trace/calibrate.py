"""Fit LinkTimeModel parameters from an ingested trace (DESIGN.md §15).

Estimators are deliberately robust — medians and MADs, never means — so
the transient artifacts the model itself injects (the 2x-100x roaming slow
link, WAN congestion waves, timeout stalls) cannot drag the fit:

* compute time   — median ``local`` duration (a compute-only event costs
  exactly C); falls back to the minimum observed duration;
* tier bases     — per-directed-link median pull duration, then the median
  over links within each tier; missing tiers are filled from the default
  model's tier ratios; a final cummax clamp restores the documented
  ``TIERS`` ordering invariant;
* jitter         — 1.4826 * MAD of log-residuals around each link's own
  median (the lognormal sigma a robust estimator sees), from links whose
  median clears the compute floor (censored links carry no spread info);
* per-link skew  — ``link_scale`` entries for inter_cluster (WAN) directed
  links whose median deviates from the tier base (the paper's measured
  WAN asymmetry), 1.0 elsewhere.

Durations recorded by the simulator are event times max(C, N): links whose
transfer is faster than compute are *censored* — their base time is only
known to be <= C.  Calibration records those tiers in ``censored_tiers``
and pins their base at the observed median, which leaves every
``iteration_time`` query identical (the max() floor hides the difference).

The returned model disables the synthetic perturbations (no roaming slow
link) — measured traces already embed whatever slowness really happened.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.nettime import TIERS, LinkTimeModel, Topology
from repro.trace.schema import Trace

#: Relative clearance over the compute floor below which a link's median is
#: treated as censored (duration == C tells us nothing about N).
_CENSOR_EPS = 1e-6


@dataclass
class CalibrationResult:
    model: LinkTimeModel
    compute_time: float
    base_times: dict
    jitter: float
    link_scale: np.ndarray
    #: median relative |observed - predicted| / observed over uncensored pulls
    residual: float
    n_pulls: int
    censored_tiers: tuple = ()
    per_link_median: dict = field(default_factory=dict)

    def summary(self) -> str:
        bt = ", ".join(f"{t}={self.base_times[t]:.4g}" for t in TIERS)
        return (
            f"calibrated from {self.n_pulls} pulls: compute="
            f"{self.compute_time:.4g}s, {bt}, jitter={self.jitter:.3f}, "
            f"residual={self.residual:.3%}"
            + (f", censored={list(self.censored_tiers)}"
               if self.censored_tiers else "")
        )


def _median(xs) -> float:
    return float(np.median(np.asarray(list(xs), dtype=float)))


def calibrate(
    trace: Trace,
    topology: Topology | None = None,
    seed: int = 0,
    **model_kwargs,
) -> CalibrationResult:
    """Fit a fresh ``LinkTimeModel`` to ``trace`` on ``topology``.

    ``topology`` defaults to the one recorded in the trace meta.  Extra
    ``model_kwargs`` pass through to the ``LinkTimeModel`` constructor
    (e.g. ``scenario=`` or ``dead_link_timeout=``).
    """
    if topology is None:
        topology = trace.topology()
    if topology is None:
        raise ValueError(
            "calibrate() needs a Topology: none passed and the trace meta "
            "carries no placement"
        )

    by_link = trace.by_link(kinds=("pull",))
    n_pulls = sum(len(v) for v in by_link.values())
    defaults = LinkTimeModel(topology).base_times

    # -- compute time -------------------------------------------------------
    local_durs = [r.duration for r in trace.records if r.kind == "local"]
    meta_compute = trace.meta.get("compute_time")
    if local_durs:
        compute = _median(local_durs)
    elif meta_compute is not None:
        # Sync-only traces carry no "local" records, and their per-link
        # pulls are raw network times (can dip *below* compute), so the
        # min-pull floor would underestimate; the exporter's recorded
        # compute is exact.
        compute = float(meta_compute)
    elif n_pulls:
        compute = min(min(r.duration for r in v) for v in by_link.values())
    else:
        compute = LinkTimeModel(topology).compute_time

    # -- per-link medians, grouped into tiers -------------------------------
    link_med = {lk: _median(r.duration for r in v) for lk, v in by_link.items()}
    tier_meds: dict = {t: [] for t in TIERS}
    for (i, m), med in link_med.items():
        tier_meds[topology.tier(i, m)].append(med)

    base: dict = {}
    censored = []
    for t in TIERS:
        if tier_meds[t]:
            base[t] = _median(tier_meds[t])
            if base[t] <= compute * (1.0 + _CENSOR_EPS):
                censored.append(t)
    if base:
        # Missing tiers: scale a neighboring observed tier by the default
        # model's tier ratios (best prior available without observations).
        ref = next(t for t in TIERS if t in base)
        for t in TIERS:
            if t not in base:
                base[t] = base[ref] * defaults[t] / defaults[ref]
    else:
        base = dict(defaults)
    # Restore the documented ordering invariant (cummax along TIERS): a
    # censored near tier can observe *above* a far tier's true base.
    prev = 0.0
    for t in TIERS:
        base[t] = max(base[t], prev)
        prev = base[t]

    # -- jitter: robust lognormal sigma from uncensored links ---------------
    log_resid = []
    for lk, v in by_link.items():
        med = link_med[lk]
        if med <= compute * (1.0 + _CENSOR_EPS) or len(v) < 3:
            continue
        log_resid.extend(np.log(r.duration) - np.log(med) for r in v)
    if len(log_resid) >= 8:
        jitter = float(min(1.0, 1.4826 * np.median(np.abs(log_resid))))
    else:
        jitter = 0.0

    # -- per-directed-link WAN skew -----------------------------------------
    M = topology.n_workers
    link_scale = np.ones((M, M))
    for (i, m), med in link_med.items():
        if topology.tier(i, m) != "inter_cluster":
            continue
        if med <= compute * (1.0 + _CENSOR_EPS):
            continue
        link_scale[i, m] = med / base["inter_cluster"]

    # -- residual of the fitted model over uncensored pulls -----------------
    rel = []
    for (i, m), v in by_link.items():
        pred = max(compute, base[topology.tier(i, m)] * link_scale[i, m])
        for r in v:
            if r.duration > compute * (1.0 + _CENSOR_EPS):
                rel.append(abs(r.duration - pred) / r.duration)
    residual = _median(rel) if rel else 0.0

    model = LinkTimeModel(
        topology,
        compute_time=compute,
        base_times=dict(base),
        jitter=jitter,
        # Measured traces already contain whatever slowness really happened;
        # don't re-inject the synthetic roaming slow link.
        slowdown_range=(1.0, 1.0),
        seed=seed,
        link_scale=link_scale.copy(),
        **model_kwargs,
    )
    return CalibrationResult(
        model=model,
        compute_time=compute,
        base_times=dict(base),
        jitter=jitter,
        link_scale=link_scale,
        residual=residual,
        n_pulls=n_pulls,
        censored_tiers=tuple(censored),
        per_link_median=link_med,
    )
