"""Trace summarizer CLI: ``python -m repro.trace FILE [--top N]``.

Prints per-tier latency statistics, the slowest directed links, and
timeout counts for any trace file (JSONL or CSV) — the quick look a
measured timeline gets before calibration, and the CI sanity-print for
the committed fixture.  Tier attribution needs the topology recorded in
the trace meta (simulator exports carry it); without one the per-link
view still prints.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.trace.schema import load_trace


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=float), q))


def summarize(path, top: int = 5, out=None) -> None:
    trace = load_trace(path)
    w = (sys.stdout if out is None else out).write
    counts = trace.counts()
    meta = trace.meta
    w(f"trace {path}\n")
    if meta:
        keys = ("algorithm", "engine", "n_workers", "seed", "total_events")
        kv = ", ".join(f"{k}={meta[k]}" for k in keys if k in meta)
        if kv:
            w(f"  meta: {kv}\n")
    w(
        f"  records: {len(trace.records)} "
        f"({', '.join(f'{k}={v}' for k, v in counts.items() if v)})\n"
    )
    w(f"  horizon: {trace.horizon:.3f}s virtual\n")

    by_link = trace.by_link(kinds=("pull",))
    if not by_link:
        w("  no pull records — nothing to profile\n")
        return

    topo = trace.topology()
    if topo is not None:
        tiers: dict = {}
        for (i, m), recs in by_link.items():
            tiers.setdefault(topo.tier(i, m), []).extend(
                r.duration for r in recs
            )
        w("  per-tier pull latency (seconds):\n")
        for tier, durs in tiers.items():
            w(
                f"    {tier:<14} n={len(durs):<6} "
                f"p50={_pct(durs, 50):.4g} p90={_pct(durs, 90):.4g} "
                f"p99={_pct(durs, 99):.4g} max={max(durs):.4g}\n"
            )
    else:
        w("  (no topology in meta — skipping tier attribution)\n")

    med = {
        lk: float(np.median([r.duration for r in v]))
        for lk, v in by_link.items()
    }
    slowest = sorted(med.items(), key=lambda kv: -kv[1])[:top]
    w(f"  slowest directed links (median, top {len(slowest)}):\n")
    for (i, m), d in slowest:
        w(f"    {i}->{m}: {d:.4g}s over {len(by_link[(i, m)])} pulls\n")

    timeouts: dict = {}
    for r in trace.records:
        if r.kind == "timeout":
            timeouts[(r.src, r.dst)] = timeouts.get((r.src, r.dst), 0) + 1
    if timeouts:
        total = sum(timeouts.values())
        w(f"  timeouts: {total} across {len(timeouts)} links\n")
        for (i, m), n in sorted(timeouts.items(), key=lambda kv: -kv[1])[:top]:
            w(f"    {i}->{m}: {n}\n")
    else:
        w("  timeouts: none\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="Summarize a repro.trace file (JSONL or CSV).",
    )
    ap.add_argument("file", help="trace file (.jsonl or .csv)")
    ap.add_argument(
        "--top", type=int, default=5,
        help="how many slowest links / noisiest timeout links to list",
    )
    args = ap.parse_args(argv)
    summarize(args.file, top=args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())
