"""Trace-backed time source for the ``LinkTimeModel.time_source`` seam.

``ReplayLinkSource`` hands back *measured* durations: per directed link, in
recorded order, one per ``network_time`` query.  Once a link's recordings
run out (past the trace horizon) it returns None and the model — normally
the calibrated one — takes over.  Scenario dead-link semantics are
untouched: ``LinkTimeModel`` resolves dead links *before* consulting the
source, exactly as the original run priced its timeouts without drawing
link times (timeout records are therefore excluded from the replay queues
by default — reattach the scenario to regenerate them).

Why same-seed replay is exact (pinned by tests/test_trace.py): peer
selection and batch draws come from the simulator rng, jitter from the
model's private rng — a served duration consumes neither, so the streams
stay aligned; serving event k its recorded link time reproduces its heap
reschedule time exactly, hence the same pop order, hence (by induction)
the same peer/batch draws for every later event.  Each async record
carries ``net`` — the *raw* ``iteration_time`` the event drew, before any
strategy multiplier — and the seam feeds ``iteration_time = max(C,
served)`` back into ``event_timing``, which re-applies ps-async's
congestion multiplier and netmax-topk's wire ratio deterministically.
Raw values are already ``max(C, N)``, so the max is idempotent and the
duration and its comm/compute split round-trip bit-exactly for **all
eight strategies**.  Legacy traces without ``net`` fall back to the
recorded event duration, which equals the raw link time for the
unit-multiplier gossip family (the pre-``net`` exactness contract) and
degrades to a link-conditions replay for ps-async/netmax-topk.

Synchronous strategies replay exactly too, by a different route: the
traced round loop taps every raw per-link network time a round queries
(see ``traced_round_timing``), ``round_timing`` queries links in a fixed
deterministic order, and the per-link FIFO queues here serve those draws
back in that order — so the recomputed round durations (congestion and
ring aggregation included, both deterministic) match bit-exactly.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.nettime import LinkTimeModel
from repro.trace.schema import Trace


class ReplayLinkSource:
    """Serve measured per-link durations in order; None past the horizon."""

    def __init__(self, trace: Trace, include_timeouts: bool = False):
        kinds = ("pull", "timeout") if include_timeouts else ("pull",)
        by_link = trace.by_link(kinds=kinds)
        # Serve the raw link time (``net``) when the record carries one —
        # event_timing re-applies any strategy multiplier on top — and the
        # event duration for legacy records (exact for gossip, where the
        # two coincide).
        self._queues = {
            lk: deque(
                r.duration if r.net is None else r.net for r in v
            ) for lk, v in by_link.items()
        }
        self._median = {
            lk: float(
                np.median([r.duration if r.net is None else r.net for r in v])
            )
            for lk, v in by_link.items()
        }
        self.horizon = trace.horizon
        self.served = 0
        self.fallbacks = 0

    # -- LinkTimeModel seam --------------------------------------------------
    def network_time(self, i: int, m: int, now: float):
        q = self._queues.get((i, m))
        if q:
            self.served += 1
            return q.popleft()
        self.fallbacks += 1
        return None

    def expected(self, i: int, m: int, now: float):
        """Non-consuming estimate for ``LinkTimeModel.matrix``."""
        return self._median.get((i, m))

    # -- introspection / what-if hooks --------------------------------------
    def remaining(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def scale_link(self, i: int, m: int, factor: float,
                   floor: float = 0.0) -> None:
        """Multiply the link's queued durations (and its estimate) by
        ``factor`` — a what-if link upgrade/downgrade applied to the
        measured timeline itself.  ``floor`` clamps from below (durations
        are event times, so a compute floor keeps them physical)."""
        if factor <= 0:
            raise ValueError(f"scale factor must be positive, got {factor}")
        q = self._queues.get((i, m))
        if q is not None:
            self._queues[(i, m)] = deque(max(floor, d * factor) for d in q)
            self._median[(i, m)] = max(floor, self._median[(i, m)] * factor)

    def drop_worker(self, w: int) -> None:
        """Forget every measurement touching worker ``w`` (a what-if move:
        its old links no longer exist, the model prices the new ones)."""
        for lk in [lk for lk in self._queues if w in lk]:
            del self._queues[lk]
            del self._median[lk]

    def links(self):
        return sorted(self._queues)


def replay_model(
    trace: Trace,
    calibration=None,
    include_timeouts: bool = False,
    **model_kwargs,
) -> LinkTimeModel:
    """A ``LinkTimeModel`` that replays ``trace`` and falls back to the
    calibrated model past the horizon.

    ``calibration`` is a ``CalibrationResult`` (fitted here from the trace
    when omitted); its model's parameters seed the fallback.  Keyword
    overrides (``seed=``, ``scenario=``, ...) win over calibrated values.
    """
    if calibration is None:
        from repro.trace.calibrate import calibrate

        calibration = calibrate(trace)
    base = calibration.model
    kwargs = dict(
        compute_time=base.compute_time,
        base_times=dict(base.base_times),
        jitter=base.jitter,
        slowdown_range=base.slowdown_range,
        seed=base.seed,
        link_scale=None if base.link_scale is None else base.link_scale.copy(),
    )
    kwargs.update(model_kwargs)
    return LinkTimeModel(
        base.topology,
        time_source=ReplayLinkSource(trace, include_timeouts=include_timeouts),
        **kwargs,
    )
