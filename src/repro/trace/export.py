"""Chrome-trace / Perfetto export of a traced simulation (DESIGN.md §15).

``chrome_trace`` turns a ``SimConfig.trace``-enabled ``SimResult`` (or an
ingested ``Trace``) into the Chrome Trace Event JSON that chrome://tracing
and https://ui.perfetto.dev open directly:

* one track (tid) per worker, carrying its events as complete ("X") slices
  — pulls named ``pull i->m``, compute-only events ``local``, stalls
  ``timeout i->m`` — with the comm/compute split in args when available;
* synchronous rounds on their own track (they span all workers);
* Monitor policy publishes as global instant ("i") events.

Timestamps are virtual-time microseconds (the simulator's seconds * 1e6).
"""

from __future__ import annotations

import json

from repro.trace.schema import Trace

_PID = 0


def _meta_event(name: str, tid: int, label: str) -> dict:
    return {
        "ph": "M",
        "pid": _PID,
        "tid": tid,
        "name": name,
        "args": {"name": label},
    }


def _slices(records):
    """Yield (t, dur, src, dst, kind, extra) from either source shape."""
    for r in records:
        if isinstance(r, tuple):  # SimResult.trace_events 8-tuple
            t, dur, src, dst, kind, comm, comp, _net = r
            yield t, dur, src, dst, kind, {"comm": comm, "compute": comp}
        else:  # TraceRecord
            yield r.t_start, r.duration, r.src, r.dst, r.kind, {}


def chrome_trace(source, meta: dict | None = None) -> dict:
    """Build the Chrome Trace Event dict from a SimResult or Trace."""
    if isinstance(source, Trace):
        records = source.records
        refreshes = [
            (r.t_start, None) for r in source.records if r.kind == "refresh"
        ]
        meta = dict(source.meta, **(meta or {}))
    else:  # SimResult
        if not source.trace_events and source.events and source.events[-1]:
            raise ValueError(
                "SimResult has no trace_events; run simulate() with "
                "SimConfig(trace=True)"
            )
        records = source.trace_events
        refreshes = [(t, rho) for (t, rho, _P) in source.policy_log]
        meta = dict(meta or {})

    events: list = [_meta_event("process_name", 0, "repro simulation")]
    workers = sorted(
        {s for (_, _, s, _, k, _) in _slices(records) if s >= 0 and k != "refresh"}
    )
    for w in workers:
        events.append(_meta_event("thread_name", w, f"worker {w}"))
    round_tid = (max(workers) + 1) if workers else 0
    has_rounds = any(k == "round" for (_, _, _, _, k, _) in _slices(records))
    if has_rounds:
        events.append(_meta_event("thread_name", round_tid, "rounds"))

    for t, dur, src, dst, kind, extra in _slices(records):
        if kind == "refresh":
            continue  # emitted below from the refresh list
        if kind == "round":
            name, tid = "round", round_tid
        elif kind == "local":
            name, tid = "local", src
        else:  # pull / timeout
            name, tid = f"{kind} {src}->{dst}", src
        ev = {
            "ph": "X",
            "pid": _PID,
            "tid": tid,
            "name": name,
            "cat": kind,
            "ts": t * 1e6,
            "dur": dur * 1e6,
        }
        args = {"src": src, "dst": dst, **extra}
        ev["args"] = args
        events.append(ev)

    for t, rho in refreshes:
        ev = {
            "ph": "i",
            "pid": _PID,
            "tid": 0,
            "name": "monitor refresh",
            "cat": "refresh",
            "ts": t * 1e6,
            "s": "g",  # global scope: draws a full-height marker line
        }
        if rho is not None:
            ev["args"] = {"rho": rho}
        events.append(ev)

    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        out["otherData"] = meta
    return out


def write_chrome_trace(source, path, meta: dict | None = None) -> None:
    """Write Perfetto-openable JSON for a SimResult or Trace."""
    with open(path, "w") as f:
        json.dump(chrome_trace(source, meta=meta), f)
