"""repro.trace — trace-driven replay, calibration, and what-if analysis.

The simulator's wall-clock claims come from a *modeled* link-time process
(core/nettime.py).  This package closes the loop with measured timelines
(DESIGN.md §15):

* ``schema``    — versioned per-event trace records + JSONL/CSV ingest,
  including loaders for externally-measured timelines;
* ``export``    — Chrome-trace / Perfetto JSON from a traced ``SimResult``
  (per-worker tracks, Monitor refreshes as instant events);
* ``calibrate`` — fit ``LinkTimeModel`` parameters (tier base times,
  compute time, jitter spread, per-directed-link WAN skew) from a trace
  with robust estimators and a reported residual;
* ``replay``    — a trace-backed time source plugged into the
  ``LinkTimeModel.time_source`` seam: measured durations replayed by
  directed link in order, calibrated-model fallback past the horizon;
* ``whatif``    — wall-clock / time-to-loss deltas for mutations of a
  calibrated baseline (upgrade a WAN link, move a worker, switch
  algorithm).

``python -m repro.trace FILE`` summarizes any trace file.
"""

from repro.trace.calibrate import CalibrationResult, calibrate
from repro.trace.export import chrome_trace, write_chrome_trace
from repro.trace.replay import ReplayLinkSource, replay_model
from repro.trace.schema import (
    KINDS,
    SCHEMA,
    Trace,
    TraceRecord,
    from_sim_result,
    load_trace,
    read_csv,
    read_jsonl,
    write_jsonl,
)
from repro.trace.whatif import (
    MoveWorker,
    SwitchAlgorithm,
    UpgradeLink,
    WhatIf,
    WhatIfReport,
)

__all__ = [
    "KINDS",
    "SCHEMA",
    "CalibrationResult",
    "MoveWorker",
    "ReplayLinkSource",
    "SwitchAlgorithm",
    "Trace",
    "TraceRecord",
    "UpgradeLink",
    "WhatIf",
    "WhatIfReport",
    "calibrate",
    "chrome_trace",
    "from_sim_result",
    "load_trace",
    "read_csv",
    "read_jsonl",
    "replay_model",
    "write_chrome_trace",
    "write_jsonl",
]
