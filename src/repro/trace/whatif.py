"""What-if cost model over a calibrated, replayed baseline (DESIGN.md §15).

The capacity-planning questions the paper's heterogeneity numbers raise —
which link upgrade, placement change, or strategy switch buys the most
wall-clock — answered by re-running the *measured* timeline under a
mutation:

    session = WhatIf(trace, calibration, cfg, data)
    session.query(UpgradeLink(0, 31, speedup=4.0))
    session.query(MoveWorker(7, cluster=0))
    session.query(SwitchAlgorithm("netmax"))

Each query replays the trace through ``ReplayLinkSource`` with the
mutation applied — scaled measured durations for a link upgrade, dropped
measurements + calibrated-model pricing of the new links for a moved
worker, the same link timeline under a different strategy for a switch —
and reports wall-clock and time-to-loss deltas against the unmutated
replay baseline.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core.nettime import LinkTimeModel
from repro.trace.calibrate import CalibrationResult
from repro.trace.replay import ReplayLinkSource
from repro.trace.schema import Trace


# -- mutations ---------------------------------------------------------------


@dataclass(frozen=True)
class UpgradeLink:
    """Speed the directed link src->dst up by ``speedup``x (>1 = faster;
    0.5 = a 2x *downgrade*).  ``symmetric`` applies it both ways — the
    physical-link upgrade the paper's WAN numbers suggest."""

    src: int
    dst: int
    speedup: float
    symmetric: bool = True

    def describe(self) -> str:
        arrow = "<->" if self.symmetric else "->"
        return f"upgrade link {self.src}{arrow}{self.dst} by {self.speedup}x"


@dataclass(frozen=True)
class MoveWorker:
    """Relocate ``worker`` into ``cluster``: its measured link history is
    discarded (those links no longer exist) and the calibrated model
    prices its new links — inter_pod within the new cluster, WAN across."""

    worker: int
    cluster: int

    def describe(self) -> str:
        return f"move worker {self.worker} to cluster {self.cluster}"


@dataclass(frozen=True)
class SwitchAlgorithm:
    """Run a different registered strategy over the same link timeline."""

    algorithm: str

    def describe(self) -> str:
        return f"switch algorithm to {self.algorithm}"


class RelocatedTopology:
    """Duck-typed Topology with one worker moved to another cluster.

    The moved worker lands in its own pod there, so its links resolve to
    ``inter_pod`` within the destination cluster and ``inter_cluster``
    across — the coarsest (most conservative) placement a relocation can
    guarantee.  Everything else delegates to the base placement.
    """

    def __init__(self, base, worker: int, cluster: int):
        if not (0 <= worker < base.n_workers):
            raise ValueError(f"worker {worker} not in topology")
        if cluster < 0:
            raise ValueError(f"bad cluster {cluster}")
        self.base = base
        self.worker = worker
        self.cluster = cluster
        self.n_workers = base.n_workers
        self.n_clusters = max(base.n_clusters, cluster + 1)

    def cluster_of(self, i: int) -> int:
        return self.cluster if i == self.worker else self.base.cluster_of(i)

    def host_of(self, i: int) -> int:
        if i == self.worker:  # a host of its own, past every real one
            return self.base.host_of(self.n_workers - 1) + 1
        return self.base.host_of(i)

    def pod_of(self, i: int) -> int:
        if i == self.worker:
            return self.base.pod_of(self.n_workers - 1) + 1
        return self.base.pod_of(i)

    def tier(self, i: int, m: int) -> str:
        if self.worker in (i, m):
            if self.cluster_of(i) != self.cluster_of(m):
                return "inter_cluster"
            return "inter_pod"
        return self.base.tier(i, m)

    def __getattr__(self, name):
        return getattr(self.base, name)


# -- the query session -------------------------------------------------------


@dataclass
class WhatIfReport:
    mutation: str
    target_loss: float
    baseline_wall_clock: float
    mutated_wall_clock: float
    baseline_time_to_loss: float
    mutated_time_to_loss: float
    baseline_final_loss: float
    mutated_final_loss: float

    @property
    def wall_clock_delta(self) -> float:
        """Virtual seconds saved (positive = the mutation is faster)."""
        return self.baseline_wall_clock - self.mutated_wall_clock

    @property
    def wall_clock_speedup(self) -> float:
        return self.baseline_wall_clock / self.mutated_wall_clock

    @property
    def time_to_loss_delta(self) -> float:
        return self.baseline_time_to_loss - self.mutated_time_to_loss

    @property
    def time_to_loss_speedup(self) -> float:
        return self.baseline_time_to_loss / self.mutated_time_to_loss

    def summary(self) -> str:
        return (
            f"{self.mutation}: wall-clock {self.baseline_wall_clock:.2f}s -> "
            f"{self.mutated_wall_clock:.2f}s ({self.wall_clock_speedup:.2f}x)"
            f", time-to-loss({self.target_loss:.3f}) "
            f"{self.baseline_time_to_loss:.2f}s -> "
            f"{self.mutated_time_to_loss:.2f}s"
        )


class WhatIf:
    """Replayed-baseline what-if queries.

    ``data`` is the simulate() data bundle ``(data_x, data_y, part_idx,
    eval_x, eval_y)``; ``cfg`` the baseline SimConfig (its seed pins the
    replay, see replay.py).  ``target_loss`` defaults to 3/4 of the
    baseline replay's loss descent — a level both runs cross unless the
    mutation is catastrophic; pass one explicitly to compare at a fixed
    quality bar.
    """

    def __init__(
        self,
        trace: Trace,
        calibration: CalibrationResult,
        cfg,
        data,
        target_loss: float | None = None,
        record_every: int = 100,
    ):
        self.trace = trace
        self.calibration = calibration
        self.cfg = cfg
        self.data = data
        self.record_every = record_every
        self._target = target_loss
        self._baseline = None

    # -- internals ----------------------------------------------------------
    def _model(self, mutations) -> LinkTimeModel:
        cal = self.calibration.model
        topo = cal.topology
        scale = (
            np.ones((topo.n_workers, topo.n_workers))
            if cal.link_scale is None
            else cal.link_scale.copy()
        )
        source = ReplayLinkSource(self.trace)
        for mut in mutations:
            if isinstance(mut, UpgradeLink):
                pairs = [(mut.src, mut.dst)]
                if mut.symmetric:
                    pairs.append((mut.dst, mut.src))
                for i, m in pairs:
                    source.scale_link(
                        i, m, 1.0 / mut.speedup, floor=cal.compute_time
                    )
                    scale[i, m] /= mut.speedup
            elif isinstance(mut, MoveWorker):
                topo = RelocatedTopology(topo, mut.worker, mut.cluster)
                source.drop_worker(mut.worker)
                # Its calibrated per-link skew described links that no
                # longer exist.
                scale[mut.worker, :] = 1.0
                scale[:, mut.worker] = 1.0
            elif not isinstance(mut, SwitchAlgorithm):
                raise TypeError(f"unknown mutation {mut!r}")
        return LinkTimeModel(
            topo,
            compute_time=cal.compute_time,
            base_times=dict(cal.base_times),
            jitter=cal.jitter,
            slowdown_range=cal.slowdown_range,
            seed=cal.seed,
            link_scale=scale,
            time_source=source,
        )

    def _cfg(self, mutations):
        for mut in mutations:
            if isinstance(mut, SwitchAlgorithm):
                return dataclasses.replace(self.cfg, algorithm=mut.algorithm)
        return self.cfg

    def _run(self, mutations):
        from repro.train.simulator import simulate

        return simulate(
            self._cfg(mutations),
            self._model(mutations),
            *self.data,
            record_every=self.record_every,
        )

    @property
    def baseline(self):
        """The unmutated replay (cached)."""
        if self._baseline is None:
            self._baseline = self._run(())
        return self._baseline

    @property
    def target_loss(self) -> float:
        if self._target is None:
            base = self.baseline
            lo, hi = base.losses[-1], base.losses[0]
            self._target = lo + 0.25 * (hi - lo)
        return self._target

    # -- the query API ------------------------------------------------------
    def query(self, mutation) -> WhatIfReport:
        """Evaluate one mutation (or a sequence applied together)."""
        mutations = (
            tuple(mutation)
            if isinstance(mutation, (list, tuple))
            else (mutation,)
        )
        base, mut = self.baseline, self._run(mutations)
        target = self.target_loss
        return WhatIfReport(
            mutation="; ".join(m.describe() for m in mutations),
            target_loss=target,
            baseline_wall_clock=base.times[-1],
            mutated_wall_clock=mut.times[-1],
            baseline_time_to_loss=base.time_to_loss(target),
            mutated_time_to_loss=mut.time_to_loss(target),
            baseline_final_loss=base.losses[-1],
            mutated_final_loss=mut.losses[-1],
        )
