"""Matched gossip rounds via Birkhoff-von Neumann decomposition (beyond paper).

The paper's pull is worker->neighbor point-to-point over TCP.  In SPMD the
naive equivalent (every worker gathers the full worker-axis stack and indexes
its neighbor) costs an all-gather: M x shard bytes.  If instead each round's
neighbor assignment is a *permutation* pi, the pull lowers to
``collective_permute`` — exactly one shard in, one shard out per worker,
point-to-point, overlappable with compute.

This module turns a NetMax policy P into a distribution over permutations
whose per-edge marginal frequencies approximate P:

1. Sinkhorn-project P (row-stochastic) to the nearest doubly stochastic Q on
   the same support (self-loops allowed: a fixed point = "no pull this round").
2. Birkhoff-decompose Q = sum_j theta_j Pi_j (theta_j > 0, sum = 1) using
   repeated perfect matchings on the remaining support.
3. Sample Pi_j ~ theta each round.  E[pi matrix] = Q, so the consensus
   operator's second moment is Y_Q — recomputed and reported so the
   convergence guarantee (Thm 1) still holds for the matched sampler.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def sinkhorn(P: np.ndarray, iters: int = 500, tol: float = 1e-10) -> np.ndarray:
    """Project a nonnegative matrix onto doubly stochastic via Sinkhorn-Knopp.

    Zero-support entries stay zero.  Requires total support (guaranteed when
    the diagonal is free: we add a small self-loop mass where needed).
    """
    Q = P.copy().astype(np.float64)
    # Ensure total support: give every row/col a diagonal escape hatch.
    eps = max(Q[Q > 0].min() * 1e-3, 1e-12) if (Q > 0).any() else 1e-12
    np.fill_diagonal(Q, np.maximum(np.diag(Q), eps))
    for _ in range(iters):
        Q /= Q.sum(axis=1, keepdims=True)
        Q /= Q.sum(axis=0, keepdims=True)
        r = np.abs(Q.sum(axis=1) - 1.0).max()
        if r < tol:
            break
    # One last row normalization keeps rows exact (cols off by <= tol).
    Q /= Q.sum(axis=1, keepdims=True)
    return Q


def _perfect_matching(support: np.ndarray) -> np.ndarray | None:
    """Hopcroft-Karp-lite: augmenting-path perfect matching on a 0/1 matrix.

    Returns match[i] = column matched to row i, or None if no perfect
    matching exists.
    """
    n = support.shape[0]
    match_col = np.full(n, -1, dtype=np.int64)  # col -> row

    def try_assign(i: int, seen: np.ndarray) -> bool:
        for j in range(n):
            if support[i, j] and not seen[j]:
                seen[j] = True
                if match_col[j] == -1 or try_assign(match_col[j], seen):
                    match_col[j] = i
                    return True
        return False

    for i in range(n):
        if not try_assign(i, np.zeros(n, dtype=bool)):
            return None
    match_row = np.full(n, -1, dtype=np.int64)
    for j in range(n):
        match_row[match_col[j]] = j
    return match_row


@dataclass
class BirkhoffDecomposition:
    permutations: np.ndarray  # (k, M) int — perm[j][i] = neighbor of i
    weights: np.ndarray  # (k,) float, sums to 1
    Q: np.ndarray  # the doubly stochastic matrix decomposed
    residual: float  # mass not captured (numerical tail)

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        j = int(rng.choice(len(self.weights), p=self.weights))
        return self.permutations[j]

    @property
    def n_components(self) -> int:
        return len(self.weights)


def birkhoff_decompose(
    Q: np.ndarray, max_components: int = 128, tol: float = 1e-7
) -> BirkhoffDecomposition:
    """Decompose a doubly stochastic Q into a convex sum of permutations."""
    R = Q.copy().astype(np.float64)
    M = Q.shape[0]
    perms: list[np.ndarray] = []
    weights: list[float] = []
    for _ in range(max_components):
        mass = R.max()
        if mass < tol:
            break
        support = R > tol
        match = _perfect_matching(support)
        if match is None:
            break  # numerically exhausted
        theta = float(R[np.arange(M), match].min())
        if theta < tol:
            # Mask the smallest edge and retry would loop; treat as done.
            break
        perms.append(match.copy())
        weights.append(theta)
        R[np.arange(M), match] -= theta
    if not perms:
        perms.append(np.arange(M))
        weights.append(1.0)
    w = np.asarray(weights)
    residual = float(max(0.0, 1.0 - w.sum()))
    w = w / w.sum()
    return BirkhoffDecomposition(np.asarray(perms), w, Q, residual)


def matched_sampler(P: np.ndarray, max_components: int = 128) -> BirkhoffDecomposition:
    """Policy matrix -> permutation sampler with matching edge marginals."""
    return birkhoff_decompose(sinkhorn(P), max_components=max_components)


def marginal_matrix(dec: BirkhoffDecomposition) -> np.ndarray:
    """E[permutation matrix] under the sampler (should equal dec.Q)."""
    M = dec.permutations.shape[1]
    E = np.zeros((M, M))
    for perm, w in zip(dec.permutations, dec.weights):
        E[np.arange(M), perm] += w
    return E
