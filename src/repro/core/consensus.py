"""Consensus SGD math (paper §III-B, §IV).

Two views of the same algorithm live here:

* **Analysis view** (numpy): the one-step random operator ``D^k`` (Eq. 19),
  its second moment ``Y_P = E[(D^k)^T D^k]`` (Eq. 22), and helpers used by the
  policy generator and the theory tests.

* **Runtime view** (jax): the two-step parameter update of Algorithm 2
  (lines 11, 13-15) applied to arbitrary parameter pytrees, plus the lockstep
  "gossip round" operator used by the SPMD trainer (every worker performs one
  Alg.-2 iteration per round with i.i.d. neighbor draws — see DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Analysis view (numpy)
# --------------------------------------------------------------------------


def gamma_matrix(P: np.ndarray, d: np.ndarray) -> np.ndarray:
    """gamma_{i,m} = (d_{i,m} + d_{m,i}) / (2 p_{i,m}), 0 where p=0 or no edge."""
    num = d + d.T
    with np.errstate(divide="ignore", invalid="ignore"):
        g = np.where((P > 0) & (num > 0), num / (2.0 * np.maximum(P, 1e-300)), 0.0)
    return g


def mean_iteration_times(P: np.ndarray, T: np.ndarray, d: np.ndarray) -> np.ndarray:
    """t_bar_i = sum_m t_{i,m} p_{i,m} d_{i,m}   (Eq. 2)."""
    return (T * P * d).sum(axis=1)


def worker_activation_probs(
    P: np.ndarray, T: np.ndarray | None, d: np.ndarray
) -> np.ndarray:
    """p_i per Eq. (3); uniform 1/M when no time matrix is supplied.

    For any feasible Algorithm-3 policy the equality constraints (Eq. 10)
    force t_bar_i identical across i, hence p_i = 1/M (Lemma 1).
    """
    M = P.shape[0]
    if T is None:
        return np.full(M, 1.0 / M)
    tbar = mean_iteration_times(P, T, d)
    # Workers that never communicate (tbar == 0) get frequency 0 by convention.
    with np.errstate(divide="ignore"):
        freq = np.where(tbar > 0, 1.0 / np.maximum(tbar, 1e-300), 0.0)
    s = freq.sum()
    return freq / s if s > 0 else np.full(M, 1.0 / M)


def build_Y(
    P: np.ndarray,
    alpha: float,
    rho: float,
    d: np.ndarray,
    T: np.ndarray | None = None,
) -> np.ndarray:
    """Second-moment matrix Y_P = E[(D^k)^T D^k], entries per Eq. (22).

    Edges whose selection probability is zero contribute nothing (the
    corresponding event never happens), which is how the Monitor retires a
    dead link without touching the math.
    """
    M = P.shape[0]
    p = worker_activation_probs(P, T, d)
    g = gamma_matrix(P, d)
    ar = alpha * rho
    # p_{i,m} * gamma_{i,m} = (d_{i,m}+d_{m,i})/2 when p>0 — a constant per edge.
    pg = np.where(P > 0, P * g, 0.0)
    pg2 = np.where(P > 0, P * g * g, 0.0)
    # Vectorized over all (i, m) at once (this sits inside Algorithm 3's
    # K·R grid, so the former Python double loop was O(K·R·M²)).  gamma's
    # zero diagonal keeps rowl/rowq diagonals exactly 0, matching the
    # loop's skipped m == i entries.
    rowl = p[:, None] * pg  # rowl[i, m] = p_i pg_{i,m};  rowl.T[i, m] = p_m pg_{m,i}
    rowq = p[:, None] * pg2
    Y = ar * (rowl + rowl.T) - ar * ar * (rowq + rowq.T)
    lin_d = 2.0 * ar * rowl.sum(axis=1)
    quad_d = ar * ar * (rowq + rowq.T).sum(axis=1)
    Y[np.arange(M), np.arange(M)] = 1.0 - lin_d + quad_d
    return Y


def sample_event(
    rng: np.random.Generator, P: np.ndarray, p: np.ndarray
) -> tuple[int, int]:
    """Draw (i, m): active worker i ~ p, neighbor m ~ P[i]."""
    M = P.shape[0]
    i = int(rng.choice(M, p=p))
    row = P[i] / P[i].sum()
    m = int(rng.choice(M, p=row))
    return i, m


def D_matrix(i: int, m: int, alpha: float, rho: float, P, d) -> np.ndarray:
    """D^k = I + alpha*rho*gamma_{i,m} e_i (e_m - e_i)^T  (Eq. 19)."""
    M = P.shape[0]
    D = np.eye(M)
    if i != m and d[i, m]:
        g = (d[i, m] + d[m, i]) / (2.0 * P[i, m])
        w = alpha * rho * g
        D[i, i] -= w
        D[i, m] += w
    return D


# --------------------------------------------------------------------------
# Runtime view (jax, pytree-level)
# --------------------------------------------------------------------------


def mixing_weight(alpha: float, rho: float, p_im: float, d_sym: float = 2.0):
    """w = alpha * rho * gamma = alpha*rho*(d_im+d_mi)/(2*p_im)."""
    return alpha * rho * d_sym / (2.0 * p_im)


def two_step_update(params, grads, pulled, alpha, w):
    """Algorithm 2 lines 11+13-15 on a parameter pytree.

    x_half = x - alpha * g          (first step: local SGD)
    x_next = (1-w) * x_half + w * x_pull   (second step: consensus mix)

    ``w`` may be a scalar or broadcastable leaf-wise weight (per-worker when
    leaves carry a leading worker axis).
    """

    def leaf(x, g, xp):
        x_half = x - alpha * g
        return (1.0 - w) * x_half + w * xp

    return jax.tree_util.tree_map(leaf, params, grads, pulled)


def stacked_round(params, grads, neighbors, weights, alpha):
    """Lockstep gossip round on *stacked* replicas (leading axis = worker).

    params/grads: pytrees whose leaves are (M, ...).
    neighbors:    int32 (M,) — neighbor index drawn per worker (may equal i).
    weights:      f32 (M,)  — alpha*rho*gamma_{i, m_i}; 0 where m_i == i.

    Pulled values are the *pre-round* neighbor params (Eq. 16 pulls x_m^k,
    not x_m^k - alpha g_m^k).
    """

    def leaf(x, g):
        pulled = jnp.take(x, neighbors, axis=0)
        x_half = x - alpha * g
        w = weights.reshape((-1,) + (1,) * (x.ndim - 1))
        return (1.0 - w) * x_half + w * pulled

    return jax.tree_util.tree_map(leaf, params, grads)


def sample_round(rng: np.random.Generator, P: np.ndarray, alpha: float, rho: float, d: np.ndarray):
    """Draw one lockstep round: per-worker neighbor + mixing weight (host side)."""
    M = P.shape[0]
    neighbors = np.empty(M, dtype=np.int32)
    weights = np.zeros(M, dtype=np.float32)
    for i in range(M):
        row = P[i] / P[i].sum()
        m = int(rng.choice(M, p=row))
        neighbors[i] = m
        if m != i and d[i, m]:
            g = (d[i, m] + d[m, i]) / (2.0 * P[i, m])
            weights[i] = alpha * rho * g
    return neighbors, weights
