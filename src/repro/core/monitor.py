"""Network Monitor (paper Algorithm 1) + worker-side EMA (Algorithm 2, 19-22).

The Monitor is a *host-side control-plane* component: it never touches model
parameters (unlike a parameter server), only per-link iteration-time EMAs.
Every schedule period it pulls the EMA matrix from the workers and publishes
a fresh (P, rho) produced by Algorithm 3.

Fault tolerance (DESIGN.md §14): two independent detectors feed the same
connectivity mask —

* **missed reports** — a worker that stopped reporting has its links marked
  dead (time = inf) after ``dead_after`` missed reports (covers crashes and
  elastic departures);
* **failure notifications** — the data plane reports each timed-out pull
  (``notify_failure``); the Monitor masks the link, *escalates* the mask to
  the whole failure domain (a peer when several pullers fail to reach it, a
  cluster pair when failures span several peers across one WAN pair), and
  proposes an out-of-schedule Eq.-14 refresh so the policy re-routes without
  waiting for the next T_s tick.  Masks expire after ``revive_after``
  refreshes (probation): a recovered link is re-probed and, if still dead,
  re-masked by the next notification.

Algorithm 3 then optimizes only over the live subgraph, so the next policy
routes around the failure.  A restarted Monitor rebuilds all state from
worker EMAs — it keeps no durable state of its own.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.policy import PolicyResult, connectivity_key, generate_policy_matrix


@dataclass
class IterationTimeEMA:
    """Worker-side EMA of iteration times (Algorithm 2, UPDATETIMEVECTOR).

    T[m] <- beta * T[m] + (1 - beta) * t_{i,m}.  Smaller beta tracks faster
    networks dynamics (paper §III-B).
    """

    n_workers: int
    beta: float = 0.5
    times: np.ndarray = field(init=False)
    counts: np.ndarray = field(init=False)

    def __post_init__(self):
        self.times = np.zeros(self.n_workers)
        self.counts = np.zeros(self.n_workers, dtype=np.int64)

    def update(self, m: int, t: float) -> None:
        if self.counts[m] == 0:
            self.times[m] = t  # seed the EMA with the first observation
        else:
            self.times[m] = self.beta * self.times[m] + (1.0 - self.beta) * t
        self.counts[m] += 1

    def snapshot(self) -> np.ndarray:
        """Observed EMAs; never-observed links report 0 (Monitor fills them)."""
        return self.times.copy()


@dataclass
class MonitorFailover:
    """Standby-Monitor failover state (DESIGN.md §18).

    One standby candidate runs in every cluster; the current leader renews
    their **leases** by heartbeating at each Monitor wake (heartbeats ride
    the same directed WAN reachability as EMA reports).  A standby whose
    lease has been silent for ``lease_periods`` schedule periods considers
    the leader gone; when enough mutually-reachable standbys agree
    (``quorum``, default a majority of clusters — split-brain can then
    never elect two leaders), the lowest-id fully-WAN-connected candidate
    takes over.  The handoff re-seeds the EMA matrix from the new leader's
    reachable reports, drops the warm LP basis, and clears stale failure
    evidence (it was collected at the old vantage point); the election
    wake itself doubles as the out-of-schedule refresh.  With no quorum
    (or no eligible candidate) no refresh fires and the data plane keeps
    training on its last published per-worker policy rows — degraded, not
    stalled.

    All decisions are pure functions of ``(segment, virtual time, this
    state)`` and consume no RNG — both engines drive them through the
    shared ``scenarios.driver.monitor_boundary``, which is what keeps
    reference-vs-batched parity exact under failover.
    """

    lease_periods: float = 1.0
    quorum: int | None = None  # None = majority of clusters
    last_heartbeat: dict = field(default_factory=dict)  # cluster -> time
    n_failovers: int = 0
    n_skipped_refreshes: int = 0  # wakes with no live leader and no quorum
    leader_log: list = field(default_factory=list)  # [(t, new leader cluster)]


@dataclass
class NetworkMonitor:
    """Algorithm 1.  ``collect`` <- worker EMAs; ``step`` -> (P, rho)."""

    n_workers: int
    alpha: float
    K: int = 8
    R: int = 8
    eps: float = 1e-2
    # T_s (paper uses 2 minutes).  This is the single source of truth for
    # the monitor period: the simulator's event loop schedules refreshes off
    # this value, and SimConfig.monitor_period (when set) is forwarded here
    # by Algorithm.make_monitor rather than tracked separately.
    schedule_period: float = 120.0
    dead_after: int = 3
    # Base connectivity mask (M, M); None = fully connected.  step() combines
    # it with the live-worker mask so Algorithm 3 only routes over live links.
    d: np.ndarray | None = None
    # -- dead-link detection from failure notifications (DESIGN.md §14) ----
    # Worker placement, for failure-domain escalation (a control plane knows
    # its own topology); None disables cluster-level escalation.
    topology: object | None = None
    # Out-of-schedule refresh fires this long after the first failure of a
    # burst — detection is only honest once the pull's timeout has elapsed,
    # so drivers default it to the link model's dead_link_timeout, by which
    # point the whole failure domain has evidence pending.  None = unset.
    reroute_delay: float | None = None
    # A failure mask expires after this many refreshes (probation): the link
    # is re-opened, re-probed, and re-masked on the next failure if the
    # outage persists.  This is what lets a recovered cluster rejoin.
    revive_after: int = 3
    # Escalation thresholds: distinct pullers failing to reach one peer =>
    # the peer is down; distinct unreachable peers across one directed
    # cluster pair => the WAN between the two clusters is down.
    peer_escalation: int = 2
    cluster_escalation: int = 2
    # The cluster the Monitor physically lives in (control plane placement).
    # None = the legacy omniscient Monitor that sees every report regardless
    # of partitions.  When set, the scenario drivers drop EMA reports and
    # failure notifications from workers that cannot currently reach this
    # cluster, and policy publishes only land on workers the Monitor can
    # reach — the far side of a partition keeps training on its stale
    # policy (scenarios/driver.monitor_reach / publish_policy).
    home_cluster: int | None = None
    # Standby-Monitor failover (None = the PR-7 single pinned Monitor:
    # if its cluster dies, no refresh ever fires again).  Requires
    # ``home_cluster``; driven by scenarios/driver.monitor_boundary.
    failover: MonitorFailover | None = None

    _T: np.ndarray = field(init=False)
    _missed: np.ndarray = field(init=False)
    policy: PolicyResult | None = field(init=False, default=None)
    history: list = field(init=False, default_factory=list)
    # Warm-start protocol (DESIGN.md §13): the last refresh's optimal LP
    # basis, threaded into the next Algorithm-3 sweep so steady-state
    # re-solves are dual-simplex restarts of a handful of pivots.  Opaque;
    # ``step`` drops it explicitly whenever the effective edge set changes
    # (``_basis_key``) — a basis from a larger live set must never be
    # re-threaded (the solver's shape validation is a fallback, not the
    # invalidation mechanism).
    _basis: object | None = field(init=False, default=None)
    _basis_key: bytes | None = field(init=False, default=None)
    # Failure evidence: directed link -> refresh index when last reported.
    _fail_links: dict = field(init=False, default_factory=dict)
    _fail_wake: float | None = field(init=False, default=None)
    _refresh_idx: int = field(init=False, default=0)

    def __post_init__(self):
        M = self.n_workers
        self._T = np.zeros((M, M))
        self._missed = np.zeros(M, dtype=np.int64)

    # -- data plane ----------------------------------------------------------
    def collect(self, reports: dict[int, np.ndarray]) -> None:
        """Receive {worker_id: EMA vector}; absent workers accrue a miss."""
        for i in range(self.n_workers):
            if i in reports:
                self._T[i, :] = reports[i]
                self._missed[i] = 0
            else:
                self._missed[i] += 1

    def _time_matrix(self) -> np.ndarray:
        """EMA matrix with dead workers masked and unobserved links imputed."""
        T = self._T.copy()
        observed = T[T > 0]
        fill = float(observed.mean()) if observed.size else 1.0
        T[T <= 0] = fill  # never-measured links: assume average cost
        np.fill_diagonal(T, 0.0)
        dead = self._missed >= self.dead_after
        T[dead, :] = np.inf
        T[:, dead] = np.inf
        return T

    def notify_failure(self, i: int, m: int, now: float) -> float | None:
        """Data-plane report: worker ``i``'s pull from ``m`` timed out.

        Records the evidence and returns the virtual time at which an
        out-of-schedule Eq.-14 refresh should fire (the driver lowers its
        next Monitor wake to this); one wake covers a whole failure burst.
        """
        self._fail_links[(int(i), int(m))] = self._refresh_idx
        if self._fail_wake is None:
            self._fail_wake = now + (self.reroute_delay or 0.0)
        return self._fail_wake

    def _failure_masks(self, conn: np.ndarray) -> None:
        """Mask reported-dead links out of ``conn``, escalated to the
        failure domain the evidence supports (module docstring)."""
        # Evidence recorded after refresh ``age`` masks refreshes age+1
        # .. age+revive_after, then expires (the link re-opens on probation).
        for k in [k for k, age in self._fail_links.items()
                  if self._refresh_idx - age > self.revive_after]:
            del self._fail_links[k]
        if not self._fail_links:
            return
        cluster = (
            [self.topology.cluster_of(w) for w in range(self.n_workers)]
            if self.topology is not None else None
        )
        pullers: dict[int, set] = {}
        for i, m in self._fail_links:
            # Evidence is directed — i's pull from m timed out — and so is
            # the mask: the reverse link m->i may be perfectly alive under
            # an asymmetric (one-direction) outage, and if it is not, m's
            # own failed pulls report it independently.
            conn[i, m] = 0.0
            pullers.setdefault(m, set()).add(i)
        for m, ps in pullers.items():
            # A WAN outage also produces many cross-cluster failures toward
            # each remote peer; "the peer itself is down" is only the best
            # explanation once one of its own cluster-mates can't reach it
            # (a crashed worker fails intra pulls too, a WAN outage never
            # does).  Without topology info, any quorum escalates.
            same = cluster is None or any(cluster[i] == cluster[m] for i in ps)
            if len(ps) >= self.peer_escalation and same:
                conn[m, :] = 0.0
                conn[:, m] = 0.0
        if cluster is None:
            return
        peers_by_pair: dict[tuple, set] = {}
        for i, m in self._fail_links:
            if cluster[i] != cluster[m]:
                peers_by_pair.setdefault((cluster[i], cluster[m]), set()).add(m)
        for (ca, cb), peers in peers_by_pair.items():
            if len(peers) >= self.cluster_escalation:
                # Directed escalation: the evidence says pulls FROM ca
                # TOWARD cb die, so only that direction of the WAN pair is
                # masked — a symmetric outage generates the mirror evidence
                # stream and masks the reverse within the same burst.
                a = np.array([c == ca for c in cluster])
                b = np.array([c == cb for c in cluster])
                conn[np.ix_(a, b)] = 0.0

    def adopt_leader(self, cluster: int, now: float) -> None:
        """Leadership handoff to the standby in ``cluster`` (DESIGN.md §18).

        A standby holds none of the old leader's soft state, and all of it
        is rebuildable from worker reports — so the handoff *drops* it:
        the EMA matrix and missed-report counters reset (the next
        ``collect`` re-seeds them from the workers the new leader can
        reach), the warm LP basis is invalidated (PR-4 rule: never thread
        a basis across a vantage change), and pending failure evidence is
        cleared (it was directed evidence *toward the old home*; the new
        leader re-accumulates its own within one reroute delay).
        """
        fo = self.failover
        self.home_cluster = int(cluster)
        self._T[:] = 0.0
        self._missed[:] = 0
        self._basis = None
        self._basis_key = None
        self._fail_links.clear()
        self._fail_wake = None
        fo.n_failovers += 1
        fo.leader_log.append((float(now), int(cluster)))
        # The new leader's own heartbeat starts every lease afresh.
        for c in list(fo.last_heartbeat):
            fo.last_heartbeat[c] = float(now)

    # -- control plane -------------------------------------------------------
    def step(self) -> PolicyResult:
        """One Algorithm-1 period: recompute and publish (P, rho)."""
        self._refresh_idx += 1
        T = self._time_matrix()
        live = ~np.all(~np.isfinite(T) | (T == 0), axis=1)
        # Connectivity mask consistent with ``live``: base topology minus
        # links to/from dead workers (Algorithm 3 then optimizes only over
        # the live subgraph instead of re-deriving liveness from inf times),
        # minus the failure-notification masks.
        conn = np.ones((self.n_workers, self.n_workers)) if self.d is None else self.d.copy()
        np.fill_diagonal(conn, 0.0)
        conn[~live, :] = 0.0
        conn[:, ~live] = 0.0
        self._failure_masks(conn)
        # Warm-start invalidation: the cached basis belongs to the previous
        # refresh's live edge set; if the set changed (a worker died or
        # rejoined, links were masked or revived), drop it — never re-thread
        # a basis across a membership change.
        key = connectivity_key(conn)
        if self._basis is not None and key != self._basis_key:
            self._basis = None
        self._basis_key = key
        res = generate_policy_matrix(
            self.alpha, self.K, self.R, T, d=conn, eps=self.eps,
            warm=self._basis,
        )
        self._basis = res.basis
        self._fail_wake = None
        self.policy = res
        self.history.append(
            dict(
                rho=res.rho,
                t_bar=res.t_bar,
                lambda2=res.lambda2,
                T_convergence=res.T_convergence,
                n_live=int(live.sum()),
                n_dead_links=len(self._fail_links),
                n_pivots=res.n_pivots,
                n_warm_used=res.n_warm_used,
            )
        )
        return res

    @property
    def live_workers(self) -> np.ndarray:
        return np.where(self._missed < self.dead_after)[0]
