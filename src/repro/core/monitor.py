"""Network Monitor (paper Algorithm 1) + worker-side EMA (Algorithm 2, 19-22).

The Monitor is a *host-side control-plane* component: it never touches model
parameters (unlike a parameter server), only per-link iteration-time EMAs.
Every schedule period it pulls the EMA matrix from the workers and publishes
a fresh (P, rho) produced by Algorithm 3.

Fault tolerance: a worker that stopped reporting has its links marked dead
(time = inf) after ``dead_after`` missed reports; Algorithm 3 masks dead
links out of the connectivity graph, so the next policy routes around the
failure.  A restarted Monitor rebuilds all state from worker EMAs — it keeps
no durable state of its own.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.policy import PolicyResult, generate_policy_matrix


@dataclass
class IterationTimeEMA:
    """Worker-side EMA of iteration times (Algorithm 2, UPDATETIMEVECTOR).

    T[m] <- beta * T[m] + (1 - beta) * t_{i,m}.  Smaller beta tracks faster
    networks dynamics (paper §III-B).
    """

    n_workers: int
    beta: float = 0.5
    times: np.ndarray = field(init=False)
    counts: np.ndarray = field(init=False)

    def __post_init__(self):
        self.times = np.zeros(self.n_workers)
        self.counts = np.zeros(self.n_workers, dtype=np.int64)

    def update(self, m: int, t: float) -> None:
        if self.counts[m] == 0:
            self.times[m] = t  # seed the EMA with the first observation
        else:
            self.times[m] = self.beta * self.times[m] + (1.0 - self.beta) * t
        self.counts[m] += 1

    def snapshot(self) -> np.ndarray:
        """Observed EMAs; never-observed links report 0 (Monitor fills them)."""
        return self.times.copy()


@dataclass
class NetworkMonitor:
    """Algorithm 1.  ``collect`` <- worker EMAs; ``step`` -> (P, rho)."""

    n_workers: int
    alpha: float
    K: int = 8
    R: int = 8
    eps: float = 1e-2
    # T_s (paper uses 2 minutes).  This is the single source of truth for
    # the monitor period: the simulator's event loop schedules refreshes off
    # this value, and SimConfig.monitor_period (when set) is forwarded here
    # by Algorithm.make_monitor rather than tracked separately.
    schedule_period: float = 120.0
    dead_after: int = 3
    # Base connectivity mask (M, M); None = fully connected.  step() combines
    # it with the live-worker mask so Algorithm 3 only routes over live links.
    d: np.ndarray | None = None

    _T: np.ndarray = field(init=False)
    _missed: np.ndarray = field(init=False)
    policy: PolicyResult | None = field(init=False, default=None)
    history: list = field(init=False, default_factory=list)
    # Warm-start protocol (DESIGN.md §13): the last refresh's optimal LP
    # basis, threaded into the next Algorithm-3 sweep so steady-state
    # re-solves are dual-simplex restarts of a handful of pivots.  Opaque;
    # the solver validates shape and discards it after membership changes.
    _basis: object | None = field(init=False, default=None)

    def __post_init__(self):
        M = self.n_workers
        self._T = np.zeros((M, M))
        self._missed = np.zeros(M, dtype=np.int64)

    # -- data plane ----------------------------------------------------------
    def collect(self, reports: dict[int, np.ndarray]) -> None:
        """Receive {worker_id: EMA vector}; absent workers accrue a miss."""
        for i in range(self.n_workers):
            if i in reports:
                self._T[i, :] = reports[i]
                self._missed[i] = 0
            else:
                self._missed[i] += 1

    def _time_matrix(self) -> np.ndarray:
        """EMA matrix with dead workers masked and unobserved links imputed."""
        T = self._T.copy()
        observed = T[T > 0]
        fill = float(observed.mean()) if observed.size else 1.0
        T[T <= 0] = fill  # never-measured links: assume average cost
        np.fill_diagonal(T, 0.0)
        dead = self._missed >= self.dead_after
        T[dead, :] = np.inf
        T[:, dead] = np.inf
        return T

    # -- control plane -------------------------------------------------------
    def step(self) -> PolicyResult:
        """One Algorithm-1 period: recompute and publish (P, rho)."""
        T = self._time_matrix()
        live = ~np.all(~np.isfinite(T) | (T == 0), axis=1)
        # Connectivity mask consistent with ``live``: base topology minus
        # links to/from dead workers (Algorithm 3 then optimizes only over
        # the live subgraph instead of re-deriving liveness from inf times).
        conn = np.ones((self.n_workers, self.n_workers)) if self.d is None else self.d.copy()
        np.fill_diagonal(conn, 0.0)
        conn[~live, :] = 0.0
        conn[:, ~live] = 0.0
        res = generate_policy_matrix(
            self.alpha, self.K, self.R, T, d=conn, eps=self.eps,
            warm=self._basis,
        )
        self._basis = res.basis
        self.policy = res
        self.history.append(
            dict(
                rho=res.rho,
                t_bar=res.t_bar,
                lambda2=res.lambda2,
                T_convergence=res.T_convergence,
                n_live=int(live.sum()),
                n_pivots=res.n_pivots,
                n_warm_used=res.n_warm_used,
            )
        )
        return res

    @property
    def live_workers(self) -> np.ndarray:
        return np.where(self._missed < self.dead_after)[0]
