"""Heterogeneous link-time model (paper §II-B, Fig. 2/3 and §V network setup).

Models the per-iteration time t_{i,m} = max(C_i, N_{i,m}) of worker i pulling
from worker m: local compute overlapped with the network transfer (the paper
parallelizes them, §II-B).  Topology tiers map the paper's "intra-machine vs
inter-machine vs WAN" onto pod hardware: intra-host ICI, intra-pod ICI,
inter-pod DCN, and — for the paper-§V wide-area scenarios at M=64+ — an
inter-cluster WAN tier (``Topology.pods_per_cluster``).  Dynamic
perturbations reproduce the paper's evaluation setup ("randomly slow down
one link by 2x-100x, change the slow link every 5 min").

Tier invariants (pinned by tests/test_properties.py): per-tier base times
are ordered intra_host <= intra_pod <= inter_pod <= inter_cluster, every
iteration time is >= the compute time, and the dynamic slow-link factor
stays within ``slowdown_range``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


#: Topology tiers from nearest to farthest; LinkTimeModel.base_times must be
#: non-decreasing along this order.
TIERS = ("intra_host", "intra_pod", "inter_pod", "inter_cluster")


@dataclass
class Topology:
    """Placement of M workers onto a cluster/pod/host hierarchy.

    ``pods_per_cluster=None`` (default) keeps the legacy single-cluster
    three-tier model; setting it partitions pods into WAN-separated clusters
    whose cross-links resolve to the ``inter_cluster`` tier (paper §V
    wide-area setting).
    """

    n_workers: int
    workers_per_host: int = 4
    hosts_per_pod: int = 2
    pods_per_cluster: int | None = None  # None = one cluster, no WAN tier

    def host_of(self, i: int) -> int:
        return i // self.workers_per_host

    def pod_of(self, i: int) -> int:
        return self.host_of(i) // self.hosts_per_pod

    def cluster_of(self, i: int) -> int:
        if not self.pods_per_cluster:
            return 0
        return self.pod_of(i) // self.pods_per_cluster

    def tier(self, i: int, m: int) -> str:
        if self.host_of(i) == self.host_of(m):
            return "intra_host"
        if self.pod_of(i) == self.pod_of(m):
            return "intra_pod"
        if self.cluster_of(i) == self.cluster_of(m):
            return "inter_pod"
        return "inter_cluster"

    @property
    def n_clusters(self) -> int:
        return self.cluster_of(self.n_workers - 1) + 1

    @classmethod
    def multi_cluster(
        cls,
        n_workers: int,
        workers_per_host: int = 4,
        hosts_per_pod: int = 2,
        pods_per_cluster: int = 2,
    ) -> "Topology":
        """Paper-§V-style wide-area placement: clusters of
        ``workers_per_host * hosts_per_pod * pods_per_cluster`` workers
        joined by WAN links."""
        return cls(n_workers, workers_per_host=workers_per_host,
                   hosts_per_pod=hosts_per_pod,
                   pods_per_cluster=pods_per_cluster)


@dataclass
class LinkTimeModel:
    """Produces t_{i,m} matrices; supports paper-style dynamic slowdowns.

    Base times are per-tier transfer seconds for one model pull; the paper's
    Fig. 3 measured a ~4x gap between intra- and inter-machine iteration time
    — the defaults keep that ratio and add a slower inter-pod tier.
    """

    topology: Topology
    compute_time: float = 0.012  # C_i: one local grad step, overlapped
    base_times: dict = field(
        default_factory=lambda: {
            "intra_host": 0.010,
            "intra_pod": 0.040,
            "inter_pod": 0.120,
            # WAN links between clusters (paper §V wide-area): another ~4x
            # over the DCN tier, keeping the Fig.-3-style tier ratios.
            "inter_cluster": 0.480,
        }
    )
    jitter: float = 0.05  # lognormal-ish multiplicative noise
    slowdown_range: tuple = (2.0, 100.0)  # paper §V: 2x-100x on one link
    slow_interval: float = 300.0  # change the slow link every 5 minutes
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._slow_edge: tuple[int, int] | None = None
        self._slow_factor: float = 1.0
        self._next_change: float = 0.0

    # -- dynamics -----------------------------------------------------------
    def advance_to(self, now: float) -> None:
        """Re-draw the slowed link if the change interval elapsed."""
        while now >= self._next_change:
            M = self.topology.n_workers
            i = int(self._rng.integers(M))
            m = int(self._rng.integers(M - 1))
            m = m if m < i else m + 1
            self._slow_edge = (i, m)
            lo, hi = self.slowdown_range
            self._slow_factor = float(self._rng.uniform(lo, hi))
            self._next_change += self.slow_interval

    # -- queries ------------------------------------------------------------
    def network_time(self, i: int, m: int, now: float = 0.0) -> float:
        self.advance_to(now)
        t = self.base_times[self.topology.tier(i, m)]
        if self._slow_edge in ((i, m), (m, i)):
            t *= self._slow_factor
        if self.jitter > 0:
            t *= float(np.exp(self._rng.normal(0.0, self.jitter)))
        return t

    def iteration_time(self, i: int, m: int, now: float = 0.0) -> float:
        """t_{i,m} = max(C_i, N_{i,m})  (paper §II-B)."""
        return max(self.compute_time, self.network_time(i, m, now))

    def matrix(self, now: float = 0.0) -> np.ndarray:
        """Expected iteration-time matrix at virtual time ``now`` (no jitter)."""
        self.advance_to(now)
        M = self.topology.n_workers
        T = np.zeros((M, M))
        for i in range(M):
            for m in range(M):
                if i == m:
                    continue
                t = self.base_times[self.topology.tier(i, m)]
                if self._slow_edge in ((i, m), (m, i)):
                    t *= self._slow_factor
                T[i, m] = max(self.compute_time, t)
        return T


def homogeneous_times(M: int, t: float = 0.02) -> np.ndarray:
    """Uniform-link matrix (paper §V homogeneous setting)."""
    T = np.full((M, M), t)
    np.fill_diagonal(T, 0.0)
    return T


def pod_link_times(
    M: int,
    workers_per_pod: int,
    intra: float = 0.02,
    inter: float = 0.24,
    compute: float = 0.012,
) -> np.ndarray:
    """Two-tier pod matrix used by the production mesh benchmarks."""
    T = np.zeros((M, M))
    for i in range(M):
        for m in range(M):
            if i == m:
                continue
            same = (i // workers_per_pod) == (m // workers_per_pod)
            T[i, m] = max(compute, intra if same else inter)
    return T
