"""Heterogeneous link-time model (paper §II-B, Fig. 2/3 and §V network setup).

Models the per-iteration time t_{i,m} = max(C_i, N_{i,m}) of worker i pulling
from worker m: local compute overlapped with the network transfer (the paper
parallelizes them, §II-B).  Topology tiers map the paper's "intra-machine vs
inter-machine vs WAN" onto pod hardware: intra-host ICI, intra-pod ICI,
inter-pod DCN, and — for the paper-§V wide-area scenarios at M=64+ — an
inter-cluster WAN tier (``Topology.pods_per_cluster``).  Dynamic
perturbations reproduce the paper's evaluation setup ("randomly slow down
one link by 2x-100x, change the slow link every 5 min"); the WAN tier can
additionally carry temporally-correlated congestion jitter and asymmetric
per-direction bandwidth (``wan_jitter`` / ``wan_asymmetry``, default-off,
drawn from a dedicated seedable stream so existing traces stay pinned).

Tier invariants (pinned by tests/test_properties.py): per-tier base times
are ordered intra_host <= intra_pod <= inter_pod <= inter_cluster, every
iteration time is >= the compute time, and the dynamic slow-link factor
stays within ``slowdown_range``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


#: Topology tiers from nearest to farthest; LinkTimeModel.base_times must be
#: non-decreasing along this order.
TIERS = ("intra_host", "intra_pod", "inter_pod", "inter_cluster")


@dataclass
class Topology:
    """Placement of M workers onto a cluster/pod/host hierarchy.

    ``pods_per_cluster=None`` (default) keeps the legacy single-cluster
    three-tier model; setting it partitions pods into WAN-separated clusters
    whose cross-links resolve to the ``inter_cluster`` tier (paper §V
    wide-area setting).
    """

    n_workers: int
    workers_per_host: int = 4
    hosts_per_pod: int = 2
    pods_per_cluster: int | None = None  # None = one cluster, no WAN tier

    def host_of(self, i: int) -> int:
        return i // self.workers_per_host

    def pod_of(self, i: int) -> int:
        return self.host_of(i) // self.hosts_per_pod

    def cluster_of(self, i: int) -> int:
        if not self.pods_per_cluster:
            return 0
        return self.pod_of(i) // self.pods_per_cluster

    def tier(self, i: int, m: int) -> str:
        if self.host_of(i) == self.host_of(m):
            return "intra_host"
        if self.pod_of(i) == self.pod_of(m):
            return "intra_pod"
        if self.cluster_of(i) == self.cluster_of(m):
            return "inter_pod"
        return "inter_cluster"

    @property
    def n_clusters(self) -> int:
        return self.cluster_of(self.n_workers - 1) + 1

    def host_ids(self) -> np.ndarray:
        """(M,) host index per worker (vectorized ``host_of``)."""
        return np.arange(self.n_workers) // self.workers_per_host

    def pod_ids(self) -> np.ndarray:
        return self.host_ids() // self.hosts_per_pod

    def cluster_ids(self) -> np.ndarray:
        if not self.pods_per_cluster:
            return np.zeros(self.n_workers, dtype=int)
        return self.pod_ids() // self.pods_per_cluster

    @classmethod
    def multi_cluster(
        cls,
        n_workers: int,
        workers_per_host: int = 4,
        hosts_per_pod: int = 2,
        pods_per_cluster: int = 2,
    ) -> "Topology":
        """Paper-§V-style wide-area placement: clusters of
        ``workers_per_host * hosts_per_pod * pods_per_cluster`` workers
        joined by WAN links."""
        return cls(n_workers, workers_per_host=workers_per_host,
                   hosts_per_pod=hosts_per_pod,
                   pods_per_cluster=pods_per_cluster)


@dataclass
class LinkTimeModel:
    """Produces t_{i,m} matrices; supports paper-style dynamic slowdowns.

    Base times are per-tier transfer seconds for one model pull; the paper's
    Fig. 3 measured a ~4x gap between intra- and inter-machine iteration time
    — the defaults keep that ratio and add a slower inter-pod tier.
    """

    topology: Topology
    compute_time: float = 0.012  # C_i: one local grad step, overlapped
    base_times: dict = field(
        default_factory=lambda: {
            "intra_host": 0.010,
            "intra_pod": 0.040,
            "inter_pod": 0.120,
            # WAN links between clusters (paper §V wide-area): another ~4x
            # over the DCN tier, keeping the Fig.-3-style tier ratios.
            "inter_cluster": 0.480,
        }
    )
    jitter: float = 0.05  # lognormal-ish multiplicative noise
    slowdown_range: tuple = (2.0, 100.0)  # paper §V: 2x-100x on one link
    slow_interval: float = 300.0  # change the slow link every 5 minutes
    seed: int = 0
    # -- WAN scenario depth (paper §V wide-area; all default-OFF so the
    # engine-parity pins and every historical trace stay bit-identical:
    # when zero, no extra rng is consumed and no factor is applied) -------
    # Temporally-correlated (AR(1)) multiplicative jitter on inter_cluster
    # links: one latent state per unordered cluster pair, refreshed every
    # ``wan_jitter_interval`` virtual seconds with coefficient
    # ``wan_jitter_corr``, applied as exp(wan_jitter * state) to both
    # directions.  Models slow WAN congestion waves rather than iid noise.
    wan_jitter: float = 0.0
    wan_jitter_corr: float = 0.9
    wan_jitter_interval: float = 60.0
    # Static per-direction bandwidth skew on inter_cluster links: an
    # antisymmetric per-cluster-pair draw s, applied as exp(+wan_asymmetry*s)
    # one way and exp(-wan_asymmetry*s) the other (uplink != downlink).
    wan_asymmetry: float = 0.0
    # WAN draws come from their own stream so toggling them never perturbs
    # the base jitter/slow-link sequence.  None -> derived from ``seed``.
    wan_seed: int | None = None
    # -- scripted network dynamics (repro.scenarios; DESIGN.md §14) --------
    # A declarative ``Timeline`` (or pre-compiled ``CompiledTimeline``) of
    # cluster outages, link degradations, and worker churn.  Compiled here
    # into a piecewise link-state machine advanced by ``advance_to``:
    # purely time-dependent, consumes NO rng, so attaching a scenario never
    # perturbs the jitter/slow-link draw sequence and ``scenario=None``
    # stays bit-identical to every historical trace.
    scenario: object | None = None
    # A pull over a scenario-dead link blocks for this long (virtual
    # seconds), then fails: the transfer times out, no data moves, and the
    # event's duration is exactly the timeout (no jitter is drawn for it).
    dead_link_timeout: float = 30.0
    # -- trace-driven replay / calibration seam (repro.trace; DESIGN.md §15)
    # A pluggable time source consulted FIRST for live links: when its
    # ``network_time(i, m, now)`` returns a duration, that value is used
    # verbatim — no tier base, degrade, slow-link, or jitter factor applies
    # and NO rng is consumed (measured durations already embed all of them).
    # Returning None falls through to the model (the "past the trace
    # horizon" fallback).  Scenario dead-link semantics take precedence:
    # a dead link times out without ever consulting the source.
    # ``repro.trace.replay.ReplayLinkSource`` is the canonical provider.
    time_source: object | None = None
    # Per-directed-link multiplier on the *modeled* transfer time, applied
    # after scenario degradation (calibration's per-link WAN-skew output;
    # repro.trace.calibrate).  None = off; the replay path above bypasses
    # it (measured durations are already per-link).  Accepts either a dense
    # (M, M) array (legacy/calibration form) or a sparse ``{(i, m): factor}``
    # dict — both are folded into an internal edge map holding only the
    # non-unit entries, so fleet-scale models never pay (M, M) memory for
    # a handful of skewed WAN links.
    link_scale: object | None = None

    def __post_init__(self):
        # Observation tap for ``network_time`` (NOT a constructor field):
        # when set to a callable ``tap(i, m, value, dead)`` every query is
        # reported just before it returns.  The simulators' sync loops
        # install it around ``round_timing`` so traced runs capture the
        # per-link times a round draws (repro.trace); it never alters the
        # returned value or the rng stream.
        self.query_tap = None
        self._rng = np.random.default_rng(self.seed)
        self._slow_edge: tuple[int, int] | None = None
        self._slow_factor: float = 1.0
        self._next_change: float = 0.0
        nc = self.topology.n_clusters
        self._wan_rng = np.random.default_rng(
            self.seed + 1 if self.wan_seed is None else self.wan_seed
        )
        # Antisymmetric direction skew and AR(1) states, drawn up front for
        # every cluster pair so determinism is independent of query order.
        self._wan_dir = np.zeros((nc, nc))
        if self.wan_asymmetry > 0 and nc > 1:
            s = np.triu(self._wan_rng.standard_normal((nc, nc)), k=1)
            self._wan_dir = s - s.T
        self._wan_state = np.zeros((nc, nc))
        self._wan_next: float = 0.0
        self._scn = None
        self._scn_idx = 0
        if self.scenario is not None:
            scn = self.scenario
            if not hasattr(scn, "segments"):  # a declarative Timeline
                scn = scn.compile(self.topology)
            if scn.n_workers != self.topology.n_workers:
                raise ValueError(
                    f"scenario compiled for {scn.n_workers} workers, "
                    f"topology has {self.topology.n_workers}"
                )
            self._scn = scn
        # Non-unit link-scale entries as a sparse edge map (a multiply by
        # exactly 1.0 is a bit-exact no-op, so dropping unit entries keeps
        # dense-array inputs bit-identical to the legacy dense path).
        self._scale_map: dict[tuple[int, int], float] = {}
        if self.link_scale is not None:
            M = self.topology.n_workers
            if isinstance(self.link_scale, dict):
                for (i, m), f in self.link_scale.items():
                    if not (0 <= i < M and 0 <= m < M):
                        raise ValueError(
                            f"link_scale key ({i}, {m}) out of range for M={M}"
                        )
                    if f != 1.0:
                        self._scale_map[(int(i), int(m))] = float(f)
            else:
                self.link_scale = np.asarray(self.link_scale, dtype=float)
                if self.link_scale.shape != (M, M):
                    raise ValueError(
                        f"link_scale shape {self.link_scale.shape} != ({M}, {M})"
                    )
                for a, b in zip(*np.nonzero(self.link_scale != 1.0)):
                    self._scale_map[(int(a), int(b))] = float(
                        self.link_scale[a, b]
                    )

    @property
    def compiled_scenario(self):
        """The compiled timeline driving this model (None when static)."""
        return self._scn

    @property
    def current_segment(self):
        """The sparse link-state ``Segment`` in effect at the model's
        current virtual time (``advance_to``); None when no scenario is
        attached.  O(1) — used by the scenario drivers to answer Monitor
        reachability queries without materializing dense masks."""
        if self._scn is None:
            return None
        return self._scn.segments[self._scn_idx]

    # -- dynamics -----------------------------------------------------------
    def advance_to(self, now: float) -> None:
        """Re-draw the slowed link if the change interval elapsed; advance
        the correlated-WAN-jitter AR(1) states on their own cadence; step
        the scenario's piecewise link state to the segment containing
        ``now`` (deterministic, no rng)."""
        if self._scn is not None:
            self._scn_idx = self._scn.segment_index(now, hint=self._scn_idx)
        while now >= self._next_change:
            M = self.topology.n_workers
            i = int(self._rng.integers(M))
            m = int(self._rng.integers(M - 1))
            m = m if m < i else m + 1
            self._slow_edge = (i, m)
            lo, hi = self.slowdown_range
            self._slow_factor = float(self._rng.uniform(lo, hi))
            self._next_change += self.slow_interval
        if self.wan_jitter > 0 and self.topology.n_clusters > 1:
            nc = self.topology.n_clusters
            rho = self.wan_jitter_corr
            while now >= self._wan_next:
                noise = np.triu(self._wan_rng.standard_normal((nc, nc)), k=1)
                noise = noise + noise.T  # shared by both directions
                self._wan_state = (
                    rho * self._wan_state + np.sqrt(1.0 - rho * rho) * noise
                )
                self._wan_next += self.wan_jitter_interval

    def _wan_factor(self, i: int, m: int) -> float:
        """Current inter_cluster multiplier for the directed link i -> m."""
        ci, cm = self.topology.cluster_of(i), self.topology.cluster_of(m)
        f = 1.0
        if self.wan_asymmetry > 0:
            f *= float(np.exp(self.wan_asymmetry * self._wan_dir[ci, cm]))
        if self.wan_jitter > 0:
            f *= float(np.exp(self.wan_jitter * self._wan_state[ci, cm]))
        return f

    def link_dead(self, i: int, m: int) -> bool:
        """Whether the scenario currently marks the directed link i -> m
        dead (cluster outage or a departed endpoint).  Reflects the state
        as of the last ``advance_to``."""
        if self._scn is None:
            return False
        return self._scn.segments[self._scn_idx].link_dead(i, m)

    # -- queries ------------------------------------------------------------
    def network_time(self, i: int, m: int, now: float = 0.0) -> float:
        self.advance_to(now)
        if self._scn is not None:
            seg = self._scn.segments[self._scn_idx]
            if seg.link_dead(i, m):
                # Timed-out transfer: a deterministic stall — no jitter or
                # slow-link factor applies and no rng is consumed.
                if self.query_tap is not None:
                    self.query_tap(i, m, self.dead_link_timeout, True)
                return self.dead_link_timeout
        if self.time_source is not None:
            # Measured duration served verbatim: embeds every factor below,
            # so none applies and no rng is consumed.  None = past the trace
            # horizon, fall through to the model.
            served = self.time_source.network_time(i, m, now)
            if served is not None:
                if self.query_tap is not None:
                    self.query_tap(i, m, float(served), False)
                return float(served)
        tier = self.topology.tier(i, m)
        t = self.base_times[tier]
        if self._scn is not None:
            t *= self._scn.segments[self._scn_idx].degrade_factor(i, m)
        if self._scale_map:
            t *= self._scale_map.get((i, m), 1.0)
        if tier == "inter_cluster" and (self.wan_jitter > 0 or self.wan_asymmetry > 0):
            t *= self._wan_factor(i, m)
        if self._slow_edge in ((i, m), (m, i)):
            t *= self._slow_factor
        if self.jitter > 0:
            t *= float(np.exp(self._rng.normal(0.0, self.jitter)))
        if self.query_tap is not None:
            self.query_tap(i, m, t, False)
        return t

    def iteration_time(self, i: int, m: int, now: float = 0.0) -> float:
        """t_{i,m} = max(C_i, N_{i,m})  (paper §II-B)."""
        return max(self.compute_time, self.network_time(i, m, now))

    def matrix(self, now: float = 0.0) -> np.ndarray:
        """Expected iteration-time matrix at virtual time ``now`` (no jitter).

        Inherently dense — (M, M) output for the Monitor's policy LP and
        the dense test/analysis paths — but computed from the sparse link
        state with vectorized tier arithmetic (no Python double loop), and
        bit-identical to the historical per-element computation.
        """
        self.advance_to(now)
        topo = self.topology
        M = topo.n_workers
        host, pod, cl = topo.host_ids(), topo.pod_ids(), topo.cluster_ids()
        bt = self.base_times
        T = np.where(
            host[:, None] == host[None, :],
            bt["intra_host"],
            np.where(
                pod[:, None] == pod[None, :],
                bt["intra_pod"],
                np.where(
                    cl[:, None] == cl[None, :],
                    bt["inter_pod"],
                    bt["inter_cluster"],
                ),
            ),
        ).astype(float)
        seg = self._scn.segments[self._scn_idx] if self._scn is not None else None
        # Per-element factor order matches network_time exactly (degrade,
        # link_scale, WAN, slow link) so the values stay bit-identical.
        if seg is not None:
            for (i, m), f in seg.degrade_map.items():
                T[i, m] *= f
        for (i, m), f in self._scale_map.items():
            T[i, m] *= f
        if (self.wan_jitter > 0 or self.wan_asymmetry > 0) and topo.n_clusters > 1:
            # Slow-moving expected factors (direction skew + current AR(1)
            # congestion state); only the iid jitter is left out.
            F = np.ones((topo.n_clusters, topo.n_clusters))
            if self.wan_asymmetry > 0:
                F = F * np.exp(self.wan_asymmetry * self._wan_dir)
            if self.wan_jitter > 0:
                F = F * np.exp(self.wan_jitter * self._wan_state)
            cross = cl[:, None] != cl[None, :]
            Ffull = F[cl[:, None], cl[None, :]]
            T[cross] *= Ffull[cross]
        if self._slow_edge is not None:
            i, m = self._slow_edge
            T[i, m] *= self._slow_factor
            T[m, i] *= self._slow_factor
        T = np.maximum(self.compute_time, T)
        if seg is not None:
            T[seg.dead] = max(self.compute_time, self.dead_link_timeout)
        if self.time_source is not None:
            exp = getattr(self.time_source, "expected", None)
            if exp is not None:
                for i in range(M):
                    for m in range(M):
                        if i == m or (seg is not None and seg.link_dead(i, m)):
                            continue
                        served = exp(i, m, now)
                        if served is not None:
                            T[i, m] = max(self.compute_time, float(served))
        np.fill_diagonal(T, 0.0)
        return T

    def link_state_nbytes(self) -> int:
        """Host memory held by the model's link state: scenario segments,
        the sparse link-scale map, and the per-cluster WAN states.  O(M)
        for sparse configurations — the fleet-scale regression test pins
        this stays far below the (M, M) dense footprint."""
        n = self._wan_dir.nbytes + self._wan_state.nbytes
        n += 64 * len(self._scale_map)
        if isinstance(self.link_scale, np.ndarray):
            n += self.link_scale.nbytes
        if self._scn is not None:
            n += self._scn.nbytes
        return n


def homogeneous_times(M: int, t: float = 0.02) -> np.ndarray:
    """Uniform-link matrix (paper §V homogeneous setting)."""
    T = np.full((M, M), t)
    np.fill_diagonal(T, 0.0)
    return T


def pod_link_times(
    M: int,
    workers_per_pod: int,
    intra: float = 0.02,
    inter: float = 0.24,
    compute: float = 0.012,
) -> np.ndarray:
    """Two-tier pod matrix used by the production mesh benchmarks."""
    pod = np.arange(M) // workers_per_pod
    T = np.where(pod[:, None] == pod[None, :], max(compute, intra),
                 max(compute, inter)).astype(float)
    np.fill_diagonal(T, 0.0)
    return T
