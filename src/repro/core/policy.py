"""Communication policy generation (paper Algorithm 3 + Appendix A).

``generate_policy_matrix`` is the Network Monitor's core computation:
a nested grid search over the mixing weight rho (outer, K points) and the
target mean iteration time t_bar (inner, R points).  Each grid point solves
the LP of Eq. (14) — minimize self-selection subject to Eqs. (10)-(13) —
and is scored by the convergence-time model T = t_bar * ln(eps)/ln(lambda2).

Solver hot path (DESIGN.md §13): every grid point is solved by the
bounded-variable revised simplex with an **optimal-basis warm start**
threaded across the whole sweep via ``WarmStartCarry`` — across the t_bar
grid only ``b`` changes and across rho steps only the Eq.-11 bound floors
change, so each re-solve is a dual-simplex restart of a handful of pivots
instead of a from-scratch two-phase solve.  The Monitor threads its carry
across policy refreshes too (steady-state re-solves start from the last
optimal basis).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

try:  # scipy ships in the target env; gate anyway per repo policy
    import scipy.sparse as _sp
except ImportError:  # pragma: no cover - exercised only without scipy
    _sp = None

from repro.core import consensus, theory
from repro.solver.lp import BasisState, solve_lp

# Strictness margin for the strict inequality Eq. (11): p > alpha*rho*(d+d').
_FLOOR_MARGIN = 1e-6

# At and above this M the Eq.-14 constraint matrix is built directly in CSC
# form (each column holds at most two nonzeros — the worker's Eq.-10 row and
# its Eq.-13 row), skipping the O(M^3) dense allocation entirely: ~2 MB
# sparse vs ~270 MB dense at M=256 full graph.  The solver's LU engine
# prices through CSC natively; values are identical to the dense build, so
# this is a storage choice, not a behavior change.
_SPARSE_A_MIN_M = 64


@dataclass
class WarmStartCarry:
    """Mutable warm-start state threaded across an Eq.-14 grid sweep.

    ``basis`` is the opaque ``BasisState`` of the most recent *feasible*
    solve (infeasible grid points return no reusable basis); the counters
    are diagnostics surfaced on ``PolicyResult`` and in BENCH_policy.json.
    """

    basis: BasisState | None = None
    n_solves: int = 0
    n_warm_used: int = 0
    n_pivots: int = 0
    # ``enabled=False`` keeps the counters but never feeds the basis back
    # into a solve — the cold-start baseline for BENCH_policy.json.
    enabled: bool = True


@dataclass
class PolicyResult:
    P: np.ndarray
    rho: float
    t_bar: float
    lambda2: float
    T_convergence: float
    # Diagnostics for EXPERIMENTS.md / the Monitor log.
    n_lp_solved: int = 0
    n_lp_feasible: int = 0
    grid: list = field(default_factory=list)
    # Warm-start protocol: last optimal LP basis (opaque) + sweep counters.
    # n_solves counts actual simplex runs across the whole sweep (grid
    # points skipped by the feasibility pre-check never run one), so it is
    # the denominator for a warm-start hit rate.
    basis: BasisState | None = None
    n_pivots: int = 0
    n_warm_used: int = 0
    n_solves: int = 0

    @property
    def ok(self) -> bool:
        return np.isfinite(self.T_convergence)


@dataclass
class _Eq14Instance:
    """Eq.-14 LP skeleton shared across a whole (rho, t_bar) grid sweep.

    Everything here depends only on (T, d): across the t_bar grid only
    ``b`` changes and across rho steps only the Eq.-11 bound floors, so
    the constraint matrix — the expensive part, O(M^3) dense at full
    connectivity — is built once per policy generation instead of once
    per grid point.  ``A`` is dense below ``_SPARSE_A_MIN_M`` (the
    bit-exact historical path) and CSC at scale.
    """

    M: int
    n: int
    ii: np.ndarray      # edge row indices (ascending i, ascending m per row)
    mm: np.ndarray      # edge col indices
    pos: np.ndarray     # LP variable slot of each edge
    start: np.ndarray   # LP variable slot of each diagonal p_{i,i}
    c: np.ndarray
    A: object           # ndarray or scipy.sparse CSC
    ub: np.ndarray
    dsym: np.ndarray    # d[ii, mm] + d[mm, ii] — the Eq.-11 floor weights


def _build_eq14(T: np.ndarray, d: np.ndarray) -> _Eq14Instance:
    """Build the Eq.-14 instance skeleton for connectivity ``d``.

    Variable layout matches the historical per-(i, m) Python loop exactly:
    for each worker i the diagonal p_{i,i} first, then p_{i,m} over edges
    in ascending m.  (The simplex pivot path — hence the solution bits —
    depends on variable order, so the vectorized build must preserve it.)
    """
    M = T.shape[0]
    eye = np.eye(M, dtype=bool)
    edge = (d != 0) & ~eye
    n_per_row = 1 + edge.sum(axis=1)
    start = np.concatenate(([0], np.cumsum(n_per_row)[:-1]))  # (i,i) slots
    ii, mm = np.nonzero(edge)  # row-major: ascending i, ascending m per row
    pos = start[ii] + edge.cumsum(axis=1)[ii, mm]  # edge slots
    n = int(n_per_row.sum())
    c = np.zeros(n)
    c[start] = 1.0  # objective: minimize self-selection
    ub = np.ones(n)
    dsym = d[ii, mm] + d[mm, ii]
    if M >= _SPARSE_A_MIN_M and _sp is not None:
        # Direct CSC build: diagonal columns hold one nonzero (Eq.-13 row
        # M+i), edge columns two (Eq.-10 row i with coefficient T_im, then
        # Eq.-13 row M+i) — rows ascending within each column, columns in
        # variable order, so the structure matches csc_matrix(dense).
        col_nnz = np.ones(n, dtype=np.int64)
        col_nnz[pos] = 2
        indptr = np.concatenate(([0], np.cumsum(col_nnz)))
        data = np.empty(int(indptr[-1]))
        indices = np.empty(int(indptr[-1]), dtype=np.int32)
        indices[indptr[start]] = M + np.arange(M)
        data[indptr[start]] = 1.0
        indices[indptr[pos]] = ii
        data[indptr[pos]] = T[ii, mm]
        indices[indptr[pos] + 1] = M + ii
        data[indptr[pos] + 1] = 1.0
        A = _sp.csc_matrix((data, indices, indptr), shape=(2 * M, n))
    else:
        A = np.zeros((2 * M, n))
        # Eq. (10): sum_m t_{i,m} p_{i,m} d_{i,m} = M * t_bar.
        A[ii, pos] = T[ii, mm]
        # Eq. (13): sum_m p_{i,m} = 1 (diagonal included).
        A[M + np.arange(M), start] = 1.0
        A[M + ii, pos] = 1.0
    return _Eq14Instance(M, n, ii, mm, pos, start, c, A, ub, dsym)


def _solve_policy_lp(
    T: np.ndarray,
    d: np.ndarray,
    alpha: float,
    rho: float,
    t_bar: float,
    carry: WarmStartCarry | None = None,
    inst: _Eq14Instance | None = None,
) -> np.ndarray | None:
    """LP of Eq. (14): min sum_i p_{i,i} s.t. Eqs. (10)-(13).

    Variables: p_{i,m} for every edge (d_{i,m}=1) plus every diagonal p_{i,i}
    — sparse connectivity masks shrink the variable set to live edges, which
    is where multi-cluster topologies win.  Eq. (10): per-worker expected
    iteration time == M * t_bar (equalizes p_i).  Eq. (11): p_{i,m} >=
    alpha*rho*(d_{i,m}+d_{m,i}) + margin on edges.  Eq. (13): rows sum to
    one (diagonal included).  ``carry`` (optional) supplies the warm-start
    basis for the solve and receives the updated one; ``inst`` reuses a
    prebuilt ``_Eq14Instance`` across the grid (sweeps pass it — only
    ``b`` and the floors change between grid points).
    """
    if inst is None:
        inst = _build_eq14(T, d)
    M, n = inst.M, inst.n
    lb = np.zeros(n)
    lb[inst.pos] = alpha * rho * inst.dsym + _FLOOR_MARGIN
    b = np.zeros(2 * M)
    b[:M] = M * t_bar
    b[M:] = 1.0
    warm = carry.basis if carry is not None and carry.enabled else None
    res = solve_lp(inst.c, inst.A, b, lb=lb, ub=inst.ub, warm=warm)
    if carry is not None:
        carry.n_solves += 1
        carry.n_pivots += res.pivots
        carry.n_warm_used += int(res.warm_used)
        if res.basis is not None:
            carry.basis = res.basis
    if not res.ok:
        return None
    x = np.maximum(res.x, 0.0)
    P = np.zeros((M, M))
    P[inst.ii, inst.mm] = x[inst.pos]
    P[np.arange(M), np.arange(M)] = x[inst.start]
    return P


def _t_bar_interval(
    T: np.ndarray, d: np.ndarray, alpha: float, rho: float
) -> tuple[float, float]:
    """Feasible [L, U] for t_bar (Appendix A, Eqs. 26/28).

    Broadcast over all worker rows at once — the former per-(i, m) Python
    loops made this the O(K·M²) floor of Algorithm 3 at M=64+.  The per-row
    reduction goes through ``np.cumsum`` (a sequential accumulation), so it
    is bit-identical to the historical left-to-right Python ``sum`` — the
    parity test in tests/test_policy.py pins exact equality."""
    M = T.shape[0]
    eye = np.eye(M, dtype=bool)
    terms = T * (d + d.T)
    terms[eye] = 0.0  # the loop skipped m == i
    L_rows = alpha * rho / M * np.cumsum(terms, axis=1)[:, -1]
    edge = (d != 0) & ~eye
    if not edge.any(axis=1).all():
        return (np.inf, -np.inf)  # isolated node: infeasible
    U_rows = np.where(edge, T, -np.inf).max(axis=1) / M
    return max(0.0, float(L_rows.max())), float(U_rows.min())


def _eq14_time_bounds(
    T: np.ndarray, d: np.ndarray, alpha: float, rho: float
) -> tuple[float, float]:
    """Exact feasible range of M*t_bar for the Eq.-14 LP at this rho.

    The LP couples workers only through the shared t_bar (each worker's
    variables appear in exactly its own Eq.-10 and Eq.-13 rows), so it is
    feasible iff every worker can realize sum_m T_im p_im == M*t_bar under
    its floors/caps — a per-row fractional-knapsack range: the minimum puts
    every edge at its Eq.-11 floor, the maximum greedily spends the
    remaining row budget (1 - floors, p_ii >= 0) on the slowest edges.
    Returns (max_i tmin_i, min_i tmax_i); (inf, -inf) when some row's
    floors alone overflow the row-stochastic budget.  ``inner_loop`` uses
    this to skip provably infeasible grid points without a simplex run —
    those cold, iteration-heavy phase-1 solves were most of the Algorithm-3
    wall time at M=128.
    """
    M = T.shape[0]
    eye = np.eye(M, dtype=bool)
    edge = (d != 0) & ~eye
    f = np.where(edge, alpha * rho * (d + d.T) + _FLOOR_MARGIN, 0.0)
    fsum = f.sum(axis=1)
    if np.any(fsum > 1.0 + 1e-9):
        return np.inf, -np.inf
    Te = np.where(edge, T, 0.0)
    tmin = (Te * f).sum(axis=1)
    order = np.argsort(np.where(edge, -T, np.inf), axis=1, kind="stable")
    Ts = np.take_along_axis(Te, order, axis=1)
    caps = np.take_along_axis(np.where(edge, 1.0 - f, 0.0), order, axis=1)
    taken = np.minimum(np.cumsum(caps, axis=1), (1.0 - fsum)[:, None])
    take = np.diff(taken, axis=1, prepend=0.0)
    tmax = tmin + (take * Ts).sum(axis=1)
    return float(tmin.max()), float(tmax.min())


def _rho_grid_upper(alpha: float, Tm: np.ndarray, d: np.ndarray) -> float:
    """Upper end of the outer rho grid (engineering guard, see below).

    Clamp the outer grid to the region where the inner interval [L(rho), U]
    is non-empty and the Eq.-11 floors can sum to <= 1, so no grid point is
    wasted on provably infeasible rho.  L(rho) = alpha*rho*A with A below;
    U is rho-free.  Broadcast over rows — pinned bit-exact against the
    historical per-row generator loops by tests/test_policy.py.
    """
    M = Tm.shape[0]
    U_rho = 0.5 / alpha
    dsym = d + d.T
    deg2 = dsym.sum(axis=1)
    with np.errstate(invalid="ignore"):
        A = ((Tm * dsym).sum(axis=1) / M).max()
    live = d.sum(axis=1) > 0
    if d.sum() > 0:
        U_t = ((Tm * d).max(axis=1) / M)[live].min()
    else:
        U_t = 0.0
    if A > 0:
        U_rho = min(U_rho, U_t / (A * alpha))
    if deg2.max() > 0:
        U_rho = min(U_rho, 1.0 / (alpha * deg2.max()) * (1.0 - 1e-6))
    return U_rho


def inner_loop(
    alpha: float,
    rho: float,
    R: int,
    T: np.ndarray,
    d: np.ndarray,
    eps: float = 1e-2,
    carry: WarmStartCarry | None = None,
    inst: _Eq14Instance | None = None,
) -> PolicyResult | None:
    """Algorithm 3 INNERLOOP: grid over t_bar in [L, U], LP + eig score.

    Across the grid only ``b`` changes (b[:M] = M*t_bar), so with ``carry``
    each solve after the first is a warm dual-simplex restart.  ``inst``
    (optional) reuses a prebuilt Eq.-14 skeleton — the outer loop passes
    one so the constraint matrix is built once per policy generation.
    """
    L, U = _t_bar_interval(T, d, alpha, rho)
    if not np.isfinite(U) or U <= L:
        return None
    M = T.shape[0]
    if inst is None:
        inst = _build_eq14(T, d)
    lo, hi = _eq14_time_bounds(T, d, alpha, rho)
    best: PolicyResult | None = None
    n_solved = n_feasible = 0
    grid = []
    for r in range(1, R + 1):
        t_bar = L + (U - L) * r / R
        target = M * t_bar
        tol = 1e-6 * max(1.0, abs(target))
        if target < lo - tol or target > hi + tol:
            # Provably infeasible (conservative margin: boundary points
            # still go to the LP so the verdict matches the solver's).
            # Skipped points are not counted in n_lp_solved: that counter
            # means "simplex runs", consistent with the pivot/warm counters.
            grid.append((rho, t_bar, None, np.inf))
            continue
        n_solved += 1
        try:
            P = _solve_policy_lp(T, d, alpha, rho, t_bar, carry=carry,
                                 inst=inst)
        except (RuntimeError, MemoryError):
            # Simplex iteration cap / instance too large for this grid point:
            # score it infeasible so the Monitor degrades to other grid
            # points or the uniform fallback instead of dying mid-run.
            P = None
        if P is None:
            grid.append((rho, t_bar, None, np.inf))
            continue
        n_feasible += 1
        Y = consensus.build_Y(P, alpha, rho, d)
        lam2 = theory.lambda2(Y)
        Tc = theory.convergence_time(t_bar, lam2, eps)
        grid.append((rho, t_bar, lam2, Tc))
        if best is None or Tc < best.T_convergence:
            best = PolicyResult(P, rho, t_bar, lam2, Tc)
    if best is not None:
        best.n_lp_solved = n_solved
        best.n_lp_feasible = n_feasible
        best.grid = grid
    return best


def generate_policy_matrix(
    alpha: float,
    K: int,
    R: int,
    T: np.ndarray,
    d: np.ndarray | None = None,
    eps: float = 1e-2,
    warm: BasisState | None = None,
    warm_start: bool = True,
) -> PolicyResult:
    """Algorithm 3 GENERATEPOLICYMATRIX.

    Parameters mirror the paper: learning rate alpha, outer-loop rounds K
    (grid over rho in (0, 0.5/alpha]), inner-loop rounds R (grid over t_bar),
    iteration-time matrix T.  ``d`` is the connectivity mask (default: fully
    connected on finite links — entries of T that are inf/nan are treated as
    dead links and masked out, which is how failed nodes are retired).

    ``warm`` seeds the sweep with the previous refresh's optimal basis (the
    Monitor threads this across Algorithm-1 periods); the returned
    ``PolicyResult.basis`` is the token for the next call.  A stale or
    differently-shaped token is validated and discarded by the solver, so
    callers never need to invalidate it themselves.  ``warm_start=False``
    forces every grid point to a cold solve (benchmark baseline).
    """
    T = np.asarray(T, dtype=np.float64)
    M = T.shape[0]
    if d is None:
        d = np.ones((M, M)) - np.eye(M)
    d = np.asarray(d, dtype=np.float64).copy()
    dead = ~np.isfinite(T)
    d[dead] = 0.0
    d[dead.T] = 0.0
    Tm = np.where(np.isfinite(T), T, 0.0)

    # Fault tolerance: isolated workers (all links dead) are excluded from
    # the optimization; the policy is solved on the live subgraph and
    # embedded back (dead rows/cols zero).  lambda2 then measures consensus
    # of the *live* replicas, which is what convergence means post-failure.
    np.fill_diagonal(d, 0.0)
    live = np.where(d.sum(axis=1) > 0)[0]
    if 0 < live.size < M:
        sub = generate_policy_matrix(
            alpha, K, R, Tm[np.ix_(live, live)], d[np.ix_(live, live)], eps,
            warm=warm,  # shape-checked by the solver; free if stale
            warm_start=warm_start,
        )
        P = np.zeros((M, M))
        P[np.ix_(live, live)] = sub.P
        return PolicyResult(
            P, sub.rho, sub.t_bar, sub.lambda2, sub.T_convergence,
            sub.n_lp_solved, sub.n_lp_feasible, sub.grid,
            basis=sub.basis, n_pivots=sub.n_pivots,
            n_warm_used=sub.n_warm_used, n_solves=sub.n_solves,
        )

    U_rho = _rho_grid_upper(alpha, Tm, d)
    delta = U_rho / K
    carry = WarmStartCarry(basis=warm, enabled=warm_start)
    inst = _build_eq14(Tm, d)  # one constraint matrix for the whole sweep
    best: PolicyResult | None = None
    all_grid = []
    for k in range(1, K + 1):
        rho = k * delta
        # Across rho steps only the Eq.-11 bound floors change: the carry's
        # basis stays dual-feasible and restarts in a handful of pivots.
        res = inner_loop(alpha, rho, R, Tm, d, eps, carry=carry, inst=inst)
        if res is None:
            continue
        all_grid.extend(res.grid)
        if best is None or res.T_convergence < best.T_convergence:
            best = res
    if best is None:
        # No feasible grid point (e.g. alpha*rho floor too high everywhere):
        # fall back to the uniform policy — still convergent (Thm 1), just
        # not time-optimized.  The Monitor logs this condition.
        P = uniform_policy(d)
        rho = 0.25 / alpha / max(1.0, d.sum(axis=1).max())
        Y = consensus.build_Y(P, alpha, rho, d)
        lam2 = theory.lambda2(Y)
        tbar = float(consensus.mean_iteration_times(P, Tm, d).mean())
        best = PolicyResult(P, rho, tbar, lam2, theory.convergence_time(tbar, lam2, eps))
    best.grid = all_grid
    best.basis = carry.basis
    best.n_pivots = carry.n_pivots
    best.n_warm_used = carry.n_warm_used
    best.n_solves = carry.n_solves
    return best


def generate_policy_matrix_batched(
    alpha: float,
    K: int,
    R: int,
    T: np.ndarray,
    d: np.ndarray | None = None,
    eps: float = 1e-2,
    backend: str = "numpy",
) -> PolicyResult:
    """Algorithm 3 with the whole (rho, t_bar) grid solved in one dispatch.

    Semantically ``generate_policy_matrix`` (same grid, same feasibility
    pre-filter, same scoring), but every surviving grid point becomes one
    instance of a lockstep batched simplex (``repro.solver.batch``) — all
    points price and ratio-test together in stacked GEMMs — and all
    feasible policies are scored with a single stacked ``eigvalsh``.

    ``backend`` selects the lockstep engine: ``"numpy"`` (default) is the
    host path; ``"jax"`` routes the same stack through the jitted
    ``repro.solver.batch_jax`` device program (masked ``lax.while_loop``
    termination, batched einsum FTRAN/BTRAN) — same pivot rules, so both
    backends pick the same grid point (pinned in tests/test_revised.py).

    Numerics follow a different summation order than the serial sweep, so
    the selected grid point matches the serial path up to solver tolerance
    (exactly, away from near-ties), not bit-for-bit — engine-parity
    callers keep the serial path.  Best suited to small/medium M where the
    grid, not one LP, dominates; at large M the serial warm-start sweep's
    dual restarts are cheaper than lockstep cold starts.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown batched-sweep backend {backend!r}")
    T = np.asarray(T, dtype=np.float64)
    M = T.shape[0]
    if d is None:
        d = np.ones((M, M)) - np.eye(M)
    d = np.asarray(d, dtype=np.float64).copy()
    dead = ~np.isfinite(T)
    d[dead] = 0.0
    d[dead.T] = 0.0
    Tm = np.where(np.isfinite(T), T, 0.0)
    np.fill_diagonal(d, 0.0)
    live = np.where(d.sum(axis=1) > 0)[0]
    if 0 < live.size < M:
        sub = generate_policy_matrix_batched(
            alpha, K, R, Tm[np.ix_(live, live)], d[np.ix_(live, live)], eps,
            backend=backend,
        )
        P = np.zeros((M, M))
        P[np.ix_(live, live)] = sub.P
        return PolicyResult(
            P, sub.rho, sub.t_bar, sub.lambda2, sub.T_convergence,
            sub.n_lp_solved, sub.n_lp_feasible, sub.grid,
            basis=sub.basis, n_pivots=sub.n_pivots,
            n_warm_used=sub.n_warm_used, n_solves=sub.n_solves,
        )

    U_rho = _rho_grid_upper(alpha, Tm, d)
    delta = U_rho / K
    inst = _build_eq14(Tm, d)
    cand: list[tuple[float, float]] = []
    grid: list = []
    for k in range(1, K + 1):
        rho = k * delta
        L, U = _t_bar_interval(Tm, d, alpha, rho)
        if not np.isfinite(U) or U <= L:
            continue
        lo, hi = _eq14_time_bounds(Tm, d, alpha, rho)
        for r in range(1, R + 1):
            t_bar = L + (U - L) * r / R
            target = M * t_bar
            tol = 1e-6 * max(1.0, abs(target))
            if target < lo - tol or target > hi + tol:
                grid.append((rho, t_bar, None, np.inf))
            else:
                cand.append((rho, t_bar))

    best: PolicyResult | None = None
    n_pivots = 0
    n_feasible = 0
    if cand:
        if backend == "jax":
            from repro.solver.batch_jax import solve_lp_batch_jax as _batch
        else:
            from repro.solver.batch import solve_lp_batch as _batch

        S = len(cand)
        rho_s = np.array([c0 for c0, _ in cand])
        tb_s = np.array([c1 for _, c1 in cand])
        b = np.zeros((S, 2 * M))
        b[:, :M] = (M * tb_s)[:, None]
        b[:, M:] = 1.0
        lb = np.zeros((S, inst.n))
        lb[:, inst.pos] = (
            alpha * rho_s[:, None] * inst.dsym[None, :] + _FLOOR_MARGIN
        )
        results = _batch(inst.c, inst.A, b, lb_stack=lb, ub_stack=inst.ub)
        n_pivots = int(sum(r.pivots for r in results))
        Ps, feas = [], []
        for s, res in enumerate(results):
            if not res.ok:
                grid.append((rho_s[s], tb_s[s], None, np.inf))
                continue
            x = np.maximum(res.x, 0.0)
            P = np.zeros((M, M))
            P[inst.ii, inst.mm] = x[inst.pos]
            P[np.arange(M), np.arange(M)] = x[inst.start]
            Ps.append(P)
            feas.append(s)
        n_feasible = len(feas)
        if feas:
            Ys = np.stack([
                consensus.build_Y(P, alpha, rho_s[s], d)
                for P, s in zip(Ps, feas)
            ])
            ev = np.linalg.eigvalsh(Ys)  # one stacked decomposition
            lam2 = ev[:, -2] if M >= 2 else ev[:, -1]
            for P, s, l2 in zip(Ps, feas, lam2):
                Tc = theory.convergence_time(tb_s[s], float(l2), eps)
                grid.append((rho_s[s], tb_s[s], float(l2), Tc))
                if best is None or Tc < best.T_convergence:
                    best = PolicyResult(
                        P, float(rho_s[s]), float(tb_s[s]), float(l2), Tc
                    )
    if best is None:
        P = uniform_policy(d)
        rho = 0.25 / alpha / max(1.0, d.sum(axis=1).max())
        Y = consensus.build_Y(P, alpha, rho, d)
        lam2 = theory.lambda2(Y)
        tbar = float(consensus.mean_iteration_times(P, Tm, d).mean())
        best = PolicyResult(
            P, rho, tbar, lam2, theory.convergence_time(tbar, lam2, eps)
        )
    best.n_lp_solved = len(cand)
    best.n_lp_feasible = n_feasible
    best.grid = grid
    best.n_pivots = n_pivots
    best.n_solves = len(cand)
    return best


def connectivity_key(d: np.ndarray) -> bytes:
    """Fingerprint of an effective edge set (who may talk to whom).

    An optimal-basis warm start is only meaningful across solves that share
    the same variable layout — the Eq.-14 LP's variables are the live edges
    of ``d`` — so a caller threading ``PolicyResult.basis`` across refreshes
    must drop it whenever this key changes (live set shrank, links masked).
    The solver's shape validation would also reject a stale basis, but that
    is a fallback, not a contract; the Monitor invalidates explicitly.
    """
    return np.ascontiguousarray(d != 0).tobytes()


def uniform_policy(d: np.ndarray) -> np.ndarray:
    """AD-PSGD-style uniform neighbor selection (no self-loops)."""
    M = d.shape[0]
    mask = (d != 0) & ~np.eye(M, dtype=bool)
    cnt = mask.sum(axis=1)
    P = np.zeros((M, M))
    rows = cnt > 0
    P[rows] = mask[rows] / cnt[rows, None]
    return P
