"""NetMax core: the paper's primary contribution.

Submodules (import directly, e.g. ``from repro.core import policy``):

- consensus: two-step consensus SGD update (Alg. 2), D^k / Y_P math (§IV)
- policy: communication policy generation (Alg. 3) via grid search + LP
- monitor: Network Monitor (Alg. 1) + worker-side iteration-time EMA
- theory: convergence bounds (Thm 1/2/3), approximation ratio (App. B)
- matching: Birkhoff matched gossip rounds (beyond paper)
- compression: sparsified/quantized pulls + error feedback (beyond paper)
- nettime: heterogeneous link-time model
"""
