"""Convergence theory (paper §IV + Appendices A/B).

Second-eigenvalue machinery, the deviation bound of Theorems 1/2, the
convergence-time objective k*t_bar used by Algorithm 3, and the
approximation-ratio bound of Appendix B.
"""

from __future__ import annotations

import numpy as np


def lambda2(Y: np.ndarray) -> float:
    """Second largest eigenvalue of the (symmetric) second-moment matrix."""
    ev = np.linalg.eigvalsh(Y)
    return float(ev[-2]) if ev.shape[0] >= 2 else float(ev[-1])


def lambda1(Y: np.ndarray) -> float:
    ev = np.linalg.eigvalsh(Y)
    return float(ev[-1])


def is_doubly_stochastic(Y: np.ndarray, tol: float = 1e-6) -> bool:
    return bool(
        np.all(Y >= -tol)
        and np.allclose(Y.sum(axis=0), 1.0, atol=1e-5)
        and np.allclose(Y.sum(axis=1), 1.0, atol=1e-5)
    )


def effective_lambda(Y: np.ndarray) -> float:
    """lambda = lambda2 if Y is doubly stochastic else lambda1 (paper §IV)."""
    return lambda2(Y) if is_doubly_stochastic(Y) else lambda1(Y)


def deviation_bound(lam: float, dev0: float, alpha: float, sigma: float, k: int) -> float:
    """RHS of Eq. (23)/(24): lam^k * dev0 + alpha^2 sigma^2 lam/(1-lam)."""
    if lam >= 1.0:
        return float("inf")
    return lam**k * dev0 + alpha**2 * sigma**2 * lam / (1.0 - lam)


def convergence_steps(lam: float, eps: float) -> float:
    """Smallest k with lam^k <= eps (Eq. 9)."""
    if lam <= 0.0:
        return 1.0
    if lam >= 1.0:
        return float("inf")
    return np.log(eps) / np.log(lam)


def convergence_time(t_bar: float, lam: float, eps: float) -> float:
    """T_conv = t_bar * ln(eps)/ln(lambda)  (Algorithm 3 line 21)."""
    return t_bar * convergence_steps(lam, eps)


def global_step_time(P: np.ndarray, T: np.ndarray, d: np.ndarray) -> float:
    """Expected duration of one *global* step for an arbitrary policy.

    Workers iterate concurrently; global steps arrive at combined rate
    sum_i 1/t_bar_i, so t_bar_global = 1/sum_i(1/t_bar_i).  For an
    Algorithm-3 policy (t_bar_i = M*t_bar for all i) this reduces to t_bar.
    """
    from repro.core.consensus import mean_iteration_times

    tbar = mean_iteration_times(P, T, d)
    rates = np.where(tbar > 0, 1.0 / np.maximum(tbar, 1e-300), 0.0)
    s = rates.sum()
    return float(1.0 / s) if s > 0 else float("inf")


def approximation_ratio(U: float, L: float, M: int, a: float) -> float:
    """Appendix-B bound Eq. (38) for a fully-connected heterogeneous graph.

    ratio <= (U/L) * [ln(M-1) - ln(M-3)] / [ln(1-2a+a^M) - ln(1-2a+a^(M+1))]
    where a is the minimum positive entry of Y_P.  Requires M > 3, 0<a<1.
    """
    if M <= 3 or not (0.0 < a < 1.0) or L <= 0.0:
        return float("inf")
    num = np.log(M - 1.0) - np.log(M - 3.0)
    # den = ln(1-2a+a^M) - ln(1-2a+a^(M+1)); for small a the difference
    # underflows in direct form, so compute via log1p of the exact ratio.
    den = np.log1p((a**M - a ** (M + 1)) / (1.0 - 2.0 * a + a ** (M + 1)))
    if den <= 0.0:
        return float("inf")
    return float((U / L) * num / den)


def lambda2_lower_bound(M: int) -> float:
    """Eq. (34): lambda2 >= (M-3)/(M-1) on a fully-connected graph."""
    return (M - 3.0) / (M - 1.0)


def lambda2_upper_bound(a: float, M: int) -> float:
    """Eq. (35): Kirkland cycle bound given minimum positive entry a."""
    return (1.0 - 2.0 * a + a ** (M + 1)) / (1.0 - 2.0 * a + a**M)
