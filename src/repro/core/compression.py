"""Gossip compression: sparsified / quantized pulls with error feedback.

Beyond-paper distributed-optimization tricks (DESIGN.md §8.3/8.5).  The
consensus mix moves ``w * (x_pull - x_half)``; compressing that delta before
it crosses a slow link cuts collective bytes by the compression ratio.  Error
feedback (Karimireddy et al. style memory) keeps the compression unbiased in
the long run so the Thm-1 analysis degrades gracefully (bounded extra noise
absorbed into sigma^2).

All ops are jit-friendly and pytree-polymorphic.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))
    return flat, (treedef, shapes, sizes)

def _unflatten(flat, spec):
    treedef, shapes, sizes = spec
    leaves = []
    off = 0
    for shp, sz in zip(shapes, sizes):
        leaves.append(flat[off : off + sz].reshape(shp))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, leaves)


@partial(jax.jit, static_argnames=("k",))
def topk_mask(flat: jnp.ndarray, k: int) -> jnp.ndarray:
    """Keep the k largest-magnitude entries, zero the rest."""
    if k >= flat.size:
        return flat
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return flat * mask


def randk_mask(flat: jnp.ndarray, k: int, key: jax.Array) -> jnp.ndarray:
    """Keep k uniformly random entries, rescaled to stay unbiased."""
    if k >= flat.size:
        return flat
    idx = jax.random.choice(key, flat.size, shape=(k,), replace=False)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return flat * mask * (flat.size / k)


def quantize_int8(flat: jnp.ndarray, key: jax.Array | None = None):
    """Symmetric int8 quantization with optional stochastic rounding."""
    scale = jnp.maximum(jnp.abs(flat).max(), 1e-12) / 127.0
    x = flat / scale
    if key is not None:
        x = jnp.floor(x + jax.random.uniform(key, x.shape))
    else:
        x = jnp.round(x)
    q = jnp.clip(x, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class ErrorFeedback:
    """Per-worker error-feedback memory for compressed gossip deltas.

    usage:
        delta = pulled - x_half                      # what we want to send
        sent, state = ef.compress(delta + state)     # compress with memory
        state captures what was dropped; next round re-injects it.
    """

    def __init__(self, ratio: float = 0.01, mode: str = "topk"):
        assert mode in ("topk", "randk")
        self.ratio = float(ratio)
        self.mode = mode

    def init_state(self, tree):
        return jax.tree_util.tree_map(jnp.zeros_like, tree)

    def compress(self, delta_tree, state_tree, key: jax.Array | None = None):
        flat, spec = _flatten(delta_tree)
        sflat, _ = _flatten(state_tree)
        target = flat + sflat
        k = max(1, int(self.ratio * target.size))
        if self.mode == "topk":
            sent = topk_mask(target, k)
        else:
            assert key is not None, "randk needs a PRNG key"
            sent = randk_mask(target, k, key)
        new_state = target - sent
        return _unflatten(sent, spec), _unflatten(new_state, spec)

    def bytes_ratio(self) -> float:
        """Approximate wire-bytes ratio (values + int32 indices vs dense f32)."""
        return self.ratio * 2.0  # value + index per kept entry
