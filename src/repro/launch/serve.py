"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the batched KV-cache engine on a reduced config (CPU) or the full
config under the production mesh (TPU).  The decode step function is the
exact program the dry-run lowers for decode_32k / long_500k.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.configs.base import get_arch
    from repro.models import lm
    from repro.serve.engine import Request, ServeEngine

    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_capacity=args.batch, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"arch={cfg.name} served {len(done)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s, batch={args.batch})")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt={r.prompt.tolist()} -> {r.out}")


if __name__ == "__main__":
    main()
