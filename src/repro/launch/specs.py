"""input_specs(): ShapeDtypeStruct stand-ins for every lowered program.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
``train_step`` / ``serve_prefill`` / ``serve_step`` against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.models import lm


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec, M: int) -> dict:
    """Stacked training batch: leaves (M, B/M, ...)."""
    B = shape.global_batch
    assert B % M == 0, f"global_batch {B} not divisible by {M} workers"
    b = B // M
    S = shape.seq_len
    s_text = S - cfg.n_vis_tokens if cfg.n_vis_tokens else S
    out = {
        "tokens": _sds((M, b, s_text), jnp.int32),
        "labels": _sds((M, b, s_text), jnp.int32),
    }
    if cfg.n_vis_tokens:
        out["vis_embeds"] = _sds((M, b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        out["frames"] = _sds((M, b, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    return out


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    s_text = S - cfg.n_vis_tokens if cfg.n_vis_tokens else S
    out = {"tokens": _sds((B, s_text), jnp.int32)}
    if cfg.n_vis_tokens:
        out["vis_embeds"] = _sds((B, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        out["frames"] = _sds((B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    cache = jax.tree_util.tree_map(
        lambda l: _sds(l.shape, l.dtype), lm.abstract_cache(cfg, B, S)
    )
    return {
        "cache": cache,
        "token": _sds((B,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def gossip_specs(M: int) -> dict:
    return {
        "neighbors": _sds((M,), jnp.int32),
        "weights": _sds((M,), jnp.float32),
        "lr": _sds((), jnp.float32),
    }


def input_specs(cfg: ArchConfig, shape_name: str, M: int, optimizer) -> dict:
    """All inputs for the program selected by the shape's kind."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        from repro.train.trainer import abstract_stacked

        params, opt_state = abstract_stacked(cfg, optimizer, M)
        return {
            "params": params,
            "opt_state": opt_state,
            "batch": train_batch_specs(cfg, shape, M),
            "gossip_in": gossip_specs(M),
        }
    params = jax.tree_util.tree_map(
        lambda l: _sds(l.shape, l.dtype), lm.abstract_params(cfg)
    )
    if shape.kind == "prefill":
        return {"params": params, "batch": prefill_batch_specs(cfg, shape)}
    return {"params": params, **decode_specs(cfg, shape)}
