import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init).  For each cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves the sharding plan (worker axes / TP / FSDP — dist.sharding),
  3. lowers + compiles the program against ShapeDtypeStruct inputs,
  4. prints memory_analysis() + cost_analysis(),
  5. runs the HLO cost model (analysis.hlo) for trip-count-correct FLOPs /
     bytes / per-collective bytes, and
  6. appends a JSON record under artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--gossip ppermute]
"""

import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.analysis.hlo import HloCostModel
from repro.configs.base import SHAPES, all_archs
from repro.dist import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import sgd

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _opt_state_specs(opt_state_abstract, pspecs):
    """Momentum trees mirror params; scalars replicate."""
    from jax.sharding import PartitionSpec as P

    out = {}
    for k, v in opt_state_abstract.items():
        out[k] = pspecs if k in ("m", "v") else P()
    return out


def build_lowered(cfg, shape_name, mesh, gossip_mode="ppermute"):
    """Returns (lowered, meta) for one cell."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    shape = SHAPES[shape_name]
    optimizer = sgd(momentum=0.9, weight_decay=1e-4)
    ns = lambda s: NamedSharding(mesh, s)

    if shape.kind == "train":
        plan = shd.plan_for(cfg, mesh)
        M = max(plan.n_workers, 1)
        waxes = plan.worker_axes
        inputs = sp.input_specs(cfg, shape_name, M, optimizer)
        pspecs = shd.param_specs(cfg, inputs["params"], plan, stacked=True)
        ospecs = _opt_state_specs(inputs["opt_state"], pspecs)
        bspecs = shd.batch_specs(cfg, plan, shape, stacked=True)
        gspecs = {k: P() for k in inputs["gossip_in"]}

        from repro.train.trainer import TrainStepConfig, make_train_step

        mode = gossip_mode if M > 1 else "none"
        step_cfg = TrainStepConfig(gossip_mode=mode)
        perm = tuple((i + 1) % M for i in range(M)) if mode == "ppermute" else None
        train_step = make_train_step(
            cfg, optimizer, M, step_cfg, mesh=mesh, worker_axes=waxes,
            param_specs=pspecs,
        )
        fn = lambda params, opt_state, batch, gossip_in: train_step(
            params, opt_state, batch, gossip_in, perm=perm
        )
        in_sh = (
            jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(ns, ospecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(ns, bspecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(ns, gspecs, is_leaf=lambda x: isinstance(x, P)),
        )
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0, 1))
        lowered = jitted.lower(
            inputs["params"], inputs["opt_state"], inputs["batch"], inputs["gossip_in"]
        )
        meta = dict(M=M, mode=mode, program="train_step")
        return lowered, meta

    plan = shd.plan_for(cfg, mesh, serve=True)
    inputs = sp.input_specs(cfg, shape_name, 1, optimizer)
    pspecs = shd.param_specs(cfg, inputs["params"], plan, stacked=False)
    p_sh = jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        bspecs = shd.prefill_batch_specs(cfg, plan, inputs["batch"])
        b_sh = jax.tree_util.tree_map(ns, bspecs, is_leaf=lambda x: isinstance(x, P))
        fn = lambda params, batch: lm.prefill_logits(params, batch, cfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(inputs["params"], inputs["batch"])
        return lowered, dict(M=1, mode="serve", program="serve_prefill")

    cspecs = shd.cache_specs(cfg, inputs["cache"], plan, shape.global_batch)
    c_sh = jax.tree_util.tree_map(ns, cspecs, is_leaf=lambda x: isinstance(x, P))
    t_sh = ns(shd.serve_batch_spec(plan, shape.global_batch))
    fn = lambda params, cache, token, pos: lm.decode_step(params, cache, token, pos, cfg)
    jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh, ns(P())), donate_argnums=(1,))
    lowered = jitted.lower(inputs["params"], inputs["cache"], inputs["token"], inputs["pos"])
    return lowered, dict(M=1, mode="serve", program="serve_step")


def apply_opt_flags(cfg, opt: str):
    """§Perf hillclimb variants, applied on top of the baseline config.

    noselect  — drop the redundant causal carry select in chunked attention
    padheads  — zero-init inert heads to the next TP multiple (unlocks head
                sharding for archs with H %% 16 != 0: llama4/starcoder/
                internvl/whisper)
    dpworkers — enumerate workers over ALL non-pod... all mesh axes (pure
                NetMax-DP, TP=1): eliminates TP activation psums for small
                models at the cost of per-worker replica memory
    nogossip  — ablation: local SGD only (isolates gossip collective cost)
    """
    from dataclasses import replace

    from repro.models import attention as attn_mod

    for flag in filter(None, opt.split(",")):
        if flag == "noselect":
            attn_mod.CAUSAL_CARRY_SELECT = False
        elif flag == "dpworkers":
            cfg = replace(cfg, worker_axes=("pod", "data", "model"))
        elif flag == "padheads":
            tp = 16
            He = -(-cfg.n_heads // tp) * tp  # next multiple of tp
            if (He - cfg.n_heads) % cfg.n_kv_heads == 0:
                cfg = replace(cfg, pad_heads=He - cfg.n_heads)
            else:
                # MHA-style: pad q and kv together (whisper 12 -> 16).
                pkv = (-cfg.n_kv_heads) % tp
                g = cfg.n_heads // cfg.n_kv_heads
                cfg = replace(cfg, pad_heads=pkv * g, pad_kv_heads=pkv)
        elif flag == "nogossip":
            pass  # handled via gossip_mode
        else:
            raise ValueError(f"unknown opt flag {flag!r}")
    return cfg


def run_cell(arch, shape_name, multi_pod, gossip_mode="ppermute", save_hlo=False,
             quiet=False, opt=""):
    cfg = all_archs()[arch]
    if opt:
        cfg = apply_opt_flags(cfg, opt)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = dict(
        arch=arch, shape=shape_name, mesh=mesh_name, gossip=gossip_mode,
        opt=opt, ok=False, skipped=False,
    )
    if not cfg.supports(shape):
        rec.update(skipped=True, reason="full-attention arch at 500k context (DESIGN.md §4)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    t0 = time.time()
    try:
        lowered, meta = build_lowered(cfg, shape_name, mesh, gossip_mode)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                if hasattr(ma, k):
                    mem[k] = int(getattr(ma, k))
        except Exception as e:  # CPU backend may not implement it
            mem["error"] = str(e)
        ca = {}
        try:
            raw = compiled.cost_analysis()
            ca = {k: float(v) for k, v in raw.items() if isinstance(v, (int, float))}
        except Exception as e:
            ca["error"] = str(e)
        hlo_text = compiled.as_text()
        rep = HloCostModel(hlo_text).entry_cost()
        rec.update(
            ok=True,
            chips=n_chips,
            M=meta["M"],
            program=meta["program"],
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory_analysis=mem,
            cost_analysis_raw={k: ca.get(k) for k in ("flops", "bytes accessed") if k in ca},
            hlo_flops_per_device=rep.flops,
            hlo_bytes_per_device=rep.bytes_accessed,
            collective_bytes_per_device=rep.collective_bytes,
            collective_count=rep.collective_count,
            unknown_trip_loops=rep.unknown_trip_loops,
            hlo_size_chars=len(hlo_text),
            params=lm.param_count(cfg),
            active_params=lm.active_param_count(cfg),
        )
        if save_hlo:
            ARTIFACTS.mkdir(parents=True, exist_ok=True)
            suffix = f"_{opt.replace(',', '+')}" if opt else ""
            with gzip.open(
                ARTIFACTS / f"{mesh_name}_{arch}_{shape_name}{suffix}.hlo.gz", "wt"
            ) as f:
                f.write(hlo_text)
        if not quiet:
            print(f"[{mesh_name}|{arch}|{shape_name}] OK compile={t_compile:.1f}s "
                  f"flops/dev={rep.flops:.3e} bytes/dev={rep.bytes_accessed:.3e} "
                  f"coll={rep.collective_bytes}")
            print("  memory_analysis:", mem)
            print("  cost_analysis:", rec["cost_analysis_raw"])
    except Exception as e:
        rec.update(error=f"{type(e).__name__}: {e}", traceback=traceback.format_exc()[-2000:])
        if not quiet:
            print(f"[{mesh_name}|{arch}|{shape_name}] FAIL: {e}")
    return rec


def reanalyze(records_path: str) -> None:
    """Re-run the HLO cost model over saved .hlo.gz artifacts (no recompiles)."""
    recs = []
    with open(records_path) as f:
        for line in f:
            recs.append(json.loads(line))
    out = []
    for rec in recs:
        p = ARTIFACTS / f"{rec['mesh']}_{rec['arch']}_{rec['shape']}.hlo.gz"
        if rec.get("ok") and p.exists():
            with gzip.open(p, "rt") as f:
                rep = HloCostModel(f.read()).entry_cost()
            rec.update(
                hlo_flops_per_device=rep.flops,
                hlo_bytes_per_device=rep.bytes_accessed,
                collective_bytes_per_device=rep.collective_bytes,
                collective_count=rep.collective_count,
                unknown_trip_loops=rep.unknown_trip_loops,
            )
            print(f"reanalyzed {rec['mesh']}|{rec['arch']}|{rec['shape']}: "
                  f"flops={rep.flops:.3e} bytes={rep.bytes_accessed:.3e}")
        out.append(rec)
    with open(records_path, "w") as f:
        for rec in out:
            f.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--gossip", default="ppermute",
                    choices=["ppermute", "gather", "masked_psum", "none"])
    ap.add_argument("--save-hlo", action="store_true", default=True)
    ap.add_argument("--no-save-hlo", dest="save_hlo", action="store_false")
    ap.add_argument("--reanalyze", metavar="RECORDS")
    ap.add_argument("--opt", default="", help="comma-separated hillclimb flags")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze(args.reanalyze)
        return 0

    cells = []
    archs = sorted(a for a in all_archs() if a != "netmax_paper")
    if args.all:
        for a in archs:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    for mp in meshes:
        for a, s in cells:
            rec = run_cell(a, s, mp, args.gossip, args.save_hlo, opt=args.opt)
            records.append(rec)
            if args.out:
                outp = Path(args.out)
                outp.parent.mkdir(parents=True, exist_ok=True)
                with open(outp, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["ok"] for r in records)
    n_skip = sum(r["skipped"] for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, "
          f"{len(records) - n_ok - n_skip} failed / {len(records)} cells")
    return 0 if n_ok + n_skip == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
