"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state.  The single-pod mesh is 16x16 = 256 chips (data, model);
multi-pod adds a leading pod axis: 2x16x16 = 512 chips.
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    if len(devs) == n:
        return jax.make_mesh(shape, axes, axis_types=auto)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devs)} — run under "
            "launch/dryrun.py (it forces 512 host devices) or on real hardware"
        )
    # More devices than the mesh needs (single-pod under the 512-device
    # dry-run env): build from the first n.
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(shape), axes, axis_types=auto
    )


def make_debug_mesh(n_workers: int = 2, tp: int = 1):
    """Tiny mesh for subprocess SPMD tests (host platform devices)."""
    return jax.make_mesh((n_workers, tp), ("data", "model"))


def worker_count(mesh, worker_axes: tuple) -> int:
    """Number of NetMax workers enumerated by the given mesh axes."""
    M = 1
    for ax in worker_axes:
        if ax in mesh.shape:
            M *= mesh.shape[ax]
    return M


def worker_axis_names(mesh, worker_axes: tuple) -> tuple:
    """The subset of worker_axes present in this mesh (single-pod drops 'pod')."""
    return tuple(ax for ax in worker_axes if ax in mesh.shape)
