"""Production training driver: ``python -m repro.launch.train --arch <id> ...``

Composes the full stack: arch config -> mesh/sharding plan -> NetMax trainer
(or a baseline algorithm) -> Network Monitor -> checkpoint/restart.  On real
hardware this runs under the production mesh; on CPU it runs reduced configs
for verification (--reduced).

The same step function the multi-pod dry-run lowers is executed here — there
is exactly one trainer code path.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config (default on cpu backend)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--algo", default="netmax",
                    choices=["netmax", "allreduce", "prague", "local"])
    ap.add_argument("--gossip", default="gather",
                    choices=["gather", "masked_psum", "ppermute"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--monitor-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_arch
    from repro.core import consensus
    from repro.core.monitor import IterationTimeEMA, NetworkMonitor
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.synthetic import TokenStream
    from repro.optim import sgd
    from repro.train import checkpoint as ckpt
    from repro.train.trainer import TrainStepConfig, init_stacked, make_train_step

    M = args.workers
    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced()

    opt = sgd(momentum=0.9, weight_decay=1e-4)
    from repro.algos import get_algorithm

    if args.algo == "prague":
        algo = get_algorithm("prague", trainer_groups=max(2, M // 2))
    else:
        algo = get_algorithm("netmax" if args.algo == "local" else args.algo)
    step_cfg = TrainStepConfig(
        gossip_mode="none" if args.algo in ("allreduce", "local") else args.gossip,
    )
    step_fn = jax.jit(make_train_step(cfg, opt, M, algo, step_cfg))
    stream = TokenStream(cfg.vocab_size, args.seq, args.batch_per_worker, seed=0)

    topo = Topology(M, workers_per_host=max(1, M // 2), hosts_per_pod=1)
    link = LinkTimeModel(topo, jitter=0.05, seed=1)
    monitor = NetworkMonitor(M, alpha=args.lr, K=6, R=6)
    emas = [IterationTimeEMA(M, beta=0.5) for _ in range(M)]
    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / max(M - 1, 1), 0.0)
    rho = 0.5 / (2 * args.lr * max(M - 1, 1))
    rng = np.random.default_rng(0)

    start = 0
    params, opt_state = init_stacked(cfg, opt, M, jax.random.PRNGKey(0))
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        params, opt_state, man, mon = ckpt.restore(args.ckpt, params, opt_state)
        start = man["data_cursor"].get("round", 0)
        if mon and "P" in mon:
            P, rho = np.asarray(mon["P"]), mon.get("rho", rho)
        print(f"[resume] round {start}")

    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params)) // M
    print(f"[{args.algo}] arch={cfg.name} M={M} params/worker={n/1e6:.1f}M "
          f"gossip={step_cfg.gossip_mode}")

    t_virt = 0.0
    for r in range(start, args.rounds):
        batch = {
            k: jnp.stack([jnp.asarray(stream.batch(w, r)[k]) for w in range(M)])
            for k in ("tokens", "labels")
        }
        nb, wts = consensus.sample_round(rng, P, args.lr, rho, d)
        gi = {"neighbors": jnp.asarray(nb), "weights": jnp.asarray(wts),
              "lr": jnp.float32(args.lr)}
        t0 = time.time()
        params, opt_state, m = step_fn(params, opt_state, batch, gi)
        for i in range(M):
            emas[i].update(int(nb[i]), link.iteration_time(i, int(nb[i]), now=t_virt))
        t_virt += max(link.iteration_time(i, int(nb[i]), now=t_virt) for i in range(M))

        if args.algo == "netmax" and (r + 1) % args.monitor_every == 0:
            monitor.collect({i: emas[i].snapshot() for i in range(M)})
            pol = monitor.step()
            if np.isfinite(pol.T_convergence):
                P, rho = pol.P, pol.rho
                bad = P.sum(axis=1) <= 0
                P[bad] = np.where(d[bad] > 0, 1.0 / max(M - 1, 1), 0.0)
        if (r + 1) % args.log_every == 0 or r == start:
            print(f"round {r+1:5d} loss={float(m['loss']):.4f} "
                  f"step_wall={time.time()-t0:.2f}s virt={t_virt:.1f}s")
        if args.ckpt and (r + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, r + 1, params, opt_state,
                      monitor_state={"rho": float(rho), "P": P.tolist()},
                      data_cursor={"round": r + 1})

    print("done.")


if __name__ == "__main__":
    main()
