"""Event-driven asynchronous decentralized-training simulator.

This is where the paper's *wall-clock* claims are reproduced faithfully:
each worker has its own virtual clock; one event = one Alg.-2 iteration of
one worker (grad step on its own data + pull from a sampled neighbor), with
the iteration duration drawn from the heterogeneous LinkTimeModel.  The
Network Monitor wakes on its own schedule (T_s) and republishes (P, rho).

The simulator itself is a *thin driver*: all communication semantics —
peer/group selection, mixing, timing — live in pluggable ``Algorithm``
strategies (repro.algos; DESIGN.md §1).  ``SimConfig.algorithm`` names any
registered strategy (or carries an ``Algorithm`` instance directly):

    from repro.algos import list_algorithms
    for name in list_algorithms():
        simulate(SimConfig(algorithm=name, ...), ...)

Two interchangeable engines execute every registered strategy
(``SimConfig.engine``; DESIGN.md §11-§12):

* ``"reference"`` — the original loops: one Python iteration + one jitted
  dispatch per event (async) or per worker per round (sync), per-replica
  pytrees.  Slow but maximally simple; the ground truth every strategy is
  cross-checked against.
* ``"batched"``  — the batched engine (train/engine.py): replicas stacked
  into leading-M pytrees.  Async families run causally-independent event
  cohorts in one donated vmapped call each (ps-async through its
  serialized-PS-row variant), consecutive small cohorts scan-fused into
  single dispatches; synchronous families run each round as one dispatch
  (segment-mean group averaging), rounds scan-fused between record
  boundaries.  Parity with the reference engine is pinned by
  tests/test_engines.py for every registered strategy.
* ``"auto"`` (default) — consults ``Algorithm.supports_batched`` at
  dispatch time (a capability check, not a family list): batched whenever
  the strategy supports it, reference otherwise.

Models are real JAX models (small MLPs) trained on real (synthetic) data —
losses/accuracies are measured, not modeled.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.algos import Algorithm, get_algorithm, mean_params
from repro.core.monitor import IterationTimeEMA
from repro.core.nettime import LinkTimeModel
from repro.scenarios.driver import (
    apply_action,
    attempt_fails,
    monitor_boundary,
    notify_monitor,
    prepare_monitor,
)
from repro.scenarios.timeline import ScenarioCursor
from repro.train.elastic import reseed_replica
from repro.train.events import EventHeap


# --------------------------------------------------------------------------
# Small real model: MLP classifier (pure JAX)
# --------------------------------------------------------------------------


def mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": jax.random.normal(k, (a, b)) / np.sqrt(a),
            "b": jnp.zeros((b,)),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def ce_loss(params, x, y):
    logits = mlp_apply(params, x)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


@jax.jit
def _grad_step(params, x, y, lr, momentum_state, mu):
    loss, grads = jax.value_and_grad(ce_loss)(params, x, y)
    new_m = jax.tree_util.tree_map(lambda m, g: mu * m + g, momentum_state, grads)
    new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
    return loss, new_p, new_m


# --------------------------------------------------------------------------
# Simulation
# --------------------------------------------------------------------------


@dataclass
class SimConfig:
    # Any registered strategy name (repro.algos.list_algorithms()) or an
    # Algorithm instance.
    algorithm: str | Algorithm = "netmax"
    n_workers: int = 8
    lr: float = 0.05
    momentum: float = 0.9
    rho: float | None = None  # netmax: from Monitor
    batch_size: int = 64
    total_events: int = 4000
    # Monitor schedule period T_s.  None defers to NetworkMonitor's own
    # default (the paper's 2 minutes); setting it here is the single source
    # of truth — the simulator reads the period back off the Monitor.
    monitor_period: float | None = None
    # Pin the Monitor control plane to a cluster (DESIGN.md §14/§16): when a
    # scenario partitions that cluster off, reports from the far side are
    # dropped, failure notifications are lost, and policy publishes only
    # land on reachable workers — the far side keeps training on its stale
    # policy.  None = legacy omniscient Monitor (bit-identical to history).
    monitor_home_cluster: int | None = None
    # Standby-Monitor failover (DESIGN.md §18): one standby per cluster,
    # heartbeat leases, deterministic re-election when the home cluster is
    # gone.  Requires monitor_home_cluster (an omniscient Monitor has no
    # home to fail over from).  Off = PR-7 behavior, bit-identical.
    monitor_failover: bool = False
    # Lease length in schedule periods, and the election quorum (None =
    # majority of clusters; small test topologies with 2 clusters need an
    # explicit quorum=1 because the single standby can never be a majority).
    monitor_lease_periods: float = 1.0
    monitor_quorum: int | None = None
    # Control-plane fault injection (scenarios.chaos.ChaosInjector): dropped
    # EMA reports and lost policy publishes, decided once per wake inside
    # the shared monitor_boundary — so engine parity survives chaos.  The
    # injector is stateful (rng streams advance per call); pass a fresh one
    # per run when comparing runs.
    chaos: object | None = None
    ema_beta: float = 0.5
    policy_K: int = 8
    policy_R: int = 8
    prague_group: int = 4
    prague_contention: float = 0.5
    serial_compute: bool = False  # Fig. 7 ablation: no compute/comm overlap
    uniform_policy: bool = False  # Fig. 7 ablation: no adaptive probabilities
    adaptive_weight: bool = True  # NetMax gamma weighting vs fixed 1/2
    ps_node: int = 0  # which worker doubles as the PS (ps-* algorithms)
    ps_congestion: float = 0.4
    seed: int = 0
    # Execution engine: "auto" | "reference" | "batched" (see module
    # docstring).  Explicitly requesting "batched" for a strategy whose
    # supports_batched capability check fails (exotic apply_comm or
    # reduce_groups override without a batched form) raises; "auto" routes
    # those to the reference loops.
    engine: str = "auto"
    # Batched engine only: route identity-delta mixes through the fused
    # kernels/ops.mix_rows path (Pallas gossip_mix on TPU, jnp reference on
    # CPU) instead of the tree-map leaf rule.
    use_mix_kernel: bool = False
    # Batched engine, async gossip family only: split the stacked replica
    # pytree row-wise across the local device mesh (DESIGN.md §16).  Each
    # cohort then runs as a full-M masked step — O(M/D) rows, grads, and
    # batch gathers per device — with the peer pull lowered through
    # repro.dist (lax.ppermute at one worker per mesh slot, a sharded
    # gather otherwise).  Requires n_workers % len(jax.devices()) == 0.
    shard_workers: bool = False
    # Batched engine only: fuse consecutive batch-length-homogeneous
    # cohorts (async) / rounds between record boundaries (sync) into single
    # lax.scan dispatches carrying (R, Mom), plus serial-burst scans for
    # singleton-level runs.  Off = one dispatch per cohort or round; the
    # logical cohort structure and all host-side results (times, events,
    # comm/compute) are identical either way, device math to float
    # tolerance (only SimResult.dispatches differs materially).
    fuse_chains: bool = True
    # Record a per-event trace stream in SimResult.trace_events (repro.trace;
    # DESIGN.md §15).  Purely host-side bookkeeping on values both engines
    # already compute, so the stream is part of the engine-parity contract:
    # reference and batched emit bit-identical records (pinned by
    # tests/test_engines.py).  Off by default — tracing never perturbs the
    # simulation itself.
    trace: bool = False


@dataclass
class SimResult:
    times: list = field(default_factory=list)  # virtual seconds per record
    losses: list = field(default_factory=list)  # global mean loss
    accs: list = field(default_factory=list)
    events: list = field(default_factory=list)
    comm_time: float = 0.0
    compute_time: float = 0.0
    policy_updates: int = 0
    engine: str = "reference"  # which engine produced this result
    cohorts: int = 0  # batched engine: logical cohorts (levels / rounds)
    dispatches: int = 0  # batched engine: actual device dispatches (<= cohorts
    #                      when chain fusion packs several cohorts per call)
    # Scenario telemetry (repro.scenarios), identical across engines:
    # every timed-out pull as (t, i, m), and every published policy as
    # (t, rho, P) — the bench suite reads time-to-reroute off these.
    failed_pulls: list = field(default_factory=list)
    policy_log: list = field(default_factory=list)
    # Failover telemetry (monitor_failover=True): every leadership change
    # as (t, new leader cluster), and how many scheduled refreshes were
    # skipped because no live leader held the control plane.  Identical
    # across engines (the shared monitor_boundary makes every decision).
    leader_log: list = field(default_factory=list)
    skipped_refreshes: int = 0
    # Per-event trace stream (SimConfig.trace; repro.trace): one tuple
    # ``(t_start, duration, src, dst, kind, comm, compute, net)`` per event
    # in pop order — kind in {"pull", "local", "timeout"} for async events
    # (dst = -1 when there is no peer) and "round" for synchronous rounds
    # (src = dst = -1).  ``net`` is the raw link time the event drew
    # (``Timing.net``) before any strategy multiplier — ps-async congestion,
    # netmax-topk wire ratio — which is what lets replay serve it back
    # through the link seam and re-apply the multipliers for bit-exact
    # async replay of every strategy; None when no link time was drawn.
    # Sync rounds additionally emit one "pull" (or "timeout") record per
    # link the round queried, carrying the raw network time in ``duration``
    # — that is what makes sync replay and calibration from sync traces
    # exact.  Identical across engines, like failed_pulls.
    trace_events: list = field(default_factory=list)

    def time_to_loss(self, target: float) -> float:
        for t, l in zip(self.times, self.losses):
            if l <= target:
                return t
        return float("inf")

    def final_accuracy(self) -> float:
        return self.accs[-1] if self.accs else 0.0


def traced_round_timing(algo, state, cfg, link_model, groups, t, res):
    """``algo.round_timing`` plus trace capture — shared by both engines.

    With tracing off this is a plain pass-through.  Traced, it installs
    ``link_model.query_tap`` for the duration of the call so every
    ``network_time`` query the round makes lands in ``res.trace_events``
    as a zero-duration-free per-link "pull" record (raw network time,
    comm/compute = 0), followed by the aggregate "round" record.  Links a
    scenario has killed tap as "timeout" — replay skips those queues and
    lets the scenario regenerate the stall.  The tap also fires on the
    served branch of a replayed model, so a replayed run re-emits a
    bit-identical stream.
    """
    if not cfg.trace:
        return algo.round_timing(state, cfg, link_model, groups, t)
    taps: list = []
    link_model.query_tap = lambda i, m, v, dead: taps.append((i, m, v, dead))
    try:
        timing = algo.round_timing(state, cfg, link_model, groups, t)
    finally:
        link_model.query_tap = None
    res.trace_events.extend(
        (t, v, i, m, "timeout" if dead else "pull", 0.0, 0.0, None)
        for (i, m, v, dead) in taps
    )
    res.trace_events.append(
        (t, timing.duration, -1, -1, "round", timing.comm, timing.compute,
         None)
    )
    return timing


def simulate(
    cfg: SimConfig,
    link_model: LinkTimeModel,
    data_x: np.ndarray,
    data_y: np.ndarray,
    part_idx: list[np.ndarray],
    eval_x: np.ndarray,
    eval_y: np.ndarray,
    record_every: int = 100,
    _cohort_log: list | None = None,
) -> SimResult:
    algo = get_algorithm(cfg.algorithm)
    M = cfg.n_workers
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    dims = [data_x.shape[1], 128, 64, int(data_y.max()) + 1]
    p0 = mlp_init(key, dims)

    state = algo.init_state(cfg, M)
    res = SimResult()

    # ---------------- engine selection --------------------------------------
    # "auto" consults the strategy's supports_batched *capability* at
    # dispatch time — there is no hard-coded family list, so a newly
    # registered strategy rides the batched engine as soon as its semantics
    # have a batched form.
    engine = cfg.engine
    if engine == "auto":
        engine = "batched" if algo.supports_batched else "reference"
    if engine not in ("reference", "batched"):
        raise ValueError(f"unknown engine {cfg.engine!r}")
    if engine == "batched":
        if not algo.supports_batched:
            raise ValueError(
                f"engine='batched' cannot execute {algo.name!r} "
                "(Algorithm.supports_batched is False); use engine='reference'"
            )
        from repro.train.engine import run_batched, run_batched_sync

        if algo.synchronous:
            return run_batched_sync(
                algo, cfg, state, rng, p0, link_model,
                data_x, data_y, part_idx, eval_x, eval_y,
                record_every, res,
            )
        return run_batched(
            algo, cfg, state, rng, p0, link_model,
            data_x, data_y, part_idx, eval_x, eval_y,
            record_every, res, cohort_log=_cohort_log,
        )

    replicas = [jax.tree_util.tree_map(jnp.array, p0) for _ in range(M)]
    momenta = [jax.tree_util.tree_map(jnp.zeros_like, p0) for _ in range(M)]

    def eval_now(t, ev):
        mean_p = mean_params(replicas)
        loss = float(ce_loss(mean_p, jnp.asarray(eval_x), jnp.asarray(eval_y)))
        logits = mlp_apply(mean_p, jnp.asarray(eval_x))
        acc = float((jnp.argmax(logits, -1) == jnp.asarray(eval_y)).mean())
        res.times.append(t)
        res.losses.append(loss)
        res.accs.append(acc)
        res.events.append(ev)

    def batch_for(i):
        idx = rng.choice(part_idx[i], size=min(cfg.batch_size, len(part_idx[i])))
        return jnp.asarray(data_x[idx]), jnp.asarray(data_y[idx])

    def grad_step(i):
        x, y = batch_for(i)
        loss, new_p, momenta[i] = _grad_step(
            replicas[i], x, y, cfg.lr, momenta[i], cfg.momentum
        )
        return new_p

    scn = link_model.compiled_scenario
    cursor = ScenarioCursor(scn) if scn is not None else None
    active = set(range(M))

    def reseed(w, src):
        reseed_replica(replicas, momenta, w, src)

    # ---------------- synchronous strategies: round-based loop ----------------
    if algo.synchronous:
        t = 0.0
        rounds = cfg.total_events // M
        for r in range(rounds):
            # Churn actions fire before the first round starting at or after
            # their time.  For round strategies only the rejoin reseed acts
            # here: the barrier still spans all M workers, so a departed
            # member stalls the round at the link timeout (non-adaptive
            # baselines pay; that is the paper's Fig.-7 contrast).
            if cursor is not None:
                for act in cursor.pop_due(t):
                    apply_action(act, active=active, reseed=reseed)
            groups = algo.select_groups(state, rng)
            timing = traced_round_timing(
                algo, state, cfg, link_model, groups, t, res
            )
            t += timing.duration
            res.comm_time += timing.comm
            res.compute_time += timing.compute
            for i in range(M):
                replicas[i] = grad_step(i)
            algo.reduce_groups(replicas, groups)
            if r % max(1, record_every // M) == 0:
                eval_now(t, (r + 1) * M)
        eval_now(t, rounds * M)
        return res

    # ---------------- asynchronous strategies: event-driven loop --------------
    monitor = algo.make_monitor(cfg, M, d=state.d) if algo.wants_monitor(cfg) else None
    # O(M^2) worker-side EMA state only exists to feed Monitor.collect;
    # monitor-less runs skip it (mirrors the batched engine exactly).
    emas = ([IterationTimeEMA(M, beta=cfg.ema_beta) for _ in range(M)]
            if monitor is not None else None)
    next_monitor = monitor.schedule_period if monitor else float("inf")
    prepare_monitor(monitor, link_model)

    heap = EventHeap()
    for i in range(M):
        heap.push(rng.exponential(0.005), i)
    ev = 0
    t = 0.0
    while ev < cfg.total_events:
        # Scenario churn actions fire before the first event popping at or
        # after their time (heap membership, EMA reset, replica reseed).
        if cursor is not None:
            for act in cursor.pop_due(heap.peek_time()):
                apply_action(act, active=active, reseed=reseed, rng=rng,
                             heap=heap, emas=emas, ema_beta=cfg.ema_beta)
        t, i = heap.pop()
        ev += 1

        m = algo.select_peer(state, i, rng)
        x_half = grad_step(i)
        # A pull over a scenario-dead link times out: the attempt is priced
        # (event_timing sees the timeout), nothing is mixed, and the Monitor
        # is notified so it can re-route out of schedule.
        failed = scn is not None and attempt_fails(link_model, algo, state, i, m, t)
        if failed:
            algo.apply_failed(state, cfg, replicas, i, x_half)
            res.failed_pulls.append((t, i, m))
            next_monitor = notify_monitor(
                monitor, i, m, t, next_monitor, link_model=link_model
            )
            communicated = True
        else:
            communicated = algo.apply_comm(state, cfg, replicas, i, m, x_half)
        timing = algo.event_timing(state, cfg, link_model, i, m, communicated, t)
        if cfg.trace:
            # ``failed`` first: the failed branch sets communicated=True (the
            # attempt is priced) but the record must say "timeout".
            kind = "timeout" if failed else (
                "pull" if communicated else "local"
            )
            res.trace_events.append(
                (t, timing.duration, i, m if m is not None else -1, kind,
                 timing.comm, timing.compute, timing.net)
            )
        res.comm_time += timing.comm
        res.compute_time += timing.compute
        if emas is not None and algo.reports_ema and m is not None:
            emas[i].update(m, timing.duration)

        heap.push(t + timing.duration, i)

        # Network Monitor wakes every T_s (period owned by the Monitor) or
        # at an out-of-schedule failure-triggered refresh.
        if monitor is not None and t >= next_monitor:
            # Failover tick + chaos + collect + step + publish, shared with
            # the batched loop (scenarios/driver); None = refresh skipped
            # because the leader's cluster is dead and no quorum elected.
            pol = monitor_boundary(
                monitor, algo, state, link_model, emas, active, t,
                chaos=cfg.chaos,
            )
            if pol is not None:
                res.policy_updates += 1
                res.policy_log.append((t, pol.rho, pol.P.copy()))
            next_monitor += monitor.schedule_period

        if ev % record_every == 0:
            eval_now(t, ev)
    eval_now(t, ev)
    if monitor is not None and monitor.failover is not None:
        res.leader_log = list(monitor.failover.leader_log)
        res.skipped_refreshes = monitor.failover.n_skipped_refreshes
    return res
