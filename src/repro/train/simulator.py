"""Event-driven asynchronous decentralized-training simulator.

This is where the paper's *wall-clock* claims are reproduced faithfully:
each worker has its own virtual clock; one event = one Alg.-2 iteration of
one worker (grad step on its own data + pull from a sampled neighbor), with
the iteration duration drawn from the heterogeneous LinkTimeModel.  The
Network Monitor wakes on its own schedule (T_s) and republishes (P, rho).

Algorithms share the event loop and differ only in communication semantics:

  netmax     adaptive P from Alg. 3; mix weight alpha*rho*gamma_{i,m}
  adpsgd     uniform neighbor, fixed averaging weight 1/2 (Lian et al.)
  adpsgd+mon AD-PSGD with Monitor-optimized probabilities (paper §V-H)
  allreduce  synchronous: all workers step together at the slowest pace
  prague     random groups of g workers partial-allreduce per iteration
  ps-sync    parameter server, synchronous (barrier at PS)
  ps-async   parameter server, per-worker async push/pull

Models are real JAX models (small MLPs) trained on real (synthetic) data —
losses/accuracies are measured, not modeled.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus
from repro.core.monitor import IterationTimeEMA, NetworkMonitor
from repro.core.nettime import LinkTimeModel


# --------------------------------------------------------------------------
# Small real model: MLP classifier (pure JAX)
# --------------------------------------------------------------------------


def mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": jax.random.normal(k, (a, b)) / np.sqrt(a),
            "b": jnp.zeros((b,)),
        }
        for k, a, b in zip(ks, dims[:-1], dims[1:])
    ]


def mlp_apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def ce_loss(params, x, y):
    logits = mlp_apply(params, x)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (logz - gold).mean()


@jax.jit
def _grad_step(params, x, y, lr, momentum_state, mu):
    loss, grads = jax.value_and_grad(ce_loss)(params, x, y)
    new_m = jax.tree_util.tree_map(lambda m, g: mu * m + g, momentum_state, grads)
    new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
    return loss, new_p, new_m


@jax.jit
def _mix(params, pulled, w):
    return jax.tree_util.tree_map(
        lambda a, b: (1.0 - w) * a + w * b, params, pulled
    )


# --------------------------------------------------------------------------
# Simulation
# --------------------------------------------------------------------------


@dataclass
class SimConfig:
    algorithm: str = "netmax"
    n_workers: int = 8
    lr: float = 0.05
    momentum: float = 0.9
    rho: float | None = None  # netmax: from Monitor
    batch_size: int = 64
    total_events: int = 4000
    monitor_period: float = 30.0  # T_s
    ema_beta: float = 0.5
    policy_K: int = 8
    policy_R: int = 8
    prague_group: int = 4
    # Concurrent partial-allreduce groups contend for shared links (paper
    # §V-B: "concurrent executions of partial-allreduce of different groups
    # compete for the limited bandwidth capacity, resulting in network
    # congestion").  Each extra concurrent group inflates ring time by this
    # factor.
    prague_contention: float = 0.5
    serial_compute: bool = False  # Fig. 7 ablation: no compute/comm overlap
    uniform_policy: bool = False  # Fig. 7 ablation: no adaptive probabilities
    adaptive_weight: bool = True  # NetMax gamma weighting vs fixed 1/2
    ps_node: int = 0  # which worker doubles as the PS (ps-* algorithms)
    # All PS traffic funnels through one node (paper SSVI: "the training is
    # constrained by the network capacity at the parameter server").  Each
    # additional concurrent worker inflates the PS link time.
    ps_congestion: float = 0.4
    seed: int = 0


@dataclass
class SimResult:
    times: list = field(default_factory=list)  # virtual seconds per record
    losses: list = field(default_factory=list)  # global mean loss
    accs: list = field(default_factory=list)
    events: list = field(default_factory=list)
    comm_time: float = 0.0
    compute_time: float = 0.0
    policy_updates: int = 0

    def time_to_loss(self, target: float) -> float:
        for t, l in zip(self.times, self.losses):
            if l <= target:
                return t
        return float("inf")

    def final_accuracy(self) -> float:
        return self.accs[-1] if self.accs else 0.0


def _mean_params(replicas):
    return jax.tree_util.tree_map(lambda *xs: sum(xs) / len(xs), *replicas)


def simulate(
    cfg: SimConfig,
    link_model: LinkTimeModel,
    data_x: np.ndarray,
    data_y: np.ndarray,
    part_idx: list[np.ndarray],
    eval_x: np.ndarray,
    eval_y: np.ndarray,
    record_every: int = 100,
) -> SimResult:
    M = cfg.n_workers
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    dims = [data_x.shape[1], 128, 64, int(data_y.max()) + 1]
    p0 = mlp_init(key, dims)
    replicas = [jax.tree_util.tree_map(jnp.array, p0) for _ in range(M)]
    momenta = [jax.tree_util.tree_map(jnp.zeros_like, p0) for _ in range(M)]

    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / (M - 1), 0.0)
    # Initial rho: keeps w = alpha*rho*gamma <= 0.5 under the uniform policy
    # (gamma = M-1); the Monitor's Alg.-3 rho replaces it on first refresh.
    rho = cfg.rho if cfg.rho is not None else 0.5 / (2 * cfg.lr * (M - 1))
    emas = [IterationTimeEMA(M, beta=cfg.ema_beta) for _ in range(M)]
    monitor = NetworkMonitor(M, alpha=cfg.lr, K=cfg.policy_K, R=cfg.policy_R)
    use_monitor = cfg.algorithm in ("netmax", "adpsgd+mon") and not cfg.uniform_policy

    res = SimResult()

    def eval_now(t, ev):
        mean_p = _mean_params(replicas)
        loss = float(ce_loss(mean_p, jnp.asarray(eval_x), jnp.asarray(eval_y)))
        logits = mlp_apply(mean_p, jnp.asarray(eval_x))
        acc = float((jnp.argmax(logits, -1) == jnp.asarray(eval_y)).mean())
        res.times.append(t)
        res.losses.append(loss)
        res.accs.append(acc)
        res.events.append(ev)

    def batch_for(i):
        idx = rng.choice(part_idx[i], size=min(cfg.batch_size, len(part_idx[i])))
        return jnp.asarray(data_x[idx]), jnp.asarray(data_y[idx])

    # ---------------- synchronous algorithms: round-based loop ----------------
    if cfg.algorithm in ("allreduce", "prague", "ps-sync"):
        t = 0.0
        rounds = cfg.total_events // M
        for r in range(rounds):
            # compute + comm time for the round
            comp = link_model.compute_time
            if cfg.algorithm == "allreduce":
                # ring allreduce: bottlenecked by the slowest link in the ring
                ring = [(i, (i + 1) % M) for i in range(M)]
                step_t = max(link_model.iteration_time(i, j, now=t) for i, j in ring)
                comm = step_t * 2 * (M - 1) / M  # 2(M-1)/M ring phases
            elif cfg.algorithm == "prague":
                order = rng.permutation(M)
                comm = 0.0
                g = cfg.prague_group
                n_groups = max(1, M // g)
                congestion = 1.0 + cfg.prague_contention * (n_groups - 1)
                for s in range(0, M, g):
                    grp = order[s : s + g]
                    if len(grp) < 2:
                        continue
                    ring = [(int(grp[a]), int(grp[(a + 1) % len(grp)])) for a in range(len(grp))]
                    ct = max(link_model.iteration_time(i, j, now=t) for i, j in ring)
                    comm = max(comm, ct * 2 * (len(grp) - 1) / len(grp) * congestion)
            else:  # ps-sync: every worker exchanges with the PS node
                ps = cfg.ps_node
                congestion = 1.0 + cfg.ps_congestion * (M - 2)
                comm = max(
                    link_model.iteration_time(i, ps, now=t) for i in range(M) if i != ps
                ) * congestion
            t += comp + comm
            res.comm_time += comm
            res.compute_time += comp
            # parameter updates
            for i in range(M):
                x, y = batch_for(i)
                _, replicas[i], momenta[i] = _grad_step(
                    replicas[i], x, y, cfg.lr, momenta[i], cfg.momentum
                )
            if cfg.algorithm == "prague":
                for s in range(0, M, cfg.prague_group):
                    grp = [int(w) for w in order[s : s + cfg.prague_group]]
                    mean_p = _mean_params([replicas[i] for i in grp])
                    for i in grp:
                        replicas[i] = mean_p
            else:
                mean_p = _mean_params(replicas)
                for i in range(M):
                    replicas[i] = mean_p
            if r % max(1, record_every // M) == 0:
                eval_now(t, (r + 1) * M)
        eval_now(t, rounds * M)
        return res

    # ---------------- asynchronous algorithms: event-driven loop --------------
    heap = []
    for i in range(M):
        heapq.heappush(heap, (rng.exponential(0.005), i))
    next_monitor = cfg.monitor_period
    ps = cfg.ps_node
    ev = 0
    t = 0.0
    while ev < cfg.total_events:
        t, i = heapq.heappop(heap)
        ev += 1

        if cfg.algorithm == "ps-async":
            m = ps if i != ps else None
            x, y = batch_for(i)
            _, replicas[i], momenta[i] = _grad_step(
                replicas[i], x, y, cfg.lr, momenta[i], cfg.momentum
            )
            if m is not None:
                # push/pull with PS: PS absorbs then returns the average;
                # the PS link carries all M-1 workers' traffic (congestion).
                mean_p = _mix(replicas[ps], replicas[i], 0.5)
                replicas[ps] = mean_p
                replicas[i] = mean_p
                congestion = 1.0 + cfg.ps_congestion * (M - 2)
                dur = link_model.iteration_time(i, ps, now=t) * congestion
            else:
                dur = link_model.compute_time
        else:
            # gossip family: sample neighbor from P[i]
            row = P[i] / P[i].sum()
            m = int(rng.choice(M, p=row))
            x, y = batch_for(i)
            _, x_half, momenta[i] = _grad_step(
                replicas[i], x, y, cfg.lr, momenta[i], cfg.momentum
            )
            if m != i and d[i, m]:
                if cfg.algorithm == "netmax" and cfg.adaptive_weight:
                    gamma = (d[i, m] + d[m, i]) / (2 * P[i, m])
                    w = min(cfg.lr * rho * gamma, 0.9)
                else:
                    w = 0.5  # AD-PSGD fixed averaging
                replicas[i] = _mix(x_half, replicas[m], w)
                net = link_model.iteration_time(i, m, now=t)
            else:
                replicas[i] = x_half
                net = 0.0
            comp = link_model.compute_time
            dur = (comp + net) if cfg.serial_compute else max(comp, net)
            res.comm_time += net if cfg.serial_compute else max(0.0, net - comp)
            res.compute_time += comp
            emas[i].update(m, dur)

        heapq.heappush(heap, (t + dur, i))

        # Network Monitor wakes every T_s
        if use_monitor and t >= next_monitor:
            monitor.collect({j: emas[j].snapshot() for j in range(M)})
            pol = monitor.step()
            P = pol.P.copy()
            # guard: keep rows valid for sampling
            bad = P.sum(axis=1) <= 0
            P[bad] = np.where(d[bad] > 0, 1.0 / (M - 1), 0.0)
            if cfg.algorithm == "netmax":
                rho = pol.rho
            res.policy_updates += 1
            next_monitor += cfg.monitor_period

        if ev % record_every == 0:
            eval_now(t, ev)
    eval_now(t, ev)
    return res
