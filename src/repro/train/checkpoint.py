"""Checkpoint/restart: atomic, sharded-by-worker, bit-exact resume.

Layout:  <dir>/step_<N>/
           worker_<i>.npz     flattened param+opt leaves for worker i
           monitor.json       Network Monitor state (policy, EMA times)
           manifest.json      step, M, rng, data cursor, tree structure hash

Write protocol: write into step_<N>.tmp/, fsync files, atomic rename to
step_<N>/, then update LATEST (write-tmp + rename).  A crash mid-write
leaves the previous LATEST intact; partial .tmp dirs are garbage-collected
on the next save.  Restore is bit-exact (tested: resume == uninterrupted).
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        out[name] = np.asarray(leaf)
    return out


def _tree_hash(tree) -> str:
    names = sorted(_flatten_with_names(jax.eval_shape(lambda: tree)).keys()) if False else sorted(
        _flatten_with_names(tree).keys()
    )
    import hashlib

    return hashlib.sha1("|".join(names).encode()).hexdigest()[:16]


def save(
    ckpt_dir: str | Path,
    step: int,
    params,
    opt_state,
    *,
    monitor_state: dict | None = None,
    data_cursor: dict | None = None,
    worker_sharded: bool = True,
):
    """params/opt_state leaves: (M, ...) stacked over workers."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    # GC stale tmp dirs from crashed saves.
    for p in ckpt_dir.glob("step_*.tmp"):
        shutil.rmtree(p, ignore_errors=True)

    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    tmp.mkdir(parents=True)
    pflat = _flatten_with_names(params)
    oflat = _flatten_with_names(opt_state)
    M = next(iter(pflat.values())).shape[0] if (worker_sharded and pflat) else 1
    for i in range(M):
        blob = {}
        for k, v in pflat.items():
            blob[f"p/{k}"] = v[i] if worker_sharded else v
        for k, v in oflat.items():
            blob[f"o/{k}"] = v[i] if (worker_sharded and v.ndim > 0 and v.shape[:1] == (M,)) else v
        path = tmp / f"worker_{i}.npz"
        with open(path, "wb") as f:
            np.savez(f, **blob)
            f.flush()
            os.fsync(f.fileno())
    manifest = dict(
        step=step,
        n_workers=M,
        worker_sharded=worker_sharded,
        tree_hash=_tree_hash(params),
        data_cursor=data_cursor or {},
    )
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if monitor_state is not None:
        with open(tmp / "monitor.json", "w") as f:
            json.dump(monitor_state, f)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # LATEST pointer, atomically.
    lat_tmp = ckpt_dir / "LATEST.tmp"
    lat_tmp.write_text(str(step))
    os.replace(lat_tmp, ckpt_dir / "LATEST")
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str | Path, params_like, opt_like, step: int | None = None):
    """Returns (params, opt_state, manifest, monitor_state|None).

    params_like/opt_like: pytrees (e.g. abstract or current values) defining
    structure; restored arrays replace the leaves.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint in {ckpt_dir}"
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    M = manifest["n_workers"]
    sharded = manifest["worker_sharded"]
    blobs = [np.load(d / f"worker_{i}.npz") for i in range(M)]

    def rebuild(tree, prefix):
        flat_names = list(_flatten_with_names(tree).keys())
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        new_leaves = []
        for name, leaf in zip(flat_names, leaves):
            key = f"{prefix}/{name}"
            if sharded and blobs[0][key].ndim == np.asarray(leaf).ndim - 1:
                arr = np.stack([b[key] for b in blobs])
            else:
                arr = blobs[0][key]
            new_leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    params = rebuild(params_like, "p")
    opt_state = rebuild(opt_like, "o")
    mon = None
    if (d / "monitor.json").exists():
        mon = json.loads((d / "monitor.json").read_text())
    return params, opt_state, manifest, mon
