"""NetMax training step, SPMD-ready, driven by a pluggable ``Algorithm``.

``make_train_step`` builds the jit-able per-round function.  Parameters are
*stacked* over NetMax workers (leading M dim, sharded over the worker mesh
axes); one round = every worker performs one Alg.-2 iteration:

  1. per-worker grads               (vmapped value_and_grad)
  2. algorithm grad reduction       (identity | all-mean | group-mean)
  3. local optimizer step           (x_half; momenta stay worker-local)
  4. gossip pull of pre-round x     (gather | ppermute | compressed)
  5. algorithm consensus mix        (the same leaf rule the event-driven
                                     simulator applies — DESIGN.md §1)

The strategy (which peers, which weights, which reduction) comes from
``repro.algos``: pass an ``Algorithm`` instance or a registry name.  The
pre-protocol boolean flags on ``TrainStepConfig`` (``allreduce``,
``prague_groups``) still work as a deprecation shim that maps them onto
registry names; ``gossip_mode`` / ``use_gossip_mix_kernel`` / ``grad_clip``
remain *execution* options orthogonal to the strategy.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.algos import Algorithm, get_algorithm
from repro.configs.base import ArchConfig
from repro.dist import gossip
from repro.models import lm
from repro.optim import Optimizer


@dataclass(frozen=True)
class TrainStepConfig:
    gossip_mode: str = "gather"  # gather | ppermute | masked_psum | none
    allreduce: bool = False  # DEPRECATED: use algo="allreduce"
    prague_groups: int = 0  # DEPRECATED: use algo="prague"
    use_gossip_mix_kernel: bool = False  # Pallas fused mix (TPU)
    grad_clip: float = 0.0


def resolve_algorithm(algo, step_cfg: TrainStepConfig) -> Algorithm:
    """Map the caller's strategy spec (Algorithm | name | legacy flags) to an
    Algorithm instance."""
    if algo is not None and (step_cfg.allreduce or step_cfg.prague_groups > 1):
        raise ValueError(
            "conflicting strategy specs: an explicit algo was given alongside "
            "legacy TrainStepConfig flags (allreduce/prague_groups); drop the "
            "flags"
        )
    if isinstance(algo, Algorithm):
        return algo
    if isinstance(algo, str):
        return get_algorithm(algo)
    # Legacy: derive the strategy from TrainStepConfig booleans.
    if step_cfg.allreduce:
        warnings.warn(
            "TrainStepConfig(allreduce=True) is deprecated; pass "
            "algo='allreduce' to make_train_step instead",
            DeprecationWarning, stacklevel=3,
        )
        return get_algorithm("allreduce")
    if step_cfg.prague_groups > 1:
        warnings.warn(
            "TrainStepConfig(prague_groups=...) is deprecated; pass "
            "algo='prague' to make_train_step instead",
            DeprecationWarning, stacklevel=3,
        )
        return get_algorithm("prague", trainer_groups=step_cfg.prague_groups)
    # Default gossip strategy: the mixing weights arrive per-round via
    # gossip_in, so netmax covers the whole adaptive/uniform gossip family.
    return get_algorithm("netmax")


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    M: int,
    algo: Algorithm | str | TrainStepConfig | None = None,
    step_cfg: TrainStepConfig | None = None,
    mesh=None,
    worker_axes: tuple = (),
    param_specs=None,
):
    """Returns train_step(params, opt_state, batch, gossip_in) ->
    (params, opt_state, metrics).

    params/opt_state leaves: (M, ...).  batch leaves: (M, B/M, ...).
    gossip_in: {'neighbors': (M,) i32, 'weights': (M,) f32, 'lr': f32[],
                'perm': static via closure for ppermute mode}

    ``algo``: an Algorithm instance or registry name.  Passing a
    TrainStepConfig here (the pre-registry calling convention) still works:
    its flags select the strategy via the deprecation shim.
    """
    if isinstance(algo, TrainStepConfig):
        assert step_cfg is None, "pass TrainStepConfig once, not twice"
        step_cfg = algo
        algo = None
    if step_cfg is None:
        step_cfg = TrainStepConfig()
    algorithm = resolve_algorithm(algo, step_cfg)
    if not algorithm.supports_trainer:
        raise NotImplementedError(
            f"algorithm {algorithm.name!r} has no lockstep SPMD form; "
            "use the event-driven simulator (train/simulator.py) instead"
        )

    def per_worker_loss(p, b):
        return lm.loss_fn(p, b, cfg)

    vgrad = jax.vmap(jax.value_and_grad(per_worker_loss))

    def grad_fn(params, batch):
        from repro.models.scan_utils import microbatch_scan

        return microbatch_scan(vgrad, params, batch, cfg.microbatches)

    def local_step(params, opt_state, batch, lr):
        losses, grads = grad_fn(params, batch)
        if step_cfg.grad_clip:
            from repro.optim.optimizers import clip_by_global_norm

            grads, _ = clip_by_global_norm(grads, step_cfg.grad_clip)
        # Strategy-owned grad reduction: identity for gossip, global mean
        # for allreduce/ps-sync, group mean for prague.
        grads = algorithm.transform_grads(grads, M)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        x_half = optimizer.apply(params, updates)
        return losses, x_half, opt_state

    def gossip_pull(params, neighbors, perm):
        if step_cfg.gossip_mode == "gather":
            return gossip.pull_gather(params, neighbors)
        if step_cfg.gossip_mode == "masked_psum":
            return gossip.pull_masked_psum(params, neighbors, M)
        if step_cfg.gossip_mode == "ppermute":
            assert perm is not None and mesh is not None
            return gossip.pull_ppermute(params, perm, mesh, worker_axes, specs=param_specs)
        raise ValueError(step_cfg.gossip_mode)

    communicates = (
        algorithm.communicates_in_trainer
        and step_cfg.gossip_mode != "none"
        and M > 1
    )

    def train_step(params, opt_state, batch, gossip_in, *, perm=None):
        lr = gossip_in["lr"]
        losses, x_half, opt_state = local_step(params, opt_state, batch, lr)
        if communicates:
            pulled = gossip_pull(params, gossip_in["neighbors"], perm)
            if step_cfg.use_gossip_mix_kernel and type(algorithm).delta_transform is Algorithm.delta_transform:
                from repro.kernels import ops as kops

                # Fused Pallas mix — only valid for the identity delta
                # transform (the kernel hard-codes the linear mix).
                new_params = kops.gossip_mix_tree(
                    x_half, pulled, gossip_in["weights"]
                )
            else:
                new_params = algorithm.mix_stacked(
                    x_half, pulled, gossip_in["weights"]
                )
        else:
            new_params = x_half
        metrics = {"loss": losses.mean(), "loss_per_worker": losses}
        return new_params, opt_state, metrics

    return train_step


def init_stacked(cfg: ArchConfig, optimizer: Optimizer, M: int, key):
    """Initialize M worker replicas (identical start — paper Alg. 2 line 1
    uses independent x_i^0; identical init is the common practical choice
    and also what D-PSGD baselines use)."""
    params1 = lm.init_params(cfg, key)
    params = jax.tree_util.tree_map(lambda l: jnp.broadcast_to(l[None], (M,) + l.shape), params1)
    # Materialize (broadcast_to creates views; optimizer needs real buffers).
    params = jax.tree_util.tree_map(jnp.array, params)
    opt_state = optimizer.init(params)
    return params, opt_state


def abstract_stacked(cfg: ArchConfig, optimizer: Optimizer, M: int):
    """ShapeDtypeStructs for the stacked training state (dry-run)."""
    p1 = lm.abstract_params(cfg)
    stack = lambda l: jax.ShapeDtypeStruct((M,) + l.shape, l.dtype)
    params = jax.tree_util.tree_map(stack, p1)
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state
