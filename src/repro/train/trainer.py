"""NetMax training step + baseline algorithms, SPMD-ready.

``make_train_step`` builds the jit-able per-round function.  Parameters are
*stacked* over NetMax workers (leading M dim, sharded over the worker mesh
axes); one round = every worker performs one Alg.-2 iteration:

  1. per-worker grads               (vmapped value_and_grad)
  2. local optimizer step           (x_half; momenta stay worker-local)
  3. gossip pull of pre-round x     (gather | ppermute | compressed)
  4. consensus mix                  ((1-w) x_half + w pulled,
                                     w_i = alpha*rho*gamma_{i,m_i})

Baselines (same substrate, different step): Allreduce-SGD (psum grads),
AD-PSGD (uniform gossip — NetMax with a uniform policy), Prague-style
group partial-allreduce, PS-sync/async (see train/simulator.py for the
async time semantics).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import gossip
from repro.models import lm
from repro.optim import Optimizer


@dataclass(frozen=True)
class TrainStepConfig:
    gossip_mode: str = "gather"  # gather | ppermute | masked_psum | none
    allreduce: bool = False  # Allreduce-SGD baseline (replaces gossip)
    prague_groups: int = 0  # >0: Prague-style partial all-reduce groups
    use_gossip_mix_kernel: bool = False  # Pallas fused mix (TPU)
    grad_clip: float = 0.0


def make_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    M: int,
    step_cfg: TrainStepConfig = TrainStepConfig(),
    mesh=None,
    worker_axes: tuple = (),
    param_specs=None,
):
    """Returns train_step(params, opt_state, batch, gossip_in) ->
    (params, opt_state, metrics).

    params/opt_state leaves: (M, ...).  batch leaves: (M, B/M, ...).
    gossip_in: {'neighbors': (M,) i32, 'weights': (M,) f32, 'lr': f32[],
                'perm': static via closure for ppermute mode}
    """

    def per_worker_loss(p, b):
        return lm.loss_fn(p, b, cfg)

    vgrad = jax.vmap(jax.value_and_grad(per_worker_loss))

    def grad_fn(params, batch):
        from repro.models.scan_utils import microbatch_scan

        return microbatch_scan(vgrad, params, batch, cfg.microbatches)

    def local_step(params, opt_state, batch, lr):
        losses, grads = grad_fn(params, batch)
        if step_cfg.grad_clip:
            from repro.optim.optimizers import clip_by_global_norm

            grads, _ = clip_by_global_norm(grads, step_cfg.grad_clip)
        if step_cfg.allreduce:
            # Allreduce-SGD baseline: average grads across workers
            # (mean over the stacked worker dim — lowers to an all-reduce
            # along the worker mesh axes).
            grads = jax.tree_util.tree_map(
                lambda g: jnp.broadcast_to(g.mean(axis=0, keepdims=True), g.shape), grads
            )
        elif step_cfg.prague_groups > 1:
            # Prague: random group partial-allreduce.  Groups are contiguous
            # worker ranges re-randomized on the host per round via the
            # neighbors permutation; here: mean within G groups.
            G = step_cfg.prague_groups

            def group_mean(g):
                gg = g.reshape((G, M // G) + g.shape[1:])
                gg = jnp.broadcast_to(gg.mean(axis=1, keepdims=True), gg.shape)
                return gg.reshape(g.shape)

            grads = jax.tree_util.tree_map(group_mean, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        x_half = optimizer.apply(params, updates)
        return losses, x_half, opt_state

    def gossip_pull(params, neighbors, perm):
        if step_cfg.gossip_mode == "none" or M == 1:
            return params
        if step_cfg.gossip_mode == "gather":
            return gossip.pull_gather(params, neighbors)
        if step_cfg.gossip_mode == "masked_psum":
            return gossip.pull_masked_psum(params, neighbors, M)
        if step_cfg.gossip_mode == "ppermute":
            assert perm is not None and mesh is not None
            return gossip.pull_ppermute(params, perm, mesh, worker_axes, specs=param_specs)
        raise ValueError(step_cfg.gossip_mode)

    def train_step(params, opt_state, batch, gossip_in, *, perm=None):
        lr = gossip_in["lr"]
        losses, x_half, opt_state = local_step(params, opt_state, batch, lr)
        if step_cfg.allreduce or step_cfg.prague_groups > 1 or step_cfg.gossip_mode == "none":
            new_params = x_half
        else:
            pulled = gossip_pull(params, gossip_in["neighbors"], perm)
            if step_cfg.use_gossip_mix_kernel:
                from repro.kernels import ops as kops

                new_params = kops.gossip_mix_tree(
                    x_half, pulled, gossip_in["weights"]
                )
            else:
                new_params = gossip.mix(x_half, pulled, gossip_in["weights"])
        metrics = {"loss": losses.mean(), "loss_per_worker": losses}
        return new_params, opt_state, metrics

    return train_step


def init_stacked(cfg: ArchConfig, optimizer: Optimizer, M: int, key):
    """Initialize M worker replicas (identical start — paper Alg. 2 line 1
    uses independent x_i^0; identical init is the common practical choice
    and also what D-PSGD baselines use)."""
    params1 = lm.init_params(cfg, key)
    params = jax.tree_util.tree_map(lambda l: jnp.broadcast_to(l[None], (M,) + l.shape), params1)
    # Materialize (broadcast_to creates views; optimizer needs real buffers).
    params = jax.tree_util.tree_map(jnp.array, params)
    opt_state = optimizer.init(params)
    return params, opt_state


def abstract_stacked(cfg: ArchConfig, optimizer: Optimizer, M: int):
    """ShapeDtypeStructs for the stacked training state (dry-run)."""
    p1 = lm.abstract_params(cfg)
    stack = lambda l: jax.ShapeDtypeStruct((M,) + l.shape, l.dtype)
    params = jax.tree_util.tree_map(stack, p1)
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state
