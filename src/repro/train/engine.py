"""Batched cohort engine for the event-driven simulator (DESIGN.md §11).

The reference engine (train/simulator.py) executes one worker event per
Python iteration — two jitted dispatches over a per-replica pytree each —
which tops out around 8–16 workers.  This engine keeps the *exact same
host-side event machinery* (heap order, rng draw order, LinkTimeModel
draws, EMA updates, Monitor schedule) but stacks all M replicas/momenta
into leading-M pytrees and executes *cohorts* of causally-independent
events in one donated, jitted, vmapped call.

Scheduling works in two layers:

* **Windows** — events are *drawn* strictly in heap-pop order (peer
  selection, batch indices, link-time jitter, EMA updates), so every host
  rng consumes bits in exactly the reference order.  A window extends until
  the next *boundary*: a Monitor wake (the policy refresh changes
  subsequent peer draws), a ``record_every`` evaluation (which must observe
  the state after exactly that many events), or the event cap.
* **Cohorts** — each window is level-scheduled into causally-independent
  event sets.  One fused dispatch gathers every pull from *pre-cohort*
  replica rows, computes, then scatters all actor rows, so executing a
  level against pre-cohort state must be indistinguishable from the
  reference's strictly-sequential execution.  An event's level is one plus
  the maximum over its hazards, all expressed on replica rows (an event
  *writes* its actor's row and *reads* its actor + peer rows):

  1. write-after-write / read-after-write on the actor row — a worker's
     next event both rewrites and grad-reads the row its previous event
     wrote, so per-worker order is strict;
  2. read-after-write on the peer row — the reference serves a pull the
     *post*-update value of any peer event that already ran, so a pull
     must land in a strictly later level than its peer row's last write;
  3. write-after-read on the actor row — an earlier-popped pull of this
     row must not see this event's write, so the write's level is at
     least the reader's (the *same* level is fine: gathers happen before
     the scatter).

The two engines therefore produce identical `times`/`events`/`comm_time`
and near-identical losses (tests/test_engines.py pins both).

Cohorts are padded to ~1.5x-stepped size buckets (≤ M) so only O(log M)
XLA programs are compiled; pad rows use distinct idle workers with a
validity mask so the scatter is conflict-free.  The mixing math inside the fused
step is ``Algorithm.mix_stacked_tree`` — the same leaf rule the SPMD
trainer jits — or, for identity-delta strategies with
``SimConfig.use_mix_kernel``, the fused ``kernels/ops.mix_rows`` path
(Pallas ``gossip_mix_rows`` on TPU).
"""

from __future__ import annotations

import functools
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.algos.base import Algorithm
from repro.core.monitor import IterationTimeEMA
from repro.train import simulator as _sim

tree_map = jax.tree_util.tree_map

# Compiled cohort steps, keyed by (Algorithm.cache_token(), lr, momentum,
# use_mix_kernel).  Reused across simulate() calls so repeated runs (tests,
# benchmarks) don't re-trace identical programs.
_STEP_CACHE: dict = {}


def _bucket(n: int, cap: int) -> int:
    """Smallest ~1.5x-stepped bucket >= n, capped at M (pad rows must be
    distinct).  Finer than powers of two: the fused step is compute-bound,
    so padded rows are wasted FLOPs, while each extra bucket only costs one
    more (small) XLA program."""
    b = 1
    while b < n:
        b = b * 2 if b < 4 else (b * 3 + 1) // 2
    return min(b, cap)


def _make_cohort_step(algo: Algorithm, lr: float, mu: float, use_mix_kernel: bool):
    """Build the donated, jitted fused step for one strategy.

    Signature: (R, Mom, dx, dy, ints, w) -> (R, Mom) where R/Mom leaves are
    (M, ...) stacked replicas/momenta, dx/dy the device-resident training
    set, and the per-cohort operands cross the host boundary as just two
    arrays: ``ints`` (K, 3+B) i32 packing [actor row, peer row, valid,
    batch indices...] and ``w`` (K,) f32 mix weights (0 ⇒ no
    communication).  valid=0 marks padding: the row is written back
    unchanged.
    """
    vgrad = jax.vmap(jax.value_and_grad(_sim.ce_loss))
    identity_delta = type(algo).delta_transform is Algorithm.delta_transform

    def mix(x_half, pulled, w):
        if use_mix_kernel and identity_delta:
            from repro.kernels import ops as kops

            return kops.gossip_mix_tree(x_half, pulled, w)
        return algo.mix_stacked_tree(x_half, pulled, w)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def cohort_step(R, Mom, dx, dy, ints, w):
        idx, nb, valid = ints[:, 0], ints[:, 1], ints[:, 2] > 0
        xb, yb = dx[ints[:, 3:]], dy[ints[:, 3:]]
        h = tree_map(lambda l: l[idx], R)
        pulled = tree_map(lambda l: l[nb], R)  # pre-cohort peer rows
        mom = tree_map(lambda l: l[idx], Mom)
        _, grads = vgrad(h, xb, yb)
        new_m = tree_map(lambda m, g: mu * m + g, mom, grads)
        x_half = tree_map(lambda p, m: p - lr * m, h, new_m)
        mixed = mix(x_half, pulled, w)

        def keep_valid(new, old):
            v = valid.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(v, new, old)

        mixed = tree_map(keep_valid, mixed, h)
        new_m = tree_map(keep_valid, new_m, mom)
        R = tree_map(lambda l, v: l.at[idx].set(v), R, mixed)
        Mom = tree_map(lambda l, v: l.at[idx].set(v), Mom, new_m)
        return R, Mom

    return cohort_step


def _cohort_step_for(algo: Algorithm, lr: float, mu: float, use_mix_kernel: bool):
    key = (algo.cache_token(), float(lr), float(mu), bool(use_mix_kernel))
    fn = _STEP_CACHE.get(key)
    if fn is None:
        fn = _make_cohort_step(algo, lr, mu, use_mix_kernel)
        _STEP_CACHE[key] = fn
    return fn


@jax.jit
def _eval_stacked(R, eval_x, eval_y):
    mean_p = tree_map(lambda l: l.mean(axis=0), R)
    loss = _sim.ce_loss(mean_p, eval_x, eval_y)
    logits = _sim.mlp_apply(mean_p, eval_x)
    acc = (jnp.argmax(logits, -1) == eval_y).mean()
    return loss, acc


def run_batched(
    algo: Algorithm,
    cfg,
    state,
    rng: np.random.Generator,
    p0,
    link_model,
    data_x: np.ndarray,
    data_y: np.ndarray,
    part_idx,
    eval_x: np.ndarray,
    eval_y: np.ndarray,
    record_every: int,
    res,
    cohort_log: list | None = None,
):
    """Run the async event loop on stacked state; mutates and returns ``res``.

    ``cohort_log``, when a list, receives one dict per cohort (actors,
    peers, event range, boundary flag) — the scheduler-invariant tests
    introspect it.
    """
    M = cfg.n_workers
    total = cfg.total_events

    # Stacked replicas: all workers start from the same p0, like the
    # reference engine's per-replica copies.
    R = tree_map(lambda l: jnp.array(jnp.broadcast_to(l[None], (M,) + l.shape)), p0)
    Mom = tree_map(lambda l: jnp.zeros((M,) + l.shape, l.dtype), p0)
    step = _cohort_step_for(algo, cfg.lr, cfg.momentum, cfg.use_mix_kernel)

    emas = [IterationTimeEMA(M, beta=cfg.ema_beta) for _ in range(M)]
    monitor = algo.make_monitor(cfg, M, d=state.d) if algo.wants_monitor(cfg) else None
    next_monitor = monitor.schedule_period if monitor else float("inf")

    ex, ey = jnp.asarray(eval_x), jnp.asarray(eval_y)
    # Training set lives on device; per-cohort batches are gathered there
    # from (K, B) index arrays instead of shipping (K, B, D) floats.
    dx, dy = jnp.asarray(data_x), jnp.asarray(data_y)

    def eval_now(t, ev):
        loss, acc = _eval_stacked(R, ex, ey)
        res.times.append(t)
        res.losses.append(float(loss))
        res.accs.append(float(acc))
        res.events.append(ev)

    bsz = [min(cfg.batch_size, len(part_idx[i])) for i in range(M)]

    heap = []
    for i in range(M):
        heapq.heappush(heap, (rng.exponential(0.005), i))

    ev = 0
    t = 0.0
    window_cap = max(4 * M, 64)  # backstop when record_every is huge

    def draw_event():
        """Pop + fully draw the next event, consuming every host rng in
        reference order (peer, batch, link jitter, EMA, reschedule)."""
        nonlocal ev, t
        t_ev, i = heapq.heappop(heap)
        ev += 1
        m = algo.select_peer(state, i, rng)
        bidx = rng.choice(part_idx[i], size=bsz[i])
        communicated = algo.would_communicate(state, i, m)
        w = algo.mix_weight(state, cfg, i, m) if communicated else 0.0
        timing = algo.event_timing(state, cfg, link_model, i, m, communicated, t_ev)
        res.comm_time += timing.comm
        res.compute_time += timing.compute
        if algo.reports_ema and m is not None:
            emas[i].update(m, timing.duration)
        heapq.heappush(heap, (t_ev + timing.duration, i))
        t = t_ev
        return (t_ev, i, m, float(w), communicated, bidx, ev)

    def schedule_window(window):
        """Level-schedule a window into causally-independent cohorts.

        One O(1)-per-event pass in pop order; see the module docstring for
        the three hazard rules.  Returns cohorts ordered by level, each a
        pop-ordered event list with all-distinct actors; executing them in
        order with gather-before-scatter semantics reproduces the
        reference's sequential result exactly.
        """
        last_write: dict[int, int] = {}  # row -> level of its latest write
        max_read: dict[int, int] = {}  # row -> highest level that read it
        groups: list[list] = []
        level_blen: list = []  # batch length per level (one dispatch each)
        for e in window:
            _, i, m, _, communicated, bidx, _ = e
            lvl = last_write.get(i, 0) + 1  # rules 1 (WAW/RAW on actor row)
            if communicated:
                lvl = max(lvl, last_write.get(m, 0) + 1)  # rule 2 (RAW peer)
                # rule 3 bookkeeping happens below via max_read
            lvl = max(lvl, max_read.get(i, 0))  # rule 3 (WAR on actor row)
            # One fused call needs a uniform batch length, and rule 3's
            # same-level exemption is only sound if the whole level IS one
            # call (gather-before-scatter) — so batch length is part of a
            # level's identity.  Raising a level past a mismatched one is
            # always safe: every hazard above is a lower bound, and the
            # bookkeeping below records the *final* level.
            blen = len(bidx)
            while lvl <= len(level_blen) and level_blen[lvl - 1] != blen:
                lvl += 1
            last_write[i] = lvl
            if communicated:
                max_read[m] = max(max_read.get(m, 0), lvl)
            while len(groups) < lvl:  # lvl <= len(groups)+1: no gaps
                groups.append([])
                level_blen.append(blen)
            groups[lvl - 1].append(e)
        return groups

    def execute(cohort):
        """One fused dispatch for one cohort (padded to a size bucket)."""
        nonlocal R, Mom
        K = len(cohort)
        B = _bucket(K, M)
        actors = {e[1] for e in cohort}
        blen = len(cohort[0][5])
        ints = np.zeros((B, 3 + blen), np.int32)
        w = np.zeros(B, np.float32)
        for k, e in enumerate(cohort):
            # self-pull (w=0) for non-communicating events
            ints[k, 0] = e[1]
            ints[k, 1] = e[2] if e[4] else e[1]
            ints[k, 2] = 1
            ints[k, 3:] = e[5]
            w[k] = e[3]
        if B > K:  # pad rows: distinct idle workers, written back unchanged
            free = np.fromiter(
                (r for r in range(M) if r not in actors), np.int32, M - K
            )[: B - K]
            ints[K:, 0] = free
            ints[K:, 1] = free
        R, Mom = step(R, Mom, dx, dy, ints, w)
        res.cohorts += 1
        if cohort_log is not None:
            cohort_log.append([(e[6], e[1], e[2] if e[4] else None) for e in cohort])

    while ev < total:
        # ---- draw one window of events, stopping at the next boundary ----
        window = []
        while len(window) < window_cap and ev < total:
            e = draw_event()
            window.append(e)
            if (monitor is not None and e[0] >= next_monitor) or e[6] % record_every == 0:
                break
        t_last, ev_last = window[-1][0], window[-1][6]

        # ---- execute the whole window, level by level ----
        for cohort in schedule_window(window):
            execute(cohort)

        # ---- boundaries fire after the window, exactly as the reference
        # loop fires them after the boundary event (Monitor first, then the
        # periodic evaluation) ----
        if monitor is not None and t_last >= next_monitor:
            monitor.collect({j: emas[j].snapshot() for j in range(M)})
            pol = monitor.step()
            algo.on_policy(state, pol)
            res.policy_updates += 1
            next_monitor += monitor.schedule_period
        if ev_last % record_every == 0:
            eval_now(t_last, ev_last)

    eval_now(t, ev)
    res.engine = "batched"
    return res
